"""Force a multi-device CPU topology for the job-axis sharding lanes.

`XLA_FLAGS=--xla_force_host_platform_device_count=N` must land in the
environment BEFORE the JAX backend initializes, which makes it an
entry-point concern: `tests/conftest.py` (the in-process shard test
lanes), `tests/golden/regen.py` (fixture regeneration under the test
topology), `benchmarks/run.py` and `benchmarks/fleet_bench.py` (the
`--shards` sweep) all need the same guard.  THIS module is the one copy
of it — deliberately jax-free, so importing it can never initialize the
backend it is trying to configure.

Forcing more devices than a run will use is not free (each forced device
dilutes the host's intra-op thread pool, slowing single-device work), so
callers pass exactly the count they need and the guard appends only when
the caller's environment has not already forced one.
"""

import os

FLAG = "xla_force_host_platform_device_count"


def force_host_device_count(n: int = 4) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS
    unless a count is already forced.  A no-op after backend init — call
    it before anything touches a jax array."""
    if FLAG in os.environ.get("XLA_FLAGS", ""):
        return
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" --{FLAG}={int(n)}"
    ).strip()
