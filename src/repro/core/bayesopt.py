"""Bayesian-optimized iterative configuration search (paper §III-E).

Two searchers share one engine:

  * ``cherrypick_search``  — the baseline: plain Bayesian optimization with a
    Matérn-5/2 GP and Expected Improvement over the whole space (CherryPick,
    Alipourfard et al., NSDI'17): 3 random initial configs, then argmax-EI,
    stopping once max EI < 10 % of the best observed cost (and at least
    ``min_observations`` configs were tried).

  * ``ruya_search`` — the paper's contribution: the same engine, but run first
    over the memory-derived *priority group*; only after the group is
    exhausted does the search open up to the remaining configurations, with
    the GP retaining every observation made so far.

Both searchers can be run past their stopping criterion (``to_exhaustion``)
so the evaluation can measure "after how many iterations was the optimal /
near-optimal configuration first tried" (Table II) independently of when the
stop fired; the would-have-stopped iteration is recorded in the result.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import fast_bo
from repro.core.search_space import SearchSpace

__all__ = [
    "BOSettings",
    "SearchTrace",
    "cherrypick_search",
    "ruya_search",
    "trial_budget",
]

CostFn = Callable[[int], float]


@dataclasses.dataclass(frozen=True)
class BOSettings:
    n_init: int = 3  # random initial configurations (CherryPick §4)
    ei_stop_rel: float = 0.10  # stop when max EI < 10 % of best cost
    min_observations: int = 6  # don't stop before this many trials
    max_iters: Optional[int] = None
    xi: float = 0.0


@dataclasses.dataclass
class SearchTrace:
    """Complete record of one search run."""

    tried: List[int]  # config indices in trial order
    costs: List[float]  # observed costs in trial order
    stop_iteration: Optional[int]  # 1-based iteration where the criterion fired
    phase_boundary: Optional[int]  # trials made in the priority phase (Ruya)

    @property
    def best_cost(self) -> float:
        return float(np.min(self.costs))

    @property
    def best_index(self) -> int:
        return self.tried[int(np.argmin(self.costs))]

    def iterations_until(self, threshold_cost: float) -> Optional[int]:
        """1-based iteration at which a cost ≤ threshold was first observed."""
        for i, c in enumerate(self.costs):
            if c <= threshold_cost:
                return i + 1
        return None


def trial_budget(n_prio: int, n_rem: int, settings: BOSettings) -> int:
    """Per-job trial budget — and therefore the packed-buffer capacity B.

    THE single source of this formula: B sets the static (B,B)
    factorization extent of the packed BO step, so the sequential and
    batched engines must compute it identically for their float32 traces
    to stay bit-identical.  The budget floor is the scripted init count —
    the sequential engine observes every init pick before its first
    budget check.
    """
    n_init = min(settings.n_init, n_prio)
    total = n_prio + n_rem
    if settings.max_iters is not None:
        total = min(total, max(settings.max_iters, n_init))
    return total


def _bo_loop(
    space: SearchSpace,
    cost_fn: CostFn,
    rng: np.random.Generator,
    candidate_order: Sequence[Sequence[int]],
    settings: BOSettings,
    to_exhaustion: bool,
    layout: str = "feature",
) -> SearchTrace:
    """Shared engine.  ``candidate_order`` is a list of candidate *pools*;
    pool k+1 is only opened once pool k is exhausted (Ruya's two phases).
    The GP is always fit on every observation made so far."""
    n = len(space)
    tried: List[int] = []
    costs: List[float] = []
    stop_iteration: Optional[int] = None
    phase_boundary: Optional[int] = None
    encoded_all = np.asarray(space.encoded(), np.float32)

    obs_mask = np.zeros(n, bool)

    # Packed-buffer capacity: `trial_budget` is shared with the fleet
    # engine, so both factorize (B,B) systems of identical static extent —
    # a prerequisite for bit-identical traces.
    pools_raw = [list(pool) for pool in candidate_order]
    n_prio = len(pools_raw[0]) if pools_raw else 0
    n_rem = sum(len(p) for p in pools_raw[1:])
    capacity = max(trial_budget(n_prio, n_rem, settings), 1)

    # Device-resident probe over the shared fleet_step program; built lazily
    # at the first BO step (a search that only runs scripted init picks, or
    # has empty pools, never touches the device).
    probe: Optional[fast_bo.SequentialProbe] = None

    def observe(idx: int) -> None:
        c = float(cost_fn(idx))
        tried.append(idx)
        costs.append(c)
        obs_mask[idx] = True

    for phase, pool in enumerate(pools_raw):
        pool = [int(i) for i in pool if not obs_mask[i]]
        if not pool:
            continue
        if phase >= 1 and phase_boundary is None:
            phase_boundary = len(tried)

        # Random initialization only in the first phase; later phases reuse
        # the GP knowledge gained so far (paper §III-E).
        if phase == 0:
            n_init = min(settings.n_init, len(pool))
            init = rng.choice(len(pool), size=n_init, replace=False)
            for idx in (pool[int(i)] for i in init):
                observe(idx)

        cand_mask = np.zeros(n, bool)
        cand_mask[np.asarray(pool, np.int64)] = True

        if probe is not None:
            probe.set_pool(cand_mask)

        while bool(np.any(cand_mask & ~obs_mask)):
            if settings.max_iters is not None and len(tried) >= settings.max_iters:
                return SearchTrace(tried, costs, stop_iteration, phase_boundary)
            if probe is None:
                probe = fast_bo.SequentialProbe(
                    encoded_all, capacity, xi=settings.xi, layout=layout
                )
                probe.set_pool(cand_mask)
                probe.start(obs_mask, tried, costs)
            pick, max_ei, best = probe.step(costs[-1] if costs else 0.0)
            # The threshold product is rounded to float32 to match the fleet
            # engine's on-device criterion bit-for-bit (both operands of the
            # comparison are then exactly representable float32 values).
            if (
                stop_iteration is None
                and len(tried) >= settings.min_observations
                and max_ei < float(np.float32(settings.ei_stop_rel) * np.float32(best))
            ):
                stop_iteration = len(tried)
                if not to_exhaustion:
                    return SearchTrace(tried, costs, stop_iteration, phase_boundary)
            observe(pick)

    return SearchTrace(tried, costs, stop_iteration, phase_boundary)


def cherrypick_search(
    space: SearchSpace,
    cost_fn: CostFn,
    rng: np.random.Generator,
    *,
    settings: BOSettings = BOSettings(),
    to_exhaustion: bool = False,
    layout: str = "feature",
) -> SearchTrace:
    """Baseline: plain CherryPick BO over the full space.

    ``layout`` selects the packed engine's geometry path — "feature" (the
    O(n·d) feature-buffer default) or "gather" (the retained O(n²)
    d²-gather path, kept for bit-identity cross-checks).
    """
    return _bo_loop(
        space, cost_fn, rng, [list(range(len(space)))], settings,
        to_exhaustion, layout,
    )


def ruya_search(
    space: SearchSpace,
    cost_fn: CostFn,
    rng: np.random.Generator,
    priority: Sequence[int],
    remaining: Sequence[int],
    *,
    settings: BOSettings = BOSettings(),
    to_exhaustion: bool = False,
    layout: str = "feature",
) -> SearchTrace:
    """Ruya: BO over the priority group first, then over the remaining space.

    With an empty ``remaining`` (unclear jobs, or a requirement every config
    satisfies) this degrades exactly to the baseline — the paper's fallback.
    ``layout`` as in `cherrypick_search`.
    """
    pools = [list(priority)] + ([list(remaining)] if len(remaining) else [])
    return _bo_loop(space, cost_fn, rng, pools, settings, to_exhaustion, layout)
