"""Gaussian-process regression in pure JAX.

This is the surrogate model used by the CherryPick-style Bayesian optimization
(Alipourfard et al., NSDI'17) that Ruya builds on.  Matérn-5/2 kernel over the
encoded configuration features, observation noise, Cholesky-based posterior.

Hyperparameters (lengthscale, amplitude, noise) are selected by maximizing the
log marginal likelihood over a small deterministic grid — robust, derivative
free, and cheap for the O(70)-point spaces this paper works with.  Everything
is jnp so the whole fit+predict path is jittable.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "GPParams",
    "GPPosterior",
    "matern52",
    "matern52_from_sqdist",
    "pairwise_sqdist",
    "fit_gp",
    "gp_predict",
]

_JITTER = 1e-8


@dataclasses.dataclass(frozen=True)
class GPParams:
    """Kernel hyperparameters."""

    lengthscale: jax.Array  # (n_features,) or scalar
    amplitude: jax.Array  # scalar
    noise: jax.Array  # scalar observation noise variance


@dataclasses.dataclass(frozen=True)
class GPPosterior:
    """Cached posterior factorization for prediction."""

    params: GPParams
    x_train: jax.Array  # (n, d) standardized features
    chol: jax.Array  # (n, n) lower Cholesky of K + noise*I
    alpha: jax.Array  # (n,) K^{-1} (y - mean)
    y_mean: jax.Array  # scalar — standardization mean of y
    y_std: jax.Array  # scalar — standardization scale of y


def matern52(x1: jax.Array, x2: jax.Array, params: GPParams) -> jax.Array:
    """Matérn-5/2 kernel matrix between (n,d) and (m,d).

    Handles vector (per-feature) lengthscales.  For the scalar-lengthscale
    hyperparameter grids, use `pairwise_sqdist` + `matern52_from_sqdist`
    instead: the raw distance tensor is lengthscale-independent, so the six
    grid lengthscales share one d² computation.
    """
    scaled1 = x1 / params.lengthscale
    scaled2 = x2 / params.lengthscale
    # Pairwise Euclidean distances, numerically clamped.
    d2 = (
        jnp.sum(scaled1**2, -1)[:, None]
        + jnp.sum(scaled2**2, -1)[None, :]
        - 2.0 * scaled1 @ scaled2.T
    )
    d = jnp.sqrt(jnp.maximum(d2, 1e-12))
    sqrt5_d = jnp.sqrt(5.0) * d
    return params.amplitude * (1.0 + sqrt5_d + 5.0 / 3.0 * d**2) * jnp.exp(-sqrt5_d)


def pairwise_sqdist(x1: jax.Array, x2: jax.Array = None) -> jax.Array:
    """Raw pairwise squared Euclidean distances between (n,d) and (m,d).

    Lengthscale-free: a scalar lengthscale only rescales d² (d²/ls²), so one
    precomputed tensor serves every point of a lengthscale grid — and, in
    `fast_bo`, every step of a whole search.  Clamped at zero (the quadratic
    expansion can go slightly negative in float32).
    """
    if x2 is None:
        x2 = x1
    d2 = (
        jnp.sum(x1**2, -1)[:, None]
        + jnp.sum(x2**2, -1)[None, :]
        - 2.0 * x1 @ x2.T
    )
    return jnp.maximum(d2, 0.0)


def matern52_from_sqdist(
    d2: jax.Array, lengthscale: jax.Array, amplitude: jax.Array = 1.0
) -> jax.Array:
    """Matérn-5/2 from precomputed raw squared distances, scalar lengthscale."""
    s2 = jnp.maximum(d2 / (lengthscale * lengthscale), 1e-12)
    d = jnp.sqrt(s2)
    sqrt5_d = jnp.sqrt(5.0) * d
    return amplitude * (1.0 + sqrt5_d + 5.0 / 3.0 * s2) * jnp.exp(-sqrt5_d)


def _candidate_grid(n_features: int) -> Tuple[jax.Array, jax.Array]:
    """Deterministic (lengthscale, noise) grid for hyperparameter selection."""
    lengthscales = jnp.array([0.1, 0.25, 0.5, 1.0, 2.0, 4.0])
    noises = jnp.array([1e-4, 1e-2, 1e-1])
    ls, nz = jnp.meshgrid(lengthscales, noises, indexing="ij")
    return ls.reshape(-1), nz.reshape(-1)


def fit_gp(x: jax.Array, y: jax.Array) -> GPPosterior:
    """Fit a GP to observations.

    ``x``: (n, d) raw features (already encoded); ``y``: (n,) raw costs.
    Features are assumed pre-standardized by the search-space encoder;
    targets are standardized internally so the amplitude grid is scale free.
    """
    # canonicalize_dtype maps float64 -> float32 when x64 is disabled, so this
    # picks the widest float the runtime allows without poking at jax.config
    # internals (jax.config.read is not stable across JAX versions).
    x = jnp.asarray(x, jax.dtypes.canonicalize_dtype(jnp.float64))
    y = jnp.asarray(y, x.dtype)
    y_mean = jnp.mean(y)
    y_std = jnp.maximum(jnp.std(y), 1e-8)
    y_n = (y - y_mean) / y_std

    ls_grid, nz_grid = _candidate_grid(x.shape[-1])

    # One raw d² tensor serves the whole (lengthscale, noise) grid: scalar
    # lengthscales only rescale it.
    n = x.shape[0]
    d2 = pairwise_sqdist(x)
    eye = jnp.eye(n, dtype=x.dtype)

    def lml_for(ls, nz):
        k = matern52_from_sqdist(d2, ls) + (nz + _JITTER) * eye
        chol = jnp.linalg.cholesky(k)
        alpha = jax.scipy.linalg.cho_solve((chol, True), y_n)
        return (
            -0.5 * y_n @ alpha
            - jnp.sum(jnp.log(jnp.diagonal(chol)))
            - 0.5 * n * jnp.log(2.0 * jnp.pi)
        )

    lmls = jax.vmap(lml_for)(ls_grid, nz_grid)
    lmls = jnp.where(jnp.isfinite(lmls), lmls, -jnp.inf)
    best = jnp.argmax(lmls)
    params = GPParams(
        lengthscale=ls_grid[best],
        amplitude=jnp.asarray(1.0, x.dtype),
        noise=nz_grid[best],
    )

    k = matern52_from_sqdist(d2, params.lengthscale) + (params.noise + _JITTER) * eye
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y_n)
    return GPPosterior(
        params=params, x_train=x, chol=chol, alpha=alpha, y_mean=y_mean, y_std=y_std
    )


def gp_predict(post: GPPosterior, x_new: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Posterior mean and standard deviation at ``x_new`` (m, d), in raw y units."""
    x_new = jnp.asarray(x_new, post.x_train.dtype)
    k_star = matern52(post.x_train, x_new, post.params)  # (n, m)
    mean_n = k_star.T @ post.alpha
    v = jax.scipy.linalg.solve_triangular(post.chol, k_star, lower=True)
    var_n = post.params.amplitude - jnp.sum(v * v, axis=0)
    var_n = jnp.maximum(var_n, 1e-12)
    mean = mean_n * post.y_std + post.y_mean
    std = jnp.sqrt(var_n) * post.y_std
    return mean, std
