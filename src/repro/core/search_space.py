"""Resource-configuration search space and its memory-aware split (paper §III-D).

A configuration is anything with (a) a feature encoding for the GP surrogate
(CherryPick encodes each config "by its principal features like the number of
cores and the amount of memory"), (b) a total cluster memory, and (c) optional
metadata (node count, prices, mesh/remat details for the TPU tuner, ...).

``split_search_space`` implements the paper's priority-group construction:

  LINEAR  → configs with total memory ≥ the extrapolated requirement
            (+overhead+leeway); if *no* config qualifies, prioritize the
            extremes (very high and very low total memory).
  FLAT    → the 10–20 % of configs with the lowest total memory.
  UNCLEAR → no split (priority group = whole space → plain CherryPick).
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.memory_model import MemoryCategory, MemoryModel

__all__ = ["Configuration", "SearchSpace", "split_search_space"]


@dataclasses.dataclass(frozen=True)
class Configuration:
    """One point in the discrete configuration search space."""

    name: str
    features: Tuple[float, ...]  # raw GP features (cores, mem/node, nodes, ...)
    total_memory: float  # bytes of total cluster memory
    num_nodes: int = 1
    meta: Any = None


@dataclasses.dataclass
class SearchSpace:
    configs: List[Configuration]

    def __post_init__(self) -> None:
        if not self.configs:
            raise ValueError("empty search space")
        feats = np.asarray([c.features for c in self.configs], np.float64)
        mean = feats.mean(axis=0)
        std = feats.std(axis=0)
        std = np.where(std > 1e-12, std, 1.0)
        self._encoded = (feats - mean) / std

    def __len__(self) -> int:
        return len(self.configs)

    def encoded(self, indices: Optional[Sequence[int]] = None) -> np.ndarray:
        """Standardized feature matrix (whole space or a subset)."""
        if indices is None:
            return self._encoded
        return self._encoded[np.asarray(indices, np.int64)]

    def memories(self) -> np.ndarray:
        return np.asarray([c.total_memory for c in self.configs], np.float64)


def split_search_space(
    space: SearchSpace,
    model: MemoryModel,
    input_size: float,
    *,
    per_node_overhead: float = 0.0,
    leeway: float = 0.10,
    flat_fraction: float = 1.0 / 7.0,
    extreme_fraction: float = 0.15,
) -> Tuple[List[int], List[int]]:
    """Return (priority_indices, remaining_indices) per the paper's §III-D.

    ``flat_fraction`` defaults to ~1/7 — the paper's evaluation placed the ten
    lowest-memory configs of 69 in the priority group.  ``extreme_fraction``
    controls the very-high/very-low fallback when no config satisfies a linear
    requirement.
    """
    n = len(space)
    all_idx = list(range(n))
    mems = space.memories()

    if model.category is MemoryCategory.UNCLEAR:
        return all_idx, []

    if model.category is MemoryCategory.FLAT:
        k = max(1, int(round(flat_fraction * n)))
        order = np.argsort(mems, kind="stable")
        prio = sorted(int(i) for i in order[:k])
        rest = sorted(set(all_idx) - set(prio))
        return prio, rest

    # LINEAR: require total cluster memory ≥ extrapolated requirement.
    req_base = model.estimate(input_size)
    prio = []
    for i, cfg in enumerate(space.configs):
        requirement = req_base * (1.0 + leeway) + per_node_overhead * cfg.num_nodes
        if cfg.total_memory >= requirement:
            prio.append(i)
    if not prio:
        # Requirement exceeds every config: prioritize the extremes — "some
        # jobs can make use of all memory they are given and others need
        # either enough or none" (paper §III-D).
        k = max(1, int(round(extreme_fraction * n)))
        order = np.argsort(mems, kind="stable")
        prio = sorted({int(i) for i in order[:k]} | {int(i) for i in order[-k:]})
    if len(prio) == n:
        # Requirement met by everything → no reduction (paper observed this
        # for PageRank/Spark "huge"); behave exactly like the baseline.
        return all_idx, []
    rest = sorted(set(all_idx) - set(prio))
    return prio, rest
