"""Resource-configuration search space and its memory-aware split (paper §III-D).

A configuration is anything with (a) a feature encoding for the GP surrogate
(CherryPick encodes each config "by its principal features like the number of
cores and the amount of memory"), (b) a total cluster memory, and (c) optional
metadata (node count, prices, mesh/remat details for the TPU tuner, ...).

``split_search_space`` implements the paper's priority-group construction:

  LINEAR  → configs with total memory ≥ the extrapolated requirement
            (+overhead+leeway); if *no* config qualifies, prioritize the
            extremes (very high and very low total memory).
  FLAT    → the 10–20 % of configs with the lowest total memory.
  UNCLEAR → no split (priority group = whole space → plain CherryPick).

``split_masks_device`` is the same rule computed ON DEVICE over the space's
static per-config arrays (total memories, node counts), returning the
(n,) priority mask directly — the narrowing then scales with the catalog
(one vectorized comparison + a stable sort instead of a Python loop over
10⁴–10⁵ configs).  It runs in float64 (`jax.experimental.enable_x64`) so
every comparison and the stable sort are bit-equal to the host rule —
`tests/test_search_space.py` pins mask == list equality, which is what lets
`repro.fleet.session.TuningSession` use the device split while staying
trace-identical to the host-split drivers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.memory_model import MemoryCategory, MemoryModel

__all__ = [
    "Configuration",
    "SearchSpace",
    "split_masks_device",
    "split_search_space",
]


@dataclasses.dataclass(frozen=True)
class Configuration:
    """One point in the discrete configuration search space."""

    name: str
    features: Tuple[float, ...]  # raw GP features (cores, mem/node, nodes, ...)
    total_memory: float  # bytes of total cluster memory
    num_nodes: int = 1
    meta: Any = None


@dataclasses.dataclass
class SearchSpace:
    configs: List[Configuration]

    def __post_init__(self) -> None:
        if not self.configs:
            raise ValueError("empty search space")
        feats = np.asarray([c.features for c in self.configs], np.float64)
        mean = feats.mean(axis=0)
        std = feats.std(axis=0)
        std = np.where(std > 1e-12, std, 1.0)
        self._encoded = (feats - mean) / std
        # Static per-config arrays, built once: the §III-D split (host or
        # device) reads these instead of looping over Configuration objects.
        self._memories = np.asarray(
            [c.total_memory for c in self.configs], np.float64
        )
        self._num_nodes = np.asarray(
            [c.num_nodes for c in self.configs], np.float64
        )

    def __len__(self) -> int:
        return len(self.configs)

    def encoded(self, indices: Optional[Sequence[int]] = None) -> np.ndarray:
        """Standardized feature matrix (whole space or a subset)."""
        if indices is None:
            return self._encoded
        return self._encoded[np.asarray(indices, np.int64)]

    def memories(self) -> np.ndarray:
        return self._memories

    def num_nodes(self) -> np.ndarray:
        return self._num_nodes


def split_search_space(
    space: SearchSpace,
    model: MemoryModel,
    input_size: float,
    *,
    per_node_overhead: float = 0.0,
    leeway: float = 0.10,
    flat_fraction: float = 1.0 / 7.0,
    extreme_fraction: float = 0.15,
) -> Tuple[List[int], List[int]]:
    """Return (priority_indices, remaining_indices) per the paper's §III-D.

    ``flat_fraction`` defaults to ~1/7 — the paper's evaluation placed the ten
    lowest-memory configs of 69 in the priority group.  ``extreme_fraction``
    controls the very-high/very-low fallback when no config satisfies a linear
    requirement.
    """
    n = len(space)
    all_idx = list(range(n))
    mems = space.memories()

    if model.category is MemoryCategory.UNCLEAR:
        return all_idx, []

    if model.category is MemoryCategory.FLAT:
        k = max(1, int(round(flat_fraction * n)))
        order = np.argsort(mems, kind="stable")
        prio = sorted(int(i) for i in order[:k])
        rest = sorted(set(all_idx) - set(prio))
        return prio, rest

    # LINEAR: require total cluster memory ≥ extrapolated requirement.
    req_base = model.estimate(input_size)
    prio = []
    for i, cfg in enumerate(space.configs):
        requirement = req_base * (1.0 + leeway) + per_node_overhead * cfg.num_nodes
        if cfg.total_memory >= requirement:
            prio.append(i)
    if not prio:
        # Requirement exceeds every config: prioritize the extremes — "some
        # jobs can make use of all memory they are given and others need
        # either enough or none" (paper §III-D).
        k = max(1, int(round(extreme_fraction * n)))
        order = np.argsort(mems, kind="stable")
        prio = sorted({int(i) for i in order[:k]} | {int(i) for i in order[-k:]})
    if len(prio) == n:
        # Requirement met by everything → no reduction (paper observed this
        # for PageRank/Spark "huge"); behave exactly like the baseline.
        return all_idx, []
    rest = sorted(set(all_idx) - set(prio))
    return prio, rest


def _jit64(fun):
    """jit a float64 split kernel lazily (jax import deferred to first use)."""
    cache = {}

    def wrapper(*args, k: int):
        import jax

        if "fn" not in cache:
            cache["fn"] = jax.jit(fun, static_argnames=("k",))
        return cache["fn"](*args, k=k)

    return wrapper


@_jit64
def _flat_prio_mask(mems, *, k: int):
    """FLAT rule: True at the k lowest-memory configs (stable ties)."""
    import jax.numpy as jnp

    order = jnp.argsort(mems, stable=True)
    return jnp.zeros(mems.shape[0], bool).at[order[:k]].set(True)


@_jit64
def _linear_prio_mask(mems, nodes, req_base, leeway, overhead, *, k: int):
    """LINEAR rule: memory ≥ requirement, else the very-high/very-low extremes."""
    import jax.numpy as jnp

    requirement = req_base * (1.0 + leeway) + overhead * nodes
    qualify = mems >= requirement
    order = jnp.argsort(mems, stable=True)
    extremes = (
        jnp.zeros(mems.shape[0], bool)
        .at[order[:k]].set(True)
        .at[order[-k:]].set(True)
    )
    return jnp.where(jnp.any(qualify), qualify, extremes)


def split_masks_device(
    space: SearchSpace,
    model: MemoryModel,
    input_size: float,
    *,
    per_node_overhead: float = 0.0,
    leeway: float = 0.10,
    flat_fraction: float = 1.0 / 7.0,
    extreme_fraction: float = 0.15,
):
    """§III-D priority split computed ON DEVICE; returns the (n,) bool mask.

    Bit-equal to `split_search_space` by construction: the per-config
    requirement math runs elementwise in float64 (under
    `jax.experimental.enable_x64`, so device IEEE ops match the host's), the
    FLAT / extremes selections use a stable argsort (same permutation as
    `np.argsort(kind="stable")`), and the group sizes ``k`` are rounded on
    the host with the same expressions.  The remaining mask is always the
    complement (`~prio`) — including the all-qualify LINEAR case, where the
    complement of an all-True mask is the host rule's empty remainder.

    The host-side cost is O(1): the static per-config arrays come from the
    `SearchSpace` cache, so narrowing a 10⁴–10⁵-point catalog is one device
    comparison + sort instead of a Python loop over configs.
    """
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    n = len(space)
    if model.category is MemoryCategory.UNCLEAR:
        return jnp.ones(n, bool)
    with enable_x64():
        mems = jnp.asarray(space.memories())
        if model.category is MemoryCategory.FLAT:
            k = max(1, int(round(flat_fraction * n)))
            return _flat_prio_mask(mems, k=min(k, n))
        k = max(1, int(round(extreme_fraction * n)))
        return _linear_prio_mask(
            mems,
            jnp.asarray(space.num_nodes()),
            jnp.asarray(np.float64(model.estimate(input_size))),
            jnp.asarray(np.float64(leeway)),
            jnp.asarray(np.float64(per_node_overhead)),
            k=min(k, n),
        )
