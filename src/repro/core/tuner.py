"""End-to-end Ruya tuner: profile → categorize → split → two-phase BO search.

This module is environment-agnostic.  An environment supplies:
  * a profiling run function   run(sample_size) -> (runtime_s, peak_mem_bytes)
  * the full input size        (bytes, or tokens-per-device for the TPU tuner)
  * the discrete search space  (SearchSpace)
  * a trial cost function      cost_fn(config_index) -> float

Two environments ship with the repo: the Scout-like cluster emulator
(`repro.cluster`) reproducing the paper's evaluation, and the TPU
sharding-configuration autotuner (`repro.launch.autotune`).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.core.bayesopt import (
    BOSettings,
    SearchTrace,
    cherrypick_search,
    ruya_search,
)
from repro.core.memory_model import MemoryModel
from repro.core.profiler import ProfileResult, profile_job
from repro.core.search_space import SearchSpace, split_search_space

__all__ = ["RuyaReport", "run_ruya", "run_cherrypick"]


@dataclasses.dataclass
class RuyaReport:
    profile: ProfileResult
    priority: Tuple[int, ...]
    remaining: Tuple[int, ...]
    trace: SearchTrace

    @property
    def memory_model(self) -> MemoryModel:
        return self.profile.model


def run_ruya(
    *,
    profile_run: Callable[[float], Tuple[float, float]],
    full_input_size: float,
    space: SearchSpace,
    cost_fn: Callable[[int], float],
    rng: np.random.Generator,
    per_node_overhead: float = 0.0,
    leeway: float = 0.10,
    flat_fraction: float = 1.0 / 7.0,
    settings: BOSettings = BOSettings(),
    to_exhaustion: bool = False,
    profile_result: Optional[ProfileResult] = None,
) -> RuyaReport:
    """The full Ruya pipeline.  ``profile_result`` can be injected to reuse a
    previous profiling phase (the paper: profiling only repeats when the
    execution context changes)."""
    prof = profile_result or profile_job(profile_run, full_input_size)
    prio, rest = split_search_space(
        space,
        prof.model,
        full_input_size,
        per_node_overhead=per_node_overhead,
        leeway=leeway,
        flat_fraction=flat_fraction,
    )
    trace = ruya_search(
        space,
        cost_fn,
        rng,
        prio,
        rest,
        settings=settings,
        to_exhaustion=to_exhaustion,
    )
    return RuyaReport(
        profile=prof, priority=tuple(prio), remaining=tuple(rest), trace=trace
    )


def run_cherrypick(
    *,
    space: SearchSpace,
    cost_fn: Callable[[int], float],
    rng: np.random.Generator,
    settings: BOSettings = BOSettings(),
    to_exhaustion: bool = False,
) -> SearchTrace:
    """The baseline, for side-by-side evaluation (paper §IV-C)."""
    return cherrypick_search(
        space, cost_fn, rng, settings=settings, to_exhaustion=to_exhaustion
    )
