"""End-to-end Ruya tuner: profile → categorize → split → two-phase BO search.

This module is environment-agnostic.  An environment supplies:
  * a profiling run function   run(sample_size) -> (runtime_s, peak_mem_bytes)
  * the full input size        (bytes, or tokens-per-device for the TPU tuner)
  * the discrete search space  (SearchSpace)
  * a trial cost function      cost_fn(config_index) -> float

Two environments ship with the repo: the Scout-like cluster emulator
(`repro.cluster`) reproducing the paper's evaluation, and the TPU
sharding-configuration autotuner (`repro.launch.autotune`).

Both execution styles run the packed-observation BO engine (`fast_bo`):
`cost_table` replay goes through the batched fleet engine (since PR 4 a
deprecation shim over `repro.fleet.session.TuningSession`), a live
`cost_fn` through the sequential driver's device-resident probe — one
shared compiled step, identical traces (see `fast_bo` for the layout and
the float32 discipline).  For streaming workloads, shared profiling, and
cross-job warm-starting, hold a `TuningSession` directly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.core.bayesopt import (
    BOSettings,
    SearchTrace,
    cherrypick_search,
    ruya_search,
)
from repro.core.memory_model import MemoryModel
from repro.core.profiler import ProfileResult, profile_job
from repro.core.search_space import SearchSpace, split_search_space

__all__ = ["RuyaReport", "run_ruya", "run_cherrypick"]


@dataclasses.dataclass
class RuyaReport:
    profile: ProfileResult
    priority: Tuple[int, ...]
    remaining: Tuple[int, ...]
    trace: SearchTrace

    @property
    def memory_model(self) -> MemoryModel:
        return self.profile.model


def run_ruya(
    *,
    profile_run: Optional[Callable[[float], Tuple[float, float]]] = None,
    full_input_size: float = 0.0,
    space: SearchSpace,
    cost_fn: Optional[Callable[[int], float]] = None,
    cost_table: Optional[np.ndarray] = None,
    rng: np.random.Generator,
    per_node_overhead: float = 0.0,
    leeway: float = 0.10,
    flat_fraction: float = 1.0 / 7.0,
    settings: BOSettings = BOSettings(),
    to_exhaustion: bool = False,
    profile_result: Optional[ProfileResult] = None,
    objective="runtime",
) -> RuyaReport:
    """The full Ruya pipeline.  ``profile_result`` can be injected to reuse a
    previous profiling phase (the paper: profiling only repeats when the
    execution context changes).

    Costs come either from ``cost_fn`` (live trials, driven by the sequential
    engine) or from ``cost_table`` (recorded/emulated workload replay, driven
    by the batched fleet engine as a fleet of one).  Both engines are
    trace-identical, so the choice is purely about execution style.

    ``objective`` routes the replay scoring ("runtime" — the default,
    pinned legacy path — or "cost" / a weight mapping; see
    `repro.fleet.session.objective_table`).  Non-runtime objectives need
    the ``cost_table`` path with pricing axes (``runtime_table`` /
    ``price_table``) — a live ``cost_fn`` observes one scalar per trial
    and has no second axis to trade against.

    .. deprecated:: PR 4
        The ``cost_table`` path is a one-shot deprecation shim over
        `repro.fleet.session.TuningSession` (a session of one job, drained
        immediately — bit-identical, pinned by `tests/test_session.py`).
        New replay/fleet code should hold a session; the live ``cost_fn``
        path remains the sequential probe driver.
    """
    if (cost_fn is None) == (cost_table is None):
        raise ValueError("provide exactly one of cost_fn / cost_table")
    if cost_table is not None:
        from repro.fleet.driver import FleetJob, tune_fleet

        job = FleetJob(
            name="job",
            space=space,
            cost_table=np.asarray(cost_table, np.float64),
            full_input_size=full_input_size,
            profile_run=profile_run,
            profile_result=profile_result,
            per_node_overhead=per_node_overhead,
            leeway=leeway,
            flat_fraction=flat_fraction,
        )
        return tune_fleet(
            [job], [rng], settings=settings, to_exhaustion=to_exhaustion,
            objective=objective,
        )[0]
    from repro.fleet.session import canonical_objective

    if canonical_objective(objective) != "runtime":
        raise ValueError(
            "non-runtime objectives need the cost_table path with pricing "
            "axes; a live cost_fn observes a single scalar per trial"
        )

    if profile_result is None and profile_run is None:
        raise ValueError("provide profile_run or profile_result")
    prof = profile_result or profile_job(profile_run, full_input_size)
    prio, rest = split_search_space(
        space,
        prof.model,
        full_input_size,
        per_node_overhead=per_node_overhead,
        leeway=leeway,
        flat_fraction=flat_fraction,
    )
    trace = ruya_search(
        space,
        cost_fn,
        rng,
        prio,
        rest,
        settings=settings,
        to_exhaustion=to_exhaustion,
    )
    return RuyaReport(
        profile=prof, priority=tuple(prio), remaining=tuple(rest), trace=trace
    )


def run_cherrypick(
    *,
    space: SearchSpace,
    cost_fn: Optional[Callable[[int], float]] = None,
    cost_table: Optional[np.ndarray] = None,
    rng: np.random.Generator,
    settings: BOSettings = BOSettings(),
    to_exhaustion: bool = False,
) -> SearchTrace:
    """The baseline, for side-by-side evaluation (paper §IV-C).

    Like `run_ruya`, accepts either a live ``cost_fn`` or a recorded
    ``cost_table`` (the latter runs on the batched fleet engine — since
    PR 4 a deprecation shim over `repro.fleet.session.TuningSession`).
    """
    if (cost_fn is None) == (cost_table is None):
        raise ValueError("provide exactly one of cost_fn / cost_table")
    if cost_table is not None:
        from repro.fleet.driver import FleetJob, tune_fleet

        job = FleetJob(
            name="job", space=space, cost_table=np.asarray(cost_table, np.float64)
        )
        return tune_fleet(
            [job], [rng], mode="cherrypick", settings=settings,
            to_exhaustion=to_exhaustion,
        )[0].trace
    return cherrypick_search(
        space, cost_fn, rng, settings=settings, to_exhaustion=to_exhaustion
    )
