"""Acquisition functions for Bayesian-optimized configuration search.

CherryPick (and hence Ruya) uses Expected Improvement: the next configuration
to try is the one believed to yield the most significant cost saving over the
best configuration seen so far.  Probability of Improvement is provided for
completeness (it is the other acquisition the paper names).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["expected_improvement", "probability_of_improvement"]


def _norm_pdf(z: jax.Array) -> jax.Array:
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)


def _norm_cdf(z: jax.Array) -> jax.Array:
    return 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))


def expected_improvement(
    mean: jax.Array, std: jax.Array, best: jax.Array, xi: float = 0.0
) -> jax.Array:
    """EI for cost *minimization*: E[max(best - f, 0)].

    ``mean``/``std``: GP posterior at candidate points; ``best``: lowest
    observed cost; ``xi``: optional exploration margin.
    """
    std = jnp.maximum(std, 1e-12)
    improvement = best - mean - xi
    z = improvement / std
    ei = improvement * _norm_cdf(z) + std * _norm_pdf(z)
    return jnp.maximum(ei, 0.0)


def probability_of_improvement(
    mean: jax.Array, std: jax.Array, best: jax.Array, xi: float = 0.0
) -> jax.Array:
    """P[f < best - xi] under the GP posterior (cost minimization)."""
    std = jnp.maximum(std, 1e-12)
    return _norm_cdf((best - mean - xi) / std)
