"""Memory-usage modeling and categorization (paper §III-C).

Given profiling readings ``(input_size_i, peak_memory_i)`` from small sample
runs, fit ordinary least squares ``mem = slope * size + intercept`` and
categorize the job by the training-set R² score:

  R² > 0.99      → LINEAR  : memory scales with input; extrapolate confidently.
  R² < 0.10      → FLAT    : memory does not scale with input size.
  0.10 ≤ R² ≤ 0.99 → UNCLEAR : no usable model; fall back to plain BO.

The thresholds are the paper's (§III-C / §IV-B).  The model also carries the
constant overhead terms of §III-D: per-node framework+OS overhead and a
multiplicative leeway factor, which together turn the extrapolated *job*
requirement into a *total-cluster-memory* requirement.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import numpy as np

__all__ = [
    "MemoryCategory",
    "MemoryModel",
    "fit_memory_model",
    "LINEAR_R2_THRESHOLD",
    "FLAT_R2_THRESHOLD",
]

LINEAR_R2_THRESHOLD = 0.99
FLAT_R2_THRESHOLD = 0.10


class MemoryCategory(enum.Enum):
    LINEAR = "linear"
    FLAT = "flat"
    UNCLEAR = "unclear"


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Fitted memory model for one job."""

    category: MemoryCategory
    slope: float  # bytes of memory per byte of input (LINEAR) else 0
    intercept: float  # bytes
    r2: float
    sizes: tuple  # profiling sample sizes (bytes)
    readings: tuple  # peak-memory readings (bytes)

    def estimate(self, input_size: float) -> float:
        """Extrapolated job memory requirement for ``input_size`` bytes.

        Only meaningful for LINEAR jobs; FLAT jobs return the mean reading;
        UNCLEAR jobs return NaN (caller must not rely on it).
        """
        if self.category is MemoryCategory.LINEAR:
            return self.slope * input_size + self.intercept
        if self.category is MemoryCategory.FLAT:
            return float(np.mean(self.readings))
        return float("nan")

    def total_cluster_requirement(
        self,
        input_size: float,
        *,
        per_node_overhead: float = 0.0,
        num_nodes: int = 0,
        leeway: float = 0.10,
    ) -> float:
        """Paper §III-D: job requirement + framework/OS overhead + leeway."""
        base = self.estimate(input_size)
        return base * (1.0 + leeway) + per_node_overhead * num_nodes


def _ols_r2(x: np.ndarray, y: np.ndarray) -> tuple:
    """Least-squares slope/intercept and training-set R²."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    xm, ym = x.mean(), y.mean()
    sxx = np.sum((x - xm) ** 2)
    if sxx <= 0.0:  # degenerate: all sample sizes identical
        return 0.0, float(ym), 0.0
    slope = float(np.sum((x - xm) * (y - ym)) / sxx)
    intercept = float(ym - slope * xm)
    pred = slope * x + intercept
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - ym) ** 2))
    if ss_tot <= 0.0:
        # Perfectly constant readings: a constant model explains everything,
        # but there is by definition no correlation with input size -> R²=0.
        return slope, intercept, 0.0
    return slope, intercept, 1.0 - ss_res / ss_tot


def fit_memory_model(
    sizes: Sequence[float],
    readings: Sequence[float],
    *,
    linear_threshold: float = LINEAR_R2_THRESHOLD,
    flat_threshold: float = FLAT_R2_THRESHOLD,
) -> MemoryModel:
    """Fit + categorize memory readings per paper §III-C."""
    if len(sizes) != len(readings):
        raise ValueError("sizes and readings must have equal length")
    if len(sizes) < 2:
        raise ValueError("need at least two profiling samples")
    slope, intercept, r2 = _ols_r2(np.asarray(sizes), np.asarray(readings))
    # A *negative* slope with high R² is not the paper's "linear" growth
    # pattern (memory shrinking with more input is an artifact); treat as
    # unclear so the searcher falls back to the baseline.
    if r2 > linear_threshold and slope > 0:
        category = MemoryCategory.LINEAR
    elif r2 < flat_threshold:
        category = MemoryCategory.FLAT
    else:
        category = MemoryCategory.UNCLEAR
    if category is not MemoryCategory.LINEAR:
        slope_out, intercept_out = 0.0, float(np.mean(readings))
    else:
        slope_out, intercept_out = slope, intercept
    return MemoryModel(
        category=category,
        slope=slope_out,
        intercept=intercept_out,
        r2=float(r2),
        sizes=tuple(float(s) for s in sizes),
        readings=tuple(float(r) for r in readings),
    )
