"""Single-machine profiling-run driver (paper §III-B).

The paper's procedure, made executable against any job abstraction:

  1. start with ~1 % of the dataset;
  2. adjust the sample iteratively so the profiling run's execution time lands
     between 30 s and 300 s — long enough to get past framework init, short
     enough to keep profiling cheap (runs longer than the cap are *canceled*
     at the cap and restarted with a smaller sample, and the canceled time is
     still charged to the profiling budget);
  3. run five linearly spaced sample sizes (the calibrated size and four
     smaller, equally spaced portions of it) and record peak memory for each;
  4. hand (sizes, readings) to the memory model for categorization.

The job abstraction is a callable ``run(sample_size) -> (runtime_s, peak_mem)``
so the same driver profiles both the Scout-like Spark/Hadoop emulator and the
TPU tuner's compile-based memory probe.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

from repro.core.memory_model import MemoryModel, fit_memory_model

__all__ = [
    "PermanentRunError",
    "ProfileResult",
    "ProfilingRunError",
    "TransientRunError",
    "profile_job",
    "schedule_sample_sizes",
]

RunFn = Callable[[float], Tuple[float, float]]


class ProfilingRunError(RuntimeError):
    """A profiling/probe run failed instead of returning a reading.

    This is the taxonomy the retry layer (`repro.fleet.retry`) classifies
    by: raise `TransientRunError` for failures worth retrying (preempted
    sample machine, OOM-killed sampler, lost connection) and
    `PermanentRunError` for failures no retry can fix (the job binary is
    broken, the dataset is gone).  Anything else that escapes a run
    callable is treated as permanent — an unknown failure must not be
    silently retried into a profiling budget.
    """


class TransientRunError(ProfilingRunError):
    """A profiling/probe run failed in a way a retry may fix."""


class PermanentRunError(ProfilingRunError):
    """A profiling/probe run failed in a way no retry can fix."""


@dataclasses.dataclass(frozen=True)
class ProfileResult:
    sizes: Tuple[float, ...]
    readings: Tuple[float, ...]
    total_time_s: float  # wall time spent profiling (incl. canceled runs)
    calibration_runs: int
    model: MemoryModel


def schedule_sample_sizes(calibrated: float, n_samples: int = 5) -> List[float]:
    """Five equally spaced portions of the calibrated sample (paper §III-B)."""
    if n_samples < 2:
        raise ValueError("need at least two samples to fit a line")
    return [calibrated * (i + 1) / n_samples for i in range(n_samples)]


def profile_job(
    run: RunFn,
    full_input_size: float,
    *,
    initial_fraction: float = 0.01,
    min_runtime_s: float = 30.0,
    max_runtime_s: float = 300.0,
    n_samples: int = 5,
    max_calibration_runs: int = 12,
) -> ProfileResult:
    """Calibrate the sample size, run the profiling sweep, fit the model."""
    sample = full_input_size * initial_fraction
    total_time = 0.0
    calibration_runs = 0

    # --- calibration: land the runtime inside [min, max] -------------------
    while calibration_runs < max_calibration_runs:
        runtime, _ = run(sample)
        calibration_runs += 1
        if runtime > max_runtime_s:
            # canceled at the cap; only the cap is charged (paper: "the
            # profiling job can be canceled and restarted").
            total_time += max_runtime_s
            sample *= max_runtime_s / (2.0 * runtime)
            continue
        total_time += runtime
        if runtime < min_runtime_s:
            if sample >= full_input_size:
                break  # even the full dataset is quick — profile as-is
            growth = min_runtime_s / max(runtime, 1e-9) * 1.5
            sample = min(sample * growth, full_input_size)
            continue
        break
    sample = min(sample, full_input_size)

    # --- sweep: five linearly spaced sizes ---------------------------------
    sizes = schedule_sample_sizes(sample, n_samples)
    readings: List[float] = []
    for s in sizes:
        runtime, peak_mem = run(s)
        total_time += min(runtime, max_runtime_s)
        readings.append(peak_mem)

    model = fit_memory_model(sizes, readings)
    return ProfileResult(
        sizes=tuple(sizes),
        readings=tuple(readings),
        total_time_s=total_time,
        calibration_runs=calibration_runs,
        model=model,
    )
