"""Packed-observation, fully-jitted Bayesian-optimization step and fleet update.

The paper replays every search 200× over a 69-point space; the ROADMAP's
north star is production-scale spaces (real cloud catalogs span 10⁴–10⁵
instance-type × count combinations).  At most B points are ever observed
per search (B = the trial budget, 16–32 in the paper's regime), so the GP
never needs full-extent linear algebra — and, since PR 3, it never needs
full-extent *geometry* either: the engine carries a packed **(B,d) feature
buffer** of the observed points (in trial order) and computes the (B,B)
training block and the (B,n) cross block on the fly against the static
(n,d) encoding.  Nothing of extent n×n is ever materialized.

Per-step cost (n = space extent, d = features, B = trial capacity,
w = warm-start seeds):

    layout           memory      kernel blocks          factorizations  posterior
    dense            O(n²)       6·O(n²·d)              18·O(n³)        O(n²)
    d²-gather (PR 2) O(n²)       gathers + 6·O(B²)      18·O(B³)        O(B·n)
    feature (PR 3)   O(n·d)      O(B²d + B·n·d)+6·O(B²) 18·O(B³)        O(B·n)
    fused (PR 8)     O(n·d)      same flops, streamed   18·O(B³)        O(B·tile)

The fused row's last column is the *transient* bound: the EI/argmax tail
runs as a streaming (max, argmax) reduction over n/tile tiles
(`repro.kernels.ei_argmax`), so the (B,n) cross block — the feature
layout's one remaining extent-n per-step allocation — never exists; its
flops are unchanged.

Session-era paths ride the same step with zero new device code (PR 4):

    warm seeding     O(w·d) host prefill of the packed (B,)/(B,d) buffers
                     before the first step; a seeded search starts at t = w,
                     so it runs ≤ B − w fresh steps at unchanged extents
    on-device split  O(n log n) §III-D mask build once per admission
                     (search_space.split_masks_device), float64, bit-equal
                     to the host rule — no O(n) Python narrowing loop
    sharded step     one `shard_map` dispatch advances S chunks, one per
                     device (repro.fleet.sharding): per-device compute is
                     the unchanged extent-r chunk program, communication
                     is ZERO bytes per step (searches are independent, no
                     collectives) — only the O(S·r·(n·d + B·d + n))
                     placement at admission and the O(S·r·B) register
                     gather at retirement, once per chunk lifetime
    mid-flight       a cancelled/failed/preempted row is retired by
    retirement       latching its `done` flag (PR 7): every write in the
                     step is already gated on `live = ~done ∧ budget`, so
                     the row freezes in place as a dummy-pad — zero new
                     device code, and its vmap-independent chunk-mates'
                     traces are untouched by construction (pinned
                     bit-identical by the golden disturbed-fleet scenario)
    per-group        the async service (PR 9, repro.fleet.service) drives
    dispatch         each admission group's chunks from its own host
                     thread — the device program is the unchanged chunk
                     step; only WHO calls it and WHEN changes, plus an
                     optional committed device placement per group.
                     Because vmap rows are independent and row extents
                     stay inside the f32 batch-extent-invariant [2, 8]
                     window, chunk membership and step interleaving are
                     trace-neutral: the async schedule is pinned
                     bit-identical to the lockstep drain by the
                     golden-through-service and interleaving-fuzz lanes
    objective        O(n) host derivation once per submission (PR 10,
    routing          repro.fleet.session.objective_table): "cost" and
                     weighted runtime/cost blends rebuild the job's (n,)
                     score table from its pricing axes BEFORE packing —
                     the device step is objective-agnostic and unchanged
                     at every extent; objective="runtime" passes the
                     job's own table through untouched (pinned as_dict-
                     equal to the golden fixtures by `-m pricing`)

The d²-gather layout paid a one-off O(n²·d) `precompute_d2` per search and
held the (n,n) tensor for its whole lifetime — an O(n²) memory wall that
caps searches near n ≈ 10³.  The feature layout recomputes the two distance
blocks each step (O(B²d + Bnd), trivially cheap for B ≪ n) from O(n·d)
state, so n = 10⁴–10⁵ spaces run in megabytes.  All layouts are retained:
`bo_step_core` (feature) is the default in both engines,
`bo_step_core_fused` streams its EI/argmax tail through
`repro.kernels.ei_argmax` (layout="fused", bit-identical — the tail IS the
same function — with O(B·tile) transients), `bo_step_core_gather` +
`precompute_d2` are the PR-2 path kept for cross-checking and benchmarking,
and `bo_step_core_dense` is the original full-extent baseline.

Layout.  `FleetState` holds the trial log `tried` (B,), a packed target
buffer `py` (B,), and the packed feature buffer `feats` (B,d), all aligned
in trial order — observation k lives in slot k.  `bo_step_core` computes
the (B,B)/(B,n) raw squared-distance blocks from `feats` via
`packed_sqdist_blocks`, standardizes the packed targets, selects
(lengthscale, noise) by masked log marginal likelihood over the 18-point
grid, computes the posterior over all n points for the winner only, and
argmaxes Expected Improvement over the candidate mask.

Bit-identity across layouts.  `packed_sqdist_blocks` computes the (B,n)
cross block with *exactly* `gp.pairwise_sqdist`'s expansion — sum-of-
squares per row, one matmul for the cross terms, clamp at zero — which is
also how `precompute_d2` fills the (n,n) tensor; the contraction axis (d)
and its summation order are identical whether the left operand has extent
B or n, so cross rows are bitwise equal to rows of the precomputed tensor.
The (B,B) training block is then a column gather of the cross block by
`tried` (a second (B,d)·(d,B) self-matmul can fuse differently from the
(n,d)·(d,n) one — observed at d = 1 — while gathers are exact), so block
identity with the d²-gather layout holds by construction (XLA:CPU,
float32; property-checked in `tests/test_feature_buffer.py`).  Every op
downstream of the blocks is shared (`_packed_core`), so the two layouts
produce bit-identical (pick, max_ei, best) — and therefore bit-identical
search traces.

Padding is exact, not approximate.  Packed slots ≥ t are masked: their
kernel rows/columns are zeroed and their diagonal entries set to 1, so the
(B,B) Cholesky block-decouples — L is the factor of the observed block
direct-summed with an identity — and padded slots contribute exactly 0 to
alpha, the posterior mean, and the variance correction (their cross rows
are zeroed too).  Garbage in padded `tried`/`py`/`feats` slots is inert as
long as it is finite (the engine only ever writes -1/0 there); padded
*space* points (mask-level padding) are likewise never candidates and
never observed.  Warm-start seeding composes with this unchanged: seeds
occupy slots < t like any observation (index in `tried`, float32 cost in
`py`, the canonical encoding row in `feats`, observation mask set), so the
padding proof applies verbatim to a seeded buffer — slots ≥ t stay inert,
slots < t are ordinary training points.

Float32 discipline (unchanged from the dense engine): XLA:CPU float32
results differ between compilation contexts — batch extent 1 compiles to
different programs than extents ≥ 2 (hence everything runs at extent ≥ 2),
extents 2–8 are empirically invariant, ≥ 12 diverge, and `lax.while_loop`
bodies compute different last-ulp floats (and run 5-8× slower) than the
same ops standalone.  In the late-search regime one ulp flips argmax picks,
so BOTH engines execute the single `fleet_step` program:

  * the fleet engine (`repro.fleet.batched_engine`) vmaps it over lockstep
    chunks of 2–8 jobs, grouped by (space shape, packed capacity B) so
    every job factorizes the same static extents as a solo run would;
  * the sharded fleet engine (`repro.fleet.sharding`) runs the SAME
    vmapped program per device under `shard_map` — the body is traced at
    the per-device chunk extent (still 2–8), so sharding adds no new
    compilation context and stays bit-identical (pinned by the
    golden-trace harness in `tests/golden/`);
  * the sequential driver's `SequentialProbe` carries a batch-extent-2
    state (row 1 a discarded duplicate) on device across a whole search,
    donating it to each jitted probe call: per step one f32 scalar goes up
    (the latest observed cost, patched into the packed buffer) and three
    scalars come back — no per-iteration copies of any state buffer.

`tests/test_fleet.py` asserts sequential↔batched trace identity
seed-for-seed (both layouts, and feature↔gather cross-layout);
`tests/test_feature_buffer.py` property-checks the feature blocks against
the d²-gather blocks and `gp.pairwise_sqdist` bit-for-bit, including
padded-slot inertness; `tests/test_core_bo.py` checks the packed math
against the readable reference in `gp.py`/`acquisition.py` and the
retained dense path (`bo_step_core_dense`, the full-extent baseline for
`benchmarks/fleet_bench.py`'s scaling sweep).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.gp import GPParams, matern52, matern52_from_sqdist, pairwise_sqdist
from repro.kernels.ei_argmax import ei_argmax, ei_from_sqdist

__all__ = [
    "FleetState",
    "SequentialProbe",
    "bo_step",
    "bo_step_core",
    "bo_step_core_dense",
    "bo_step_core_fused",
    "bo_step_core_gather",
    "encode_features",
    "fleet_step",
    "gather_sqdist_blocks",
    "packed_sqdist_blocks",
    "precompute_d2",
]

_JITTER = 1e-8
_LENGTHSCALES = (0.1, 0.25, 0.5, 1.0, 2.0, 4.0)
_NOISES = (1e-4, 1e-2, 1e-1)

_LAYOUTS = ("feature", "gather", "fused")


def encode_features(encoded) -> np.ndarray:
    """Canonical float32 host view of the encoded space.

    THE single conversion both engines use for the static (n,d) geometry:
    the feature buffer is filled with rows of exactly this array, so the
    sequential and fleet engines (and host-side buffer reconstruction in
    `SequentialProbe.start`) all see bit-identical features.
    """
    return np.asarray(encoded, np.float32)


@jax.jit
def _pairwise_sqdist_f32(encoded: jax.Array) -> jax.Array:
    return pairwise_sqdist(encoded.astype(jnp.float32))


def precompute_d2(encoded) -> jax.Array:
    """(n,n) raw pairwise squared distances over the encoded space, float32.

    The PR-2 d²-gather layout: computed once per search — UNBATCHED, so
    sequential and fleet runs of the same space get bit-identical tensors —
    and threaded through every step as a constant.  O(n²) memory; retained
    for cross-checking the feature-buffer layout and for benchmarking, not
    used by the default engines.
    """
    return _pairwise_sqdist_f32(jnp.asarray(encode_features(encoded)))


def packed_sqdist_blocks(
    feats: jax.Array,  # (B, d) packed features of observed points
    encoded: jax.Array,  # (n, d) static encoding of the whole space
    tried: jax.Array,  # (B,) i32 trial log, -1 padded
) -> Tuple[jax.Array, jax.Array]:
    """((B,B), (B,n)) raw squared-distance blocks from the feature buffer.

    The (B,n) cross block is `gp.pairwise_sqdist`'s expansion verbatim —
    same sum-of-squares, same matmul contraction over d, same clamp — and
    its rows are bitwise equal to rows of `precompute_d2`'s (n,n) tensor
    (the contraction axis and its order are identical whether the left
    operand has extent B or n).  The (B,B) training block is then a COLUMN
    GATHER of the cross block by `tried`, not a second matmul: a
    (B,d)·(d,B) self-product can fuse differently from the (n,d)·(d,n)
    one (observed at d = 1 on XLA:CPU, last-ulp), while gathers are exact
    — so block identity with the d²-gather layout holds by construction.
    O(Bnd) compute and O(Bn) memory; nothing of extent n² exists.
    """
    d2_bn = pairwise_sqdist(feats, encoded)
    idx = jnp.maximum(tried, 0)  # padded slots gather column 0; masked later
    return d2_bn[:, idx], d2_bn


def gather_sqdist_blocks(
    d2: jax.Array,  # (n, n) precomputed raw squared distances
    tried: jax.Array,  # (B,) i32 trial log, -1 padded
) -> Tuple[jax.Array, jax.Array]:
    """((B,B), (B,n)) blocks gathered from the precomputed (n,n) tensor.

    The PR-2 layout; padded slots gather row 0 (finite garbage, masked
    exactly downstream).
    """
    idx = jnp.maximum(tried, 0)
    return d2[idx[:, None], idx[None, :]], d2[idx]


def _masked_posterior(
    x: jax.Array,  # (n, d)
    obs_mask: jax.Array,  # (n,) bool
    y_n: jax.Array,  # (n,) standardized targets, 0 where unobserved
    lengthscale: jax.Array,
    noise: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Reference form of the exact-masking construction: (lml, mean, var)
    over ALL n points for one (lengthscale, noise).

    This is the specification `tests/test_core_bo.py` checks against the
    readable subset-GP in `gp.py`; the packed `bo_step_core` computes the
    same math with the observed set packed into (B,) buffers instead of
    masked in place at extent n.
    """
    m = obs_mask.astype(x.dtype)
    params = GPParams(lengthscale=lengthscale, amplitude=jnp.asarray(1.0, x.dtype), noise=noise)
    k = matern52(x, x, params)
    mm = m[:, None] * m[None, :]
    k_eff = k * mm + jnp.diag(jnp.where(obs_mask, noise + _JITTER, 1.0))
    chol = jnp.linalg.cholesky(k_eff)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y_n * m)
    lml = (
        -0.5 * (y_n * m) @ alpha
        - jnp.sum(jnp.log(jnp.diagonal(chol)) * m)
        - 0.5 * jnp.sum(m) * jnp.log(2.0 * jnp.pi)
    )
    k_star = k * m[:, None]  # masked training rows
    mean_n = k_star.T @ alpha
    v = jax.scipy.linalg.solve_triangular(chol, k_star, lower=True)
    var_n = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    return lml, mean_n, var_n


def _packed_head(
    d2_bb: jax.Array,  # (B, B) raw squared distances, training block
    py: jax.Array,  # (B,) f32 packed observed costs, trial order
    t: jax.Array,  # () i32 observations made (valid packed slots)
) -> Tuple[jax.Array, ...]:
    """The training-side math every packed layout shares: target
    standardization, the 18-point (lengthscale, noise) grid, masked
    Cholesky factorizations, and marginal-likelihood selection.  Everything
    here is extent-B — the space extent n never appears — so the fused
    layout runs it verbatim and streams only the tail.

    Returns ``(pm, best, ls_sel, chol, alpha, y_mean, y_std)``: the
    selected posterior factors the EI tail consumes.
    """
    b = py.shape[0]
    pmask = jnp.arange(b) < t
    pm = pmask.astype(jnp.float32)

    py = py.astype(jnp.float32)
    n_obs = jnp.maximum(jnp.sum(pm), 1.0)
    y_mean = jnp.sum(py * pm) / n_obs
    y_var = jnp.sum(pm * (py - y_mean) ** 2) / n_obs
    y_std = jnp.maximum(jnp.sqrt(y_var), 1e-8)
    y_train = jnp.where(pmask, (py - y_mean) / y_std, 0.0)

    # The kernel depends on the lengthscale only, and a scalar lengthscale
    # only rescales d²: 6 elementwise rescales of one (B,B) block serve all
    # 18 (lengthscale, noise) grid points.
    ls = jnp.asarray(_LENGTHSCALES, jnp.float32)
    nz = jnp.asarray(_NOISES, jnp.float32)
    ks = jax.vmap(lambda l: matern52_from_sqdist(d2_bb, l))(ls)  # (6, B, B)

    mm = pm[:, None] * pm[None, :]
    # Mask once per lengthscale (6 products), not per grid combo (18); the
    # noise only touches the diagonal, added by a B-element scatter.
    ks_masked = ks * mm[None]  # (6, B, B)
    diag_idx = jnp.arange(b)

    def factorize(k_masked, noise):
        """Masked-kernel Cholesky + lml for one (lengthscale, noise)."""
        diag = jnp.where(pmask, noise + _JITTER, 1.0)
        k_eff = k_masked.at[diag_idx, diag_idx].add(diag)
        chol = jnp.linalg.cholesky(k_eff)
        alpha = jax.scipy.linalg.cho_solve((chol, True), y_train)
        lml = (
            -0.5 * y_train @ alpha
            - jnp.sum(jnp.log(jnp.diagonal(chol)) * pm)
            - 0.5 * jnp.sum(pm) * jnp.log(2.0 * jnp.pi)
        )
        return lml, chol, alpha

    # ls-major grid order (matches jnp.meshgrid(..., indexing="ij")):
    # combo h = (h // 3)-th lengthscale, (h % 3)-th noise.
    ks18 = jnp.repeat(ks_masked, nz.shape[0], axis=0)  # (18, B, B)
    nz18 = jnp.tile(nz, ls.shape[0])  # (18,)
    lmls, chols, alphas = jax.vmap(factorize)(ks18, nz18)
    lmls = jnp.where(jnp.isfinite(lmls), lmls, -jnp.inf)
    best_h = jnp.argmax(lmls)

    best = jnp.min(jnp.where(pmask, py, jnp.inf))
    return (
        pm, best, ls[best_h // nz.shape[0]], chols[best_h], alphas[best_h],
        y_mean, y_std,
    )


def _packed_core(
    d2_bb: jax.Array,  # (B, B) raw squared distances, training block
    d2_bn: jax.Array,  # (B, n) raw squared distances, cross block
    py: jax.Array,  # (B,) f32 packed observed costs, trial order
    t: jax.Array,  # () i32 observations made (valid packed slots)
    obs_mask: jax.Array,  # (n,) bool — configurations already tried
    cand_mask: jax.Array,  # (n,) bool — current candidate pool
    xi: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Everything downstream of the distance blocks, shared verbatim by the
    feature-buffer and d²-gather layouts — the op-for-op identity of this
    tail is what makes the two layouts' picks bit-identical.  The EI math
    itself is `ei_from_sqdist`, the SAME function the fused layout's tiled
    lanes execute per (B,tile) block (`repro.kernels.ei_argmax`), so the
    unfused reference and the fused kernel cannot drift apart.
    """
    pm, best, ls_sel, chol, alpha, y_mean, y_std = _packed_head(d2_bb, py, t)
    # Posterior + EI over all n points for the selected hyperparameters
    # only: one (B,n) rescale of the cross block, masked training rows.
    ei = ei_from_sqdist(
        d2_bn, pm, alpha, chol, ls_sel, y_mean, y_std, best,
        cand_mask & ~obs_mask, xi,
    )
    pick = jnp.argmax(ei)
    return pick, jnp.max(ei), best


def bo_step_core(
    encoded: jax.Array,  # (n, d) static float32 encoding of the whole space
    feats: jax.Array,  # (B, d) packed features of observed points, trial order
    tried: jax.Array,  # (B,) i32 trial log in trial order, -1 padded
    py: jax.Array,  # (B,) f32 packed observed costs, aligned with feats
    t: jax.Array,  # () i32 observations made (valid packed slots)
    obs_mask: jax.Array,  # (n,) bool — configurations already tried
    cand_mask: jax.Array,  # (n,) bool — current candidate pool
    xi: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One feature-buffer BO iteration, traceable.  Returns
    (pick_index, max_ei, best).

    All training-side linear algebra runs at the packed capacity B; the
    space extent n only appears in the O(Bnd) cross-block matmul, the (B,n)
    rescale, and the EI argmax.  Nothing of extent n² exists anywhere.
    """
    d2_bb, d2_bn = packed_sqdist_blocks(feats, encoded, tried)
    return _packed_core(d2_bb, d2_bn, py, t, obs_mask, cand_mask, xi)


def bo_step_core_fused(
    encoded: jax.Array,  # (n, d) static float32 encoding of the whole space
    feats: jax.Array,  # (B, d) packed features of observed points, trial order
    tried: jax.Array,  # (B,) i32 trial log in trial order, -1 padded
    py: jax.Array,  # (B,) f32 packed observed costs, aligned with feats
    t: jax.Array,  # () i32 observations made (valid packed slots)
    obs_mask: jax.Array,  # (n,) bool — configurations already tried
    cand_mask: jax.Array,  # (n,) bool — current candidate pool
    xi: float = 0.0,
    *,
    tile: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One fused-kernel BO iteration, traceable.  Returns
    (pick_index, max_ei, best) — bit-identical to `bo_step_core`.

    The extent-B head (`_packed_head`) runs unchanged; the n-extent tail is
    the fused streaming kernel (`repro.kernels.ei_argmax`): tiles of the
    candidate axis flow through distance → posterior rescale → EI → a
    running (max, argmax) pair, so the (B,n) cross block is NEVER
    materialized — peak transient memory drops from O(B·n) to O(B·tile).
    The training block is computed directly as `pairwise_sqdist(feats,
    encoded[tried])`: for d ≥ 2 this reproduces the feature lane's gathered
    block bit-for-bit (the (B,d)·(d,B) contraction is the same reduction,
    and XLA:CPU compiles it stably across program contexts — property- and
    golden-pinned).

    d = 1 delegates to the feature path wholesale: XLA:CPU rewrites the
    degenerate (·,1)·(1,·) matmul elementwise with CONTEXT-DEPENDENT
    fusion — any differently-shaped fused program drifts by an ulp
    (observed for the direct training block and for zero-padded d→2
    formulations alike), and one ulp flips late-search argmax picks.
    Identical program ⇒ identical bits; a single-feature space is
    degenerate for catalog-scale search anyway, which is the regime the
    kernel exists for.

    ``tile`` (None → 1024-wide tiles, single-tile for small n) and
    ``interpret`` (None → TPU: compiled Pallas, CPU: compiled `lax.scan`;
    True: Pallas interpreter, the kernel-identity test lane) are
    trace-static.
    """
    if encoded.shape[-1] < 2:
        return bo_step_core(encoded, feats, tried, py, t, obs_mask,
                            cand_mask, xi)
    idx = jnp.maximum(tried, 0)  # padded slots: column 0, masked via pm
    d2_bb = pairwise_sqdist(feats, encoded[idx])
    pm, best, ls_sel, chol, alpha, y_mean, y_std = _packed_head(d2_bb, py, t)
    pick, max_ei = ei_argmax(
        encoded, cand_mask & ~obs_mask, feats, pm, alpha, chol,
        ls_sel, y_mean, y_std, best, xi=xi, tile=tile, interpret=interpret,
    )
    return pick, max_ei, best


def bo_step_core_gather(
    d2: jax.Array,  # (n, n) raw pairwise squared distances (precompute_d2)
    tried: jax.Array,  # (B,) i32 trial log in trial order, -1 padded
    py: jax.Array,  # (B,) f32 packed observed costs, aligned with tried
    t: jax.Array,  # () i32 observations made (valid packed slots)
    obs_mask: jax.Array,  # (n,) bool — configurations already tried
    cand_mask: jax.Array,  # (n,) bool — current candidate pool
    xi: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The retained PR-2 d²-gather BO iteration: blocks gathered from the
    once-per-search (n,n) tensor instead of recomputed from features.

    Kept as the cross-check for the feature-buffer layout (the two must be
    bit-identical — `tests/test_feature_buffer.py`) and for the scaling
    sweep in `benchmarks/fleet_bench.py`.  Not used by the default engines.
    """
    d2_bb, d2_bn = gather_sqdist_blocks(d2, tried)
    return _packed_core(d2_bb, d2_bn, py, t, obs_mask, cand_mask, xi)


def bo_step_core_dense(
    encoded: jax.Array,  # (n, d) standardized features of the whole space
    obs_mask: jax.Array,  # (n,) bool — configurations already tried
    y: jax.Array,  # (n,) observed costs (garbage where not observed)
    cand_mask: jax.Array,  # (n,) bool — current candidate pool
    xi: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The pre-packed full-extent BO step: O(18n³) per call.

    Retained as the dense baseline `benchmarks/fleet_bench.py` times the
    packed layouts against, and as a second reference for the packed math
    in `tests/test_core_bo.py`.  Not used by either search engine.
    """
    x = encoded.astype(jnp.float32)
    m = obs_mask.astype(x.dtype)
    n_obs = jnp.maximum(jnp.sum(m), 1.0)
    y = y.astype(x.dtype)
    y_mean = jnp.sum(y * m) / n_obs
    y_var = jnp.sum(m * (y - y_mean) ** 2) / n_obs
    y_std = jnp.maximum(jnp.sqrt(y_var), 1e-8)
    y_n = jnp.where(obs_mask, (y - y_mean) / y_std, 0.0)

    ls = jnp.asarray(_LENGTHSCALES, x.dtype)
    nz = jnp.asarray(_NOISES, x.dtype)
    d2 = pairwise_sqdist(x)
    ks = jax.vmap(lambda l: matern52_from_sqdist(d2, l))(ls)  # (6, n, n)

    mm = m[:, None] * m[None, :]
    y_train = y_n * m
    ks_masked = ks * mm[None]  # (6, n, n)
    diag_idx = jnp.arange(ks.shape[-1])

    def factorize(k_masked, noise):
        diag = jnp.where(obs_mask, noise + _JITTER, 1.0)
        k_eff = k_masked.at[diag_idx, diag_idx].add(diag)
        chol = jnp.linalg.cholesky(k_eff)
        alpha = jax.scipy.linalg.cho_solve((chol, True), y_train)
        lml = (
            -0.5 * y_train @ alpha
            - jnp.sum(jnp.log(jnp.diagonal(chol)) * m)
            - 0.5 * jnp.sum(m) * jnp.log(2.0 * jnp.pi)
        )
        return lml, chol, alpha

    ks18 = jnp.repeat(ks_masked, nz.shape[0], axis=0)  # (18, n, n)
    nz18 = jnp.tile(nz, ls.shape[0])  # (18,)
    lmls, chols, alphas = jax.vmap(factorize)(ks18, nz18)
    lmls = jnp.where(jnp.isfinite(lmls), lmls, -jnp.inf)
    best_h = jnp.argmax(lmls)

    k_star = ks[best_h // nz.shape[0]] * m[:, None]  # masked training rows
    mean_n = k_star.T @ alphas[best_h]
    v = jax.scipy.linalg.solve_triangular(chols[best_h], k_star, lower=True)
    var_n = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    std_n = jnp.sqrt(var_n)

    mean = mean_n * y_std + y_mean
    std = std_n * y_std

    best = jnp.min(jnp.where(obs_mask, y, jnp.inf))
    improvement = best - mean - xi
    z = improvement / jnp.maximum(std, 1e-12)
    cdf = 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
    pdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    ei = jnp.maximum(improvement * cdf + std * pdf, 0.0)
    ei = jnp.where(cand_mask & ~obs_mask, ei, -jnp.inf)
    pick = jnp.argmax(ei)
    return pick, jnp.max(ei), best


class FleetState(NamedTuple):
    """Per-job search state, device-resident between `fleet_step` calls.

    The packed buffers (`tried`, `py`, `feats`) have static capacity B =
    the job's trial budget; slot k holds the k-th observation, in trial
    order.  `feats` carries the observed points' encoded features — the
    feature-buffer layout computes its kernel blocks from it, the d²-gather
    layout carries it untouched (zeros) so both layouts share one state
    type and one donation contract.
    """

    obs: jax.Array  # (n,) bool — observation mask over the space
    tried: jax.Array  # (B,) i32 — trial log, -1 padded
    py: jax.Array  # (B,) f32 — packed observed costs, aligned with tried
    feats: jax.Array  # (B, d) f32 — packed features of observed points
    t: jax.Array  # () i32 — trials made
    stop: jax.Array  # () i32 — stop-criterion iteration, -1 = not yet
    pb: jax.Array  # () i32 — phase boundary, -1 = still in phase 0
    done: jax.Array  # () bool
    last_ei: jax.Array  # () f32 — max EI of the latest BO step
    last_best: jax.Array  # () f32 — best observed cost at the latest step


def fleet_step(
    state: FleetState,
    geom: jax.Array,  # (n,d) encoded [feature layout] | (n,n) d2 [gather]
    costs: jax.Array,  # (n,) f32 — full observation table
    prio_mask: jax.Array,  # (n,) bool — priority pool (phase 0)
    rem_mask: jax.Array,  # (n,) bool — remaining pool (phase 1)
    init_picks: jax.Array,  # (I,) i32 — scripted random initialization
    init_count: jax.Array,  # () i32
    max_trials: jax.Array,  # () i32 — trial budget (pool size ∧ max_iters)
    min_obs: jax.Array,  # () i32 — no stopping before this many trials
    ei_stop_rel: jax.Array,  # () f32 — stop when max EI < rel·best
    to_exhaustion: jax.Array,  # () bool — record the stop but keep going
    xi: float = 0.0,
    layout: str = "feature",
) -> FleetState:
    """One search iteration: candidate pools → BO step → stop/phase
    bookkeeping → observation.  Applying it `max_trials` times executes one
    complete two-phase search; semantics mirror
    `repro.core.bayesopt._bo_loop` exactly.  A no-op once the job is done.

    ``layout`` is trace-static: "feature" (default) takes the (n,d)
    encoding as ``geom`` and maintains the packed feature buffer; "fused"
    takes the same geometry and buffer but streams the n-extent tail
    through the fused EI/argmax kernel (`bo_step_core_fused` — no (B,n)
    block); "gather" takes the precomputed (n,n) distance tensor (the
    retained PR-2 path) and leaves ``state.feats`` untouched.
    """
    if layout not in _LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; want one of {_LAYOUTS}")
    obs, tried, py, feats, t, stop, pb = (
        state.obs, state.tried, state.py, state.feats, state.t, state.stop,
        state.pb,
    )
    n_init_slots = init_picks.shape[0]

    budget_left = t < max_trials
    live = ~state.done & budget_left
    prio_left = prio_mask & ~obs
    rem_left = rem_mask & ~obs
    in_phase0 = jnp.any(prio_left)
    cand = jnp.where(in_phase0, prio_left, rem_left)
    has_cand = jnp.any(cand)
    # Entering the remaining phase with a non-empty pool records the
    # boundary (sequential: set at phase entry, before any phase-1 step).
    # Gated on ~done only, NOT on the budget: when max_iters lands exactly
    # on the phase-0/phase-1 boundary the sequential engine still records
    # the boundary before its budget check returns.
    pb = jnp.where(~state.done & (pb < 0) & ~in_phase0 & jnp.any(rem_left), t, pb)

    is_init = t < init_count
    if layout == "feature":
        bo_pick, max_ei, best = bo_step_core(
            geom, feats, tried, py, t, obs, cand, xi
        )
    elif layout == "fused":
        bo_pick, max_ei, best = bo_step_core_fused(
            geom, feats, tried, py, t, obs, cand, xi
        )
    else:
        bo_pick, max_ei, best = bo_step_core_gather(
            geom, tried, py, t, obs, cand, xi
        )
    scripted = init_picks[jnp.clip(t, 0, n_init_slots - 1)]
    pick = jnp.where(is_init, scripted, bo_pick).astype(jnp.int32)

    fire = (
        live
        & has_cand
        & ~is_init
        & (stop < 0)
        & (t >= min_obs)
        & (max_ei < ei_stop_rel * best)
    )
    stop = jnp.where(fire, t, stop)
    halt = fire & ~to_exhaustion
    observe = live & has_cand & ~halt

    slot = jnp.minimum(t, tried.shape[0] - 1)
    obs = jnp.where(observe, obs.at[pick].set(True), obs)
    tried = jnp.where(observe, tried.at[slot].set(pick), tried)
    py = jnp.where(observe, py.at[slot].set(costs[pick]), py)
    if layout in ("feature", "fused"):
        # The observed point's features enter the packed buffer — the only
        # geometry the next step's kernel blocks will read.
        feats = jnp.where(observe, feats.at[slot].set(geom[pick]), feats)
    t = t + observe.astype(jnp.int32)
    # A job is done when its candidates ran out, its stop criterion halted
    # it, or its trial budget is exhausted (the last also settles zero-budget
    # dummy pads so early-stop polling can see an all-done chunk).
    done = state.done | (live & (~has_cand | halt)) | ~budget_left
    return FleetState(
        obs=obs, tried=tried, py=py, feats=feats, t=t, stop=stop, pb=pb,
        done=done,
        last_ei=jnp.where(live, max_ei, state.last_ei),
        last_best=jnp.where(live, best, state.last_best),
    )


@partial(jax.jit, static_argnames=("xi", "layout"), donate_argnums=(0,))
def _probe_step(
    state2: FleetState,  # batch-extent-2 state (row 1: discarded duplicate)
    geom2, costs2, prio2, rem2, init_picks2, init_count2, last_cost,
    *, xi: float, layout: str,
):
    """One `fleet_step` application at batch extent 2 (extent 1 compiles to
    different float32 numerics).  The state is DONATED: XLA updates the
    packed buffers (including the (B,d) feature buffer) in place instead of
    copying them each iteration.

    The probe runs before the cost of its pick is known, so slot t-1 holds a
    placeholder 0 from the previous call's observation; `last_cost` patches
    in the real value before any math runs.  (The feature buffer needs no
    patching: the picked point's features are known at observation time.)
    """
    t_prev = state2.t[0]
    slot = jnp.maximum(t_prev - 1, 0)
    val = jnp.where(t_prev > 0, last_cost, state2.py[0, slot])
    state2 = state2._replace(py=state2.py.at[:, slot].set(val))

    def one(s, g, c, p, r, ip, ic):
        return fleet_step(
            s, g, c, p, r, ip, ic,
            s.t + 1,  # budget for exactly one more trial
            jnp.asarray(0, jnp.int32),
            jnp.asarray(0.0, jnp.float32),
            jnp.asarray(True),  # never halt inside the probe
            xi,
            layout,
        )

    out = jax.vmap(one)(state2, geom2, costs2, prio2, rem2, init_picks2,
                        init_count2)
    b = out.tried.shape[1]
    pick = out.tried[0, jnp.minimum(t_prev, b - 1)]
    return out, pick, out.last_ei[0], out.last_best[0]


class SequentialProbe:
    """Device-resident sequential BO stepper over the shared `fleet_step`.

    Carries the packed search state on device between steps at batch extent
    2, donating it back to every jitted probe call, so a sequential search
    makes no per-iteration device copies: per step, one f32 scalar goes up
    (the latest observed cost) and (pick, max_ei, best) scalars come back.

    ``capacity`` must equal the trial budget the fleet engine would compute
    for the same job — both engines then factorize (B,B) systems of the
    same static extent, which is what keeps their traces bit-identical.

    ``layout="feature"`` (default) keeps only the (n,d) encoding on device
    — O(n·d) memory, the 10⁴–10⁵-point regime; ``layout="fused"`` keeps
    the same encoding and streams the EI tail through the fused kernel
    (O(B·tile) transients, bit-identical picks); ``layout="gather"`` is
    the retained PR-2 path holding the (n,n) distance tensor.
    """

    def __init__(self, encoded, capacity: int, xi: float = 0.0,
                 layout: str = "feature"):
        if layout not in _LAYOUTS:
            raise ValueError(f"unknown layout {layout!r}; want one of {_LAYOUTS}")
        enc = encode_features(encoded)
        self._n, self._d = enc.shape
        self._b = max(int(capacity), 1)
        self._xi = float(xi)
        self._layout = layout
        self._enc = enc
        if layout in ("feature", "fused"):
            geom = jnp.asarray(enc)
        else:
            geom = precompute_d2(enc)
        self._geom2 = jnp.stack([geom, geom])
        # Observation values are irrelevant inside the probe: the real cost
        # arrives via `last_cost` on the following call.
        self._costs2 = jnp.zeros((2, self._n), jnp.float32)
        self._rem2 = jnp.zeros((2, self._n), bool)
        self._init_picks2 = jnp.zeros((2, 1), jnp.int32)
        self._init_count2 = jnp.zeros(2, jnp.int32)  # no scripted init
        self._pool2 = None
        self._state = None

    def set_pool(self, pool_mask) -> None:
        """Install the current phase's candidate pool (device copy, once)."""
        pool = jnp.asarray(np.asarray(pool_mask, bool))
        self._pool2 = jnp.stack([pool, pool])

    def start(self, obs_mask, trial_order: Sequence[int], trial_costs) -> None:
        """Build the device state from the host-side search history."""
        k = len(trial_order)
        if k > self._b:
            raise ValueError(f"{k} observations exceed packed capacity {self._b}")
        order = np.asarray(trial_order, np.int32)
        tried = np.full(self._b, -1, np.int32)
        py = np.zeros(self._b, np.float32)
        feats = np.zeros((self._b, self._d), np.float32)
        tried[:k] = order
        py[:k] = np.asarray(trial_costs, np.float32)
        # Rows of the canonical float32 encoding — bit-identical to what the
        # on-device observation writes would have accumulated.
        feats[:k] = self._enc[order]

        def two(a):
            a = jnp.asarray(a)
            return jnp.stack([a, a])

        self._state = FleetState(
            obs=two(np.asarray(obs_mask, bool)),
            tried=two(tried),
            py=two(py),
            feats=two(feats),
            t=two(np.asarray(k, np.int32)),
            stop=two(np.asarray(-1, np.int32)),
            pb=two(np.asarray(-1, np.int32)),
            done=two(np.asarray(False)),
            last_ei=two(np.asarray(0.0, np.float32)),
            last_best=two(np.asarray(np.inf, np.float32)),
        )

    def step(self, last_cost: float) -> Tuple[int, float, float]:
        """One BO iteration.  Returns (pick_index, max_ei, best_observed)."""
        if self._state is None or self._pool2 is None:
            raise RuntimeError("call start() and set_pool() before step()")
        self._state, pick, ei, best = _probe_step(
            self._state, self._geom2, self._costs2, self._pool2, self._rem2,
            self._init_picks2, self._init_count2,
            jnp.asarray(last_cost, jnp.float32), xi=self._xi,
            layout=self._layout,
        )
        return int(pick), float(ei), float(best)


def bo_step(
    encoded,
    obs_mask,
    y,
    cand_mask,
    xi: float = 0.0,
    *,
    trial_order: Optional[Sequence[int]] = None,
    capacity: Optional[int] = None,
    layout: str = "feature",
) -> Tuple[int, float, float]:
    """One standalone BO iteration.  Returns (pick_index, max_ei, best).

    Packs the observed set on the fly — in ascending index order unless
    ``trial_order`` is given (a sequential search passes its real trial
    order so the packed buffer matches the fleet engine's bit-for-bit) —
    and probes the shared `fleet_step` program once.  ``capacity`` defaults
    to the number of observations (a full buffer).
    """
    obs_mask = np.asarray(obs_mask, bool)
    y = np.asarray(y, np.float32)
    order = (
        np.asarray(trial_order, np.int64)
        if trial_order is not None
        else np.flatnonzero(obs_mask)
    )
    cap = int(capacity) if capacity is not None else max(1, len(order))
    probe = SequentialProbe(encoded, cap, xi=xi, layout=layout)
    probe.set_pool(cand_mask)
    probe.start(obs_mask, order, y[order])
    last = float(y[order][-1]) if len(order) else 0.0
    return probe.step(last)
