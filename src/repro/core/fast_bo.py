"""Fixed-shape, fully-jitted Bayesian-optimization step.

The paper's evaluation repeats every search 200 times over a 69-point space,
to exhaustion — thousands of GP fits.  To keep that cheap we jit ONE step
function over fixed shapes: all N configurations are always present, and
boolean masks select the observed set and the candidate pool.  Padding is
exact (not approximate): the padded kernel rows are identity rows, so the
Cholesky factorization block-decouples and padded points contribute nothing
to the posterior.

The hyperparameter grid search (same grid as `gp.py`) is vmapped inside the
step, so a single jitted call performs: standardize-y → select (lengthscale,
noise) by masked log-marginal-likelihood → posterior at all N points →
Expected Improvement on the candidate mask → argmax pick.

`tests/test_core_bo.py` property-checks this fast path against the readable
reference implementation in `gp.py`/`acquisition.py`.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.gp import GPParams, matern52

__all__ = ["bo_step"]

_JITTER = 1e-8
_LENGTHSCALES = (0.1, 0.25, 0.5, 1.0, 2.0, 4.0)
_NOISES = (1e-4, 1e-2, 1e-1)


def _masked_posterior(
    x: jax.Array,  # (n, d)
    obs_mask: jax.Array,  # (n,) bool
    y_n: jax.Array,  # (n,) standardized targets, 0 where unobserved
    lengthscale: jax.Array,
    noise: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (lml, mean_n, var_n) — posterior over ALL n points."""
    n = x.shape[0]
    m = obs_mask.astype(x.dtype)
    params = GPParams(lengthscale=lengthscale, amplitude=jnp.asarray(1.0, x.dtype), noise=noise)
    k = matern52(x, x, params)
    mm = m[:, None] * m[None, :]
    k_eff = k * mm + jnp.diag(jnp.where(obs_mask, noise + _JITTER, 1.0))
    chol = jnp.linalg.cholesky(k_eff)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y_n * m)
    lml = (
        -0.5 * (y_n * m) @ alpha
        - jnp.sum(jnp.log(jnp.diagonal(chol)) * m)
        - 0.5 * jnp.sum(m) * jnp.log(2.0 * jnp.pi)
    )
    # Posterior at all n points: k_star has masked training rows.
    k_star = k * m[:, None]  # (n_train_slots, n_points)
    mean_n = k_star.T @ alpha
    v = jax.scipy.linalg.solve_triangular(chol, k_star, lower=True)
    var_n = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    return lml, mean_n, var_n


@partial(jax.jit, static_argnames=("xi",))
def bo_step(
    encoded: jax.Array,  # (n, d) standardized features of the whole space
    obs_mask: jax.Array,  # (n,) bool — configurations already tried
    y: jax.Array,  # (n,) observed costs (garbage where not observed)
    cand_mask: jax.Array,  # (n,) bool — current candidate pool
    xi: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One BO iteration.  Returns (pick_index, max_ei, best_observed_cost)."""
    x = encoded.astype(jnp.float32)
    m = obs_mask.astype(x.dtype)
    n_obs = jnp.maximum(jnp.sum(m), 1.0)
    y = y.astype(x.dtype)
    y_mean = jnp.sum(y * m) / n_obs
    y_var = jnp.sum(m * (y - y_mean) ** 2) / n_obs
    y_std = jnp.maximum(jnp.sqrt(y_var), 1e-8)
    y_n = jnp.where(obs_mask, (y - y_mean) / y_std, 0.0)

    ls_grid, nz_grid = jnp.meshgrid(
        jnp.asarray(_LENGTHSCALES, x.dtype), jnp.asarray(_NOISES, x.dtype), indexing="ij"
    )
    ls_grid, nz_grid = ls_grid.reshape(-1), nz_grid.reshape(-1)

    lmls, means, variances = jax.vmap(
        lambda ls, nz: _masked_posterior(x, obs_mask, y_n, ls, nz)
    )(ls_grid, nz_grid)
    lmls = jnp.where(jnp.isfinite(lmls), lmls, -jnp.inf)
    best_h = jnp.argmax(lmls)
    mean_n = means[best_h]
    std_n = jnp.sqrt(variances[best_h])

    # De-standardize.
    mean = mean_n * y_std + y_mean
    std = std_n * y_std

    best = jnp.min(jnp.where(obs_mask, y, jnp.inf))
    improvement = best - mean - xi
    z = improvement / jnp.maximum(std, 1e-12)
    cdf = 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
    pdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    ei = jnp.maximum(improvement * cdf + std * pdf, 0.0)
    ei = jnp.where(cand_mask & ~obs_mask, ei, -jnp.inf)
    pick = jnp.argmax(ei)
    return pick, jnp.max(ei), best
