"""Fixed-shape, fully-jitted Bayesian-optimization step and fleet update.

The paper's evaluation repeats every search 200 times over a 69-point space,
to exhaustion — thousands of GP fits.  To keep that cheap we jit ONE step
function over fixed shapes: all N configurations are always present, and
boolean masks select the observed set and the candidate pool.  Padding is
exact (not approximate): the padded kernel rows are identity rows, so the
Cholesky factorization block-decouples and padded points contribute nothing
to the posterior.

`bo_step_core` performs: standardize-y → Matérn-5/2 kernels for the 6
lengthscales (computed once, shared by the 3 noise levels) → select
(lengthscale, noise) by masked log-marginal-likelihood over the 18-point
grid (same grid as `gp.py`) → posterior at all N points for the selected
hyperparameters only → Expected Improvement on the candidate mask → argmax.

`fleet_step` wraps the core with one search iteration's bookkeeping
(scripted init picks, two-phase candidate pools, stop/phase registers, the
observation itself) over a state pytree that lives on device.  It is the
single compiled program behind BOTH engines:

  * the fleet engine (`repro.fleet.batched_engine`) vmaps it over a chunk of
    jobs and applies it in a host-driven lockstep loop (state stays on
    device; the host only counts iterations);
  * the sequential driver's `bo_step` probes the identical function for one
    iteration at batch extent 2.

This sharing is deliberate: XLA:CPU float32 results differ between
compilation contexts — a `lax.while_loop` body computes different last-ulp
floats than the same ops standalone (and batch extent 1 differs from
extent ≥ 2, which is why the probe pads to 2) — and in the late-search
regime, where dozens of candidates carry near-zero EI, one ulp flips argmax
picks.  Executing one program everywhere is what makes sequential and
batched searches trace-identical (asserted by `tests/test_fleet.py`).
A `lax.while_loop` around `fleet_step` was tried and rejected: XLA:CPU runs
while bodies ~5-8× slower than the identical standalone computation, which
inverted the fleet speedup.

`tests/test_core_bo.py` property-checks this fast path against the readable
reference implementation in `gp.py`/`acquisition.py`.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.gp import GPParams, matern52

__all__ = ["FleetState", "bo_step", "bo_step_core", "fleet_step"]

_JITTER = 1e-8
_LENGTHSCALES = (0.1, 0.25, 0.5, 1.0, 2.0, 4.0)
_NOISES = (1e-4, 1e-2, 1e-1)


def _masked_posterior(
    x: jax.Array,  # (n, d)
    obs_mask: jax.Array,  # (n,) bool
    y_n: jax.Array,  # (n,) standardized targets, 0 where unobserved
    lengthscale: jax.Array,
    noise: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Reference form of the exact-masking construction: (lml, mean, var)
    over ALL n points for one (lengthscale, noise).

    This is the specification `tests/test_core_bo.py` checks against the
    readable subset-GP in `gp.py`; `bo_step_core` computes the same math in
    a grid-factored layout (kernels shared across noise levels, the full
    posterior only for the selected hyperparameters).
    """
    m = obs_mask.astype(x.dtype)
    params = GPParams(lengthscale=lengthscale, amplitude=jnp.asarray(1.0, x.dtype), noise=noise)
    k = matern52(x, x, params)
    mm = m[:, None] * m[None, :]
    k_eff = k * mm + jnp.diag(jnp.where(obs_mask, noise + _JITTER, 1.0))
    chol = jnp.linalg.cholesky(k_eff)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y_n * m)
    lml = (
        -0.5 * (y_n * m) @ alpha
        - jnp.sum(jnp.log(jnp.diagonal(chol)) * m)
        - 0.5 * jnp.sum(m) * jnp.log(2.0 * jnp.pi)
    )
    k_star = k * m[:, None]  # masked training rows
    mean_n = k_star.T @ alpha
    v = jax.scipy.linalg.solve_triangular(chol, k_star, lower=True)
    var_n = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    return lml, mean_n, var_n


def bo_step_core(
    encoded: jax.Array,  # (n, d) standardized features of the whole space
    obs_mask: jax.Array,  # (n,) bool — configurations already tried
    y: jax.Array,  # (n,) observed costs (garbage where not observed)
    cand_mask: jax.Array,  # (n,) bool — current candidate pool
    xi: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One BO iteration, traceable.  Returns (pick_index, max_ei, best)."""
    x = encoded.astype(jnp.float32)
    m = obs_mask.astype(x.dtype)
    n_obs = jnp.maximum(jnp.sum(m), 1.0)
    y = y.astype(x.dtype)
    y_mean = jnp.sum(y * m) / n_obs
    y_var = jnp.sum(m * (y - y_mean) ** 2) / n_obs
    y_std = jnp.maximum(jnp.sqrt(y_var), 1e-8)
    y_n = jnp.where(obs_mask, (y - y_mean) / y_std, 0.0)

    # The kernel depends on the lengthscale only: 6 kernels serve all 18
    # (lengthscale, noise) grid points.
    ls = jnp.asarray(_LENGTHSCALES, x.dtype)
    nz = jnp.asarray(_NOISES, x.dtype)

    def kernel_for(lengthscale):
        params = GPParams(
            lengthscale=lengthscale,
            amplitude=jnp.asarray(1.0, x.dtype),
            noise=jnp.asarray(0.0, x.dtype),
        )
        return matern52(x, x, params)

    ks = jax.vmap(kernel_for)(ls)  # (6, n, n)

    mm = m[:, None] * m[None, :]
    y_train = y_n * m
    # Mask once per lengthscale (6 products), not per grid combo (18); the
    # noise only touches the diagonal, added by an n-element scatter instead
    # of materializing a dense diag matrix per combo.
    ks_masked = ks * mm[None]  # (6, n, n)
    diag_idx = jnp.arange(ks.shape[-1])

    def factorize(k_masked, noise):
        """Masked-kernel Cholesky + lml for one (lengthscale, noise)."""
        diag = jnp.where(obs_mask, noise + _JITTER, 1.0)
        k_eff = k_masked.at[diag_idx, diag_idx].add(diag)
        chol = jnp.linalg.cholesky(k_eff)
        alpha = jax.scipy.linalg.cho_solve((chol, True), y_train)
        lml = (
            -0.5 * y_train @ alpha
            - jnp.sum(jnp.log(jnp.diagonal(chol)) * m)
            - 0.5 * jnp.sum(m) * jnp.log(2.0 * jnp.pi)
        )
        return lml, chol, alpha

    # ls-major grid order (matches jnp.meshgrid(..., indexing="ij")):
    # combo h = (h // 3)-th lengthscale, (h % 3)-th noise.
    ks18 = jnp.repeat(ks_masked, nz.shape[0], axis=0)  # (18, n, n)
    nz18 = jnp.tile(nz, ls.shape[0])  # (18,)
    lmls, chols, alphas = jax.vmap(factorize)(ks18, nz18)
    lmls = jnp.where(jnp.isfinite(lmls), lmls, -jnp.inf)
    best_h = jnp.argmax(lmls)

    # Posterior over all n points for the selected hyperparameters only.
    # (ks, not ks_masked: prediction columns must stay unmasked.)
    k_star = ks[best_h // nz.shape[0]] * m[:, None]  # masked training rows
    mean_n = k_star.T @ alphas[best_h]
    v = jax.scipy.linalg.solve_triangular(chols[best_h], k_star, lower=True)
    var_n = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    std_n = jnp.sqrt(var_n)

    # De-standardize.
    mean = mean_n * y_std + y_mean
    std = std_n * y_std

    best = jnp.min(jnp.where(obs_mask, y, jnp.inf))
    improvement = best - mean - xi
    z = improvement / jnp.maximum(std, 1e-12)
    cdf = 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
    pdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    ei = jnp.maximum(improvement * cdf + std * pdf, 0.0)
    ei = jnp.where(cand_mask & ~obs_mask, ei, -jnp.inf)
    pick = jnp.argmax(ei)
    return pick, jnp.max(ei), best


class FleetState(NamedTuple):
    """Per-job search state, device-resident between `fleet_step` calls."""

    obs: jax.Array  # (n,) bool — observation mask
    y: jax.Array  # (n,) f32 — observed costs (0 where unobserved)
    tried: jax.Array  # (T,) i32 — trial log, -1 padded
    t: jax.Array  # () i32 — trials made
    stop: jax.Array  # () i32 — stop-criterion iteration, -1 = not yet
    pb: jax.Array  # () i32 — phase boundary, -1 = still in phase 0
    done: jax.Array  # () bool
    last_ei: jax.Array  # () f32 — max EI of the latest BO step
    last_best: jax.Array  # () f32 — best observed cost at the latest step


def fleet_step(
    state: FleetState,
    encoded: jax.Array,  # (n, d)
    costs: jax.Array,  # (n,) f32 — full observation table
    prio_mask: jax.Array,  # (n,) bool — priority pool (phase 0)
    rem_mask: jax.Array,  # (n,) bool — remaining pool (phase 1)
    init_picks: jax.Array,  # (I,) i32 — scripted random initialization
    init_count: jax.Array,  # () i32
    max_trials: jax.Array,  # () i32 — trial budget (pool size ∧ max_iters)
    min_obs: jax.Array,  # () i32 — no stopping before this many trials
    ei_stop_rel: jax.Array,  # () f32 — stop when max EI < rel·best
    to_exhaustion: jax.Array,  # () bool — record the stop but keep going
    xi: float = 0.0,
) -> FleetState:
    """One search iteration: candidate pools → BO step → stop/phase
    bookkeeping → observation.  Applying it `max_trials` times executes one
    complete two-phase search; semantics mirror
    `repro.core.bayesopt._bo_loop` exactly.  A no-op once the job is done.
    """
    obs, y, tried, t, stop, pb = (
        state.obs, state.y, state.tried, state.t, state.stop, state.pb,
    )
    n_init_slots = init_picks.shape[0]

    budget_left = t < max_trials
    live = ~state.done & budget_left
    prio_left = prio_mask & ~obs
    rem_left = rem_mask & ~obs
    in_phase0 = jnp.any(prio_left)
    cand = jnp.where(in_phase0, prio_left, rem_left)
    has_cand = jnp.any(cand)
    # Entering the remaining phase with a non-empty pool records the
    # boundary (sequential: set at phase entry, before any phase-1 step).
    # Gated on ~done only, NOT on the budget: when max_iters lands exactly
    # on the phase-0/phase-1 boundary the sequential engine still records
    # the boundary before its budget check returns.
    pb = jnp.where(~state.done & (pb < 0) & ~in_phase0 & jnp.any(rem_left), t, pb)

    is_init = t < init_count
    bo_pick, max_ei, best = bo_step_core(encoded, obs, y, cand, xi)
    scripted = init_picks[jnp.clip(t, 0, n_init_slots - 1)]
    pick = jnp.where(is_init, scripted, bo_pick).astype(jnp.int32)

    fire = (
        live
        & has_cand
        & ~is_init
        & (stop < 0)
        & (t >= min_obs)
        & (max_ei < ei_stop_rel * best)
    )
    stop = jnp.where(fire, t, stop)
    halt = fire & ~to_exhaustion
    observe = live & has_cand & ~halt

    obs = jnp.where(observe, obs.at[pick].set(True), obs)
    y = jnp.where(observe, y.at[pick].set(costs[pick]), y)
    tried = jnp.where(observe, tried.at[jnp.minimum(t, tried.shape[0] - 1)].set(pick), tried)
    t = t + observe.astype(jnp.int32)
    # A job is done when its candidates ran out, its stop criterion halted
    # it, or its trial budget is exhausted (the last also settles zero-budget
    # dummy pads so early-stop polling can see an all-done chunk).
    done = state.done | (live & (~has_cand | halt)) | ~budget_left
    return FleetState(
        obs=obs, y=y, tried=tried, t=t, stop=stop, pb=pb, done=done,
        last_ei=jnp.where(live, max_ei, state.last_ei),
        last_best=jnp.where(live, best, state.last_best),
    )


@partial(jax.jit, static_argnames=("xi",))
def _probe_step(encoded, obs_mask, y, cand_mask, xi):
    """One `fleet_step` application at batch extent 2 (row 1 is a discarded
    duplicate — extent 1 compiles to different float32 numerics)."""
    n = encoded.shape[0]

    def probe(e, o, yy, c):
        state = FleetState(
            obs=o,
            y=yy,
            tried=jnp.full(1, -1, jnp.int32),
            t=jnp.asarray(0, jnp.int32),
            stop=jnp.asarray(-1, jnp.int32),
            pb=jnp.asarray(-1, jnp.int32),
            done=jnp.asarray(False),
            last_ei=jnp.asarray(0.0, jnp.float32),
            last_best=jnp.asarray(jnp.inf, jnp.float32),
        )
        out = fleet_step(
            state,
            e,
            jnp.zeros(n, jnp.float32),  # observation values are irrelevant
            c,  # candidate pool as the (only) phase-0 pool
            jnp.zeros(n, bool),
            jnp.zeros(1, jnp.int32),
            jnp.asarray(0, jnp.int32),  # no scripted init
            jnp.asarray(1, jnp.int32),  # budget for exactly one trial
            jnp.asarray(0, jnp.int32),
            jnp.asarray(0.0, jnp.float32),
            jnp.asarray(True),  # never halt inside the probe
            xi,
        )
        return out.tried[0], out.last_ei, out.last_best

    two = lambda a: jnp.stack([a, a])
    pick, last_ei, last_best = jax.vmap(probe)(
        two(encoded), two(obs_mask), two(y), two(cand_mask)
    )
    return pick[0], last_ei[0], last_best[0]


def bo_step(
    encoded: jax.Array,
    obs_mask: jax.Array,
    y: jax.Array,
    cand_mask: jax.Array,
    xi: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One BO iteration.  Returns (pick_index, max_ei, best_observed_cost).

    Probes the shared `fleet_step` program so the sequential engine executes
    bit-identical float ops to the batched fleet engine.
    """
    return _probe_step(
        jnp.asarray(encoded), jnp.asarray(obs_mask), jnp.asarray(y),
        jnp.asarray(cand_mask), xi,
    )
