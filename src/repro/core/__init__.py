"""Ruya's primary contribution: memory-aware two-phase Bayesian config search.

Pipeline (paper §III): single-machine profiling runs on dataset samples
(`profiler`) → OLS/R² memory-usage categorization (`memory_model`) →
memory-aware search-space split (`search_space`) → GP+EI Bayesian-optimized
iterative search, priority group first (`bayesopt`, `gp`, `acquisition`) —
orchestrated end to end by `tuner`.
"""

from repro.core.acquisition import expected_improvement, probability_of_improvement
from repro.core.bayesopt import (
    BOSettings,
    SearchTrace,
    cherrypick_search,
    ruya_search,
)
from repro.core.gp import (
    GPPosterior,
    fit_gp,
    gp_predict,
    matern52,
    matern52_from_sqdist,
    pairwise_sqdist,
)
from repro.core.memory_model import (
    MemoryCategory,
    MemoryModel,
    fit_memory_model,
)
from repro.core.profiler import ProfileResult, profile_job, schedule_sample_sizes
from repro.core.search_space import (
    Configuration,
    SearchSpace,
    split_masks_device,
    split_search_space,
)
from repro.core.tuner import RuyaReport, run_cherrypick, run_ruya

__all__ = [
    "BOSettings",
    "Configuration",
    "GPPosterior",
    "MemoryCategory",
    "MemoryModel",
    "ProfileResult",
    "RuyaReport",
    "SearchSpace",
    "SearchTrace",
    "cherrypick_search",
    "expected_improvement",
    "fit_gp",
    "fit_memory_model",
    "gp_predict",
    "matern52",
    "matern52_from_sqdist",
    "pairwise_sqdist",
    "probability_of_improvement",
    "profile_job",
    "ruya_search",
    "run_cherrypick",
    "run_ruya",
    "schedule_sample_sizes",
    "split_masks_device",
    "split_search_space",
]
