"""Microbatched gradient accumulation (lax.scan over microbatches).

Splits the per-step global batch into ``num_microbatches`` slices, runs the
loss/grad computation per slice, and accumulates gradients (and the scalar
metrics) across slices.  The accumulator dtype is configurable: bf16
accumulation halves the gradient-buffer footprint — one of the §Perf /
memory levers for the trillion-parameter MoE cells.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["accumulate_gradients"]


def accumulate_gradients(
    grad_fn: Callable[[Any, Any], Tuple[Any, Any]],
    params: Any,
    batch: Any,
    num_microbatches: int,
    *,
    accum_dtype: Optional[Any] = None,
) -> Tuple[Any, Any]:
    """Run ``grad_fn(params, microbatch) -> (grads, metrics)`` over slices.

    ``batch`` leaves must have a leading batch dimension divisible by
    ``num_microbatches``.  Returns (mean grads, mean metrics).
    """
    if num_microbatches <= 1:
        return grad_fn(params, batch)

    def reshape(x: jax.Array) -> jax.Array:
        b = x.shape[0]
        if b % num_microbatches:
            raise ValueError(
                f"batch {b} not divisible by microbatches {num_microbatches}"
            )
        return x.reshape((num_microbatches, b // num_microbatches) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)

    def to_accum(g: jax.Array) -> jax.Array:
        return g.astype(accum_dtype) if accum_dtype is not None else g

    def body(carry, mb):
        acc_g, acc_m = carry
        g, m = grad_fn(params, mb)
        acc_g = jax.tree.map(lambda a, b: a + to_accum(b), acc_g, g)
        acc_m = jax.tree.map(lambda a, b: a + b, acc_m, m)
        return (acc_g, acc_m), None

    g0, m0 = grad_fn(params, jax.tree.map(lambda x: x[0], micro))
    g0 = jax.tree.map(to_accum, g0)
    rest = jax.tree.map(lambda x: x[1:], micro)
    (gs, ms), _ = jax.lax.scan(body, (g0, m0), rest)
    inv = 1.0 / num_microbatches
    grads = jax.tree.map(lambda g: (g * inv).astype(g.dtype), gs)
    metrics = jax.tree.map(lambda m: m * inv, ms)
    return grads, metrics
