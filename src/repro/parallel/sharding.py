"""Logical-axis → mesh-axis sharding rules with divisibility-aware resolution.

Every tensor in the zoo carries *logical* axis names (see models/layers.py).
A ``ShardingRules`` maps those to mesh axes; ``resolve_pspec`` turns one
TensorSpec into a PartitionSpec, **dropping any mesh axis that does not
evenly divide the tensor dimension** (whisper's 6 heads or 51865 vocab on a
16-way model axis simply stay replicated — the config remains valid on any
mesh instead of failing to lower).

Rule sets:
  * ``default_rules``      — data parallel over ("pod","data"), tensor
                             parallel over "model", optional FSDP: the
                             "embed" axis of weight matrices sharded over
                             "data" (ZeRO-3: XLA all-gathers params on use).
  * per-config overrides   — arch configs may override single entries
                             (e.g. long-context decode shards "cache_seq").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "ShardingRules",
    "default_rules",
    "resolve_pspec",
    "resolve_tree",
    "named_sharding_tree",
]

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Immutable mapping logical-axis → mesh axis (or tuple of mesh axes)."""

    rules: Tuple[Tuple[str, MeshAxes], ...]

    @classmethod
    def from_dict(cls, d: Dict[str, MeshAxes]) -> "ShardingRules":
        return cls(tuple(sorted(d.items(), key=lambda kv: kv[0])))

    def to_dict(self) -> Dict[str, MeshAxes]:
        return dict(self.rules)

    def get(self, axis: Optional[str]) -> MeshAxes:
        if axis is None:
            return None
        return dict(self.rules).get(axis)

    def override(self, **kw: MeshAxes) -> "ShardingRules":
        d = self.to_dict()
        d.update(kw)
        return ShardingRules.from_dict(d)


def default_rules(
    *,
    data_axes: Tuple[str, ...] = ("data",),
    model_axis: str = "model",
    fsdp: bool = True,
) -> ShardingRules:
    """The framework's standard rule set.

    ``data_axes`` is ("pod","data") on the multi-pod mesh so gradient
    reduction composes across pods.  ``fsdp`` shards the "embed" axis of
    weights over the data axes (ZeRO-3).

    KV-cache length ("cache_seq") shards over ("model",)+data_axes: none of
    the zoo's kv-head counts divide a 16-way model axis, so the model axis
    would otherwise idle on decode caches — sequence-sharding it cut the
    qwen1.5-32b decode cache footprint 16× (§Perf).  Axes already consumed
    by the batch dim are skipped per-tensor by ``resolve_pspec``, which also
    gives long-context (batch=1) cells the full ("model","data") 256-way
    cache sharding.  ``shard_cache_seq`` is kept for rule overrides.
    """
    batch: MeshAxes = data_axes if len(data_axes) > 1 else data_axes[0]
    fs: MeshAxes = batch if fsdp else None
    cache_entry: MeshAxes = (model_axis,) + tuple(data_axes)
    return ShardingRules.from_dict(
        {
            "batch": batch,
            "embed": fs,
            "heads": model_axis,
            "kv_heads": model_axis,
            "head_dim": None,
            "ffn": model_axis,
            "vocab": model_axis,
            "experts": model_axis,
            "expert_ffn": None,
            "ssm_inner": model_axis,
            "ssm_state": None,
            "layers": None,
            "cache_seq": cache_entry,
            # --- activation-only logical axes (constraints) ---------------
            "seq": None,  # set to model_axis for sequence parallelism
            "act_embed": None,  # residual-stream feature dim stays local
            "capacity": batch,  # MoE slot buffers shard capacity over data
        }
    )


def _axis_size(mesh: Mesh, entry: MeshAxes) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return int(mesh.shape[entry])
    return int(np.prod([mesh.shape[a] for a in entry]))


def resolve_pspec(
    spec: "TensorSpec", rules: ShardingRules, mesh: Mesh  # noqa: F821
) -> PartitionSpec:
    """PartitionSpec for one TensorSpec, dropping non-dividing mesh axes.

    For tuple entries every usable axis is kept (unavailable or
    non-dividing axes are skipped — ("model","data") degrades to ("data",)
    when the model axis is taken).  Mesh axes already consumed by an earlier
    tensor dimension are never reused (PartitionSpec must not repeat axes).
    """
    if not spec.axes:
        return PartitionSpec()
    used: set = set()
    entries: list = []
    for dim, ax in zip(spec.shape, spec.axes):
        entry = rules.get(ax)
        if entry is None:
            entries.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        kept: list = []
        size = 1
        for a in axes:
            asize = int(mesh.shape[a])
            if a in used or dim % (size * asize) != 0:
                continue
            kept.append(a)
            size *= asize
        if not kept:
            entries.append(None)
        else:
            used.update(kept)
            entries.append(kept[0] if len(kept) == 1 else tuple(kept))
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def resolve_tree(specs: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    """PartitionSpec tree for a TensorSpec tree."""
    from repro.models.spec import is_spec  # local: avoids an import cycle

    return jax.tree.map(
        lambda s: resolve_pspec(s, rules, mesh), specs, is_leaf=is_spec
    )


def named_sharding_tree(specs: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    """NamedSharding tree for a TensorSpec tree (for in_shardings / device_put)."""
    from repro.models.spec import is_spec  # local: avoids an import cycle

    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_pspec(s, rules, mesh)),
        specs,
        is_leaf=is_spec,
    )
