"""Distribution layer: sharding rules, remat policies, microbatching."""

from repro.parallel.remat import remat_wrap
from repro.parallel.sharding import (
    ShardingRules,
    default_rules,
    resolve_pspec,
    resolve_tree,
    named_sharding_tree,
)
from repro.parallel.microbatch import accumulate_gradients
from repro.parallel.pipeline import pipeline_apply

__all__ = [
    "ShardingRules",
    "accumulate_gradients",
    "default_rules",
    "named_sharding_tree",
    "pipeline_apply",
    "remat_wrap",
    "resolve_pspec",
    "resolve_tree",
]
