"""Explicit expert parallelism via shard_map (the MoE hot path).

Why not GSPMD: the capacity-dispatch scatter/gather over a buffer sharded on
(experts × capacity) makes the SPMD partitioner reshard per layer — the
kimi-k2 dry-run showed ~93 TB of collectives per step.  The comm pattern we
actually want is static and tiny, so we write it explicitly:

  * tokens are sharded over the data axes and REPLICATED over "model";
  * experts are sharded over "model" — each model shard owns E/TP experts;
  * every device routes its local tokens, keeps the (token, k)-pairs that
    hit its own experts, runs the local expert GEMMs, and contributes a
    partial combine;
  * ONE ``psum`` over "model" completes the combine — the same volume as a
    single tensor-parallel all-reduce, replacing GSPMD's guesswork.

Capacity semantics: each expert's capacity applies per data shard
(C_local = ceil(local_tokens·k·cf/E)) rather than globally — with even
routing this drops the same tokens in expectation; noted in DESIGN.md.

FSDP composes: if the rules shard the experts' embed axis over data, the
weight shards are all-gathered over the data axes inside the body (that IS
ZeRO-3's gather, made explicit).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.parallel.constraints import current_context

__all__ = ["moe_shard_map_available", "moe_apply_shard_map"]


def _axes_tuple(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def moe_shard_map_available(cfg: ModelConfig, x_shape) -> bool:
    """Expert-parallel path is usable when a context with a model axis is
    active and the expert count divides over it."""
    ctx = current_context()
    if ctx is None or cfg.moe is None:
        return False
    rules, mesh = ctx
    maxis = rules.get("experts")
    if maxis is None or not isinstance(maxis, str) or maxis not in mesh.shape:
        return False
    return cfg.moe.num_experts % mesh.shape[maxis] == 0


def moe_apply_shard_map(
    p: Dict[str, Any], cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Drop-in replacement for the local moe dispatch (experts/router only —
    shared expert and dense residual are handled by the caller)."""
    rules, mesh = current_context()
    moe = cfg.moe
    assert moe is not None
    cd = cfg.cdtype
    b, t, d = x.shape
    e, k = moe.num_experts, moe.top_k

    maxis = rules.get("experts")  # "model"
    batch_axes = [
        a for a in _axes_tuple(rules.get("batch"))
        if b % mesh.shape[a] == 0 and a in mesh.shape
    ]
    # honor only a prefix whose product divides b
    keep = []
    size = 1
    for a in batch_axes:
        if b % (size * mesh.shape[a]) == 0:
            keep.append(a)
            size *= mesh.shape[a]
    batch_axes = tuple(keep)
    fsdp_axes = tuple(
        a for a in _axes_tuple(rules.get("embed"))
        if a in mesh.shape and d % mesh.shape[a] == 0
    )

    tp = mesh.shape[maxis]
    e_local = e // tp
    n_local = (b // max(size, 1)) * t
    c_local = max(int(math.ceil(n_local * k * moe.capacity_factor / e)), k)

    x_spec = P(batch_axes if len(batch_axes) > 1 else
               (batch_axes[0] if batch_axes else None), None, None)
    router_spec = P(None, maxis)
    w_in_spec = P(maxis, fsdp_axes if len(fsdp_axes) > 1 else
                  (fsdp_axes[0] if fsdp_axes else None), None)
    w_out_spec = P(maxis, None, fsdp_axes if len(fsdp_axes) > 1 else
                   (fsdp_axes[0] if fsdp_axes else None))

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(x_spec, router_spec, w_in_spec, w_in_spec, w_out_spec),
        out_specs=(x_spec, P()),
        # jax 0.4.37 spells the disabled varying-/replication-check
        # `check_rep` (`check_vma` is the jax 0.6 name).
        check_rep=False,
    )
    def body(xl, router_l, wg_l, wu_l, wo_l):
        nb, nt, _ = xl.shape
        n = nb * nt
        xf = xl.reshape(n, d)

        # Router needs all E columns: gather the model-sharded router weight.
        if tp > 1:
            router = jax.lax.all_gather(router_l, maxis, axis=1, tiled=True)
        else:
            router = router_l
        # FSDP: gather the embed shards of the local experts' weights.
        if fsdp_axes:
            for ax in fsdp_axes:
                wg_l = jax.lax.all_gather(wg_l, ax, axis=1, tiled=True)
                wu_l = jax.lax.all_gather(wu_l, ax, axis=1, tiled=True)
                wo_l = jax.lax.all_gather(wo_l, ax, axis=2, tiled=True)

        probs = jax.nn.softmax(
            (xf.astype(jnp.float32) @ router.astype(jnp.float32)), axis=-1
        )
        gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (n, k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
        )

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32), 0)
        aux = moe.router_aux_weight * e * jnp.sum(me * ce)
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)

        first = jax.lax.axis_index(maxis) * e_local
        flat_ids = expert_ids.T.reshape(-1)  # (k*n,) k-major
        flat_gates = gate_vals.T.reshape(-1)
        local = (flat_ids >= first) & (flat_ids < first + e_local)
        lid = jnp.where(local, flat_ids - first, e_local)
        oh = jax.nn.one_hot(lid, e_local, dtype=jnp.int32)  # (k*n, e_l)
        pos_all = jnp.cumsum(oh, axis=0) - oh
        pos = jnp.sum(pos_all * oh, axis=-1)
        kept = local & (pos < c_local)
        slot = jnp.where(kept, lid * c_local + pos, e_local * c_local)

        xk = jnp.tile(xf, (k, 1)).astype(cd)
        buf = jnp.zeros((e_local * c_local + 1, d), cd).at[slot].add(xk)
        buf = buf[: e_local * c_local].reshape(e_local, c_local, d)

        gate = jnp.einsum("ecd,edf->ecf", buf, wg_l.astype(cd))
        up = jnp.einsum("ecd,edf->ecf", buf, wu_l.astype(cd))
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(cd) * up
        out_buf = jnp.einsum("ecf,efd->ecd", h, wo_l.astype(cd)).reshape(-1, d)

        gathered = jnp.where(
            kept[:, None], out_buf[jnp.minimum(slot, e_local * c_local - 1)], 0.0
        )
        combined = jnp.sum(
            (gathered * flat_gates[:, None].astype(cd)).reshape(k, n, d), 0
        )
        y = jax.lax.psum(combined, maxis)
        return y.reshape(nb, nt, d), aux

    y, aux = body(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    return y, aux
