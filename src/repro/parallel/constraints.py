"""Activation sharding constraints (the GSPMD "pin the residual stream" trick).

Input/parameter shardings alone under-determine a training step: inside the
backward pass the partitioner may happily replicate the 1M-token residual
stream rather than all-gather FSDP weights (observed: 531 GiB/device temp on
granite-8b before constraints).  Production JAX frameworks pin activations
at layer boundaries with ``with_sharding_constraint``; models stay pure by
reading the active (rules, mesh) from a context set by the launcher around
tracing.

When no context is active (CPU smoke tests, single-device runs) every
constraint is a no-op — the same model code runs everywhere.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterator, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.parallel.sharding import ShardingRules, resolve_pspec

__all__ = ["activation_sharding", "shard_activation", "current_context"]

_CTX = threading.local()


def current_context() -> Optional[Tuple[ShardingRules, Mesh]]:
    return getattr(_CTX, "value", None)


@contextlib.contextmanager
def activation_sharding(rules: ShardingRules, mesh: Mesh) -> Iterator[None]:
    prev = current_context()
    _CTX.value = (rules, mesh)
    try:
        yield
    finally:
        _CTX.value = prev


def shard_activation(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """Constrain ``x`` to the sharding its logical ``axes`` resolve to.

    No-op outside an ``activation_sharding`` context, and axes that don't
    divide are dropped by ``resolve_pspec`` — always safe to call.
    """
    ctx = current_context()
    if ctx is None:
        return x
    from repro.models.spec import TensorSpec  # local: avoids import cycle

    rules, mesh = ctx
    if len(axes) != x.ndim:
        raise ValueError(f"axes {axes} rank != array rank {x.ndim}")
    spec = TensorSpec(tuple(x.shape), x.dtype, tuple(axes))
    ps = resolve_pspec(spec, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))
