"""GPipe-style pipeline parallelism over the "pod" axis (shard_map).

An alternative to pure data-parallel pod composition: layers are split
into S contiguous stages (stage s on pod s), microbatches stream through
with ``ppermute`` hand-offs.  The schedule is the classic GPipe forward
wavefront — T = M + S − 1 ticks for M microbatches, bubble fraction
(S−1)/T — and, because ``shard_map`` + ``ppermute`` are differentiable,
``jax.grad`` through ``pipeline_apply`` yields the reverse wavefront
automatically.

Design notes:
  * Stage parameters are the layer stack sharded on the layer axis over
    "pod" (rules override ``layers → pod``), so FSDP/TP inside a stage
    compose unchanged on the remaining mesh axes (marked ``auto``).
  * Every stage computes every tick (bubble ticks process garbage with
    constant shapes — the standard static-schedule trick); outputs are
    masked and psum-broadcast from the last stage.
  * This is the dry-run's *optional* engine: DP over pods wins at the
    assigned batch sizes (EXPERIMENTS.md §Perf), but the plumbing is load-
    bearing for >2-pod scale-out where DP's gradient all-reduce crosses
    the slow inter-pod links every step while PP crosses them M times per
    step with activation-sized messages.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # pytree; leaves stacked (num_layers, ...) — sharded over pod
    micro_inputs: jax.Array,  # (M, b, ...) microbatched activations
    *,
    mesh: Mesh,
    pod_axis: str = "pod",
) -> jax.Array:
    """Run ``stage_fn(local_params, h)`` as an S-stage GPipe.

    ``stage_fn`` receives the stage's local parameter slice (layers/S on the
    leading axis) and one microbatch of activations; returns activations of
    the same shape.  Returns (M, b, ...) outputs (replicated over pod).
    """
    n_stages = int(mesh.shape[pod_axis])
    m = micro_inputs.shape[0]
    other_axes = tuple(a for a in mesh.axis_names if a != pod_axis)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda _: P(pod_axis), stage_params),
            P(),  # every stage sees the (M, b, ...) input block
        ),
        out_specs=P(),
        # jax 0.4.37: partially-auto shard_map (manual over pod only,
        # `axis_names=`/`auto=`) lowers through an unimplemented
        # PartitionId path on CPU SPMD — so run fully manual over the
        # mesh: unmentioned axes replicate the operands, which is exactly
        # the P()-spec'd input block, and the remaining axes ({other_axes})
        # stay available to explicit collectives inside ``stage_fn``.
        # Device-varying carries are expressed by disabling the
        # replication check (`jax.lax.pvary` only exists from jax 0.6).
        check_rep=False,
    )
    def run(params_local, inputs):
        stage = jax.lax.axis_index(pod_axis)
        perm = [(i, i + 1) for i in range(n_stages - 1)]
        # carries are device-varying (each stage holds different data)
        h0 = jnp.zeros_like(inputs[0])
        outputs0 = jnp.zeros_like(inputs)

        def tick(carry, t):
            received, outputs = carry
            # stage 0 injects microbatch t (while available); others consume.
            inject = jnp.where(t < m, t, 0)
            h_in = jnp.where(stage == 0, inputs[inject], received)
            h_out = stage_fn(params_local, h_in)
            # last stage emits microbatch t-S+1 once the wave arrives
            emit = t - (n_stages - 1)
            slot = jnp.clip(emit, 0, m - 1)
            should_emit = (stage == n_stages - 1) & (emit >= 0)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(should_emit, h_out, outputs[slot]),
                slot, 0,
            )
            received = jax.lax.ppermute(h_out, pod_axis, perm)
            return (received, outputs), None

        (_, outputs), _ = jax.lax.scan(
            tick, (h0, outputs0), jnp.arange(m + n_stages - 1)
        )
        # broadcast the last stage's outputs to every pod
        mask = (stage == n_stages - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * mask, pod_axis)

    return run(stage_params, micro_inputs)
