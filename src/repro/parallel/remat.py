"""Activation-checkpoint (remat) policies for scan-over-layers bodies.

Policies (selected per config, iterated during §Perf):

  "none"  — save everything XLA wants to save (fastest, most memory);
  "dots"  — save only matmul outputs with no batch dims (weights-stationary
            checkpointing: recompute elementwise/softmax, keep GEMM results);
  "full"  — save only the layer boundary (minimum memory, recompute all).
"""

from __future__ import annotations

from typing import Callable

import jax

__all__ = ["remat_wrap", "POLICIES"]

POLICIES = ("none", "dots", "full")


def remat_wrap(fn: Callable, policy: str) -> Callable:
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(f"unknown remat policy {policy!r}; expected one of {POLICIES}")
