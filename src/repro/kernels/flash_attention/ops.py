"""Public flash-attention op: padding, TPU/CPU dispatch, custom VJP.

Forward runs the Pallas kernel on TPU (or in interpret mode when forced);
everywhere else it falls back to the jnp oracle so the same model code runs
on any backend.  The backward pass is the algebraic reference VJP — the
standard "kernel forward, XLA backward" split: training still gets the
flash forward's memory win inside remat'd layer bodies (the backward
recompute *also* uses the kernel forward), while gradients stay exact.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_kernel_call,
)

__all__ = ["flash_attention"]


def _should_use_kernel(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return True  # caller explicitly chose the kernel path
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(
    q: jax.Array,  # (B, T, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    return _forward(q, k, v, causal, sm_scale, block_q, block_k, interpret)


def _forward(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    if not _should_use_kernel(interpret):
        return ref.attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)
    t, s = q.shape[1], k.shape[1]
    qp = _pad_to(q, 1, block_q)
    kp = _pad_to(k, 1, block_k)
    vp = _pad_to(v, 1, block_k)
    out = flash_attention_kernel_call(
        qp, kp, vp,
        causal=causal, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k,
        kv_valid_len=s,
        interpret=bool(interpret),
    )
    return out[:, :t]


def _fwd(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    out = _forward(q, k, v, causal, sm_scale, block_q, block_k, interpret)
    return out, (q, k, v)


def _bwd(causal, sm_scale, block_q, block_k, interpret, residuals, g):
    q, k, v = residuals
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(
            q_, k_, v_, causal=causal, sm_scale=sm_scale
        ),
        q, k, v,
    )
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
