"""Pure-jnp oracle for flash attention (GQA, optional causal mask)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array,  # (B, T, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    group = h // kv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, t, kv, group, d)
    logits = jnp.einsum(
        "btkgh,bskh->bkgts", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.arange(t)[:, None] >= jnp.arange(s)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)
