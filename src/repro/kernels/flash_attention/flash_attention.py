"""Online-softmax flash attention for TPU (Pallas).

Grid layout (the canonical TPU flash schedule):

    grid = (batch, q_heads, T/block_q, S/block_k)

The first three axes are parallel; the KV-block axis is sequential
("arbitrary") so VMEM scratch accumulators — running max ``m``, running
denominator ``l`` and the output accumulator ``acc`` — persist across KV
iterations of one (b, h, q-block) cell.  Each step applies the standard
online-softmax rescaling.

TPU-native choices:
  * block_q = block_k = 128 by default — the MXU's native tile; both GEMMs
    in the inner loop (q·kᵀ and p·v) are 128-aligned.
  * Per-block VMEM footprint: q/k/v tiles + (block_q × D) f32 accumulator
    ≈ 128·D·(2·3 + 4) bytes ≈ 0.9 MB at D=128 — far under the ~16 MB VMEM
    budget, leaving room for double buffering.
  * GQA is folded into the k/v BlockSpec index maps (kv_head = h·KV // H):
    no KV replication in HBM, the grouping costs nothing.
  * Causal masking compares absolute positions; fully-masked KV blocks are
    skipped with ``pl.when`` (upper-triangle blocks do zero work — this is
    what makes causal flash ~2× over dense at long S).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

# JAX 0.4.x spells the Mosaic compiler-params class `TPUCompilerParams`;
# newer releases renamed it `CompilerParams`.  Accept either.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles
    o_ref,
    m_scratch, l_scratch, acc_scratch,
    *,
    sm_scale: float,
    block_q: int,
    block_k: int,
    causal: bool,
    kv_valid_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full(m_scratch.shape, NEG_INF, jnp.float32)
        l_scratch[...] = jnp.zeros(l_scratch.shape, jnp.float32)
        acc_scratch[...] = jnp.zeros(acc_scratch.shape, jnp.float32)

    q_start = qi * block_q
    k_start = ki * block_k

    # A KV block is live unless causality places it entirely in the future.
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk)

        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_valid_len  # padded keys never attend
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]  # (bq, 1)
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (bq, bk)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)

        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scratch[...] = acc_scratch[...] * alpha + pv
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scratch[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scratch[...] / l).astype(o_ref.dtype)


def flash_attention_kernel_call(
    q: jax.Array,  # (B, T, H, D) — T, S already padded to block multiples
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    *,
    causal: bool,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    kv_valid_len: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    if t % block_q or s % block_k:
        raise ValueError(f"padded dims required: T={t} S={s} blocks "
                         f"({block_q},{block_k})")
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    valid = kv_valid_len if kv_valid_len is not None else s

    grid = (b, h, t // block_q, s // block_k)

    kernel = functools.partial(
        _kernel,
        sm_scale=scale,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        kv_valid_len=valid,
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec(
                (1, block_k, 1, d),
                lambda b_, h_, qi, ki, kv=kv, h=h: (b_, ki, h_ * kv // h, 0),
            ),
            pl.BlockSpec(
                (1, block_k, 1, d),
                lambda b_, h_, qi, ki, kv=kv, h=h: (b_, ki, h_ * kv // h, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, t, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
