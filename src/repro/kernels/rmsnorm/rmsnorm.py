"""Fused RMSNorm Pallas kernel.

One grid step normalizes a (block_rows, D) tile held in VMEM: the square,
mean, rsqrt and scale all fuse into a single VMEM-resident pass — the
memory-bound op reads x once and writes once (the XLA unfused path reads x
twice when the mean and the scale don't fuse).  Rows are the flattened
(batch·seq) dim; D is the model dim, kept whole per tile (8k·f32 = 32 kB —
trivially VMEM-resident; the row-block count is the only tiling knob).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_kernel_call"]


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, D)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_kernel_call(
    x: jax.Array,  # (rows, D) — rows padded to block multiple
    scale: jax.Array,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    rows, d = x.shape
    if rows % block_rows:
        raise ValueError(f"rows {rows} not a multiple of block {block_rows}")
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
