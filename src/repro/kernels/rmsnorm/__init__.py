from repro.kernels.rmsnorm import ops, ref
from repro.kernels.rmsnorm.ops import rmsnorm

__all__ = ["ops", "ref", "rmsnorm"]
