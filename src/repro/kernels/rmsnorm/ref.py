"""Pure-jnp oracle for fused RMSNorm."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm_ref"]


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """x: (..., D); scale: (D,).  f32 statistics, output in x.dtype."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )
