"""Public fused-RMSNorm op: flattening, padding, dispatch, custom VJP."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm import ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm_kernel_call

__all__ = ["rmsnorm"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def rmsnorm(
    x: jax.Array,  # (..., D)
    scale: jax.Array,  # (D,)
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    return _forward(x, scale, eps, block_rows, interpret)


def _forward(x, scale, eps, block_rows, interpret):
    use_kernel = interpret is not None or jax.default_backend() == "tpu"
    if not use_kernel:
        return ref.rmsnorm_ref(x, scale, eps)
    shape = x.shape
    d = shape[-1]
    flat = x.reshape(-1, d)
    rows = flat.shape[0]
    pad = (-rows) % block_rows
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    out = rmsnorm_kernel_call(
        flat, scale, eps=eps, block_rows=block_rows, interpret=bool(interpret)
    )
    return out[:rows].reshape(shape)


def _fwd(x, scale, eps, block_rows, interpret):
    return _forward(x, scale, eps, block_rows, interpret), (x, scale)


def _bwd(eps, block_rows, interpret, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda x_, s_: ref.rmsnorm_ref(x_, s_, eps), x, scale)
    return vjp(g)


rmsnorm.defvjp(_fwd, _bwd)
