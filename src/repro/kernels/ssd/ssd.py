"""Mamba-2 SSD intra-chunk Pallas kernel.

The intra-chunk (diagonal) term is the SSD compute hot-spot: per
(batch, chunk, head) it is two GEMMs around an elementwise decay mask —

    scores = C · Bᵀ            (Q×N · N×Q  → Q×Q)
    y      = (scores ⊙ D ⊙ dt) · x   (Q×Q · Q×P → Q×P)

with D[i,j] = exp(Σ_{l=j+1..i} lA_l) for i ≥ j, 0 above the diagonal.

TPU mapping: grid = (B·NC, H); one grid cell holds the whole (Q, ·) working
set in VMEM — at the zoo's shapes (Q=256, N≤128, P=64) that is
Q·N + Q·Q + Q·P + Q·2 floats ≈ 0.6 MB, MXU-aligned on every GEMM dim
(Q, N, P all multiples of 64/128).  The segment-sum mask is built in-kernel
from the cumulative log-decays — O(Q) loads instead of materializing the
(Q, Q) decay in HBM, which is exactly the data-movement the fused kernel
eliminates (the unfused XLA path writes/reads the Q×Q decay + scores).

The inter-chunk recurrence stays in XLA (a short lax.scan over chunk
states — latency-bound, no kernel win).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_diag_kernel_call"]


def _kernel(x_ref, dt_ref, la_ref, b_ref, c_ref, o_ref):
    # Tiles per (b·c, h) cell: x (Q,P), dt (Q,1), lA (Q,1), B (Q,N), C (Q,N).
    x = x_ref[0, :, 0, :].astype(jnp.float32)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    la = la_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    bb = b_ref[0, :, 0, :].astype(jnp.float32)
    cc = c_ref[0, :, 0, :].astype(jnp.float32)

    q = x.shape[0]
    cs = jnp.cumsum(la)  # (Q,)
    seg = cs[:, None] - cs[None, :]  # Σ_{l=j+1..i} lA_l
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)  # (Q, Q)

    scores = jax.lax.dot_general(
        cc, bb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q) = C·Bᵀ
    w = scores * decay * dt[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)
    o_ref[0, :, 0, :] = y


def ssd_diag_kernel_call(
    x: jax.Array,  # (BC, Q, H, P)  — batch·chunks flattened
    dt: jax.Array,  # (BC, Q, H)
    lA: jax.Array,  # (BC, Q, H)
    B_: jax.Array,  # (BC, Q, H, N) — already head-expanded
    C_: jax.Array,  # (BC, Q, H, N)
    *,
    interpret: bool = False,
) -> jax.Array:
    bc, q, h, p = x.shape
    n = B_.shape[-1]
    grid = (bc, h)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bc, q, h, p), jnp.float32),
        interpret=interpret,
    )(x, dt, lA, B_, C_)
