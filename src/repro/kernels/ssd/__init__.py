from repro.kernels.ssd import ops, ref
from repro.kernels.ssd.ops import ssd_diag_chunk

__all__ = ["ops", "ref", "ssd_diag_chunk"]
