"""Public SSD intra-chunk op: reshaping, dispatch, custom VJP."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ref
from repro.kernels.ssd.ssd import ssd_diag_kernel_call

__all__ = ["ssd_diag_chunk"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_diag_chunk(
    x: jax.Array,  # (B, NC, Q, H, P)
    dt: jax.Array,  # (B, NC, Q, H)
    lA: jax.Array,  # (B, NC, Q, H)
    B_: jax.Array,  # (B, NC, Q, H, N) — head-expanded
    C_: jax.Array,  # (B, NC, Q, H, N)
    interpret: Optional[bool] = None,
) -> jax.Array:
    return _forward(x, dt, lA, B_, C_, interpret)


def _forward(x, dt, lA, B_, C_, interpret):
    use_kernel = interpret is not None or jax.default_backend() == "tpu"
    if not use_kernel:
        return ref.ssd_diag_ref(x, dt, lA, B_, C_)
    b, nc, q, h, p = x.shape
    n = B_.shape[-1]
    flat = lambda a: a.reshape((b * nc,) + a.shape[2:])
    y = ssd_diag_kernel_call(
        flat(x), flat(dt), flat(lA), flat(B_), flat(C_),
        interpret=bool(interpret),
    )
    return y.reshape(b, nc, q, h, p)


def _fwd(x, dt, lA, B_, C_, interpret):
    return _forward(x, dt, lA, B_, C_, interpret), (x, dt, lA, B_, C_)


def _bwd(interpret, res, g):
    x, dt, lA, B_, C_ = res
    _, vjp = jax.vjp(ref.ssd_diag_ref, x, dt, lA, B_, C_)
    return vjp(g)


ssd_diag_chunk.defvjp(_fwd, _bwd)
