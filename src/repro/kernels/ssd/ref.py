"""Pure-jnp oracle for the SSD intra-chunk (diagonal) term.

Matches the non-kernel branch of ``repro.models.ssm.ssd_chunked``:

    y[i] = Σ_{j ≤ i} (C_i · B_j) · exp(Σ_{l=j+1..i} lA_l) · dt_j · x_j
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_diag_ref"]


def _segsum(lA: jax.Array) -> jax.Array:
    q = lA.shape[-1]
    cs = jnp.cumsum(lA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(q)
    return jnp.where(ii[:, None] >= ii[None, :], diff, -jnp.inf)


def ssd_diag_ref(
    x: jax.Array,  # (B, NC, Q, H, P)
    dt: jax.Array,  # (B, NC, Q, H)
    lA: jax.Array,  # (B, NC, Q, H) log-decays (dt·A)
    B_: jax.Array,  # (B, NC, Q, H, N)
    C_: jax.Array,  # (B, NC, Q, H, N)
) -> jax.Array:
    seg = _segsum(jnp.moveaxis(lA.astype(jnp.float32), -1, -2))  # (B,NC,H,Q,Q)
    decay = jnp.exp(seg)
    scores = jnp.einsum(
        "bcqhn,bckhn->bchqk", C_.astype(jnp.float32), B_.astype(jnp.float32)
    )
    return jnp.einsum(
        "bchqk,bckh,bckhp->bcqhp",
        scores * decay,
        dt.astype(jnp.float32),
        x.astype(jnp.float32),
    )
