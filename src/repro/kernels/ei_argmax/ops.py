"""Public fused EI/argmax op: tile selection, padding, backend dispatch.

Three lanes, all computing the same (argmax index, max EI) pair:

  * **TPU** — the compiled Pallas kernel (`kernel.ei_argmax_kernel_call`),
    streaming the n axis through VMEM tiles.
  * **interpret** (``interpret=True``) — the SAME kernel under the Pallas
    interpreter: every kernel-body op runs as ordinary XLA:CPU ops, which
    makes the kernel's numerics testable bit-for-bit against the unfused
    reference on the CPU test topology.  This is the kernel-identity test
    lane, not a production path (the interpreter re-enters Python per
    tile — ~5× slower than the scan lane below).
  * **CPU default** — a `lax.scan` over the same tiles running the same
    shared tail (`tile.ei_from_sqdist`) with the same strict-`>` streaming
    (max, argmax) carry.  This is the production CPU lane: one compiled
    loop, O(B·tile) transient memory, bitwise identical to both the
    interpret lane and the unfused reference (pinned by
    `tests/test_ei_argmax_kernel.py` and the golden fixtures).

Padding is exact, not approximate: n is zero-padded up to a tile multiple
and the candidate mask is padded FALSE, so padded columns reach the
reduction as EI = -inf — they can never win the strict-`>` update, and an
all-masked pool returns index 0 exactly like `jnp.argmax` over all -inf.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gp import pairwise_sqdist
from repro.kernels.ei_argmax.kernel import ei_argmax_kernel_call
from repro.kernels.ei_argmax.tile import ei_from_sqdist

__all__ = ["ei_argmax"]

# 1024-wide tiles: B=24 tiles are ~100 KB transient, and the scan lane's
# per-step time is flat across 512–8192 on the CPU backend (measured in
# benchmarks/fleet_bench.py) — small spaces shrink to one 128-multiple tile.
_DEFAULT_TILE = 1024
_MIN_TILE = 128


def _pick_tile(n: int, tile: Optional[int]) -> int:
    if tile is not None:
        t = int(tile)
        if t < 1:
            raise ValueError(f"tile must be positive, got {tile}")
        return t
    if n >= _DEFAULT_TILE:
        return _DEFAULT_TILE
    return -(-n // _MIN_TILE) * _MIN_TILE  # one tile, 128-aligned


def _should_use_kernel(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return True  # caller explicitly chose the kernel path
    return jax.default_backend() == "tpu"


def _ei_argmax_scan(
    enc, mask, feats, pm, alpha, chol, ls, y_mean, y_std, best, xi, tile,
) -> Tuple[jax.Array, jax.Array]:
    """The production CPU lane: compiled scan over tiles, streaming carry.

    The scan is driven by tile OFFSETS with `dynamic_slice` in the body,
    not by reshaping the encoding into scan inputs: under the engines'
    chunk `vmap` a (nt, tile, d) xs would need the whole (chunk, n, d)
    geometry transposed to put the scan axis first — a full-size transient
    copy per step, which is exactly the footprint this lane exists to
    avoid.  Slicing returns the same values bit for bit."""
    n_pad, d = enc.shape
    nt = n_pad // tile

    def body(carry, off):
        run_val, run_idx = carry
        et = jax.lax.dynamic_slice(enc, (off, 0), (tile, d))
        mt = jax.lax.dynamic_slice(mask, (off,), (tile,))
        ei = ei_from_sqdist(
            pairwise_sqdist(feats, et), pm, alpha, chol,
            ls, y_mean, y_std, best, mt, xi,
        )
        tile_max = jnp.max(ei)
        tile_idx = jnp.argmax(ei).astype(jnp.int32) + off
        upd = tile_max > run_val  # strict: lowest maximizing index survives
        return (
            jnp.where(upd, tile_max, run_val),
            jnp.where(upd, tile_idx, run_idx),
        ), None

    init = (
        jnp.asarray(-jnp.inf, jnp.float32),
        jnp.asarray(0, jnp.int32),
    )
    offsets = jnp.arange(nt, dtype=jnp.int32) * tile
    (run_val, run_idx), _ = jax.lax.scan(body, init, offsets)
    return run_idx, run_val


def ei_argmax(
    enc: jax.Array,  # (n, d) static float32 encoding of the space
    mask: jax.Array,  # (n,) bool — candidate mask (cand & ~obs)
    feats: jax.Array,  # (B, d) packed features of observed points
    pm: jax.Array,  # (B,) f32 packed-slot validity
    alpha: jax.Array,  # (B,) K⁻¹ y_train, selected hyperparameters
    chol: jax.Array,  # (B, B) Cholesky of the masked training kernel
    ls: jax.Array,  # () selected lengthscale
    y_mean: jax.Array,  # () target mean
    y_std: jax.Array,  # () target std
    best: jax.Array,  # () best observed cost
    *,
    xi: float = 0.0,
    tile: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused (argmax index, max EI) over the masked candidates, traceable.

    Bitwise equal to `argmax/max of tile.ei_from_sqdist` over the full
    (B,n) block without ever materializing it.  ``tile=None`` picks the
    default width; ``interpret`` forces the Pallas path (True: interpreter
    — the kernel-identity test lane).
    """
    n, d = enc.shape
    t = _pick_tile(n, tile)
    n_pad = -(-n // t) * t
    if n_pad != n:
        enc = jnp.pad(enc, ((0, n_pad - n), (0, 0)))
        mask = jnp.pad(mask, (0, n_pad - n))  # False → EI = -inf, inert
    pm = pm.astype(jnp.float32)
    if _should_use_kernel(interpret):
        scal = jnp.stack([
            ls.astype(jnp.float32),
            y_mean.astype(jnp.float32),
            y_std.astype(jnp.float32),
            best.astype(jnp.float32),
        ])
        val, idx = ei_argmax_kernel_call(
            enc, mask, feats, pm, alpha, chol, scal,
            tile=t, xi=float(xi),
            interpret=bool(interpret) if interpret is not None else False,
        )
        return idx[0], val[0]
    return _ei_argmax_scan(
        enc, mask, feats, pm, alpha, chol, ls, y_mean, y_std, best,
        float(xi), t,
    )
