"""Fused posterior+EI+argmax Pallas kernel: streaming reduction over n-tiles.

The candidate axis is the grid: tile i computes the (B,tile) distance block
of its slice of the static (n,d) encoding against the (B,d) packed feature
buffer, runs the shared EI tail (`tile.ei_from_sqdist`) on it, and folds
the tile's (max EI, argmax index) into a running pair held in the two
(1,)-shaped outputs — the flash-attention running-max idiom
(`repro.kernels.flash_attention`), with the accumulator in the revisited
output block instead of VMEM scratch because the carried state is two
scalars, not a (block_q, d) tile.  The (B,n) block the unfused step
materializes never exists: peak transient memory is O(B·tile).

Tie-breaking is the load-bearing detail.  The unfused reference computes
`jnp.argmax(ei)` over all n, which returns the FIRST maximizing index.
Here each tile's `jnp.argmax` is first-within-tile, and the cross-tile
update fires only on a STRICT `>` — a later tile that merely equals the
running max never wins — so the composition returns the first maximizing
index over all n.  `jnp.max` is exact (no rounding), so the streamed max
is bitwise the full-width max.  Both properties are pinned by
`tests/test_ei_argmax_kernel.py` (manufactured cross-tile EI ties) and the
golden fixtures.

Grid axis semantics are "arbitrary" (sequential): the running pair makes
tile i+1 depend on tile i.

The triangular solve: interpret mode (and therefore every CPU test lane)
uses `jax.scipy.linalg.solve_triangular` inside the kernel body — bitwise
identical to the reference lane's solve.  The compiled-TPU path substitutes
`_forward_substitution` (a `fori_loop` forward solve; Mosaic has no
triangular-solve primitive).  Its bits may differ from LAPACK's at the
last ulp — the TPU backend is a different float32 context for the whole
engine anyway; cross-lane bit-identity is only claimed per backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.gp import pairwise_sqdist
from repro.kernels.ei_argmax.tile import ei_from_sqdist

__all__ = ["ei_argmax_kernel_call"]

# JAX 0.4.x spells the Mosaic compiler-params class `TPUCompilerParams`;
# newer releases renamed it `CompilerParams`.  Accept either.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _forward_substitution(chol: jax.Array, rhs: jax.Array) -> jax.Array:
    """Row-sweep forward solve of L x = rhs (L lower-triangular), written in
    ops Mosaic lowers (dynamic row slice, masked contraction, fori_loop) —
    the compiled-TPU stand-in for LAPACK's `solve_triangular`."""
    b = chol.shape[0]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)

    def body(i, x):
        below = (row_ids < i).astype(chol.dtype)  # rows j < i, as (b,1)
        acc = jnp.sum(chol[i][:, None] * x * below, axis=0)
        return x.at[i].set((rhs[i] - acc) / chol[i, i])

    return jax.lax.fori_loop(0, b, body, jnp.zeros_like(rhs))


def _kernel(
    enc_ref,  # (tile, d) — this tile's slice of the static encoding
    feats_ref,  # (B, d) — packed features of observed points
    pm_ref,  # (B,) — packed-slot validity
    alpha_ref,  # (B,)
    chol_ref,  # (B, B)
    scal_ref,  # (4,) — (lengthscale, y_mean, y_std, best) stacked
    mask_ref,  # (tile,) bool — candidate mask slice
    out_val_ref,  # (1,) f32 — running max EI
    out_idx_ref,  # (1,) i32 — running argmax (global index)
    *,
    tile: int,
    xi: float,
    solve,
):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        out_val_ref[...] = jnp.full_like(out_val_ref, -jnp.inf)
        out_idx_ref[...] = jnp.zeros_like(out_idx_ref)

    ls, y_mean, y_std, best = (
        scal_ref[0], scal_ref[1], scal_ref[2], scal_ref[3],
    )
    d2 = pairwise_sqdist(feats_ref[...], enc_ref[...])
    ei = ei_from_sqdist(
        d2, pm_ref[...], alpha_ref[...], chol_ref[...],
        ls, y_mean, y_std, best, mask_ref[...], xi, solve=solve,
    )
    tile_max = jnp.max(ei)
    tile_idx = jnp.argmax(ei).astype(jnp.int32) + ti * tile

    # Strict >: an equal later tile never displaces the running winner, so
    # the lowest maximizing index survives — `jnp.argmax`'s contract.
    @pl.when(tile_max > out_val_ref[0])
    def _update():
        out_val_ref[0] = tile_max
        out_idx_ref[0] = tile_idx


def ei_argmax_kernel_call(
    enc: jax.Array,  # (n_pad, d) — encoding, zero-padded to a tile multiple
    mask: jax.Array,  # (n_pad,) bool — candidate mask, False-padded
    feats: jax.Array,  # (B, d)
    pm: jax.Array,  # (B,)
    alpha: jax.Array,  # (B,)
    chol: jax.Array,  # (B, B)
    scal: jax.Array,  # (4,) — (lengthscale, y_mean, y_std, best)
    *,
    tile: int,
    xi: float,
    interpret: bool,
):
    """((1,) f32 max EI, (1,) i32 argmax) over the masked candidates."""
    n_pad, d = enc.shape
    b = feats.shape[0]
    if n_pad % tile:
        raise ValueError(f"n_pad={n_pad} not a multiple of tile={tile}")
    solve = (
        functools.partial(jax.scipy.linalg.solve_triangular, lower=True)
        if interpret
        else _forward_substitution
    )
    kernel = functools.partial(_kernel, tile=tile, xi=xi, solve=solve)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("arbitrary",),  # running pair is carried
        )
    return pl.pallas_call(
        kernel,
        grid=(n_pad // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )(enc, feats, pm, alpha, chol, scal, mask)
