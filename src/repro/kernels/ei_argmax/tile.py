"""THE shared EI tail: posterior squared-distance block → masked EI values.

Everything downstream of a raw squared-distance block — Matérn-5/2
rescale, posterior mean/variance against the packed training factors,
de-standardization, and Expected Improvement — lives in this ONE function.
The unfused reference lane (`repro.core.fast_bo._packed_core`) calls it on
the full (B,n) cross block; the fused lanes (the Pallas kernel body, its
interpret-mode twin, and the `lax.scan` CPU lane in `.ops`) call it on
(B,tile) blocks.  Sharing the function — not just the formulation — is
what makes "fused ≡ feature" a structural property instead of a reviewed
convention: the op sequence cannot drift between lanes.

Float32 bit-discipline notes (XLA:CPU, pinned by `tests/
test_ei_argmax_kernel.py` and the golden fixtures):

  * Tiling the n axis of this tail is BITWISE invariant: every op is
    either elementwise in n, or contracts only over B (`k_star.T @ alpha`,
    the triangular solve, the `v*v` column sum), so a (B,tile) slice
    computes exactly the bits of the corresponding (B,n) columns.
  * The constants are PYTHON floats (`math.sqrt`), not `jnp` scalars: a
    Pallas kernel body may not capture traced constants, and
    float32(math.sqrt(2.0)) rounds to the identical bits as
    float32(jnp.sqrt(2.0)) — the XLA lanes lose nothing.
  * The solve is injectable: the CPU/interpret lanes use LAPACK's
    `solve_triangular` (column-slice invariant — solving for a subset of
    right-hand-side columns reproduces the full solve's bits), while the
    compiled-TPU kernel substitutes a Mosaic-lowerable forward
    substitution (`kernel._forward_substitution`); per-backend bits may
    differ, exactly like the rest of the engine's per-backend float32
    contract.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.gp import matern52_from_sqdist

__all__ = ["ei_from_sqdist"]

_SQRT2 = math.sqrt(2.0)
_SQRT2PI = math.sqrt(2.0 * math.pi)


def _solve_lower(chol: jax.Array, rhs: jax.Array) -> jax.Array:
    return jax.scipy.linalg.solve_triangular(chol, rhs, lower=True)


def ei_from_sqdist(
    d2: jax.Array,  # (B, m) raw squared distances, training rows × candidates
    pm: jax.Array,  # (B,) f32 packed-slot validity (1.0 for slots < t)
    alpha: jax.Array,  # (B,) K⁻¹ y_train for the selected hyperparameters
    chol: jax.Array,  # (B, B) Cholesky factor of the masked training kernel
    ls: jax.Array,  # () selected lengthscale
    y_mean: jax.Array,  # () training-target mean
    y_std: jax.Array,  # () training-target std (clamped)
    best: jax.Array,  # () best observed cost (un-standardized)
    mask: jax.Array,  # (m,) bool — candidate mask; False → EI = -inf
    xi: float = 0.0,
    *,
    solve=_solve_lower,
) -> jax.Array:
    """Masked EI over the m candidate columns of ``d2``; (m,) float32.

    ``m`` may be the full space extent n (the reference lane) or one tile
    (the fused lanes) — the bits per column are identical either way.
    """
    k_star = matern52_from_sqdist(d2, ls) * pm[:, None]
    mean_n = k_star.T @ alpha
    v = solve(chol, k_star)
    var_n = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    std_n = jnp.sqrt(var_n)

    # De-standardize.
    mean = mean_n * y_std + y_mean
    std = std_n * y_std

    improvement = best - mean - xi
    z = improvement / jnp.maximum(std, 1e-12)
    cdf = 0.5 * (1.0 + jax.scipy.special.erf(z / _SQRT2))
    pdf = jnp.exp(-0.5 * z * z) / _SQRT2PI
    ei = jnp.maximum(improvement * cdf + std * pdf, 0.0)
    return jnp.where(mask, ei, -jnp.inf)
