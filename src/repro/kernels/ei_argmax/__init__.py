"""Fused posterior+EI+argmax kernel for catalog-scale candidate spaces.

`ei_argmax` streams the candidate axis in tiles — per tile: distance
block, posterior mean/var rescale, Expected Improvement, and a running
(max, argmax) reduction — so the (B,n) cross block the unfused BO step
materializes never exists.  `tile.ei_from_sqdist` is the ONE shared tail
both the fused lanes and the unfused reference (`repro.core.fast_bo`)
execute; `kernel.ei_argmax_kernel_call` is the Pallas kernel (TPU
compiled / interpret); `ops.ei_argmax` dispatches between them and the
production `lax.scan` CPU lane.  Wired into the engines as
``layout="fused"`` (see `fast_bo.bo_step_core_fused`).
"""

from repro.kernels.ei_argmax.kernel import ei_argmax_kernel_call
from repro.kernels.ei_argmax.ops import ei_argmax
from repro.kernels.ei_argmax.tile import ei_from_sqdist

__all__ = ["ei_argmax", "ei_argmax_kernel_call", "ei_from_sqdist"]
