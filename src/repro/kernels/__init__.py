"""Pallas TPU kernels for the zoo's compute hot-spots.

Three kernels, each a package with ``<name>.py`` (pl.pallas_call + explicit
BlockSpec VMEM tiling), ``ops.py`` (jit'd public wrapper + custom VJP) and
``ref.py`` (pure-jnp oracle used by tests and as the XLA fallback):

  * ``flash_attention`` — online-softmax causal GQA attention
  * ``rmsnorm``         — fused RMSNorm
  * ``ssd``             — Mamba-2 SSD intra-chunk term

The kernels target TPU (MXU-aligned tiles, VMEM residency); on this CPU
container they are validated with ``interpret=True``.  The Ruya paper's own
contribution is framework-level (no kernel to port) — these are the
perf-critical *substrate* layers its tuner schedules (DESIGN.md §2.1).
"""
