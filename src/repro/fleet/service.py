"""`TuningService`: the async tuning daemon over `TuningSession`.

The session advances every live search in global lockstep — one `step()`
walks every chunk, so the slowest admission group sets the pace for the
whole fleet and a straggler-stalled chunk blocks jobs it shares nothing
with.  The service removes the global barrier: each live admission group
((space shape, packed capacity) — the session's chunking unit) gets its
own host thread driving its own jitted dispatch loop at its own pace,

    service = TuningService(cache=ProfileCache(), max_in_flight=64)
    handle  = service.submit(job, seed=0)   # queues; a group worker admits
                                            # it at ITS next iteration
                                            # boundary and steps it
    service.drain()                         # block until everything lands
    service.metrics()                       # per-group latency, queue
                                            # depth, jobs/sec, fault totals
    service.shutdown(drain=True)

Why this is numerics-free: chunk membership never affects traces (vmap
rows are independent and row extents stay in the batch-extent-invariant
[2, 8] window), a submission's warm-start history snapshot and scripted
init draw happen inside `submit()` under the session lock, and each
chunk is only ever stepped by its owning group worker.  The async
schedule therefore replays every job bit-identical to the single-threaded
lockstep drain — pinned per job by the golden fixtures through the
service lanes (`tests/test_service.py`), for ANY thread interleaving.

Scheduling.  `submit()` is thread-safe and applies backpressure: at most
``max_in_flight`` jobs may be submitted-but-unfinished; the saturated
behavior is to block (default) or raise `ServiceSaturated`.  Admitted
groups spread across the host devices round-robin (committed placement —
identical programs and numerics on identical host devices, only WHERE
they run changes), so two groups' dispatch loops genuinely overlap:
group A's device wait no longer stalls group B's dispatch, which is the
stall-isolation property the straggler bench (workload G in
`benchmarks/fleet_bench.py`) measures.

Lock discipline (the deadlock-freedom argument): the session lock is the
OUTER lock — outcome listeners fire under it and may take the service
condition variable, so service code never calls into the session while
holding the CV.  Workers needing an atomic look at both sides (the
idle-exit check) take the session lock first, then the CV.

``pace`` is a test/bench seam: called as ``pace(group_key, iteration)``
by a group's worker before each of its iterations, outside all locks.
The interleaving-fuzz suite drives seeded sleeps through it; the
disturbed golden scenario uses it to hold a group mid-flight while the
test cancels a victim and reshards; workload G injects straggler delay.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

import jax

from repro.fleet.session import JobHandle, SearchOutcome, TuningSession

__all__ = ["ServiceSaturated", "TuningService"]


class ServiceSaturated(RuntimeError):
    """`submit()` with ``saturation="raise"`` found the service at its
    ``max_in_flight`` cap.  Back off and resubmit (or size the cap to the
    burst); nothing was enqueued."""


class _GroupStats:
    """Per-group metrics, mutated by the owning worker under the CV."""

    __slots__ = ("iterations", "steps", "last_step_s", "total_step_s",
                 "admitted", "device")

    def __init__(self, device: Optional[str]) -> None:
        self.iterations = 0
        self.steps = 0
        self.last_step_s = 0.0
        self.total_step_s = 0.0
        self.admitted = 0
        self.device = device

    def as_dict(self) -> dict:
        mean = self.total_step_s / self.steps if self.steps else 0.0
        return {
            "iterations": self.iterations,
            "steps": self.steps,
            "admitted": self.admitted,
            "last_step_s": self.last_step_s,
            "mean_step_s": mean,
            "device": self.device,
        }


class _GroupWorker(threading.Thread):
    """One admission group's dispatch loop.

    Spawned when a submit leaves pending work under a group key with no
    live worker; exits when the key has neither pending jobs nor live
    chunks (checked atomically under the session lock, so a racing
    submit either sees the worker in the registry or respawns one).
    Daemonic: an abandoned service never blocks interpreter exit.
    """

    def __init__(self, service: "TuningService", key: tuple, device) -> None:
        super().__init__(name=f"tuning-group-{key}", daemon=True)
        self.key = key
        self.device = device
        self._service = service
        self.iteration = 0

    def run(self) -> None:
        svc = self._service
        session = svc._session
        try:
            while not svc._halt:
                if svc._paused:
                    svc._idle_wait()
                    continue
                admitted = session._admit_group(self.key, device=self.device)
                chunks = session._chunks_for(self.key)
                if admitted:
                    with svc._cv:
                        svc._stats[self.key].admitted += admitted
                if not chunks:
                    # Idle-exit must be atomic against submit: session lock
                    # (outer) guards the pending/chunk scan, and the
                    # registry removal happens inside it — a concurrent
                    # submit serializes either before (we see its pending
                    # rec and stay) or after (it finds the registry slot
                    # empty and spawns a fresh worker).
                    with session._lock:
                        busy = any(
                            (r.enc.shape, r.budget) == self.key
                            for r in session._pending
                        ) or any(
                            c.group_key == self.key for c in session._chunks
                        )
                        if not busy and not svc._paused:
                            with svc._cv:
                                svc._workers.pop(self.key, None)
                                svc._cv.notify_all()
                            return
                    svc._idle_wait()
                    continue
                self.iteration += 1
                if svc._pace is not None:
                    svc._pace(self.key, self.iteration)
                for ch in chunks:
                    if svc._halt:
                        return
                    t0 = time.monotonic()
                    session._step_chunk(ch)
                    dt = time.monotonic() - t0
                    with svc._cv:
                        st = svc._stats[self.key]
                        st.steps += 1
                        st.last_step_s = dt
                        st.total_step_s += dt
                with svc._cv:
                    svc._stats[self.key].iterations += 1
        except BaseException as e:  # surface in drain(), don't die silently
            with svc._cv:
                svc._errors.append((self.key, e))
                svc._workers.pop(self.key, None)
                svc._cv.notify_all()


class TuningService:
    """Persistent tuning daemon: a `TuningSession` plus per-group worker
    threads, admission backpressure, and a metrics surface.

    Constructor keywords are forwarded to `TuningSession` (``settings``,
    ``cache``, ``layout``, ``shard``, ``retry``, ...) unless an existing
    ``session`` is passed — in that case the service must be its ONLY
    submitter (the in-flight accounting counts one publication per
    service submit).

    ``max_in_flight`` bounds submitted-but-unfinished jobs; ``saturation``
    picks the at-cap behavior: "block" (default) parks the submitter on a
    condition variable until capacity frees, "raise" raises
    `ServiceSaturated` immediately.  ``devices`` spreads admission groups
    round-robin over the host topology ("auto", the default; pass an
    explicit list, or None to keep JAX default placement).  Sharded
    sessions (``shard=...``) ignore per-group placement — the bundle
    update owns its device set.

    ``pace(group_key, iteration)`` is the scheduling seam described in
    the module docstring.  `pause()`/`resume()` gate admission AND
    stepping — submissions still enqueue while paused, which is how the
    golden warm-start scenario makes a whole wave's history snapshots
    atomic with respect to the workers.

    `drain()` blocks until every service-submitted job has published,
    then applies the session's all-failed guard (`FleetFailedError`) over
    exactly the jobs this drain was waiting on.  `shutdown(drain=True)`
    drains first; ``drain=False`` abandons live work (outcomes of
    finished jobs remain readable).  The service is a context manager
    (`with TuningService(...) as svc:` → `shutdown(drain=True)` on exit).
    """

    def __init__(
        self,
        session: Optional[TuningSession] = None,
        *,
        max_in_flight: Optional[int] = None,
        saturation: str = "block",
        pace: Optional[Callable[[tuple, int], None]] = None,
        devices: object = "auto",
        **session_kwargs: object,
    ) -> None:
        if saturation not in ("block", "raise"):
            raise ValueError(f"unknown saturation mode {saturation!r}")
        if session is not None and session_kwargs:
            raise ValueError(
                "pass EITHER an existing session OR TuningSession kwargs"
            )
        if max_in_flight is not None and int(max_in_flight) < 1:
            raise ValueError("max_in_flight must be >= 1")
        # NOT `session or ...`: an empty TuningSession is falsy (__len__).
        self._session = (
            session if session is not None else TuningSession(**session_kwargs)
        )
        self.max_in_flight = None if max_in_flight is None else int(max_in_flight)
        self.saturation = saturation
        self._pace = pace

        if devices == "auto":
            self._devices = list(jax.devices())
        elif devices is None:
            self._devices = []
        else:
            self._devices = list(devices)
        if self._session.shard_devices is not None:
            self._devices = []  # sharded bundles own their placement
        self._next_device = 0

        # ONE condition variable guards all service state (worker registry,
        # stats, in-flight count, pause/halt flags) and carries every
        # signal: capacity freed, job published, worker exited, resume.
        # The session lock is the outer lock — see the module docstring.
        self._cv = threading.Condition()
        self._workers: Dict[tuple, _GroupWorker] = {}
        self._stats: Dict[tuple, _GroupStats] = {}
        self._errors: List[Tuple[tuple, BaseException]] = []
        self._paused = False
        self._halt = False
        self._in_flight = 0
        self._submitted = 0
        self._completed = 0
        self._status_counts: Dict[str, int] = {}
        self._profile_attempts_total = 0
        self._retry_backoff_total = 0.0
        self._straggler_trials = 0
        self._t_start = time.monotonic()
        self._t_first_submit: Optional[float] = None
        self._t_last_complete: Optional[float] = None

        # Fires under the SESSION lock for every published outcome —
        # touch only the CV here (never call back into the session).
        self._session._outcome_listeners.append(self._on_outcome)

    # ------------------------------------------------------------ submit

    def submit(self, job, rng=None, **kwargs) -> JobHandle:
        """Thread-safe submit with backpressure; otherwise exactly
        `TuningSession.submit` (same keywords, same determinism: the
        warm-history snapshot and scripted init draw happen here, so the
        search is pinned no matter how the workers interleave)."""
        with self._cv:
            if self._halt:
                raise RuntimeError("service is shut down")
            while (
                self.max_in_flight is not None
                and self._in_flight >= self.max_in_flight
            ):
                if self.saturation == "raise":
                    raise ServiceSaturated(
                        f"{self._in_flight} jobs in flight >= "
                        f"max_in_flight={self.max_in_flight}"
                    )
                self._cv.wait()
                if self._halt:
                    raise RuntimeError("service is shut down")
            # Reserve the slot before the session call: a submit-time
            # profiling failure publishes DURING submit and the listener's
            # decrement must find the reservation.
            self._in_flight += 1
            self._submitted += 1
            if self._t_first_submit is None:
                self._t_first_submit = time.monotonic()
        try:
            handle = self._session.submit(job, rng, **kwargs)
        except BaseException:
            with self._cv:  # nothing enqueued; release the reservation
                self._in_flight -= 1
                self._submitted -= 1
                self._cv.notify_all()
            raise
        self._ensure_workers()
        return handle

    def _ensure_workers(self) -> None:
        """Spawn a worker for every pending group key that lacks one.
        Session state is read before the CV is taken (lock order)."""
        keys = self._session._pending_group_keys()
        with self._cv:
            if self._halt:
                return
            for key in keys:
                if key in self._workers:
                    continue
                device = None
                if self._devices:
                    device = self._devices[
                        self._next_device % len(self._devices)
                    ]
                    self._next_device += 1
                if key not in self._stats:
                    self._stats[key] = _GroupStats(
                        None if device is None else str(device)
                    )
                worker = _GroupWorker(self, key, device)
                self._workers[key] = worker
                worker.start()
            self._cv.notify_all()

    def _on_outcome(self, outcome: SearchOutcome) -> None:
        # Called under the session lock; CV only (see lock discipline).
        with self._cv:
            self._in_flight -= 1
            self._completed += 1
            self._t_last_complete = time.monotonic()
            self._status_counts[outcome.status] = (
                self._status_counts.get(outcome.status, 0) + 1
            )
            self._profile_attempts_total += outcome.profile_attempts
            self._retry_backoff_total += outcome.retry_backoff_s
            self._straggler_trials += sum(
                1 for r in outcome.records if r.attempts > 1
            )
            self._cv.notify_all()

    # ----------------------------------------------------------- control

    def pause(self) -> None:
        """Park every worker (no admission, no stepping) until `resume`.
        Submissions still enqueue — a paused service is how a caller
        makes a multi-job wave's warm-history snapshots atomic."""
        with self._cv:
            self._paused = True

    def resume(self) -> None:
        with self._cv:
            self._paused = False
            self._cv.notify_all()
        self._ensure_workers()

    def _idle_wait(self, timeout: float = 0.005) -> None:
        with self._cv:
            if not self._halt:
                self._cv.wait(timeout)

    def _raise_worker_errors(self) -> None:
        with self._cv:
            if not self._errors:
                return
            key, err = self._errors[0]
        raise RuntimeError(
            f"group worker {key} died: {type(err).__name__}: {err}"
        ) from err

    # ----------------------------------------------------------- results

    def results(self) -> List[SearchOutcome]:
        return self._session.results()

    def outcome(self, handle: JobHandle) -> SearchOutcome:
        return handle.outcome()

    def cancel(self, handle: JobHandle) -> bool:
        return self._session.cancel(handle)

    def drain(self) -> List[SearchOutcome]:
        """Block until every service-submitted job has published; return
        all outcomes (submission order).  Resumes a paused service —
        parked workers cannot finish anything.  Raises `FleetFailedError`
        when EVERY job this drain was waiting on failed (same guard as
        the session's synchronous drain), and re-raises the first worker
        error if a dispatch loop died."""
        session = self._session
        with session._lock:
            waiting: Set[int] = {
                rec.handle.uid for rec in session._live_recs()
            }
            waiting.update(session._failed_since_drain)
            session._failed_since_drain = []
        self.resume()
        with self._cv:
            while self._in_flight > 0 and not self._errors and not self._halt:
                self._cv.wait(0.05)
        self._raise_worker_errors()
        session._check_all_failed(waiting)
        return session.results()

    def shutdown(self, drain: bool = True) -> List[SearchOutcome]:
        """Stop the daemon.  ``drain=True`` (default) finishes live work
        first; ``drain=False`` abandons it (workers exit at their next
        loop check; unfinished handles stay "running"/"pending" forever).
        Idempotent; returns the finished outcomes either way."""
        outcomes: List[SearchOutcome] = []
        if drain and not self._halt:
            outcomes = self.drain()
        with self._cv:
            self._halt = True
            workers = list(self._workers.values())
            self._cv.notify_all()
        for w in workers:
            w.join(timeout=10.0)
        return outcomes if drain else self.results()

    def __enter__(self) -> "TuningService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # Don't mask an in-flight exception with a drain hang: only a
        # clean exit waits for live work.
        self.shutdown(drain=exc_type is None)

    # ----------------------------------------------------------- metrics

    def metrics(self) -> dict:
        """JSON-able operational snapshot: queue depth, in-flight count,
        sustained jobs/sec (completions over the first-submit→last-
        completion window), per-group step latency/iteration counts, and
        the fleet's fault/retry totals (profiling attempts incl. retries,
        charged backoff seconds, straggler-flagged trials — the PR-7
        counters, aggregated from published outcomes)."""
        with self._session._lock:
            queue_depth = len(self._session._pending)
            live_chunks: Dict[tuple, int] = {}
            for ch in self._session._chunks:
                live_chunks[ch.group_key] = live_chunks.get(ch.group_key, 0) + 1
        with self._cv:
            # Sustained rate only over a real window: `is not None` (a
            # monotonic stamp CAN be 0.0 — truthiness silently dropped the
            # rate), and at least two completions (one completion's
            # "window" is that job's latency; the old `max(span, 1e-9)`
            # clamp extrapolated it — or a zero-width window — into
            # absurd/near-infinite jobs_per_sec).
            span = None
            if (
                self._t_first_submit is not None
                and self._t_last_complete is not None
                and self._completed >= 2
            ):
                span = self._t_last_complete - self._t_first_submit
                if span <= 0.0:
                    span = None
            groups = {}
            for key, st in self._stats.items():
                g = st.as_dict()
                g["live_chunks"] = live_chunks.get(key, 0)
                g["worker_alive"] = key in self._workers
                groups[str(key)] = g
            return {
                "uptime_s": time.monotonic() - self._t_start,
                "submitted": self._submitted,
                "completed": self._completed,
                "in_flight": self._in_flight,
                "queue_depth": queue_depth,
                "max_in_flight": self.max_in_flight,
                "paused": self._paused,
                "jobs_per_sec": (
                    None if span is None else self._completed / span
                ),
                "statuses": dict(self._status_counts),
                "faults": {
                    "profile_attempts_total": self._profile_attempts_total,
                    "profile_retries_total": (
                        self._profile_attempts_total - self._completed
                        if self._completed else 0
                    ),
                    "retry_backoff_s_total": self._retry_backoff_total,
                    "straggler_trials": self._straggler_trials,
                },
                "groups": groups,
            }
