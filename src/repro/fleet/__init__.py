"""Fleet tuning subsystem: streaming multi-job Bayesian-optimized search.

The paper evaluates Ruya one job at a time; related work (Flora, Blink)
pushes toward tuning as a *fleet service* — many jobs, shared knowledge,
negligible per-job overhead.  This package provides:

  * `session.TuningSession` — THE tuning engine: submit jobs over time,
    `step()` advances every live search one batched BO iteration (newly
    submitted jobs are admitted into lockstep chunks between steps),
    `drain()`/`results()` return first-class `TrialRecord`/`SearchOutcome`
    structures.  The session owns the `ProfileCache`, computes the §III-D
    split on device, and warm-starts searches from completed trials in the
    same memory-signature class.
  * `batched_engine.batched_search` — one-shot shim over a session: J
    independent Ruya/CherryPick searches in device-resident lockstep (one
    jitted vmapped `fleet_step` per iteration), trace-identical to the
    sequential engine in `repro.core.bayesopt`.
  * `profile_cache.ProfileCache` — Flora-style reuse of profiling runs
    across jobs whose memory patterns match (category + fitted coefficients).
  * `driver.tune_fleet` — one-shot shim: probe/profile (with cache), split,
    search, one `RuyaReport` per job — the same API `repro.core.tuner`
    exposes for J=1.
  * `sharding` — job-axis sharding across JAX devices: lockstep chunks are
    bundled S at a time and advanced by one `shard_map` dispatch
    (`TuningSession(shard=...)` / `batched_search(shard=...)`), pinned
    bit-identical to the single-device reference by `tests/golden/`.
  * `retry.RetryPolicy` — deterministic exponential backoff with seeded
    jitter for transient profiling-run failures; permanent failures
    fast-fail into first-class "failed" outcomes (`FleetFailedError` only
    when a drain is waiting on nothing else).
  * `service.TuningService` — the async daemon over a session: one host
    thread per live admission group drives its own dispatch loop at its
    own pace (no global lockstep barrier), thread-safe `submit()` with
    bounded-queue backpressure (`ServiceSaturated`), graceful shutdown,
    and a JSON metrics surface — bit-identical per job to the lockstep
    drain under any thread interleaving (pinned by `tests/test_service.py`).
"""

from repro.fleet.batched_engine import BatchedTrace, batched_search
from repro.fleet.driver import FleetJob, cluster_fleet, replay_seeds, tune_fleet
from repro.fleet.profile_cache import MemorySignature, ProfileCache
from repro.fleet.retry import RetryPolicy, RetryStats, call_with_retry
from repro.fleet.service import ServiceSaturated, TuningService
from repro.fleet.sharding import resolve_shard_devices
from repro.fleet.session import (
    FleetFailedError,
    JobHandle,
    SearchOutcome,
    TrialRecord,
    TuningSession,
    canonical_objective,
    objective_table,
)

__all__ = [
    "BatchedTrace",
    "batched_search",
    "FleetFailedError",
    "FleetJob",
    "cluster_fleet",
    "replay_seeds",
    "tune_fleet",
    "JobHandle",
    "MemorySignature",
    "ProfileCache",
    "RetryPolicy",
    "RetryStats",
    "call_with_retry",
    "canonical_objective",
    "objective_table",
    "resolve_shard_devices",
    "SearchOutcome",
    "ServiceSaturated",
    "TrialRecord",
    "TuningService",
    "TuningSession",
]
