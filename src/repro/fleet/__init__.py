"""Fleet tuning subsystem: batched multi-job Bayesian-optimized search.

The paper evaluates Ruya one job at a time; related work (Flora, Blink)
pushes toward tuning as a *fleet service* — many jobs, shared knowledge,
negligible per-job overhead.  This package provides:

  * `batched_engine.batched_search` — J independent Ruya/CherryPick searches
    advanced in device-resident lockstep (one jitted vmapped `fleet_step`
    per fleet iteration), trace-identical to the sequential engine in
    `repro.core.bayesopt`.
  * `profile_cache.ProfileCache` — Flora-style reuse of profiling runs
    across jobs whose memory patterns match (category + fitted coefficients).
  * `driver.tune_fleet` — the end-to-end fleet pipeline: probe/profile (with
    cache), split each job's space, run the batched search, return one
    `RuyaReport` per job — the same API `repro.core.tuner` exposes for J=1.
"""

from repro.fleet.batched_engine import BatchedTrace, batched_search
from repro.fleet.driver import FleetJob, cluster_fleet, replay_seeds, tune_fleet
from repro.fleet.profile_cache import MemorySignature, ProfileCache

__all__ = [
    "BatchedTrace",
    "batched_search",
    "FleetJob",
    "cluster_fleet",
    "replay_seeds",
    "tune_fleet",
    "MemorySignature",
    "ProfileCache",
]
