"""Fully-batched multi-job BO search: J searches in lockstep on device.

The sequential engine (`repro.core.bayesopt._bo_loop`) drives one job per
Python-loop iteration, paying a dispatch + host round-trip per BO step —
thousands of synchronizations for a fleet.  Here the whole fleet advances in
lockstep:

  * `jax.vmap` over jobs lifts the per-job state (observation mask, packed
    trial log/targets/features — `fast_bo.FleetState`) into batched arrays
    that stay resident on device;
  * one jitted call per iteration applies `fast_bo.fleet_step` to every job
    at once, with the state DONATED to the call so XLA updates the buffers
    in place instead of copying them per iteration; the host only counts
    iterations (all bookkeeping — including per-job stopping — happens on
    device, and iterations dispatch asynchronously, so there are no
    per-step host round-trips);
  * per-job geometry is the static (n,d) float32 encoding — the
    feature-buffer layout computes its (B,B)/(B,n) distance blocks on the
    fly from the packed (B,d) feature buffer each step, so nothing of
    extent n² is ever materialized and 10⁴–10⁵-point spaces run in O(n·d)
    memory.  The retained PR-2 path (``layout="gather"``) instead threads
    each job's precomputed (n,n) distance tensor (`fast_bo.precompute_d2`)
    through every iteration; the two layouts are bit-identical
    (`tests/test_feature_buffer.py`) and the gather path is kept for
    cross-checking and benchmarking;
  * `fleet_step` is the *same compiled program* the sequential path probes,
    so the two engines are trace-identical — `tests/test_fleet.py` asserts
    equal `tried`/`costs`/`stop_iteration` sequences seed-for-seed.  (A
    `lax.while_loop` formulation was rejected: XLA:CPU executes while bodies
    ~5-8× slower than the identical standalone program, and its different
    float32 numerics break trace equivalence with any per-step engine.)

Per-job structure is encoded as masks over a padded configuration axis:
`priority_mask` / `remaining_mask` delimit Ruya's two phases (CherryPick is
priority=everything, remaining=empty), and padded slots belong to neither
pool, so they are never candidates and — by `fast_bo`'s exact masking —
contribute nothing to any posterior.  Jobs are grouped by (space shape,
packed capacity B): the packed factorizations run at static extent B, so a
job must run at exactly the capacity the sequential engine would use for it
to stay float32-identical.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bayesopt import BOSettings, SearchTrace, trial_budget
from repro.core.fast_bo import (
    _LAYOUTS,
    FleetState,
    encode_features,
    fleet_step,
    precompute_d2,
)
from repro.core.search_space import SearchSpace

__all__ = ["BatchedTrace", "batched_search"]


@dataclasses.dataclass
class BatchedTrace:
    """Trial logs for J searches, padded to the longest run.

    ``tried[j, k]`` is the k-th configuration index tried by job j (-1 pad);
    ``costs`` is aligned with ``tried``; ``stop_iteration``/``phase_boundary``
    are -1 where the event never happened.  ``job_trace(j)`` converts one row
    to the sequential engine's `SearchTrace` so everything downstream of
    either engine speaks the same type.
    """

    tried: np.ndarray  # (J, T) int32, -1 padded
    costs: np.ndarray  # (J, T) float64, aligned with tried
    n_tried: np.ndarray  # (J,) int32
    stop_iteration: np.ndarray  # (J,) int32, -1 = criterion never fired
    phase_boundary: np.ndarray  # (J,) int32, -1 = never left the priority phase

    def __len__(self) -> int:
        return self.tried.shape[0]

    def job_trace(self, j: int) -> SearchTrace:
        k = int(self.n_tried[j])
        stop = int(self.stop_iteration[j])
        pb = int(self.phase_boundary[j])
        return SearchTrace(
            tried=[int(i) for i in self.tried[j, :k]],
            costs=[float(c) for c in self.costs[j, :k]],
            stop_iteration=stop if stop >= 0 else None,
            phase_boundary=pb if pb >= 0 else None,
        )

    def traces(self) -> List[SearchTrace]:
        return [self.job_trace(j) for j in range(len(self))]


# Jobs are processed in lockstep chunks of this extent: small enough that
# the (CHUNK·18, B, B) factorization intermediates stay cache-resident on
# CPU, large enough to amortize dispatch.  Chunk extent must not affect
# results: float32 numerics are batch-extent-invariant for extents in
# [2, 8] (extent 1 compiles to different unbatched programs, hence the ≥2
# padding below; extents ≥ 12 vectorize some reductions differently and
# diverge — verified empirically against the sequential engine, do not
# raise this without re-running tests/test_fleet.py).
_CHUNK = 8
# With early stopping enabled, the host polls the done flags at this period
# (each poll syncs the dispatch queue once).
_POLL_PERIOD = 8


@partial(jax.jit, static_argnames=("xi", "layout"), donate_argnums=(0,))
def _fleet_update(
    state, geom, costs, prio_mask, rem_mask, init_picks, init_count,
    max_trials, min_obs, ei_stop_rel, to_exhaustion, *, xi: float,
    layout: str = "feature",
):
    """One lockstep iteration for a chunk of jobs (vmapped `fleet_step`).

    The state is donated: its buffers alias the outputs, so each fleet
    iteration updates in place — no per-iteration device copies of the
    observation mask or the packed trial/target/feature buffers (asserted
    by `benchmarks/fleet_bench.py`).
    """

    def one(s, g, c, p, r, ip, ic, mt):
        return fleet_step(
            s, g, c, p, r, ip, ic, mt, min_obs, ei_stop_rel, to_exhaustion,
            xi, layout,
        )

    return jax.vmap(one)(
        state, geom, costs, prio_mask, rem_mask, init_picks, init_count,
        max_trials,
    )


def _run_chunk(
    geom, costs, prio_mask, rem_mask, init_picks, init_count, max_trials,
    settings: BOSettings, to_exhaustion: bool, capacity: int, feat_dim: int,
    layout: str,
):
    """Drive one chunk of jobs to completion; state stays on device.

    The host loop makes no data-dependent decisions (`fleet_step` is a no-op
    for finished jobs), so all iterations dispatch asynchronously; with
    early stopping it additionally polls the done flags every few steps to
    cut the tail.
    """
    j = costs.shape[0]
    n = costs.shape[1]
    state = FleetState(
        obs=jnp.zeros((j, n), bool),
        tried=jnp.full((j, capacity), -1, jnp.int32),
        py=jnp.zeros((j, capacity), jnp.float32),
        feats=jnp.zeros((j, capacity, feat_dim), jnp.float32),
        t=jnp.zeros(j, jnp.int32),
        stop=jnp.full(j, -1, jnp.int32),
        pb=jnp.full(j, -1, jnp.int32),
        done=jnp.zeros(j, bool),
        last_ei=jnp.zeros(j, jnp.float32),
        last_best=jnp.full(j, jnp.inf, jnp.float32),
    )
    args = (
        jnp.asarray(geom), jnp.asarray(costs), jnp.asarray(prio_mask),
        jnp.asarray(rem_mask), jnp.asarray(init_picks),
        jnp.asarray(init_count), jnp.asarray(max_trials),
        jnp.asarray(settings.min_observations, jnp.int32),
        jnp.asarray(settings.ei_stop_rel, jnp.float32),
        jnp.asarray(to_exhaustion),
    )
    # One extra pass beyond the trial budget: it observes nothing, but it is
    # where a budget-capped job records a phase boundary it reached exactly
    # at its last trial, and where budget exhaustion latches `done`.
    steps = int(np.max(max_trials)) + 1 if len(max_trials) else 0
    for k in range(steps):
        state = _fleet_update(state, *args, xi=settings.xi, layout=layout)
        if (
            not to_exhaustion
            and k % _POLL_PERIOD == _POLL_PERIOD - 1
            and bool(jnp.all(state.done))
        ):
            break
    return state


def _as_space_list(
    spaces: Union[SearchSpace, Sequence[SearchSpace]], n_jobs: int
) -> List[SearchSpace]:
    if isinstance(spaces, SearchSpace):
        return [spaces] * n_jobs
    spaces = list(spaces)
    if len(spaces) != n_jobs:
        raise ValueError(f"{len(spaces)} spaces for {n_jobs} jobs")
    return spaces


def batched_search(
    spaces: Union[SearchSpace, Sequence[SearchSpace]],
    cost_tables: Sequence[np.ndarray],
    rngs: Sequence[np.random.Generator],
    *,
    priority: Optional[Sequence[Sequence[int]]] = None,
    remaining: Optional[Sequence[Sequence[int]]] = None,
    settings: BOSettings = BOSettings(),
    to_exhaustion: bool = False,
    layout: str = "feature",
) -> BatchedTrace:
    """Run J independent BO searches in lockstep on device.

    ``spaces`` may be a single shared `SearchSpace` or one per job.  Jobs are
    grouped by (space shape, trial budget) — each group runs unpadded at its
    own packed capacity, so a heterogeneous fleet stays bitwise-identical to
    the per-job sequential engine (padding a 10-config job into a 20-slot
    batch, or a 10-trial budget into a 20-slot packed buffer, would be
    mathematically exact but not float32-identical).  ``cost_tables[j][i]``
    is the cost job j observes for configuration i — the full table lives on
    device so the loop never leaves it.  ``priority``/``remaining`` give
    each job's Ruya split (omitted → plain CherryPick over the whole space).
    The random initialization consumes ``rngs[j]`` exactly like the
    sequential engine, so seed-matched runs produce identical traces.
    ``layout`` selects the packed geometry path: "feature" (default, O(n·d)
    memory) or "gather" (retained PR-2 (n,n)-tensor path, bit-identical,
    kept for cross-checks — do not use it for n ≳ 10⁴ spaces).
    """
    if layout not in _LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; want one of {_LAYOUTS}")
    n_jobs = len(cost_tables)
    if len(rngs) != n_jobs:
        raise ValueError(f"{len(rngs)} rngs for {n_jobs} jobs")
    space_list = _as_space_list(spaces, n_jobs)
    if priority is None:
        priority = [list(range(len(s))) for s in space_list]
    if remaining is None:
        remaining = [[] for _ in range(n_jobs)]

    init_lists: List[List[int]] = []
    max_trials_all = np.zeros(n_jobs, np.int32)
    for j, (space, table, rng) in enumerate(zip(space_list, cost_tables, rngs)):
        n = len(space)
        table = np.asarray(table, np.float64)
        if table.shape != (n,):
            raise ValueError(f"cost table {j} has shape {table.shape}, want ({n},)")
        prio = [int(i) for i in priority[j]]
        rem = [int(i) for i in remaining[j]]
        if set(prio) & set(rem):
            raise ValueError(f"job {j}: priority and remaining pools overlap")
        # Scripted random initialization — the same draw, in the same order,
        # as `_bo_loop`'s phase-0 block, so traces match seed-for-seed.
        # Drawn up front (in job order) regardless of grouping.
        if prio:
            n_init = min(settings.n_init, len(prio))
            picked = rng.choice(len(prio), size=n_init, replace=False)
            init_lists.append([prio[int(i)] for i in picked])
        else:
            init_lists.append([])
        # Shared with the sequential engine: the budget is also the packed
        # capacity B, and the engines must agree on it exactly.
        max_trials_all[j] = trial_budget(len(prio), len(rem), settings)

    max_T = max(int(max_trials_all.max()) if n_jobs else 0, 1)
    tried = np.full((n_jobs, max_T), -1, np.int32)
    n_tried = np.zeros(n_jobs, np.int32)
    stop = np.full(n_jobs, -1, np.int32)
    pb = np.full(n_jobs, -1, np.int32)

    # Group jobs by (space shape, packed capacity); each group runs unpadded
    # at its own static extents, in cache-friendly lockstep chunks.  Chunks
    # of one job are padded with an inert dummy (zero trial budget): XLA:CPU
    # collapses singleton batch dims into unbatched programs with different
    # float32 numerics, so every call must run at extent ≥ 2.
    groups: dict = {}
    for j, space in enumerate(space_list):
        enc = space.encoded()
        groups.setdefault((enc.shape, int(max_trials_all[j])), []).append(j)

    # Per-space geometry is once-per-space work (seed-replica fleets alias
    # one SearchSpace object), computed identically to the sequential
    # engine's, then stacked per chunk.  Feature layout: the (n,d) float32
    # encoding.  Gather layout: the unbatched (n,n) distance tensor.
    geom_cache: dict = {}

    def space_geom(space: SearchSpace) -> np.ndarray:
        key = id(space)
        if key not in geom_cache:
            enc = encode_features(space.encoded())
            geom_cache[key] = (
                enc if layout == "feature" else np.asarray(precompute_d2(enc))
            )
        return geom_cache[key]

    for (shape, cap), members in groups.items():
        n, d = shape
        g = len(members)
        capacity = max(cap, 1)
        costs = np.zeros((g, n), np.float32)
        prio_mask = np.zeros((g, n), bool)
        rem_mask = np.zeros((g, n), bool)
        n_init_slots = max(1, max(len(init_lists[j]) for j in members))
        init_picks = np.zeros((g, n_init_slots), np.int32)
        init_count = np.zeros(g, np.int32)
        max_trials = np.zeros(g, np.int32)
        for i, j in enumerate(members):
            costs[i] = np.asarray(cost_tables[j], np.float32)
            prio_mask[i, np.asarray(priority[j], np.int64)] = True
            if len(remaining[j]):
                rem_mask[i, np.asarray(remaining[j], np.int64)] = True
            il = init_lists[j]
            init_picks[i, : len(il)] = il
            init_count[i] = len(il)
            max_trials[i] = max_trials_all[j]

        for lo in range(0, g, _CHUNK):
            hi = min(lo + _CHUNK, g)
            chunk = slice(lo, hi)
            geom = np.stack([space_geom(space_list[j]) for j in members[lo:hi]])
            parts = [
                geom, costs[chunk], prio_mask[chunk],
                rem_mask[chunk], init_picks[chunk], init_count[chunk],
                max_trials[chunk],
            ]
            if hi - lo == 1:
                parts = [np.concatenate([a, np.zeros_like(a[:1])]) for a in parts]
            state = _run_chunk(
                *parts, settings=settings, to_exhaustion=to_exhaustion,
                capacity=capacity, feat_dim=int(d), layout=layout,
            )
            s_tried, s_t, s_stop, s_pb = (
                np.asarray(state.tried), np.asarray(state.t),
                np.asarray(state.stop), np.asarray(state.pb),
            )
            for i, j in enumerate(members[lo:hi]):
                tried[j, :capacity] = s_tried[i]
                n_tried[j] = int(s_t[i])
                stop[j] = int(s_stop[i])
                pb[j] = int(s_pb[i])
    # Costs are reported from the float64 tables (the engine's float32 copy
    # is only the GP's view), matching the sequential trace exactly.
    out_costs = np.zeros(tried.shape, np.float64)
    for j, table in enumerate(cost_tables):
        k = int(n_tried[j])
        out_costs[j, :k] = np.asarray(table, np.float64)[tried[j, :k]]
    return BatchedTrace(
        tried=tried,
        costs=out_costs,
        n_tried=n_tried,
        stop_iteration=stop,
        phase_boundary=pb,
    )
