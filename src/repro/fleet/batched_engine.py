"""Fully-batched multi-job BO search: J searches in lockstep on device.

The sequential engine (`repro.core.bayesopt._bo_loop`) drives one job per
Python-loop iteration, paying a dispatch + host round-trip per BO step —
thousands of synchronizations for a fleet.  Here the whole fleet advances in
lockstep.  (Since the `TuningSession` redesign the chunk lifecycle — group,
admit, step, retire — lives in `repro.fleet.session`, which also serves
streaming submission and warm-starting; `batched_search` below is the
retained one-shot shim, and this module keeps the jitted lockstep update
`_fleet_update` plus the chunking constants both entry points share.
With `shard=`/`devices=`, chunks are additionally bundled across JAX
devices and advanced by one `shard_map` dispatch — see
`repro.fleet.sharding`; traces stay bit-identical either way.)

  * `jax.vmap` over jobs lifts the per-job state (observation mask, packed
    trial log/targets/features — `fast_bo.FleetState`) into batched arrays
    that stay resident on device;
  * one jitted call per iteration applies `fast_bo.fleet_step` to every job
    at once, with the state DONATED to the call so XLA updates the buffers
    in place instead of copying them per iteration; the host only counts
    iterations (all bookkeeping — including per-job stopping — happens on
    device, and iterations dispatch asynchronously, so there are no
    per-step host round-trips);
  * per-job geometry is the static (n,d) float32 encoding — the
    feature-buffer layout computes its (B,B)/(B,n) distance blocks on the
    fly from the packed (B,d) feature buffer each step, so nothing of
    extent n² is ever materialized and 10⁴–10⁵-point spaces run in O(n·d)
    memory.  The retained PR-2 path (``layout="gather"``) instead threads
    each job's precomputed (n,n) distance tensor (`fast_bo.precompute_d2`)
    through every iteration; the two layouts are bit-identical
    (`tests/test_feature_buffer.py`) and the gather path is kept for
    cross-checking and benchmarking;
  * `fleet_step` is the *same compiled program* the sequential path probes,
    so the two engines are trace-identical — `tests/test_fleet.py` asserts
    equal `tried`/`costs`/`stop_iteration` sequences seed-for-seed.  (A
    `lax.while_loop` formulation was rejected: XLA:CPU executes while bodies
    ~5-8× slower than the identical standalone program, and its different
    float32 numerics break trace equivalence with any per-step engine.)

Per-job structure is encoded as masks over a padded configuration axis:
`priority_mask` / `remaining_mask` delimit Ruya's two phases (CherryPick is
priority=everything, remaining=empty), and padded slots belong to neither
pool, so they are never candidates and — by `fast_bo`'s exact masking —
contribute nothing to any posterior.  Jobs are grouped by (space shape,
packed capacity B): the packed factorizations run at static extent B, so a
job must run at exactly the capacity the sequential engine would use for it
to stay float32-identical.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.bayesopt import BOSettings, SearchTrace, trial_budget
from repro.core.fast_bo import _LAYOUTS, fleet_step
from repro.core.search_space import SearchSpace

__all__ = ["BatchedTrace", "batched_search"]


@dataclasses.dataclass
class BatchedTrace:
    """Trial logs for J searches, padded to the longest run.

    ``tried[j, k]`` is the k-th configuration index tried by job j (-1 pad);
    ``costs`` is aligned with ``tried``; ``stop_iteration``/``phase_boundary``
    are -1 where the event never happened.  ``job_trace(j)`` converts one row
    to the sequential engine's `SearchTrace` so everything downstream of
    either engine speaks the same type.
    """

    tried: np.ndarray  # (J, T) int32, -1 padded
    costs: np.ndarray  # (J, T) float64, aligned with tried
    n_tried: np.ndarray  # (J,) int32
    stop_iteration: np.ndarray  # (J,) int32, -1 = criterion never fired
    phase_boundary: np.ndarray  # (J,) int32, -1 = never left the priority phase

    def __len__(self) -> int:
        return self.tried.shape[0]

    def job_trace(self, j: int) -> SearchTrace:
        k = int(self.n_tried[j])
        stop = int(self.stop_iteration[j])
        pb = int(self.phase_boundary[j])
        return SearchTrace(
            tried=[int(i) for i in self.tried[j, :k]],
            costs=[float(c) for c in self.costs[j, :k]],
            stop_iteration=stop if stop >= 0 else None,
            phase_boundary=pb if pb >= 0 else None,
        )

    def traces(self) -> List[SearchTrace]:
        return [self.job_trace(j) for j in range(len(self))]


# Jobs are processed in lockstep chunks of this extent: small enough that
# the (CHUNK·18, B, B) factorization intermediates stay cache-resident on
# CPU, large enough to amortize dispatch.  Chunk extent must not affect
# results: float32 numerics are batch-extent-invariant for extents in
# [2, 8] (extent 1 compiles to different unbatched programs, hence the ≥2
# padding below; extents ≥ 12 vectorize some reductions differently and
# diverge — verified empirically against the sequential engine, do not
# raise this without re-running tests/test_fleet.py).
_CHUNK = 8
# With early stopping enabled, the host polls the done flags at this period
# (each poll syncs the dispatch queue once).
_POLL_PERIOD = 8


@partial(jax.jit, static_argnames=("xi", "layout"), donate_argnums=(0,))
def _fleet_update(
    state, geom, costs, prio_mask, rem_mask, init_picks, init_count,
    max_trials, min_obs, ei_stop_rel, to_exhaustion, *, xi: float,
    layout: str = "feature",
):
    """One lockstep iteration for a chunk of jobs (vmapped `fleet_step`).

    The state is donated: its buffers alias the outputs, so each fleet
    iteration updates in place — no per-iteration device copies of the
    observation mask or the packed trial/target/feature buffers (asserted
    by `benchmarks/fleet_bench.py`).
    """

    def one(s, g, c, p, r, ip, ic, mt):
        return fleet_step(
            s, g, c, p, r, ip, ic, mt, min_obs, ei_stop_rel, to_exhaustion,
            xi, layout,
        )

    return jax.vmap(one)(
        state, geom, costs, prio_mask, rem_mask, init_picks, init_count,
        max_trials,
    )


def _as_space_list(
    spaces: Union[SearchSpace, Sequence[SearchSpace]], n_jobs: int
) -> List[SearchSpace]:
    if isinstance(spaces, SearchSpace):
        return [spaces] * n_jobs
    spaces = list(spaces)
    if len(spaces) != n_jobs:
        raise ValueError(f"{len(spaces)} spaces for {n_jobs} jobs")
    return spaces


def batched_search(
    spaces: Union[SearchSpace, Sequence[SearchSpace]],
    cost_tables: Sequence[np.ndarray],
    rngs: Sequence[np.random.Generator],
    *,
    priority: Optional[Sequence[Sequence[int]]] = None,
    remaining: Optional[Sequence[Sequence[int]]] = None,
    settings: BOSettings = BOSettings(),
    to_exhaustion: bool = False,
    layout: str = "feature",
    shard=None,
    devices=None,
) -> BatchedTrace:
    """Run J independent BO searches in lockstep on device.

    ``spaces`` may be a single shared `SearchSpace` or one per job.  Jobs are
    grouped by (space shape, trial budget) — each group runs unpadded at its
    own packed capacity, so a heterogeneous fleet stays bitwise-identical to
    the per-job sequential engine (padding a 10-config job into a 20-slot
    batch, or a 10-trial budget into a 20-slot packed buffer, would be
    mathematically exact but not float32-identical).  ``cost_tables[j][i]``
    is the cost job j observes for configuration i — the full table lives on
    device so the loop never leaves it.  ``priority``/``remaining`` give
    each job's Ruya split (omitted → plain CherryPick over the whole space).
    The random initialization consumes ``rngs[j]`` exactly like the
    sequential engine, so seed-matched runs produce identical traces.
    ``layout`` selects the packed geometry path: "feature" (default, O(n·d)
    memory) or "gather" (retained PR-2 (n,n)-tensor path, bit-identical,
    kept for cross-checks — do not use it for n ≳ 10⁴ spaces).
    ``shard``/``devices`` shard the job axis across JAX devices
    (`repro.fleet.sharding`) — a pure execution optimization, pinned
    bit-identical to the single-device default by `tests/golden/`.

    Since the `TuningSession` redesign this is a thin shim: submit every
    job to a fresh session (no profiling, no warm-starting — the splits are
    passed verbatim), drain it, and repackage the outcomes.  A statically
    submitted session runs the identical grouping/chunking/array program
    this module ran pre-redesign, so traces are unchanged bit-for-bit
    (`tests/test_fleet.py` / `tests/test_session.py`).
    """
    from repro.fleet.driver import FleetJob
    from repro.fleet.session import TuningSession

    if layout not in _LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; want one of {_LAYOUTS}")
    n_jobs = len(cost_tables)
    if len(rngs) != n_jobs:
        raise ValueError(f"{len(rngs)} rngs for {n_jobs} jobs")
    space_list = _as_space_list(spaces, n_jobs)
    if priority is None:
        priority = [list(range(len(s))) for s in space_list]
    if remaining is None:
        remaining = [[] for _ in range(n_jobs)]

    session = TuningSession(
        settings=settings, mode="cherrypick", warm_start=False,
        to_exhaustion=to_exhaustion, layout=layout, shard=shard,
        devices=devices,
    )
    for j, (space, table, rng) in enumerate(zip(space_list, cost_tables, rngs)):
        session.submit(
            FleetJob(name=f"job{j}", space=space, cost_table=table),
            rng,
            priority=[int(i) for i in priority[j]],
            remaining=[int(i) for i in remaining[j]],
        )
    outs = session.drain()

    budgets = [
        trial_budget(len(priority[j]), len(remaining[j]), settings)
        for j in range(n_jobs)
    ]
    max_T = max(max(budgets, default=0), 1)
    tried = np.full((n_jobs, max_T), -1, np.int32)
    out_costs = np.zeros((n_jobs, max_T), np.float64)
    n_tried = np.zeros(n_jobs, np.int32)
    stop = np.full(n_jobs, -1, np.int32)
    pb = np.full(n_jobs, -1, np.int32)
    for j, out in enumerate(outs):
        k = len(out.records)
        tried[j, :k] = [r.index for r in out.records]
        out_costs[j, :k] = [r.cost for r in out.records]
        n_tried[j] = k
        stop[j] = -1 if out.stop_iteration is None else out.stop_iteration
        pb[j] = -1 if out.phase_boundary is None else out.phase_boundary
    return BatchedTrace(
        tried=tried,
        costs=out_costs,
        n_tried=n_tried,
        stop_iteration=stop,
        phase_boundary=pb,
    )
