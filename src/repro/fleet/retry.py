"""Deterministic exponential backoff with seeded jitter for profiling runs.

Production profiling runs fail: sample machines get preempted, probe
processes OOM, connections drop.  Ruya's premise — profiling is cheap and
reliable — survives contact with a real cluster only if transient failures
are retried and permanent ones give up *cleanly* (a broken job binary must
not burn `max_attempts × backoff` of budget before surfacing).

Everything here is deterministic.  The backoff for attempt k is the usual
capped exponential, and the jitter — which exists to de-synchronize
retrying clients — comes from a hash of ``(seed, attempt)`` instead of a
live RNG, so a retried fleet run is a pure function of (fault schedule,
session seed): the golden-trace harness can pin a disturbed fleet's
surviving traces bit-identical to an undisturbed run, and a flaky retry
schedule can never be the reason a fixture drifts.  No RNG state is
consumed anywhere (the BO initialization draws stay aligned with the
undisturbed engines).

Classification follows `repro.core.profiler`'s taxonomy:
`TransientRunError` is retried up to ``max_attempts`` with backoff;
`PermanentRunError` — and any exception type not listed in ``transient``
— propagates immediately (fast-fail, zero backoff charged).

Sleeping is injectable and OFF by default: this repo drives emulated
clusters, so backoff is *charged* (returned in `RetryStats.backoff_s`)
rather than slept.  Pass ``sleep=time.sleep`` to actually wait.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, List, Optional, Tuple, Type, TypeVar

from repro.core.profiler import TransientRunError

__all__ = [
    "RetryPolicy",
    "RetryStats",
    "backoff_s",
    "backoff_schedule",
    "call_with_retry",
]

T = TypeVar("T")


def _hash_unit(*parts: str) -> float:
    """Deterministic uniform in [0, 1) from a string key (same idiom as
    `repro.cluster.simulator._hash_unit_normal` — sha256, not a live RNG,
    so retry jitter never perturbs the engines' scripted draws)."""
    h = hashlib.sha256("/".join(parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0**64


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff parameters for transient profiling-run failures.

    ``max_attempts`` counts ALL attempts including the first (1 = never
    retry).  The backoff before retry k (1-based failure count) is

        min(base_s · multiplier^(k-1), max_backoff_s) · (1 + jitter·u_k)

    with u_k a deterministic uniform in [-1, 1) derived from (seed, k) —
    see `backoff_s`.  ``jitter`` must keep the factor positive (< 1.0).
    """

    max_attempts: int = 4
    base_s: float = 1.0
    multiplier: float = 2.0
    max_backoff_s: float = 60.0
    jitter: float = 0.25

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts={self.max_attempts}: want >= 1")
        if not (0.0 <= self.jitter < 1.0):
            raise ValueError(f"jitter={self.jitter}: want in [0, 1)")
        if self.base_s < 0.0 or self.multiplier < 1.0 or self.max_backoff_s < 0:
            raise ValueError("backoff parameters must be non-negative, "
                             "multiplier >= 1")


@dataclasses.dataclass
class RetryStats:
    """What one retried call cost beyond its successful attempt."""

    attempts: int = 1  # total attempts made (1 = first try succeeded)
    backoff_s: float = 0.0  # total charged backoff (simulated unless slept)


def backoff_s(policy: RetryPolicy, seed: int, attempt: int) -> float:
    """Deterministic backoff before retry ``attempt`` (1-based count of
    failures so far).  A pure function of (policy, seed, attempt)."""
    if attempt < 1:
        raise ValueError(f"attempt={attempt}: retries are 1-based")
    raw = min(
        policy.base_s * policy.multiplier ** (attempt - 1),
        policy.max_backoff_s,
    )
    u = 2.0 * _hash_unit("retry", str(seed), str(attempt)) - 1.0  # [-1, 1)
    return raw * (1.0 + policy.jitter * u)


def backoff_schedule(policy: RetryPolicy, seed: int) -> List[float]:
    """The full deterministic backoff schedule: one entry per possible
    retry (``max_attempts - 1`` entries)."""
    return [backoff_s(policy, seed, k) for k in range(1, policy.max_attempts)]


def call_with_retry(
    fn: Callable[[], T],
    *,
    policy: RetryPolicy,
    seed: int,
    transient: Tuple[Type[BaseException], ...] = (TransientRunError,),
    sleep: Optional[Callable[[float], None]] = None,
    stats: Optional[RetryStats] = None,
) -> Tuple[T, RetryStats]:
    """Call ``fn`` retrying transient failures with deterministic backoff.

    Returns ``(value, RetryStats)``.  Exceptions in ``transient`` are
    retried up to ``policy.max_attempts`` total attempts; the last one is
    re-raised when the budget is exhausted.  Any other exception —
    `PermanentRunError` included — propagates immediately with no backoff
    charged (fast-fail).  ``sleep`` is called with each backoff when given
    (default: backoff is charged to the stats only — emulated clusters
    should not make the test suite wait).  ``stats`` accumulates in place
    when supplied, so one object can aggregate a probe + profile pair.
    """
    st = stats if stats is not None else RetryStats(attempts=0)
    if stats is None:
        st.attempts = 0
    last: Optional[BaseException] = None
    for attempt in range(1, policy.max_attempts + 1):
        st.attempts += 1  # count every attempt, fast-failed ones included
        try:
            value = fn()
        except transient as e:
            last = e
            if attempt == policy.max_attempts:
                raise
            delay = backoff_s(policy, seed, attempt)
            st.backoff_s += delay
            if sleep is not None:
                sleep(delay)
            continue
        return value, st
    raise last  # unreachable: loop either returned or re-raised
