"""`TuningSession`: one streaming session API over every tuning path.

Ruya's workflow is inherently incremental — profile, narrow, iterate BO
until convergence — but the repo historically exposed it as three one-shot
entry points (`run_ruya`, `run_cherrypick`, `tune_fleet`) that assume every
job is known up front.  The session turns tuning into a service:

    session = TuningSession(cache=ProfileCache(), warm_start=True)
    handle  = session.submit(job, seed=0)     # profile → split → enqueue
    session.step()                            # ONE batched BO iteration for
                                              # every live search; newly
                                              # submitted jobs are admitted
                                              # into lockstep chunks between
                                              # steps
    outcomes = session.drain()                # step until everything is done
    handle.outcome().records                  # first-class TrialRecords

Execution model.  Submitted jobs wait in a pending queue; at the next
`step()` they are grouped by (space shape, packed capacity B) — the same
grouping rule as `repro.fleet.batched_engine` — and formed into lockstep
chunks of ≤ `_CHUNK` jobs.  Each `step()` applies the donated, vmapped
`fast_bo.fleet_step` update once to every live chunk, so the whole session
advances one BO iteration per call with no data-dependent host decisions;
chunks retire when their step budget is exhausted (or, with early stopping,
when a periodic poll of the on-device done flags comes back all-True).
Draining a statically submitted fleet therefore replays `batched_search`'s
exact array program — same grouping, same chunking, same scripted-init
draws in submission order, same singleton dummy padding, same jitted update
— and is bitwise trace-identical to the pre-session engines
(`tests/test_session.py` pins this seed-for-seed against the sequential
engine for both packed geometry layouts).

Cross-job warm-starting (Flora's signature classes, Blink's recurring-job
amortization).  The session owns the tuning state: give it a
`ProfileCache` to share probe-classified profiles across jobs (without
one, each distinct job profiles exactly once, like the one-shot drivers);
either way every profiled job gets a `MemorySignature`, and completed
trials are logged per (signature, space shape) class.  A job submitted into a class with history is *seeded*: its
packed `(B,)` trial/target buffers and `(B,d)` feature buffer start
pre-filled with up to B − reserve class trials (capacity-aware — the seeds
consume packed slots and trial budget, so a seeded search runs at the same
static extents as a cold one), its observation mask marks the seeded
configs, and the scripted random initialization is skipped — the GP opens
with the class's knowledge and typically fires the EI convergence
threshold after a handful of fresh trials.  Seeding preserves `fast_bo`'s
exact padding rules: seeded slots are ordinary observations (slots < t),
written with the same canonical float32 encoding rows an on-device
observation would have produced.  A warm-started search is a deterministic
function of (class history, seed): the history is ordered by completion,
deduplicated by config index, and truncated capacity-aware, and no RNG is
consumed when seeding happens.

Memory-aware narrowing runs ON DEVICE: the §III-D priority split comes from
`repro.core.search_space.split_masks_device` (float64 on device, bit-equal
to the host rule), so admission cost scales with the catalog — no Python
loop over 10⁴–10⁵ configurations.

`run_ruya` / `run_cherrypick` / `tune_fleet` / `batched_search` remain as
thin deprecation shims over this engine.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import weakref
from typing import (
    Callable, Dict, List, Optional, Sequence, Set, Tuple, TYPE_CHECKING,
    Union,
)

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.bayesopt import BOSettings, SearchTrace, trial_budget
from repro.core.fast_bo import (
    _LAYOUTS,
    FleetState,
    encode_features,
    precompute_d2,
)
from repro.core.profiler import (
    ProfileResult,
    ProfilingRunError,
    profile_job,
)
from repro.core.search_space import split_masks_device
from repro.core.tuner import RuyaReport
# The jitted lockstep update and the chunking constants are shared verbatim
# with the pre-session engine (see `repro.fleet.batched_engine` for why 8:
# f32 numerics are batch-extent-invariant only in [2, 8] on XLA:CPU, and
# chunks of one are padded with an inert dummy because extent-1 programs
# compile to different float32 numerics).
from repro.fleet.batched_engine import _CHUNK, _POLL_PERIOD, _fleet_update
from repro.fleet.profile_cache import MemorySignature, ProfileCache
from repro.fleet.retry import RetryPolicy, RetryStats, call_with_retry
from repro.fleet.sharding import (
    collapse_rows,
    resolve_shard_devices,
    sharded_update,
)

if TYPE_CHECKING:  # import cycle: driver imports session for tune_fleet
    from repro.fleet.driver import FleetJob

__all__ = [
    "FleetFailedError",
    "JobHandle",
    "SearchOutcome",
    "TrialRecord",
    "TuningSession",
    "canonical_objective",
    "objective_table",
]

_TRIAL_SOURCES = ("init", "search", "warm")

# A tuning objective is "runtime" (the legacy table — every committed
# golden trace), "cost" (runtime×price under the job's catalog), or a
# weight mapping over both.  The canonical form is the string, or a
# sorted tuple of (axis, weight) pairs — hashable, so it can extend the
# warm-start class key (histories from different objectives score trials
# on different scales and must never cross-seed).
Objective = Union[str, Tuple[Tuple[str, float], ...]]
_OBJECTIVE_AXES = ("runtime", "cost")


def canonical_objective(objective) -> Objective:
    """Validate and canonicalize an objective spec (see `Objective`)."""
    if isinstance(objective, str):
        if objective not in _OBJECTIVE_AXES:
            raise ValueError(
                f"unknown objective {objective!r}; want one of "
                f"{_OBJECTIVE_AXES} or a weight mapping over them"
            )
        return objective
    if isinstance(objective, tuple):
        objective = dict(objective)
    if isinstance(objective, dict):
        extra = set(objective) - set(_OBJECTIVE_AXES)
        if extra or not objective:
            raise ValueError(
                f"objective weights must be over {_OBJECTIVE_AXES}, got "
                f"{sorted(objective) if objective else 'no axes'}"
            )
        weights = {k: float(v) for k, v in objective.items()}
        if min(weights.values()) < 0.0 or sum(weights.values()) <= 0.0:
            raise ValueError(
                f"objective weights must be >= 0 with a positive sum, "
                f"got {weights}"
            )
        return tuple(sorted(weights.items()))
    raise TypeError(
        f"objective must be a string or a weight mapping, got "
        f"{type(objective).__name__}"
    )


def objective_table(job: "FleetJob", objective: Objective) -> np.ndarray:
    """The (n,) float64 score table a search over ``job`` observes.

    ``"runtime"`` is the job's own ``cost_table``, byte-for-byte — the
    pinned legacy path.  ``"cost"`` scores by runtime×price from the
    job's pricing axes, normalized by its minimum (the same conditioning
    the legacy tables have); a weight mapping blends the two normalized
    axes.  Non-runtime objectives need a priced job (build one via
    `cluster_fleet(..., catalog=...)`).
    """
    obj = canonical_objective(objective)
    table = np.asarray(job.cost_table, np.float64)
    if obj == "runtime":
        return table
    rt = getattr(job, "runtime_table", None)
    price = getattr(job, "price_table", None)
    if rt is None or price is None:
        raise ValueError(
            f"job {job.name!r}: objective {objective!r} needs the job's "
            "runtime_table and price_table pricing axes — build priced "
            "jobs via cluster_fleet(..., catalog=...) or set both fields"
        )
    usd = np.asarray(rt, np.float64) * np.asarray(price, np.float64)
    usd_norm = usd / usd.min()
    if obj == "cost":
        return usd_norm
    weights = dict(obj)
    rt_norm = table / table.min()
    total = sum(weights.values())
    return (
        weights.get("runtime", 0.0) * rt_norm
        + weights.get("cost", 0.0) * usd_norm
    ) / total

# Terminal status of a search.  "converged" is the normal retirement (EI
# threshold fired or trial budget exhausted); the other three are
# first-class partial results: "cancelled" (caller revoked the job),
# "failed" (profiling failed permanently / retry budget exhausted, or an
# external executor died mid-flight), "preempted" (evicted for a
# higher-priority job — resubmit to continue from the class history).
_STATUSES = ("converged", "cancelled", "failed", "preempted")


class FleetFailedError(RuntimeError):
    """`drain()` was waiting exclusively on jobs that permanently failed.

    Partial fleets keep going — one broken job must not sink its
    chunk-mates — so failures surface as first-class "failed" outcomes.
    But when EVERY job live at the drain call ends "failed", returning
    normally would read as success; the session raises this instead (the
    outcomes stay available via `results()`)."""


@dataclasses.dataclass(frozen=True)
class TrialRecord:
    """One observation: which config, what it cost, when, and why.

    ``slot`` is the packed-buffer slot (= engine trial counter value when the
    observation was made, warm seeds included).  ``source`` is "init"
    (scripted random initialization), "search" (BO pick), or "warm" (seeded
    from the signature class's history — the cost is the donor's).
    ``attempts`` is the number of cluster runs the trial took (> 1 when a
    straggler run was re-dispatched — reported latency only, the observed
    cost is always the deterministic table value).

    ``runtime_h``/``usd`` are the trial's RAW axes — hours and dollars
    under the job's price catalog — populated only for priced jobs
    (`FleetJob.runtime_table`/`price_table` set); ``cost`` stays the
    objective's score.  Unpriced records serialize without the two keys,
    so every committed golden fixture round-trips unchanged.
    """

    index: int
    cost: float
    slot: int
    source: str = "search"
    attempts: int = 1
    runtime_h: Optional[float] = None
    usd: Optional[float] = None

    def as_dict(self) -> dict:
        d = {
            "index": int(self.index),
            "cost": float(self.cost),
            "slot": int(self.slot),
            "source": str(self.source),
            "attempts": int(self.attempts),
        }
        if self.runtime_h is not None:
            d["runtime_h"] = float(self.runtime_h)
        if self.usd is not None:
            d["usd"] = float(self.usd)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TrialRecord":
        src = str(d["source"])
        if src not in _TRIAL_SOURCES:
            raise ValueError(f"unknown trial source {src!r}")
        rt = d.get("runtime_h")
        usd = d.get("usd")
        return cls(
            index=int(d["index"]), cost=float(d["cost"]),
            slot=int(d["slot"]), source=src,
            attempts=int(d.get("attempts", 1)),
            runtime_h=None if rt is None else float(rt),
            usd=None if usd is None else float(usd),
        )


@dataclasses.dataclass
class SearchOutcome:
    """Everything one finished search produced — subsumes
    `SearchTrace`/`RuyaReport` (both are views: `trace()` / `report()`).

    ``records`` are the trials THIS search executed (sources "init" and
    "search"), in trial order; ``seeded`` are the warm-start seeds that
    pre-filled the packed buffers (source "warm", donor costs).
    ``stop_iteration`` / ``phase_boundary`` are the engine's registers and
    count packed slots — i.e. seeds included; `trace()` re-bases them onto
    the executed trials so cold searches round-trip exactly.

    ``status`` (see `_STATUSES`) makes partial results first-class: a
    cancelled/failed/preempted search still carries every trial it
    completed.  ``profile_attempts`` / ``retry_backoff_s`` surface what
    the profiling phase cost under faults (1 / 0.0 = clean first try; the
    backoff is charged, not slept — see `repro.fleet.retry`), and
    ``failure`` carries the terminal error text for "failed" outcomes.

    ``objective`` is the canonical objective the search scored trials
    under (see `canonical_objective`); ``currency`` is set ("USD") for
    priced jobs, whose records carry raw runtime/dollar axes — the inputs
    to `pareto()`, `best_usd` and `best_runtime_h`.  Both serialize only
    when non-default, so unpriced runtime-objective outcomes (every
    committed golden fixture) keep their exact legacy `as_dict` form.
    """

    name: str
    records: List[TrialRecord]
    seeded: List[TrialRecord]
    stop_iteration: Optional[int]
    phase_boundary: Optional[int]
    priority: Tuple[int, ...]
    remaining: Tuple[int, ...]
    profile: Optional[ProfileResult] = None
    signature: Optional[MemorySignature] = None
    status: str = "converged"
    profile_attempts: int = 1
    retry_backoff_s: float = 0.0
    failure: Optional[str] = None
    objective: Objective = "runtime"
    currency: Optional[str] = None

    @property
    def memory_model(self):
        return None if self.profile is None else self.profile.model

    @property
    def observations(self) -> List[TrialRecord]:
        """Seeds + executed trials, in packed-slot order."""
        return list(self.seeded) + list(self.records)

    def _require_observations(self) -> List[TrialRecord]:
        obs = self.observations
        if not obs:
            raise RuntimeError(
                f"job {self.name!r} has no observations (status "
                f"{self.status!r}) — a search that failed or was revoked "
                "before its first trial has no best configuration"
            )
        return obs

    @property
    def best_cost(self) -> float:
        """Lowest recorded cost over seeds + executed trials (seeds carry
        donor costs — for recurring same-class jobs these are the point)."""
        return min(r.cost for r in self._require_observations())

    @property
    def best_index(self) -> int:
        return min(self._require_observations(), key=lambda r: r.cost).index

    def iterations_until(self, threshold_cost: float) -> Optional[int]:
        """1-based EXECUTED trial at which cost ≤ threshold was first seen
        (seeds excluded — this measures what the search itself had to do)."""
        for i, r in enumerate(self.records):
            if r.cost <= threshold_cost:
                return i + 1
        return None

    def _priced_observations(self) -> List[TrialRecord]:
        obs = [
            r for r in self._require_observations()
            if r.runtime_h is not None and r.usd is not None
        ]
        if not obs:
            raise RuntimeError(
                f"job {self.name!r} has no priced observations — runtime/"
                "cost axes exist only for jobs built with a price catalog "
                "(cluster_fleet(..., catalog=...))"
            )
        return obs

    def pareto(self) -> List[TrialRecord]:
        """The cost/runtime Pareto front: observed trials not dominated on
        the two RAW axes (hours, dollars), in trial order.

        A trial dominates another when it is no worse on both axes and
        strictly better on at least one.  Ties on both axes keep only the
        earliest trial (deterministic tie-break by trial order), so the
        front is a pure function of the observation sequence.
        """
        obs = self._priced_observations()
        front: List[TrialRecord] = []
        for i, r in enumerate(obs):
            dominated = False
            for j, o in enumerate(obs):
                if o.runtime_h <= r.runtime_h and o.usd <= r.usd and (
                    o.runtime_h < r.runtime_h or o.usd < r.usd
                ):
                    dominated = True
                    break
                # Exact tie on both axes: the earliest trial represents it.
                if (
                    j < i
                    and o.runtime_h == r.runtime_h
                    and o.usd == r.usd
                ):
                    dominated = True
                    break
            if not dominated:
                front.append(r)
        return front

    @property
    def best_usd(self) -> float:
        """Cheapest observed trial in dollars (priced jobs only)."""
        return min(r.usd for r in self._priced_observations())

    @property
    def best_runtime_h(self) -> float:
        """Fastest observed trial in hours (priced jobs only)."""
        return min(r.runtime_h for r in self._priced_observations())

    def trace(self) -> SearchTrace:
        """The executed trials as the legacy `SearchTrace` (bit-exact for
        cold searches; warm searches re-base the registers past the seeds)."""
        w = len(self.seeded)
        stop = self.stop_iteration
        pb = self.phase_boundary
        return SearchTrace(
            tried=[r.index for r in self.records],
            costs=[r.cost for r in self.records],
            stop_iteration=None if stop is None else max(stop - w, 0),
            phase_boundary=None if pb is None else max(pb - w, 0),
        )

    def report(self) -> RuyaReport:
        """The legacy `RuyaReport` view (single-job / fleet driver output)."""
        return RuyaReport(
            profile=self.profile,
            priority=self.priority,
            remaining=self.remaining,
            trace=self.trace(),
        )

    def as_dict(self) -> dict:
        """JSON-able view; drops `profile`/`signature` (not serializable).
        The cost-aware fields ("objective", "currency") are emitted only
        when non-default, so legacy fixtures compare byte-for-byte."""
        d = {
            "name": self.name,
            "records": [r.as_dict() for r in self.records],
            "seeded": [r.as_dict() for r in self.seeded],
            "stop_iteration": self.stop_iteration,
            "phase_boundary": self.phase_boundary,
            "priority": [int(i) for i in self.priority],
            "remaining": [int(i) for i in self.remaining],
            "status": str(self.status),
            "profile_attempts": int(self.profile_attempts),
            "retry_backoff_s": float(self.retry_backoff_s),
            "failure": self.failure,
        }
        if self.objective != "runtime":
            d["objective"] = (
                self.objective if isinstance(self.objective, str)
                else dict(self.objective)
            )
        if self.currency is not None:
            d["currency"] = str(self.currency)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SearchOutcome":
        stop = d["stop_iteration"]
        pb = d["phase_boundary"]
        status = str(d.get("status", "converged"))
        if status not in _STATUSES:
            raise ValueError(f"unknown outcome status {status!r}")
        failure = d.get("failure")
        currency = d.get("currency")
        return cls(
            name=str(d["name"]),
            records=[TrialRecord.from_dict(r) for r in d["records"]],
            seeded=[TrialRecord.from_dict(r) for r in d["seeded"]],
            stop_iteration=None if stop is None else int(stop),
            phase_boundary=None if pb is None else int(pb),
            priority=tuple(int(i) for i in d["priority"]),
            remaining=tuple(int(i) for i in d["remaining"]),
            status=status,
            profile_attempts=int(d.get("profile_attempts", 1)),
            retry_backoff_s=float(d.get("retry_backoff_s", 0.0)),
            failure=None if failure is None else str(failure),
            objective=canonical_objective(d.get("objective", "runtime")),
            currency=None if currency is None else str(currency),
        )


@dataclasses.dataclass
class JobHandle:
    """Ticket for one submitted job; query it any time.

    The session is held through a weakref and the outcome is attached to
    the handle at retirement, so handles never keep a drained session (and
    its cached device geometry) alive — one-shot shims create a session per
    call, and it must be reclaimed by refcount the moment the call returns.
    """

    uid: int
    name: str
    _session: "weakref.ref[TuningSession]" = dataclasses.field(repr=False)
    _outcome: Optional[SearchOutcome] = dataclasses.field(
        default=None, repr=False
    )

    @property
    def done(self) -> bool:
        return self._outcome is not None

    @property
    def status(self) -> str:
        if self.done:
            st = self._outcome.status
            return "done" if st == "converged" else st
        session = self._session()
        if session is None:
            return "detached"  # session dropped before the job finished
        with session._lock:
            if any(r.handle.uid == self.uid for r in session._pending):
                return "pending"
        return "running"

    def cancel(self) -> bool:
        """Cancel this job — pending or mid-flight (see
        `TuningSession.cancel`).  Returns False when the job already
        finished or the session is gone; cancelling twice is a no-op."""
        session = self._session()
        if session is None:
            return False
        return session.cancel(self)

    def outcome(self) -> SearchOutcome:
        if self._outcome is None:
            raise RuntimeError(
                f"job {self.name!r} (uid {self.uid}) has not finished — "
                "call session.step()/drain() first"
            )
        return self._outcome


@dataclasses.dataclass
class _JobRec:
    """Internal per-job state between submit and retire."""

    handle: JobHandle
    job: "FleetJob"
    table64: np.ndarray  # (n,) float64 — authoritative cost table
    enc: np.ndarray  # (n,d) canonical float32 encoding (encode_features)
    prio_mask: np.ndarray  # (n,) bool
    rem_mask: np.ndarray  # (n,) bool
    init_list: List[int]
    seed_trials: List[TrialRecord]
    budget: int  # trial budget == packed capacity B (trial_budget)
    profile: Optional[ProfileResult]
    signature: Optional[MemorySignature]
    class_key: Optional[Tuple[MemorySignature, int, int]]
    prio_idx: np.ndarray  # (p,) int64, pool order
    rem_idx: np.ndarray  # (r,) int64, pool order
    profile_attempts: int = 1  # profiling attempts incl. retries
    retry_backoff_s: float = 0.0  # charged profiling backoff
    status: str = "converged"  # terminal status, set before publication
    job_priority: int = 0  # preemption rank (see preempt_below)
    objective: Objective = "runtime"  # canonical scoring objective
    # (runtime_h, usd) raw-axis tables for priced jobs; None otherwise.
    axes64: Optional[Tuple[np.ndarray, np.ndarray]] = None


class _LiveChunk:
    """One lockstep chunk (or sharded chunk bundle) mid-flight.

    ``update`` is the jitted step program — the donated single-device
    `_fleet_update` for a plain chunk, or the `shard_map` bundle update
    (`repro.fleet.sharding.sharded_update`) when the session shards the
    job axis.  Member i always lives at flat row i of the state buffers
    once any leading shard axis is collapsed (`_retire` reshapes to
    (-1, ...)): shards slice the member list contiguously and dummy pads
    only trail the last rows of a shard — so retirement is layout-agnostic
    with no explicit row map.

    A member slot holds None after a mid-flight cancel/fail/preempt: the
    outcome was already published, the row's `done` flag is latched on
    device (the update leaves done rows untouched), and retirement skips
    the tombstone.  ``n_shards`` records the leading shard axis extent
    (1 = plain single-device chunk) for host-side row collapsing.

    ``group_key`` is the admission-group identity ((space shape, packed
    capacity)) — the unit the async service schedules: every chunk of one
    key is stepped by the same group thread (`repro.fleet.service`).
    """

    __slots__ = ("state", "args", "members", "capacity", "update",
                 "steps_done", "steps_needed", "n_shards", "group_key")

    def __init__(self, state, args, members, capacity, update,
                 steps_needed, n_shards=1, group_key=None):
        self.state = state
        self.args = args
        self.members = members
        self.capacity = capacity
        self.update = update
        self.steps_done = 0
        self.steps_needed = steps_needed
        self.n_shards = n_shards
        self.group_key = group_key


class _SpaceEntry:
    """Refcounted per-space cache: the strong reference to the space keeps
    its id() stable for the entry's lifetime; the entry (and the cached
    encoding/geometry, including a gather layout's (n,n) tensor) is evicted
    when the last active submission over the space retires."""

    __slots__ = ("space", "count", "enc", "geom")

    def __init__(self, space):
        self.space = space
        self.count = 0
        self.enc: Optional[np.ndarray] = None
        self.geom: Optional[np.ndarray] = None


class TuningSession:
    """Streaming multi-job tuning session (see module docstring).

    ``settings``/``to_exhaustion``/``layout`` are session-wide (jobs group
    by packed capacity, which `BOSettings` helps determine — one settings
    object per session keeps the grouping sound).  ``mode`` is the default
    per-submit mode ("ruya" profiles + splits; "cherrypick" searches the
    whole space).  ``cache`` is the session-owned `ProfileCache`: give one
    to enable Flora-style probe-classified profile SHARING across jobs;
    with ``cache=None`` (default) each distinct job is profiled exactly
    once, like the one-shot drivers — sharing profiles changes splits and
    traces, so it must be opted into.  Warm-start seeding works either way
    (the signature class key comes from each job's own resolved profile).
    ``warm_start`` enables signature-class seeding; ``warm_reserve`` packed
    slots are always left for fresh trials (default: max(n_init, 1)).

    ``shard``/``devices`` switch on job-axis sharding: with S > 1 devices
    resolved (``shard=S``, ``shard="auto"``, or an explicit device list),
    each (shape, capacity) group's lockstep chunks are bundled S at a time
    and advanced by ONE `shard_map` dispatch per step, one chunk per
    device (`repro.fleet.sharding`).  The default (``shard=None``) is the
    single-device reference path, and a sharded session is pinned
    bit-identical to it by the golden-trace harness (`tests/golden/`): the
    per-device program is the same vmapped `fast_bo.fleet_step` at a row
    extent in [2, 8], so the established batch-extent invariance carries
    the proof.  Sharded groups re-chunk to rows = min(8, ceil(M/S)) so
    small fleets spread across devices too — chunk membership never
    affects traces (each job's state and static extents are its own).
    Caveat: bundles RETIRE as a unit, so with warm-starting on, a job
    submitted mid-flight (no intervening drain) may see a different
    class-history snapshot — and different warm seeds — across shard
    counts; drain boundaries make warm seeding shard-count-independent
    (see `repro.fleet.sharding`).

    Failure semantics (the elastic/adversarial layer).  ``retry`` governs
    profiling-run faults: `TransientRunError`s are retried with the
    deterministic seeded backoff of `repro.fleet.retry` (per-job retry
    seed derived from ``seed`` — no live RNG, the BO draws stay aligned),
    `PermanentRunError`s fast-fail, and a job whose profiling cannot
    complete becomes a first-class "failed" outcome at submit instead of
    poisoning the fleet.  `cancel`/`fail`/`preempt`/`preempt_below` retire
    a live search mid-flight — its completed trials publish immediately
    and its chunk row is frozen via the engine's `done` flag, so
    chunk-mates' traces are bit-identical to an undisturbed run (vmap rows
    are independent; pinned by the golden disturbed-fleet scenario).
    `reshard` re-bundles every live search onto a new device set (device
    churn, both directions) with per-row state resumed verbatim.
    ``drift_tolerance`` (needs a ``cache``) turns on drift detection: a
    recurring job whose fresh probe no longer matches its cached class
    model is re-profiled and re-classed (`ProfileCache.model_drifted`),
    and the session refuses to warm-seed it from the stale class's trial
    history (``drift_events`` logs the job names).

    Finished jobs release their per-job state: cost tables, masks, cached
    encodings and geometry (refcounted per space — a gather layout's (n,n)
    tensor is evicted with its last job) are dropped at retirement, so a
    long-lived service session holds only the outcomes and the per-class
    trial history (bounded by deduplication at ≤ n entries per class).
    """

    def __init__(
        self,
        *,
        settings: BOSettings = BOSettings(),
        mode: str = "ruya",
        cache: Optional[ProfileCache] = None,
        warm_start: bool = True,
        warm_reserve: Optional[int] = None,
        to_exhaustion: bool = False,
        layout: str = "feature",
        shard: Union[None, int, str] = None,
        devices: Optional[Sequence] = None,
        seed: int = 0,
        retry: RetryPolicy = RetryPolicy(),
        drift_tolerance: Optional[float] = None,
        objective="runtime",
    ) -> None:
        if mode not in ("ruya", "cherrypick"):
            raise ValueError(f"unknown mode {mode!r}")
        if layout not in _LAYOUTS:
            raise ValueError(f"unknown layout {layout!r}; want one of {_LAYOUTS}")
        # "runtime" | "cost" | {"runtime": w1, "cost": w2} — the session
        # default; overridable per submit.  "runtime" is the pinned legacy
        # path (golden-fixture bit-identity); see `objective_table`.
        self.objective: Objective = canonical_objective(objective)
        # None → single-device reference path; else a tuple of ≥ 2 devices
        # the job axis is sharded over.
        self.shard_devices = resolve_shard_devices(shard, devices)
        self.settings = settings
        self.mode = mode
        self.cache = cache
        self.warm_start = bool(warm_start)
        self.warm_reserve = (
            max(int(warm_reserve), 0) if warm_reserve is not None
            else max(settings.n_init, 1)
        )
        self.to_exhaustion = bool(to_exhaustion)
        self.layout = layout
        self.seed = int(seed)
        self.retry = retry
        self.drift_tolerance = (
            None if drift_tolerance is None else float(drift_tolerance)
        )

        # Lock discipline (the async service, `repro.fleet.service`, steps
        # chunks from per-group host threads): every access to the shared
        # mutable session state — pending queue, chunk list, outcome /
        # history / cache tables — and every chunk state transition happens
        # under this re-entrant lock.  Device WAITS happen outside it
        # (`_step_chunk` captures the state ref under the lock, then blocks
        # on the device queue unlocked), so a slow group's compute never
        # stalls another group's dispatch.  The single-threaded paths
        # (`step()`/`drain()`) take the same lock — uncontended acquisition
        # is nanoseconds against millisecond-scale chunk steps.
        self._lock = threading.RLock()
        # Called (under the lock) with each published SearchOutcome — the
        # service hooks this for completion signalling and metrics.
        self._outcome_listeners: List[Callable[[SearchOutcome], None]] = []

        self.warm_hits = 0  # jobs that were seeded
        self.warm_trials = 0  # total seeded observations
        self.drift_events: List[str] = []  # job names flagged as drifted
        # uids that turned "failed" since the last drain — the drain guard
        # (FleetFailedError) considers these alongside live jobs, so a
        # fleet that failed entirely BEFORE the drain call still raises.
        self._failed_since_drain: List[int] = []

        self._pending: List[_JobRec] = []
        self._chunks: List[_LiveChunk] = []
        self._order: List[JobHandle] = []  # submission order
        self._outcomes: Dict[int, SearchOutcome] = {}
        # id(space) → refcounted encoding/geometry (strong space ref inside)
        self._spaces: Dict[int, _SpaceEntry] = {}
        # id(job) → [job, active submissions, profile, profiling attempts,
        # charged backoff seconds, drift flag]; evicted at zero refcount
        self._jobs: Dict[int, list] = {}
        # (signature, n, d) → (ordered [(index, cost)], seen index set)
        self._history: Dict[tuple, Tuple[List[Tuple[int, float]], Set[int]]] = {}

    # ------------------------------------------------------------- submit

    def submit(
        self,
        job: "FleetJob",
        rng: Optional[np.random.Generator] = None,
        *,
        seed: Optional[int] = None,
        mode: Optional[str] = None,
        priority: Optional[Sequence[int]] = None,
        remaining: Optional[Sequence[int]] = None,
        warm_start: Optional[bool] = None,
        job_priority: int = 0,
        objective=None,
    ) -> JobHandle:
        """Register one job; it joins a lockstep chunk at the next `step()`.

        ``rng`` (or ``seed``) scripts the random initialization exactly like
        the sequential engine.  ``mode`` defaults to the session mode.
        Passing ``priority``/``remaining`` explicitly skips profiling and
        uses the given split verbatim (the `batched_search` shim's path);
        otherwise "ruya" resolves a profile (``job.profile_result``, else the
        session `ProfileCache`) and computes the §III-D split on device,
        while "cherrypick" searches the whole space.  ``warm_start``
        overrides the session default for this job; seeding only happens for
        profiled jobs (the signature is the class key) and consumes no RNG.

        Profiling faults: transient run failures are retried per the
        session `RetryPolicy`; a permanent failure (or retry exhaustion)
        returns a handle whose outcome is already published with status
        "failed" — no exception, the rest of the fleet is unaffected.
        ``job_priority`` ranks the job for `preempt_below` (higher keeps
        running; it does not affect scheduling otherwise).  ``objective``
        overrides the session objective for this job (see
        `objective_table`; non-runtime objectives need a priced job).

        Thread-safe: concurrent submitters serialize on the session lock
        (the warm-start history snapshot, the scripted init draw, and the
        pending-queue append are one atomic unit — a submission is a
        deterministic function of the class history it observed).
        """
        with self._lock:
            return self._submit_locked(
                job, rng, seed=seed, mode=mode, priority=priority,
                remaining=remaining, warm_start=warm_start,
                job_priority=job_priority, objective=objective,
            )

    def _submit_locked(
        self,
        job: "FleetJob",
        rng: Optional[np.random.Generator] = None,
        *,
        seed: Optional[int] = None,
        mode: Optional[str] = None,
        priority: Optional[Sequence[int]] = None,
        remaining: Optional[Sequence[int]] = None,
        warm_start: Optional[bool] = None,
        job_priority: int = 0,
        objective=None,
    ) -> JobHandle:
        if (rng is None) == (seed is None):
            raise ValueError("provide exactly one of rng / seed")
        if rng is None:
            rng = np.random.default_rng(seed)
        mode = self.mode if mode is None else mode
        if mode not in ("ruya", "cherrypick"):
            raise ValueError(f"unknown mode {mode!r}")
        warm = self.warm_start if warm_start is None else bool(warm_start)
        obj = (
            self.objective if objective is None
            else canonical_objective(objective)
        )

        space = job.space
        n = len(space)
        d = space.encoded().shape[1]
        # The score table the engine observes.  objective="runtime" is
        # exactly `job.cost_table` (the pinned legacy path); "cost"/blends
        # derive it from the job's pricing axes.
        table64 = objective_table(job, obj)
        if table64.shape != (n,):
            raise ValueError(
                f"job {job.name!r}: cost table has shape {table64.shape}, "
                f"want ({n},)"
            )
        axes64: Optional[Tuple[np.ndarray, np.ndarray]] = None
        rt_tab = getattr(job, "runtime_table", None)
        price_tab = getattr(job, "price_table", None)
        if rt_tab is not None and price_tab is not None:
            rt64 = np.asarray(rt_tab, np.float64)
            price64 = np.asarray(price_tab, np.float64)
            if rt64.shape != (n,) or price64.shape != (n,):
                raise ValueError(
                    f"job {job.name!r}: pricing axes have shapes "
                    f"{rt64.shape}/{price64.shape}, want ({n},)"
                )
            axes64 = (rt64, rt64 * price64)

        profile: Optional[ProfileResult] = None
        signature: Optional[MemorySignature] = None
        if priority is not None:
            prio_idx = np.asarray(priority, np.int64).reshape(-1)
            rem_idx = (
                np.zeros(0, np.int64) if remaining is None
                else np.asarray(remaining, np.int64).reshape(-1)
            )
            if len(np.intersect1d(prio_idx, rem_idx)):
                raise ValueError(
                    f"job {job.name!r}: priority and remaining pools overlap"
                )
            prio_mask = np.zeros(n, bool)
            prio_mask[prio_idx] = True
            rem_mask = np.zeros(n, bool)
            if rem_idx.size:
                rem_mask[rem_idx] = True
        elif mode == "cherrypick":
            prio_idx = np.arange(n, dtype=np.int64)
            rem_idx = np.zeros(0, np.int64)
            prio_mask = np.ones(n, bool)
            rem_mask = np.zeros(n, bool)
        else:
            try:
                profile = self._resolve_profile(job)
            except ProfilingRunError as e:
                # Permanent failure / retry budget exhausted: a first-class
                # "failed" outcome, published immediately — partial fleets
                # keep going (see FleetFailedError for the all-failed case).
                return self._register_failed(job, e)
            je = self._jobs.get(id(job))
            if je is not None and je[5]:
                # The job's class drifted: its cached profile was refreshed
                # and re-classed, and the OLD class's trial history predates
                # the shift — warm-seeding from it would anchor the GP on
                # the stale cost surface, so this job always starts cold.
                warm = False
            signature = (
                self.cache.signature(profile.model)
                if self.cache is not None
                else MemorySignature.of(profile.model)
            )
            # §III-D narrowing, computed on device from the static
            # per-config arrays; remaining is always the complement.
            prio_dev = split_masks_device(
                space,
                profile.model,
                job.full_input_size,
                per_node_overhead=job.per_node_overhead,
                leeway=job.leeway,
                flat_fraction=job.flat_fraction,
            )
            prio_mask = np.asarray(prio_dev)
            rem_mask = ~prio_mask
            prio_idx = np.flatnonzero(prio_mask)
            rem_idx = np.flatnonzero(rem_mask)

        budget = trial_budget(len(prio_idx), len(rem_idx), self.settings)

        # Warm-start seeding — decided (and the history snapshot taken) at
        # submit time, so a search is a deterministic function of (class
        # history, seed) no matter how the session is stepped afterwards.
        seed_trials: List[TrialRecord] = []
        # Non-runtime objectives score trials on a different scale, so
        # their class histories are keyed apart — a cost-objective search
        # must never warm-seed donor costs from a runtime-objective one.
        class_key = None
        if signature is not None:
            class_key = (
                (signature, n, d) if obj == "runtime"
                else (signature, n, d, obj)
            )
        if warm and class_key is not None and class_key in self._history:
            room = max(budget - self.warm_reserve, 0)
            hist = self._history[class_key][0][:room]
            seed_trials = [
                TrialRecord(
                    index=i, cost=c, slot=s, source="warm",
                    runtime_h=(
                        None if axes64 is None else float(axes64[0][i])
                    ),
                    usd=None if axes64 is None else float(axes64[1][i]),
                )
                for s, (i, c) in enumerate(hist)
            ]
            if seed_trials:
                self.warm_hits += 1
                self.warm_trials += len(seed_trials)

        # Scripted random initialization — the same draw, in the same order
        # (submission order), as the sequential engine's phase-0 block.  A
        # seeded search skips it (the GP already has observations) and
        # consumes no RNG.
        init_list: List[int] = []
        if len(prio_idx) and not seed_trials:
            n_init = min(self.settings.n_init, len(prio_idx))
            picked = rng.choice(len(prio_idx), size=n_init, replace=False)
            init_list = [int(prio_idx[int(i)]) for i in picked]

        # Past the last possible raise: retain the refcounted per-space /
        # per-job entries and register the submission.
        handle = JobHandle(
            uid=len(self._order), name=job.name, _session=weakref.ref(self)
        )
        self._retain(job)
        je = self._jobs[id(job)]
        rec = _JobRec(
            handle=handle,
            job=job,
            table64=table64,
            enc=self._encoding(space),
            prio_mask=prio_mask,
            rem_mask=rem_mask,
            init_list=init_list,
            seed_trials=seed_trials,
            budget=budget,
            profile=profile,
            signature=signature,
            class_key=class_key,
            prio_idx=prio_idx,
            rem_idx=rem_idx,
            profile_attempts=je[3],
            retry_backoff_s=je[4],
            job_priority=int(job_priority),
            objective=obj,
            axes64=axes64,
        )
        self._order.append(handle)
        self._pending.append(rec)
        return handle

    # -------------------------------------------------------------- step

    def step(self) -> int:
        """Admit pending jobs into lockstep chunks, then advance every live
        chunk by ONE batched BO iteration.  Returns the number of jobs still
        unfinished (0 → everything has retired)."""
        with self._lock:
            self._admit()
            chunks = list(self._chunks)
        for ch in chunks:
            self._step_chunk(ch)
        with self._lock:
            return self._unfinished()

    def _unfinished(self) -> int:
        """Jobs not yet published (pending + live chunk members); caller
        holds the lock."""
        return sum(
            sum(1 for m in c.members if m is not None) for c in self._chunks
        ) + len(self._pending)

    # ------------------------------------------- async-scheduling surface
    #
    # Engine-level primitives for `repro.fleet.service`: one group thread
    # per live (space shape, capacity) key drives its own chunks through
    # `_step_chunk` at its own pace, admitting ITS pending jobs at its own
    # iteration boundary.  Chunk membership never affects traces (vmap rows
    # are independent, extents stay in the invariant [2, 8] window), so the
    # async schedule is bit-identical per job to the lockstep one — the
    # golden fixtures pin it through the service lanes.

    def _pending_group_keys(self) -> Set[tuple]:
        """Admission-group keys with pending submissions."""
        with self._lock:
            return {(rec.enc.shape, rec.budget) for rec in self._pending}

    def _chunks_for(self, key: tuple) -> List["_LiveChunk"]:
        """Live chunks of one admission group (snapshot)."""
        with self._lock:
            return [ch for ch in self._chunks if ch.group_key == key]

    def _admit_group(self, key: tuple, device=None) -> int:
        """Admit every pending job of ONE admission group into chunks —
        the per-group half of `_admit`, run by that group's thread at its
        own iteration boundary.  ``device`` pins the new chunks' buffers
        (and therefore their compute) to one device, letting the service
        spread groups across the host topology; None keeps the default
        placement.  Returns the number of jobs admitted."""
        with self._lock:
            members = [
                rec for rec in self._pending
                if (rec.enc.shape, rec.budget) == key
            ]
            if not members:
                return 0
            self._pending = [
                rec for rec in self._pending
                if (rec.enc.shape, rec.budget) != key
            ]
            shape, cap = key
            n_init_slots = max(1, max(len(r.init_list) for r in members))
            if self.shard_devices is not None:
                self._chunks.extend(
                    self._build_sharded(members, shape, cap, n_init_slots)
                )
                return len(members)
            for lo in range(0, len(members), _CHUNK):
                self._chunks.append(
                    self._build_chunk(
                        members[lo : lo + _CHUNK], shape, cap, n_init_slots,
                        device=device,
                    )
                )
            return len(members)

    def _step_chunk(self, ch: "_LiveChunk") -> str:
        """Advance ONE chunk by one BO iteration; retire it if finished.

        Returns "stepped" (still live), "retired" (outcomes published),
        "dead" (every member was terminated mid-flight and published
        already), or "gone" (the chunk left `_chunks` under our feet — a
        concurrent `reshard` rebuilt the fleet; its rows were resumed into
        new chunks, nothing to do).

        All state transitions happen under the session lock — `cancel`'s
        mid-flight kill swaps `state.done`, and the update donates the old
        state's buffers, so an unlocked reader could touch deleted arrays.
        Device WAITS (the done-flag poll, the pre-retirement sync) happen
        OUTSIDE the lock on a captured state reference: only this chunk's
        owner ever advances it, so the captured buffers cannot be donated
        from under the wait."""
        with self._lock:
            if ch not in self._chunks:
                return "gone"
            if all(m is None for m in ch.members):
                self._chunks.remove(ch)
                return "dead"
            ch.state = ch.update(ch.state, ch.args)
            ch.steps_done += 1
            retire = ch.steps_done >= ch.steps_needed
            poll = (
                not retire
                and not self.to_exhaustion
                and ch.steps_done % _POLL_PERIOD == 0
            )
            done_flags = ch.state.done if (poll or retire) else None
        if poll:
            # Blocks on this chunk's device queue only.
            retire = bool(jnp.all(done_flags))
        if not retire:
            return "stepped"
        jax.block_until_ready(done_flags)
        with self._lock:
            if ch not in self._chunks:
                return "gone"
            self._retire(ch)
            self._chunks.remove(ch)
            return "retired"

    def drain(self) -> List[SearchOutcome]:
        """Step until every submitted job has finished; returns all outcomes
        (cumulative over the session's lifetime) in submission order.

        Raises `FleetFailedError` when every job this drain was waiting
        on — jobs live at the call, plus jobs that turned "failed" since
        the previous drain (profiling failures at submit, mid-flight
        `fail`s) — ends with status "failed".  All outcomes stay available
        via `results()`; a mixed fleet — some failed, some finished —
        returns normally."""
        with self._lock:
            waiting = {rec.handle.uid for rec in self._live_recs()}
            waiting.update(self._failed_since_drain)
            self._failed_since_drain = []
        while self._pending or self._chunks:
            self.step()
        self._check_all_failed(waiting)
        return self.results()

    def _check_all_failed(self, waiting: Set[int]) -> None:
        """The drain guard (see `drain`); shared with the async service's
        own drain, which waits on worker threads instead of stepping."""
        if not waiting:
            return
        with self._lock:
            outs = [self._outcomes.get(uid) for uid in sorted(waiting)]
        if all(o is not None and o.status == "failed" for o in outs):
            names = [o.name for o in outs]
            raise FleetFailedError(
                f"all {len(names)} job(s) this drain was waiting on "
                f"permanently failed: {names} — outcomes remain "
                "available via results()"
            )

    def results(self) -> List[SearchOutcome]:
        """Outcomes of all FINISHED jobs, in submission order."""
        with self._lock:
            return [
                self._outcomes[h.uid] for h in self._order
                if h.uid in self._outcomes
            ]

    def outcome(self, handle: JobHandle) -> SearchOutcome:
        return handle.outcome()

    def __len__(self) -> int:
        return len(self._order)

    # ---------------------------------------------------------- lifecycle

    def cancel(self, handle: JobHandle) -> bool:
        """Cancel a pending or mid-flight job.  Its completed trials
        publish immediately as a partial outcome (status "cancelled") and
        its chunk row is frozen via the engine's `done` flag — chunk-mates
        advance exactly as if nothing happened (vmap rows are independent;
        pinned bit-identical by the golden disturbed-fleet scenario).
        Returns False when the job already finished."""
        return self._terminate(handle, "cancelled")

    def fail(self, handle: JobHandle, reason: Optional[str] = None) -> bool:
        """Mark a live job failed (e.g. its external executor died): the
        same mid-flight retirement as `cancel`, status "failed"."""
        return self._terminate(handle, "failed", reason)

    def preempt(self, handle: JobHandle) -> bool:
        """Preempt a live job (status "preempted"): partial results are
        kept, the lockstep slot frees up, and — because completed trials of
        CONVERGED jobs are what feeds the class history — a later resubmit
        starts from the class's knowledge, not the victim's stale row."""
        return self._terminate(handle, "preempted")

    def preempt_below(self, min_priority: int) -> List[JobHandle]:
        """Preempt every live job whose submit-time ``job_priority`` is
        below ``min_priority`` (default priority is 0, so any positive
        floor evicts unranked work).  Returns the preempted handles."""
        with self._lock:
            victims = [
                rec.handle for rec in self._live_recs()
                if rec.job_priority < min_priority
            ]
            for handle in victims:
                self._terminate(handle, "preempted")
            return victims

    def _live_recs(self) -> List[_JobRec]:
        """Every unfinished submission: pending plus live chunk members."""
        recs = list(self._pending)
        for ch in self._chunks:
            recs.extend(m for m in ch.members if m is not None)
        return recs

    def _terminate(
        self, handle: JobHandle, status: str, reason: Optional[str] = None
    ) -> bool:
        with self._lock:
            return self._terminate_locked(handle, status, reason)

    def _terminate_locked(
        self, handle: JobHandle, status: str, reason: Optional[str] = None
    ) -> bool:
        if handle._outcome is not None:
            return False  # already finished (or already terminated)
        for j, rec in enumerate(self._pending):
            if rec.handle.uid == handle.uid:
                del self._pending[j]
                rec.status = status
                # Never admitted: no engine row to read — the outcome is
                # just the warm seeds (if any) and zero executed trials.
                self._publish(
                    rec, k=len(rec.seed_trials), tried_row=None,
                    stop=-1, pb=-1, failure=reason,
                )
                return True
        for ch in self._chunks:
            for i, rec in enumerate(ch.members):
                if rec is not None and rec.handle.uid == handle.uid:
                    rec.status = status
                    self._kill(ch, i, rec, reason)
                    return True
        return False  # not this session's handle

    def _kill(
        self, ch: _LiveChunk, i: int, rec: _JobRec,
        reason: Optional[str] = None,
    ) -> None:
        """Retire member ``i`` of a live chunk mid-flight: publish its
        partial outcome from a host snapshot of its row, tombstone the
        member slot, and freeze the row by latching the engine's `done`
        flag (`fast_bo.fleet_step` gates every write on
        ``live = ~done & budget_left``, so a done row is inert — its
        chunk-mates' traces are untouched)."""
        rows = collapse_rows(ch.state, ch.n_shards)
        self._publish(
            rec,
            k=int(rows.t[i]),
            tried_row=rows.tried[i],
            stop=int(rows.stop[i]),
            pb=int(rows.pb[i]),
            failure=reason,
        )
        ch.members[i] = None
        done = np.array(ch.state.done)  # writable host copy
        done.reshape(-1)[i] = True
        # Re-place with the row's original sharding (single-device chunks
        # carry a SingleDeviceSharding — the same call covers both).
        ch.state = ch.state._replace(
            done=jax.device_put(done, ch.state.done.sharding)
        )

    def reshard(
        self,
        shard: Union[None, int, str] = None,
        devices: Optional[Sequence] = None,
    ) -> int:
        """Live device churn: re-bundle every mid-flight search onto a new
        device set (devices leaving and joining are the same operation).
        Each live row's engine state is snapshotted on host
        (`repro.fleet.sharding.collapse_rows`), survivors are regrouped by
        the admission rule, and chunks are rebuilt at the new shard width
        with the rows resumed VERBATIM (dummy pads re-derived).

        Survivors' traces stay bit-identical to an undisturbed run: the
        resumed per-row state is exactly what the update would have kept
        on device, chunk membership never affects traces (vmap rows are
        independent), and the rebuilt row extent stays inside the
        batch-extent-invariant [2, 8] window — pinned by the golden
        disturbed-fleet scenario.  Pending jobs are untouched (they admit
        at the next `step()` under the new layout).  Returns the number of
        live searches re-bundled."""
        with self._lock:
            self.shard_devices = resolve_shard_devices(shard, devices)
            survivors: List[Tuple[_JobRec, FleetState]] = []
            for ch in self._chunks:
                rows = collapse_rows(ch.state, ch.n_shards)
                for i, rec in enumerate(ch.members):
                    if rec is None:
                        continue
                    row = jax.tree_util.tree_map(lambda x, _i=i: x[_i], rows)
                    survivors.append((rec, row))
            self._chunks = []
            groups: Dict[tuple, List[Tuple[_JobRec, FleetState]]] = {}
            for rec, row in survivors:
                groups.setdefault((rec.enc.shape, rec.budget), []).append(
                    (rec, row)
                )
            for (shape, cap), pairs in groups.items():
                members = [p[0] for p in pairs]
                resume = [p[1] for p in pairs]
                n_init_slots = max(1, max(len(r.init_list) for r in members))
                if self.shard_devices is not None:
                    self._chunks.extend(
                        self._build_sharded(
                            members, shape, cap, n_init_slots, resume=resume
                        )
                    )
                    continue
                for lo in range(0, len(members), _CHUNK):
                    self._chunks.append(
                        self._build_chunk(
                            members[lo : lo + _CHUNK], shape, cap,
                            n_init_slots, resume=resume[lo : lo + _CHUNK],
                        )
                    )
            return len(survivors)

    # ---------------------------------------------------------- internals

    def _retry_seed(self, job: "FleetJob") -> int:
        """Per-job retry-jitter seed: a hash of (session seed, job name) —
        deterministic, and independent across the fleet so synchronized
        backoff waves cannot form."""
        h = hashlib.sha256(f"{self.seed}/{job.name}".encode()).digest()
        return int.from_bytes(h[:8], "big")

    def _resolve_profile(self, job: "FleetJob") -> ProfileResult:
        if job.profile_result is not None:
            return job.profile_result
        if job.profile_run is None:
            raise ValueError(
                f"job {job.name!r} has neither profile_result nor profile_run"
            )
        # Memoized per job OBJECT (seed-replica fleets alias one FleetJob):
        # each distinct job profiles once.  An explicit session cache adds
        # Flora-style probe-classified sharing ACROSS jobs; without one the
        # semantics match the one-shot drivers exactly.  The whole
        # resolution (probe + full profile) is one retry unit: a transient
        # failure re-runs it from the top — emulated run fns are
        # deterministic in the sample size, so a retried resolution returns
        # an identical ProfileResult and the search trace is unchanged.
        entry = self._jobs.setdefault(
            id(job), [job, 0, None, 1, 0.0, False]
        )
        if entry[2] is None:
            stats = RetryStats(attempts=0)
            drifted = [False]

            def resolve() -> ProfileResult:
                if self.cache is not None:
                    # `last_drift` is a per-call report on a possibly
                    # shared cache: read it while still holding the
                    # cache lock so a concurrent submitter's call (from
                    # another session sharing this cache) cannot clobber
                    # it between the resolution and the read.
                    with self.cache.lock:
                        prof = self.cache.get_or_profile(
                            job.profile_run, job.full_input_size,
                            drift_tolerance=self.drift_tolerance,
                        )
                        drifted[0] = self.cache.last_drift
                    return prof
                return profile_job(job.profile_run, job.full_input_size)

            try:
                profile, stats = call_with_retry(
                    resolve, policy=self.retry,
                    seed=self._retry_seed(job), stats=stats,
                )
            finally:
                # Record the cost even when resolution ultimately failed —
                # the failed outcome reports what the attempts burned.
                entry[3], entry[4] = stats.attempts, stats.backoff_s
            entry[2] = profile
            if drifted[0]:
                entry[5] = True
                self.drift_events.append(job.name)
        return entry[2]

    def _register_failed(
        self, job: "FleetJob", error: BaseException
    ) -> JobHandle:
        """Profiling failed permanently (or exhausted its retry budget):
        publish a first-class "failed" outcome at submit time.  The job
        never enters the pending queue, so it cannot poison a chunk; the
        handle behaves like any finished job's."""
        je = self._jobs.get(id(job))
        handle = JobHandle(
            uid=len(self._order), name=job.name, _session=weakref.ref(self)
        )
        outcome = SearchOutcome(
            name=job.name,
            records=[],
            seeded=[],
            stop_iteration=None,
            phase_boundary=None,
            priority=(),
            remaining=(),
            status="failed",
            failure=f"{type(error).__name__}: {error}",
            profile_attempts=je[3] if je is not None else 1,
            retry_backoff_s=je[4] if je is not None else 0.0,
        )
        self._order.append(handle)
        self._outcomes[handle.uid] = outcome
        handle._outcome = outcome
        self._failed_since_drain.append(handle.uid)
        for listener in self._outcome_listeners:
            listener(outcome)
        return handle

    def _retain(self, job: "FleetJob") -> None:
        """Bump the refcounted per-space and per-job cache entries."""
        space = job.space
        se = self._spaces.get(id(space))
        if se is None:
            se = self._spaces[id(space)] = _SpaceEntry(space)
        se.count += 1
        je = self._jobs.setdefault(id(job), [job, 0, None, 1, 0.0, False])
        je[1] += 1

    def _release(self, rec: _JobRec) -> None:
        """Drop the retired job's share of the caches; evict empty entries
        (including a gather layout's (n,n) geometry tensor)."""
        sid = id(rec.job.space)
        se = self._spaces.get(sid)
        if se is not None:
            se.count -= 1
            if se.count <= 0:
                del self._spaces[sid]
        jid = id(rec.job)
        je = self._jobs.get(jid)
        if je is not None:
            je[1] -= 1
            if je[1] <= 0:
                del self._jobs[jid]

    def _encoding(self, space) -> np.ndarray:
        entry = self._spaces[id(space)]
        if entry.enc is None:
            entry.enc = encode_features(space.encoded())
        return entry.enc

    def _geom(self, space) -> np.ndarray:
        """Per-space geometry, once per space (seed-replica fleets alias one
        SearchSpace): the (n,d) encoding (feature and fused layouts) or the
        (n,n) distance tensor (retained gather layout)."""
        entry = self._spaces[id(space)]
        if entry.geom is None:
            enc = self._encoding(space)
            entry.geom = (
                enc if self.layout in ("feature", "fused")
                else np.asarray(precompute_d2(enc))
            )
        return entry.geom

    def _admit(self) -> None:
        """Form lockstep chunks from the pending queue — the same (space
        shape, packed capacity) grouping and ≤`_CHUNK` slicing as
        `batched_search`, so a statically submitted fleet compiles and runs
        the identical array program.  With sharding on, each group's chunks
        are instead bundled across the shard devices (`_build_sharded`)."""
        if not self._pending:
            return
        groups: Dict[tuple, List[_JobRec]] = {}
        for rec in self._pending:
            groups.setdefault((rec.enc.shape, rec.budget), []).append(rec)
        self._pending = []
        for (shape, cap), members in groups.items():
            n_init_slots = max(1, max(len(r.init_list) for r in members))
            if self.shard_devices is not None:
                self._chunks.extend(
                    self._build_sharded(members, shape, cap, n_init_slots)
                )
                continue
            for lo in range(0, len(members), _CHUNK):
                self._chunks.append(
                    self._build_chunk(
                        members[lo : lo + _CHUNK], shape, cap, n_init_slots
                    )
                )

    def _build_sharded(
        self, members: List[_JobRec], shape, cap: int, n_init_slots: int,
        resume: Optional[List[FleetState]] = None,
    ) -> List[_LiveChunk]:
        """Bundle one (shape, capacity) group's jobs across the shard
        devices: chunks of ``rows`` jobs, up to S of them per bundle, one
        `shard_map` dispatch per bundle per step.

        Rows are min(_CHUNK, ceil(M/S)) so a small fleet still spreads
        across devices — legal because chunk membership never affects
        traces (each job carries its own state and the row extent stays in
        the batch-extent-invariant [2, 8] window; pinned by the golden
        harness and the shard-invariance property suite).  A leftover
        bundle with a single chunk takes the plain single-device path.
        """
        S = len(self.shard_devices)
        m = len(members)
        rows = min(_CHUNK, max(2, -(-m // S)))
        out: List[_LiveChunk] = []
        for lo in range(0, m, S * rows):
            sl = members[lo : lo + S * rows]
            rs = None if resume is None else resume[lo : lo + S * rows]
            n_shards = -(-len(sl) // rows)
            if n_shards == 1:
                out.append(
                    self._build_chunk(sl, shape, cap, n_init_slots, resume=rs)
                )
                continue
            parts = [
                self._chunk_arrays(
                    sl[k * rows : (k + 1) * rows], shape, cap, n_init_slots,
                    rows,
                    resume=(
                        None if rs is None
                        else rs[k * rows : (k + 1) * rows]
                    ),
                )
                for k in range(n_shards)
            ]
            update, sharding = sharded_update(
                self.shard_devices[:n_shards], self.settings.xi, self.layout
            )
            state = jax.tree_util.tree_map(
                lambda *xs: jax.device_put(np.stack(xs), sharding),
                *[p[0] for p in parts],
            )
            args = tuple(
                jax.device_put(np.stack(xs), sharding)
                for xs in zip(*[p[1] for p in parts])
            ) + tuple(
                jax.device_put(np.stack([v] * n_shards), sharding)
                for v in (
                    np.asarray(self.settings.min_observations, np.int32),
                    np.asarray(self.settings.ei_stop_rel, np.float32),
                    np.asarray(self.to_exhaustion),
                )
            )
            out.append(
                _LiveChunk(
                    state=state,
                    args=args,
                    members=sl,
                    capacity=max(cap, 1),
                    update=lambda st, a, _u=update: _u(st, *a),
                    steps_needed=max(p[2] for p in parts),
                    n_shards=n_shards,
                    group_key=(shape, cap),
                )
            )
        return out

    def _build_chunk(
        self, members: List[_JobRec], shape, cap: int, n_init_slots: int,
        resume: Optional[List[FleetState]] = None,
        device=None,
    ) -> _LiveChunk:
        state_np, args_np, steps_needed = self._chunk_arrays(
            members, shape, cap, n_init_slots, max(len(members), 2),
            resume=resume,
        )
        tail_np = (
            np.asarray(self.settings.min_observations, np.int32),
            np.asarray(self.settings.ei_stop_rel, np.float32),
            np.asarray(self.to_exhaustion),
        )
        if device is None:
            state = jax.tree_util.tree_map(jnp.asarray, state_np)
            args = tuple(jnp.asarray(a) for a in args_np) + tuple(
                jnp.asarray(v) for v in tail_np
            )
        else:
            # Committed placement: the jitted update runs on ``device``
            # (identical program and numerics on the identical-ISA host
            # devices — only WHERE it executes changes, which is how the
            # service spreads group threads across the forced topology).
            put = lambda x: jax.device_put(np.asarray(x), device)
            state = jax.tree_util.tree_map(put, state_np)
            args = tuple(put(a) for a in args_np) + tuple(
                put(v) for v in tail_np
            )
        xi, layout = self.settings.xi, self.layout
        return _LiveChunk(
            state=state,
            args=args,
            members=members,
            capacity=max(cap, 1),
            update=lambda st, a: _fleet_update(st, *a, xi=xi, layout=layout),
            steps_needed=steps_needed,
            group_key=(shape, cap),
        )

    def _chunk_arrays(
        self, members: List[_JobRec], shape, cap: int, n_init_slots: int,
        rows: int, resume: Optional[List[FleetState]] = None,
    ) -> Tuple[FleetState, tuple, int]:
        """Host-side state/args for one lockstep chunk of ``rows`` rows
        (members first, then inert dummy rows — zero trial budget, cold
        defaults; rows ≥ 2 because XLA:CPU collapses singleton batch dims
        into unbatched programs with different float32 numerics).

        ``resume`` (the `reshard` path) supplies one host-side per-row
        `FleetState` per member: the row is restored VERBATIM instead of
        cold/warm-initialized, so a re-bundled search continues exactly
        where its old chunk left off.  Static args are rebuilt from the
        recs either way — they are a pure function of the submission, and
        a changed ``n_init_slots`` width is numerics-neutral (the scripted
        pick indexes it through a clip and is gated by ``init_count``)."""
        n, d = shape
        capacity = max(cap, 1)

        geom_one = self._geom(members[0].job.space)
        geom = np.zeros((rows,) + geom_one.shape, geom_one.dtype)
        costs = np.zeros((rows, n), np.float32)
        prio_mask = np.zeros((rows, n), bool)
        rem_mask = np.zeros((rows, n), bool)
        init_picks = np.zeros((rows, n_init_slots), np.int32)
        init_count = np.zeros(rows, np.int32)
        max_trials = np.zeros(rows, np.int32)
        obs0 = np.zeros((rows, n), bool)
        tried0 = np.full((rows, capacity), -1, np.int32)
        py0 = np.zeros((rows, capacity), np.float32)
        feats0 = np.zeros((rows, capacity, d), np.float32)
        t0 = np.zeros(rows, np.int32)
        stop0 = np.full(rows, -1, np.int32)
        pb0 = np.full(rows, -1, np.int32)
        done0 = np.zeros(rows, bool)
        last_ei0 = np.zeros(rows, np.float32)
        last_best0 = np.full(rows, np.inf, np.float32)

        for i, rec in enumerate(members):
            geom[i] = self._geom(rec.job.space)
            costs[i] = rec.table64.astype(np.float32)
            prio_mask[i] = rec.prio_mask
            rem_mask[i] = rec.rem_mask
            init_picks[i, : len(rec.init_list)] = rec.init_list
            init_count[i] = len(rec.init_list)
            max_trials[i] = rec.budget
            if resume is not None:
                row = resume[i]
                obs0[i] = row.obs
                tried0[i] = row.tried
                py0[i] = row.py
                feats0[i] = row.feats
                t0[i] = row.t
                stop0[i] = row.stop
                pb0[i] = row.pb
                done0[i] = row.done
                last_ei0[i] = row.last_ei
                last_best0[i] = row.last_best
                continue
            w = len(rec.seed_trials)
            if w:
                idx = np.asarray([s.index for s in rec.seed_trials], np.int64)
                obs0[i, idx] = True
                tried0[i, :w] = idx.astype(np.int32)
                py0[i, :w] = np.asarray(
                    [s.cost for s in rec.seed_trials], np.float32
                )
                # Rows of the canonical float32 encoding — bit-identical to
                # what on-device observation writes would have accumulated.
                feats0[i, :w] = rec.enc[idx]
                t0[i] = w

        state = FleetState(
            obs=obs0,
            tried=tried0,
            py=py0,
            feats=feats0,
            t=t0,
            stop=stop0,
            pb=pb0,
            done=done0,
            last_ei=last_ei0,
            last_best=last_best0,
        )
        args = (
            geom, costs, prio_mask, rem_mask, init_picks, init_count,
            max_trials,
        )
        # One extra pass beyond the largest fresh-trial budget: it observes
        # nothing, but it is where a budget-capped job records a phase
        # boundary reached exactly at its last trial, and where budget
        # exhaustion latches `done` (same schedule as the one-shot engine).
        steps_needed = int(max(max_trials[i] - t0[i] for i in range(rows))) + 1
        return state, args, steps_needed

    def _retire(self, ch: _LiveChunk) -> None:
        # Collapse any leading shard axis: member i lives at flat row i
        # whether the chunk ran on one device or a mesh (see _LiveChunk).
        cap = ch.capacity
        s_tried = np.asarray(ch.state.tried).reshape(-1, cap)
        s_t = np.asarray(ch.state.t).reshape(-1)
        s_stop = np.asarray(ch.state.stop).reshape(-1)
        s_pb = np.asarray(ch.state.pb).reshape(-1)
        for i, rec in enumerate(ch.members):
            if rec is None:
                continue  # retired mid-flight; outcome already published
            self._publish(
                rec, k=int(s_t[i]), tried_row=s_tried[i],
                stop=int(s_stop[i]), pb=int(s_pb[i]),
            )

    def _publish(
        self, rec: _JobRec, k: int, tried_row, stop: int, pb: int,
        failure: Optional[str] = None,
    ) -> None:
        """Build and register ``rec``'s `SearchOutcome` from its engine row
        (slots [w, k) are the executed trials) and release its caches.
        Shared by normal retirement, mid-flight kills (partial rows), and
        pending-queue terminations (k == w, no row)."""
        w = len(rec.seed_trials)
        n_init = len(rec.init_list)
        # Straggler latency is REPORTED (attempts = 2 for the re-dispatched
        # trial), never fed back: the observed cost is the deterministic
        # table value either way, so the trace is unchanged.
        plan = getattr(rec.job, "faults", None)
        # Priced jobs carry raw runtime/dollar axes on every record (the
        # Pareto-front inputs); unpriced jobs keep the exact legacy record
        # shape, so the golden fixtures stay byte-identical.
        rt64, usd64 = rec.axes64 if rec.axes64 is not None else (None, None)
        records = []
        for slot in range(w, k):
            idx = int(tried_row[slot])
            records.append(
                TrialRecord(
                    index=idx,
                    cost=float(rec.table64[idx]),
                    slot=slot,
                    source="init" if slot < n_init else "search",
                    attempts=(
                        2 if plan is not None
                        and plan.is_straggler(rec.job.name, slot) else 1
                    ),
                    runtime_h=None if rt64 is None else float(rt64[idx]),
                    usd=None if usd64 is None else float(usd64[idx]),
                )
            )
        outcome = SearchOutcome(
            name=rec.job.name,
            records=records,
            seeded=list(rec.seed_trials),
            stop_iteration=stop if stop >= 0 else None,
            phase_boundary=pb if pb >= 0 else None,
            # tolist() boxes at C speed; built once, at retirement.
            priority=tuple(rec.prio_idx.tolist()),
            remaining=tuple(rec.rem_idx.tolist()),
            profile=rec.profile,
            signature=rec.signature,
            status=rec.status,
            profile_attempts=rec.profile_attempts,
            retry_backoff_s=rec.retry_backoff_s,
            failure=failure,
            objective=rec.objective,
            currency=(
                getattr(rec.job, "currency", "USD")
                if rec.axes64 is not None else None
            ),
        )
        self._outcomes[rec.handle.uid] = outcome
        rec.handle._outcome = outcome
        if rec.status == "failed":
            self._failed_since_drain.append(rec.handle.uid)
        # Only CONVERGED searches feed the warm-start class history: a
        # revoked job's partial trials would make later warm seeds depend
        # on cancellation timing — the bit-identity invariant (survivors
        # match an undisturbed run) requires history from completed
        # searches only.
        if rec.status == "converged" and rec.class_key is not None:
            hist, seen = self._history.setdefault(
                rec.class_key, ([], set())
            )
            for r in records:
                if r.index not in seen:
                    seen.add(r.index)
                    hist.append((r.index, r.cost))
        # The rec (cost table, masks, encoding share) dies with the
        # chunk; evict its cache shares so a long-lived session holds
        # only outcomes and class history.
        self._release(rec)
        for listener in self._outcome_listeners:
            listener(outcome)
