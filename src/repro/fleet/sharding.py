"""Device-sharded lockstep execution: chunk bundles spread over JAX devices.

The lockstep engine advances every live search one BO iteration per
`TuningSession.step()` — but each `(space shape, packed capacity)` chunk of
≤ 8 jobs is one jitted dispatch, executed serially on one device.  A 64-job
service fleet therefore pays 8 dispatches per step and uses one core no
matter how many the host has.  This module shards the JOB AXIS: up to S
lockstep chunks (same shapes, same packed capacity, same row extent) are
stacked along a leading shard axis and advanced by ONE jitted
`shard_map` call over a 1-D device mesh — each device runs the per-chunk
program on its own slice, so S chunks advance in parallel for the dispatch
cost of one.

Why `shard_map` (and not `pmap` or GSPMD-partitioned `jit`):

  * the body is traced at the PER-DEVICE extent (the chunk's row count r),
    so each device compiles exactly the program the single-device engine
    runs — the same `fast_bo.fleet_step` vmapped at an extent in [2, 8].
    Bit-identity with the unsharded reference then rests only on the
    repo's established batch-extent invariance (extents 2–8 produce
    identical float32 on XLA:CPU) plus "same program, identical CPU
    devices" — both already load-bearing for the unsharded engine, and
    re-pinned by the golden-trace harness (`tests/golden/`);
  * GSPMD-partitioned `jit` would trace the vmap at extent S·r (> 8
    diverges on XLA:CPU) and let the partitioner re-derive per-device
    code — no extent guarantee;
  * `pmap` gives the same per-device program but its dispatch path is
    5-10× slower than jit's C++ fast path on CPU — measured SLOWER than
    the serial chunk loop on the dispatch-bound service fleet, which is
    exactly the workload sharding must win.

There is NO cross-shard communication inside the update: searches are
independent, so the partitioned program is collective-free and the only
inter-device traffic is the initial placement of each chunk's buffers and
the final gather at retirement (O(S·r·(n + B·d)) bytes, once per chunk
lifetime, not per step).

Scope of the bit-identity guarantee.  Every ADMITTED search's trace is
bit-identical to the unsharded engine's, for any submission pattern —
that is what the golden harness and the shard-invariance property suite
pin.  One timing caveat survives: sharded bundles retire as a unit (a
fast chunk's outcomes are published when its bundle's slowest chunk
finishes), so in a WARM-STARTING session that submits new jobs mid-flight
without draining, the class-history snapshot a submit sees — and hence
that new job's warm seeds — can differ across shard counts.  Drain
boundaries (``drain()``, or stepping a wave to completion before the next
submit, as the golden warm-session scenario does) make warm seeding
shard-count-independent; per-shard retirement is future work.

On CPU, multiple devices come from
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — set before the
JAX backend initializes (`repro.hostdevices.force_host_device_count`,
used by the tests' ``conftest.py`` and by
``benchmarks/run.py``/``benchmarks/fleet_bench.py`` when the fleet suite
runs).  A sharded session degrades loudly, not silently: asking for more
shards than there are devices raises, while ``shard="auto"`` uses
whatever is available (1 device → the unsharded reference path).
"""

from __future__ import annotations

from functools import lru_cache
from typing import Optional, Sequence, Tuple, Union

import numpy as np

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.fast_bo import fleet_step

__all__ = ["collapse_rows", "resolve_shard_devices", "sharded_update"]

# Name of the 1-D mesh axis the job/chunk axis is sharded over.
_AXIS = "jobs"


def resolve_shard_devices(
    shard: Union[None, int, str] = None,
    devices: Optional[Sequence] = None,
) -> Optional[Tuple]:
    """Resolve the ``shard=``/``devices=`` switch to a device tuple.

    Returns None for the single-device reference path (``shard`` unset, 1,
    or "auto" on a 1-device host), else a tuple of ≥ 2 devices.  An
    explicit ``devices=`` list wins; ``shard="auto"`` takes every local
    device; an integer asks for exactly that many and raises if the host
    does not expose them (forcing host devices is an env-var decision that
    must happen before backend init — failing loudly beats a silent
    single-device fallback that would fake the speedup).
    """
    if devices is not None:
        devs = tuple(devices)
        if shard not in (None, "auto") and int(shard) != len(devs):
            raise ValueError(
                f"shard={shard!r} disagrees with {len(devs)} explicit devices"
            )
        return devs if len(devs) > 1 else None
    if shard is None:
        return None
    if shard == "auto":
        devs = tuple(jax.devices())
        return devs if len(devs) > 1 else None
    s = int(shard)
    if s < 1:
        raise ValueError(f"shard={shard!r}: want a positive shard count")
    avail = tuple(jax.devices())
    if s > len(avail):
        raise ValueError(
            f"shard={s} but only {len(avail)} device(s) are visible — on "
            "CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{s} (or more) before the JAX backend initializes"
        )
    return avail[:s] if s > 1 else None


def collapse_rows(state, n_shards: int):
    """Host snapshot of a chunk's `FleetState` with any leading shard axis
    collapsed: member i lives at flat row i whether the chunk ran on one
    device or a mesh (shards slice the member list contiguously — see
    `repro.fleet.session._LiveChunk`).  This is the elastic re-bundle
    primitive: `TuningSession.reshard` snapshots every live row through it
    before regrouping survivors onto a new device set, and mid-flight
    cancellation reads the victim's partial trials from it before freezing
    the victim's row on device."""

    def flat(x):
        a = np.asarray(x)
        if n_shards > 1:
            return a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:])
        return a

    return jax.tree_util.tree_map(flat, state)


@lru_cache(maxsize=None)
def sharded_update(devices: Tuple, xi: float, layout: str):
    """(jitted update, NamedSharding) for a bundle of len(devices) chunks.

    The update takes ``(state, geom, costs, prio_mask, rem_mask,
    init_picks, init_count, max_trials, min_obs, ei_stop_rel,
    to_exhaustion)`` where every array — the three scalars included — has a
    leading shard axis of extent S = len(devices), placed with the returned
    sharding.  Each device applies the vmapped `fast_bo.fleet_step` to its
    own chunk slice (the same per-device program `_fleet_update` runs), and
    the state is donated so per-step updates stay in place, per shard.

    Cached per (device tuple, xi, layout): one callable serves every
    bundle shape via jit's shape cache.
    """
    mesh = Mesh(np.asarray(devices), (_AXIS,))
    spec = PartitionSpec(_AXIS)

    def chunk_update(
        state, geom, costs, prio_mask, rem_mask, init_picks, init_count,
        max_trials, min_obs, ei_stop_rel, to_exhaustion,
    ):
        # Per-device view: every operand arrives as the (1, ...) slice this
        # device owns; drop the shard axis, run the chunk program, put the
        # axis back.  No collectives — searches are independent.
        def one(s, g, c, p, r, ip, ic, mt):
            return fleet_step(
                s, g, c, p, r, ip, ic, mt,
                min_obs[0], ei_stop_rel[0], to_exhaustion[0], xi, layout,
            )

        sq = jax.tree_util.tree_map(lambda x: x[0], state)
        out = jax.vmap(one)(
            sq, geom[0], costs[0], prio_mask[0], rem_mask[0],
            init_picks[0], init_count[0], max_trials[0],
        )
        return jax.tree_util.tree_map(lambda x: x[None], out)

    sm = shard_map(
        chunk_update, mesh=mesh,
        in_specs=(spec,) * 11, out_specs=spec,
    )
    return jax.jit(sm, donate_argnums=(0,)), NamedSharding(mesh, spec)
