"""End-to-end fleet tuning: profile (with cache) → split → batched search.

One call tunes J jobs: each job is profiled (or served from the Flora-style
`ProfileCache`), its search space is split into priority/remaining groups by
the paper's §III-D rule, and all J two-phase searches run in ONE jitted
batched engine call.  Every job comes back as the same `RuyaReport` the
single-job pipeline (`repro.core.tuner.run_ruya`) produces, so benchmarks,
examples and the tuner API are engine-agnostic: J=1 is just a fleet of one.

Since the `TuningSession` redesign, `tune_fleet` is a one-shot deprecation
shim: it submits every job to a fresh `repro.fleet.session.TuningSession`
and drains it (bit-identical to the pre-session batched engine).  Hold a
session directly for streaming submission, profile-cache ownership, and
cross-job warm-starting.

`cluster_fleet` replays paper workloads through `repro.cluster.simulator`;
`replay_seeds` expands one job into a fleet of seed-replicas — the paper's
"repeat every search 200×" protocol becomes a single batched call (and,
since seed-replicas share one `SearchSpace` object, one distance-tensor
precompute serves the whole replica fleet).
"""

from __future__ import annotations

import dataclasses
from typing import (
    Callable, Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING, Union,
)

import numpy as np

if TYPE_CHECKING:  # cluster is an optional peer package of fleet
    from repro.cluster.faults import FaultPlan

from repro.core.bayesopt import BOSettings, SearchTrace, ruya_search
from repro.core.profiler import ProfileResult, profile_job
from repro.core.search_space import SearchSpace, split_search_space
from repro.core.tuner import RuyaReport
from repro.fleet.profile_cache import ProfileCache

__all__ = ["FleetJob", "cluster_fleet", "replay_seeds", "tune_fleet"]

RunFn = Callable[[float], Tuple[float, float]]


@dataclasses.dataclass
class FleetJob:
    """Everything the fleet driver needs about one job.

    The cost table is the full per-configuration cost vector — fleet mode
    replays recorded/emulated workloads, so observations are table lookups
    and the whole search can stay on device.

    ``faults`` optionally attaches the job's `FaultPlan`: the session uses
    it to surface per-trial straggler latency (reported as
    `TrialRecord.attempts`, never fed into the cost surface).  The plan's
    run failures are already baked into ``profile_run`` by whoever wrapped
    it (`FaultPlan.wrap_run` / `ClusterSimulator(faults=...)`).

    ``runtime_table``/``price_table`` are the job's raw pricing axes
    (hours and USD/hour per config under a `repro.cluster.pricing`
    catalog) — set for jobs built via ``cluster_fleet(..., catalog=...)``.
    They enable non-runtime objectives (`TuningSession(objective=...)`)
    and per-trial runtime/USD annotation (Pareto fronts); without them the
    job behaves exactly as before.
    """

    name: str
    space: SearchSpace
    cost_table: np.ndarray  # (len(space),) observed cost per config
    full_input_size: float = 0.0  # bytes
    profile_run: Optional[RunFn] = None
    profile_result: Optional[ProfileResult] = None
    per_node_overhead: float = 0.0
    leeway: float = 0.10
    flat_fraction: float = 1.0 / 7.0
    faults: Optional["FaultPlan"] = None
    runtime_table: Optional[np.ndarray] = None  # (len(space),) hours
    price_table: Optional[np.ndarray] = None  # (len(space),) USD/hour
    currency: str = "USD"


def cluster_fleet(
    keys: Sequence[str],
    *,
    per_node_overhead_gb: float = 0.5,
    sims=None,
    faults: Optional[Dict[str, "FaultPlan"]] = None,
    catalog=None,
    epoch: int = 0,
) -> List[FleetJob]:
    """Build fleet jobs from the paper's emulated Spark/Hadoop workloads.

    ``sims`` optionally supplies pre-built `ClusterSimulator`s by key
    (callers with their own memo — e.g. `benchmarks.common` — avoid
    re-instantiating the workload emulation).  ``faults`` optionally maps
    job keys to `FaultPlan`s: a planned job's profiling runs raise per the
    plan (memoized ``sims`` are bypassed for it — the fault wrapper is
    stateful and must be fresh per fleet) and the plan rides on
    `FleetJob.faults` for trial-level straggler reporting.

    ``catalog`` (a `repro.cluster.pricing.PriceCatalog`, with ``epoch``
    selecting the spot-schedule point) builds PRICED jobs: the cost table
    comes from the catalog's book and the raw runtime/price axes ride on
    the job (`runtime_table`/`price_table`) for objective routing and
    Pareto fronts.  Priced builds bypass memoized ``sims`` — those were
    built under the legacy book.  Without a catalog nothing changes:
    tables, profiling, every committed trace.
    """
    from repro.cluster.simulator import ClusterSimulator

    GiB = 1024.0**3
    sims = {} if sims is None else sims
    jobs = []
    for key in keys:
        plan = None if faults is None else faults.get(key)
        if plan is not None or catalog is not None:
            sim = ClusterSimulator.for_job(
                key, faults=plan, catalog=catalog, epoch=epoch
            )
        else:
            # NOT `sims.get(key) or ...`: same falsy-`or` shape as the
            # PR-9 session bug — route on None, not truthiness.
            sim = sims.get(key)
            if sim is None:
                sim = ClusterSimulator.for_job(key)
        # A priced job's base table is its normalized RUNTIME axis, so
        # objective="runtime" means fastest and objective="cost" means
        # cheapest under the same catalog — the two-objective contrast
        # workload H measures.  Unpriced jobs keep the legacy normalized
        # table byte-for-byte (the paper's metric, and every pinned trace).
        table = (
            sim.normalized if sim.runtime_h is None
            else sim.runtime_h / sim.runtime_h.min()
        )
        jobs.append(
            FleetJob(
                name=key,
                space=sim.space,
                cost_table=table,
                full_input_size=sim.job.input_gb * GiB,
                profile_run=sim.profile_run_fn(),
                per_node_overhead=per_node_overhead_gb * GiB,
                faults=plan,
                runtime_table=sim.runtime_h,
                price_table=sim.price_hour,
            )
        )
    return jobs


def replay_seeds(job: FleetJob, seeds: Sequence[int]) -> Tuple[
    List[FleetJob], List[np.random.Generator]
]:
    """One job × many seeds → a fleet (the paper's repetition protocol)."""
    return [job] * len(seeds), [np.random.default_rng(s) for s in seeds]


def _resolve_profile(job: FleetJob, cache: Optional[ProfileCache]) -> ProfileResult:
    if job.profile_result is not None:
        return job.profile_result
    if job.profile_run is None:
        raise ValueError(
            f"job {job.name!r} has neither profile_result nor profile_run"
        )
    if cache is not None:
        return cache.get_or_profile(job.profile_run, job.full_input_size)
    return profile_job(job.profile_run, job.full_input_size)


def tune_fleet(
    jobs: Sequence[FleetJob],
    rngs: Sequence[np.random.Generator],
    *,
    mode: str = "ruya",
    settings: BOSettings = BOSettings(),
    to_exhaustion: bool = False,
    cache: Optional[ProfileCache] = None,
    engine: str = "batched",
    shard=None,
    objective="runtime",
) -> List[RuyaReport]:
    """Tune J jobs; returns one `RuyaReport` per job.

    ``mode="ruya"`` profiles each job (through ``cache`` when given) and runs
    the two-phase search; ``mode="cherrypick"`` runs the plain-BO baseline
    (no profiling, the report's ``profile`` is None).  ``engine="batched"``
    uses the jitted multi-job engine; ``engine="sequential"`` drives the
    per-job engine in a Python loop — both produce identical traces, the
    sequential path exists for verification and J=1 fallback.  ``shard``
    (batched engine only) spreads the job axis across JAX devices — see
    `repro.fleet.sharding`; traces stay bit-identical.  ``objective``
    routes the scoring ("runtime" | "cost" | weight mapping — see
    `repro.fleet.session.objective_table`); both engines observe the same
    derived score table, so traces stay engine-identical under every
    objective.

    .. deprecated:: PR 4
        This is a one-shot deprecation shim over
        `repro.fleet.session.TuningSession` (submit everything, drain once
        — bit-identical to the pre-session engine, pinned by
        `tests/test_session.py`).  New code should hold a session: it
        admits jobs over time, owns the `ProfileCache`, and warm-starts
        recurring signature classes.
    """
    if mode not in ("ruya", "cherrypick"):
        raise ValueError(f"unknown mode {mode!r}")
    if engine not in ("batched", "sequential"):
        raise ValueError(f"unknown engine {engine!r}")
    if shard is not None and engine == "sequential":
        raise ValueError("shard= requires the batched engine")
    if len(jobs) != len(rngs):
        raise ValueError(f"{len(jobs)} jobs but {len(rngs)} rngs")

    if engine == "batched":
        from repro.fleet.session import TuningSession

        session = TuningSession(
            settings=settings, mode=mode, cache=cache, warm_start=False,
            to_exhaustion=to_exhaustion, shard=shard, objective=objective,
        )
        for job, rng in zip(jobs, rngs):
            session.submit(job, rng)
        return [out.report() for out in session.drain()]

    # Sequential verification path: the pre-session per-job engine, with
    # the host-side §III-D split (the reference `TuningSession`'s on-device
    # split is pinned against).  Objective routing happens through the
    # SAME derived table the session observes, so the two engines stay
    # trace-identical under every objective.
    from repro.fleet.session import objective_table

    tables = [objective_table(job, objective) for job in jobs]
    profiles: List[Optional[ProfileResult]] = []
    priority: List[List[int]] = []
    remaining: List[List[int]] = []
    resolved: dict = {}  # id(job) -> profile; seed-replica fleets alias jobs
    for job in jobs:
        if mode == "cherrypick":
            profiles.append(None)
            priority.append(list(range(len(job.space))))
            remaining.append([])
            continue
        if id(job) not in resolved:
            resolved[id(job)] = _resolve_profile(job, cache)
        prof = resolved[id(job)]
        prio, rest = split_search_space(
            job.space,
            prof.model,
            job.full_input_size,
            per_node_overhead=job.per_node_overhead,
            leeway=job.leeway,
            flat_fraction=job.flat_fraction,
        )
        profiles.append(prof)
        priority.append(list(prio))
        remaining.append(list(rest))

    traces: List[SearchTrace] = [
        ruya_search(
            job.space,
            lambda i, _t=table: float(_t[i]),
            rng,
            prio,
            rest,
            settings=settings,
            to_exhaustion=to_exhaustion,
        )
        for job, table, rng, prio, rest in zip(
            jobs, tables, rngs, priority, remaining
        )
    ]
    return [
        RuyaReport(
            profile=prof,
            priority=tuple(prio),
            remaining=tuple(rest),
            trace=trace,
        )
        for prof, prio, rest, trace in zip(profiles, priority, remaining, traces)
    ]
