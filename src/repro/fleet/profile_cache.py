"""Flora-style profile reuse across jobs with matching memory patterns.

Flora (Will et al., 2025) amortizes cluster tuning across a fleet by
classifying jobs and sharing knowledge within a class.  We apply the idea to
Ruya's most expensive phase: the single-machine profiling runs (minutes per
job, Table III).  A job's *memory signature* is derived from its fitted
`MemoryModel` — the category plus log-quantized slope and quantized
intercept — so two jobs whose memory scales the same way hash to the same
bucket regardless of small run-to-run noise.

The cache workflow, per job:

  1. run a cheap three-point *probe* (tiny samples, a fraction of the full
     five-run sweep) and fit a coarse model;
  2. if a profile with the probe's signature is cached → reuse it (hit);
  3. otherwise run the full §III-B profiling driver, store it under its own
     (full-fit) signature (miss).

Probing costs 3 short runs versus ~6+ longer ones for a full profile, so a
fleet of N jobs in C classes pays for C full profiles plus N cheap probes.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Callable, Dict, Optional, Tuple

from repro.core.memory_model import MemoryCategory, MemoryModel, fit_memory_model
from repro.core.profiler import ProfileResult, profile_job

__all__ = ["MemorySignature", "ProfileCache", "probe_memory_model"]

RunFn = Callable[[float], Tuple[float, float]]

_GiB = 1024.0**3


@dataclasses.dataclass(frozen=True)
class MemorySignature:
    """Hashable memory-pattern class of a job (Flora-style)."""

    category: str
    slope_bucket: int  # round(log2(slope) / resolution), LINEAR only
    intercept_bucket: int  # round(intercept / quantum)

    @classmethod
    def of(
        cls,
        model: MemoryModel,
        *,
        slope_resolution: float = 0.5,
        intercept_quantum: float = 4.0 * _GiB,
    ) -> "MemorySignature":
        if model.category is MemoryCategory.LINEAR and model.slope > 0:
            slope_bucket = round(math.log2(model.slope) / slope_resolution)
        else:
            slope_bucket = 0
        intercept = model.intercept if math.isfinite(model.intercept) else 0.0
        return cls(
            category=model.category.value,
            slope_bucket=slope_bucket,
            intercept_bucket=round(intercept / intercept_quantum),
        )


def probe_memory_model(
    run: RunFn,
    full_input_size: float,
    *,
    fractions: Tuple[float, float, float] = (0.002, 0.006, 0.01),
) -> Tuple[MemoryModel, float]:
    """Cheap classification probe: a few tiny runs, coarse OLS fit.

    Returns (coarse model, wall-seconds spent probing).  The probe exists
    only to compute a `MemorySignature` — it is far too noisy to extrapolate
    a memory requirement from.
    """
    sizes = [full_input_size * f for f in fractions]
    spent = 0.0
    readings = []
    for s in sizes:
        runtime, peak = run(s)
        spent += runtime
        readings.append(peak)
    return fit_memory_model(sizes, readings), spent


class ProfileCache:
    """Shared `ProfileResult` store keyed by `MemorySignature`.

    Drift detection (opt-in via ``drift_tolerance``): recurring jobs DRIFT
    — datasets grow, per-row slopes amortize, overheads creep (see
    `repro.cluster.workloads.drift_spec`) — and Flora-style class reuse is
    only safe while the cached profile still describes the job.  When a
    fresh probe lands in a cached class bucket but its coarse fit has
    moved beyond the tolerance from the cached profile's model, the hit is
    REFUSED: the job is flagged (``last_drift``), re-profiled in full, and
    re-classed — the fresh profile replaces the stale entry under the
    probe bucket and files under its own full-fit signature.  Callers
    (the `TuningSession`) additionally skip warm-seeding a flagged job
    from the stale class's trial history.

    Thread safety: a cache may be shared by concurrent submitters (the
    async `TuningService`, or several sessions).  Every class-table
    mutation and the whole `get_or_profile` decision run under ``lock``
    (re-entrant, exposed) — the probe-classify → hit/miss → store
    sequence is one atomic unit, so two threads probing into the same
    empty bucket cannot both "miss" and double-profile, and the counters
    stay consistent.  ``last_drift`` is a per-call report: a caller that
    needs it must read it while still holding ``lock`` (the session's
    profile resolution does exactly that).
    """

    def __init__(
        self,
        *,
        slope_resolution: float = 0.5,
        intercept_quantum: float = 4.0 * _GiB,
    ) -> None:
        self.lock = threading.RLock()
        self._store: Dict[MemorySignature, ProfileResult] = {}
        self._slope_resolution = slope_resolution
        self._intercept_quantum = intercept_quantum
        self.hits = 0
        self.misses = 0
        self.drift_reprofiles = 0
        self.last_drift = False  # did the latest get_or_profile flag drift?
        self.probe_time_s = 0.0

    def __len__(self) -> int:
        with self.lock:
            return len(self._store)

    def signature(self, model: MemoryModel) -> MemorySignature:
        return MemorySignature.of(
            model,
            slope_resolution=self._slope_resolution,
            intercept_quantum=self._intercept_quantum,
        )

    def get(self, sig: MemorySignature) -> Optional[ProfileResult]:
        with self.lock:
            return self._store.get(sig)

    def put(self, sig: MemorySignature, profile: ProfileResult) -> None:
        with self.lock:
            self._store[sig] = profile

    def model_drifted(
        self, probe: MemoryModel, cached: MemoryModel, tolerance: float
    ) -> bool:
        """Has the job's coarse probe fit moved beyond ``tolerance`` from
        the cached class profile's model?  Category changes always drift;
        linear jobs compare relative slope deviation; every category
        compares the intercept against a ``tolerance`` fraction of the
        class quantum (signature buckets are coarse by design, so a probe
        can land in the bucket while the underlying fit has moved)."""
        if probe.category is not cached.category:
            return True
        if probe.category is MemoryCategory.LINEAR:
            ref = max(abs(cached.slope), 1e-12)
            if abs(probe.slope - cached.slope) / ref > tolerance:
                return True
        icp = probe.intercept if math.isfinite(probe.intercept) else 0.0
        icc = cached.intercept if math.isfinite(cached.intercept) else 0.0
        return abs(icp - icc) > tolerance * self._intercept_quantum

    def get_or_profile(
        self,
        run: RunFn,
        full_input_size: float,
        *,
        drift_tolerance: Optional[float] = None,
        **profile_kwargs,
    ) -> ProfileResult:
        """Probe-classify the job; reuse a cached profile or run a full one.

        With ``drift_tolerance`` set, a cached hit whose coarse probe fit
        has drifted beyond the tolerance is refused and the job is
        re-profiled and re-classed (see the class docstring);
        ``last_drift`` reports the decision for the latest call (read it
        under ``lock`` when other threads share the cache).

        The whole call holds ``lock``: the emulated run fns are cheap, and
        releasing it between the probe and the store would let two threads
        double-profile one class (and tear the hit/miss counters).
        """
        with self.lock:
            coarse, probe_s = probe_memory_model(run, full_input_size)
            self.probe_time_s += probe_s
            sig = self.signature(coarse)
            self.last_drift = False
            cached = self._store.get(sig)
            if cached is not None:
                if drift_tolerance is None or not self.model_drifted(
                    coarse, cached.model, drift_tolerance
                ):
                    self.hits += 1
                    return cached
                self.last_drift = True
                self.drift_reprofiles += 1
            else:
                self.misses += 1
            profile = profile_job(run, full_input_size, **profile_kwargs)
            if self.last_drift:
                # Re-class: the fresh profile REPLACES the stale class entry
                # under the probe bucket and files under its own full fit.
                self._store[sig] = profile
                self._store[self.signature(profile.model)] = profile
            else:
                # Store under the probe signature (the lookup key future jobs
                # will compute) and the full-fit signature, which can differ
                # on noisy jobs.
                self._store.setdefault(sig, profile)
                self._store.setdefault(self.signature(profile.model), profile)
            return profile
