"""Training/serving runtime: step factories, fault-tolerant loops."""

from repro.runtime.steps import TrainState, make_train_step, make_serve_steps
from repro.runtime.loop import TrainLoop, StragglerMonitor, PreemptionGuard
from repro.runtime.serve import ServeLoop

__all__ = [
    "PreemptionGuard",
    "ServeLoop",
    "StragglerMonitor",
    "TrainLoop",
    "TrainState",
    "make_serve_steps",
    "make_train_step",
]
