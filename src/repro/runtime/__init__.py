"""Training/serving runtime: step factories, fault-tolerant loops, and
the tuning-as-a-service daemon (`TuningDaemon`)."""

from repro.runtime.steps import TrainState, make_train_step, make_serve_steps
from repro.runtime.loop import TrainLoop, StragglerMonitor, PreemptionGuard
from repro.runtime.decode_loop import ServeLoop
from repro.runtime.serve import TuningDaemon

__all__ = [
    "PreemptionGuard",
    "ServeLoop",
    "TuningDaemon",
    "StragglerMonitor",
    "TrainLoop",
    "TrainState",
    "make_serve_steps",
    "make_train_step",
]
