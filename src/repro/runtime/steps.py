"""Step-function factories: ``train_step`` and ``serve_step``s.

These are the functions the launcher jits (with shardings and donation) and
the dry-run AOT-lowers.  They are pure: ``(state, batch) -> (state, metrics)``
and ``(params, cache, tokens, index) -> (logits, cache)``.

Distributed-optimization knobs applied here (all per-arch ExecConfig):
  * microbatch gradient accumulation (lax.scan) with optional bf16 accumulator
  * bf16 gradient reduction: grads cast to bf16 *inside* the per-microbatch
    grad fn, so the cross-device reduce-scatter/all-reduce XLA inserts for
    data parallelism moves half the bytes
  * global-norm clipping, LR schedule, AdamW or Adafactor update
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ExecConfig
from repro.models.model import Model
from repro.optim import (
    OptState,
    clip_by_global_norm,
    linear_warmup_cosine,
    make_optimizer,
)

__all__ = ["TrainState", "make_train_step", "make_serve_steps"]

TrainState = Dict[str, Any]  # {"params": pytree, "opt": OptState}


def make_train_step(
    model: Model, exec_cfg: ExecConfig
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict]]:
    """Build the jittable training step for (model, exec config)."""
    optimizer = make_optimizer(
        exec_cfg.optimizer, weight_decay=exec_cfg.weight_decay
    )
    from repro.parallel.microbatch import accumulate_gradients

    accum_dtype = (
        jnp.dtype(exec_cfg.accum_dtype) if exec_cfg.accum_dtype else None
    )

    def grad_fn(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True
        )(params, mb)
        if exec_cfg.bf16_grad_reduce:
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16)
                if g.dtype == jnp.float32
                else g,
                grads,
            )
        return grads, metrics

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params, opt = state["params"], state["opt"]
        grads, metrics = accumulate_gradients(
            grad_fn, params, batch, exec_cfg.num_microbatches,
            accum_dtype=accum_dtype,
        )
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        grads, grad_norm = clip_by_global_norm(grads, exec_cfg.grad_clip)
        lr = linear_warmup_cosine(
            opt.step + 1, exec_cfg.learning_rate, exec_cfg.warmup_steps,
            exec_cfg.total_steps,
        )
        new_params, new_opt = optimizer.update(params, opt, grads, lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = grad_norm
        metrics["lr"] = lr
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(model: Model, exec_cfg: ExecConfig, key: jax.Array) -> TrainState:
    from repro.models.spec import init_tree

    optimizer = make_optimizer(
        exec_cfg.optimizer, weight_decay=exec_cfg.weight_decay
    )
    params = init_tree(key, model.param_specs())
    return {"params": params, "opt": optimizer.init(params)}


def train_state_specs(model: Model, exec_cfg: ExecConfig) -> Any:
    """TensorSpec tree matching ``init_train_state`` — for sharding/dry-run."""
    from repro.models.spec import TensorSpec

    optimizer = make_optimizer(
        exec_cfg.optimizer, weight_decay=exec_cfg.weight_decay
    )
    pspecs = model.param_specs()
    return {
        "params": pspecs,
        "opt": OptState(
            step=TensorSpec((), jnp.int32, ()),
            inner=optimizer.state_specs(pspecs),
        ),
    }


def make_serve_steps(model: Model):
    """(prefill_step, decode_step) pair for the serving path."""

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    def decode_step(params, cache, tokens, index):
        return model.decode_step(params, cache, tokens, index)

    return prefill_step, decode_step
