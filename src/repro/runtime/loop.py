"""Fault-tolerant training loop.

Large-scale behaviors implemented (and unit-tested by injection):

  * **Checkpoint/restart** — periodic async checkpoints; on construction the
    loop restores the latest checkpoint if one exists, and the deterministic
    data pipeline replays from the restored step (identical batches).
  * **Preemption handling** — SIGTERM/SIGINT set a flag (the single-process
    analogue of a maintenance-event notice); the loop finishes the in-flight
    step, writes a *synchronous* barrier checkpoint, and exits cleanly for
    the cluster manager to restart it elsewhere.
  * **Straggler mitigation** — per-step wall times feed a rolling monitor;
    steps slower than ``threshold × median`` are flagged and counted.  On a
    real multi-host deployment the same monitor ingests per-host heartbeat
    times and the launcher evicts consistently slow hosts (v5e has no
    per-step work stealing — eviction/restart *is* the mitigation); here it
    is exercised by tests via injected delays.
  * **NaN/divergence guard** — a non-finite loss aborts with a clear error
    (after checkpointing the last good state) rather than silently training
    garbage.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

__all__ = ["StragglerMonitor", "PreemptionGuard", "TrainLoop"]


class StragglerMonitor:
    """Rolling per-step wall-time monitor; flags slow steps."""

    def __init__(self, window: int = 50, threshold: float = 1.5) -> None:
        self.window = window
        self.threshold = threshold
        self.times: deque = deque(maxlen=window)
        self.flagged: List[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True if it is a straggler."""
        is_straggler = False
        if len(self.times) >= 5:
            med = float(np.median(self.times))
            if seconds > self.threshold * med:
                self.flagged.append(step)
                is_straggler = True
        self.times.append(seconds)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a checked flag (restartable exit)."""

    def __init__(self, install: bool = True) -> None:
        self.preempted = False
        self._prev: Dict[int, Any] = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:  # non-main thread (tests)
                    pass

    def _handler(self, signum, frame) -> None:  # pragma: no cover - signal path
        self.preempted = True

    def trigger(self) -> None:
        """Test hook: simulate a preemption notice."""
        self.preempted = True

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


@dataclasses.dataclass
class TrainLoop:
    """Drives ``train_step`` with checkpointing and failure handling."""

    train_step: Callable  # jitted (state, batch) -> (state, metrics)
    batch_at: Callable[[int], Dict[str, Any]]  # step -> host batch
    place_batch: Callable[[Dict[str, Any]], Dict[str, Any]]
    state: Any
    checkpoints: CheckpointManager
    checkpoint_every: int = 100
    log_every: int = 10
    monitor: StragglerMonitor = dataclasses.field(default_factory=StragglerMonitor)
    guard: Optional[PreemptionGuard] = None
    log_fn: Callable[[str], None] = print

    start_step: int = 0
    metrics_history: List[Dict[str, float]] = dataclasses.field(default_factory=list)

    def maybe_restore(self) -> int:
        """Restore the newest checkpoint if present; returns start step."""
        latest = self.checkpoints.latest_step()
        if latest is None:
            return 0
        self.state, extra = self.checkpoints.restore(self.state)
        self.start_step = int(extra.get("step", latest))
        self.log_fn(f"[restore] resumed from step {self.start_step}")
        return self.start_step

    def run(self, num_steps: int) -> Dict[str, Any]:
        guard = self.guard or PreemptionGuard(install=False)
        step = self.start_step
        end = self.start_step + num_steps
        exit_reason = "completed"

        while step < end:
            t0 = time.monotonic()
            batch = self.place_batch(self.batch_at(step))
            self.state, metrics = self.train_step(self.state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.monotonic() - t0
            step += 1

            if not np.isfinite(loss):
                self.checkpoints.wait()
                self.checkpoints.save(step, self.state, extra={"step": step})
                raise FloatingPointError(
                    f"non-finite loss {loss} at step {step}; "
                    f"state checkpointed for post-mortem"
                )

            if self.monitor.observe(step, dt):
                self.log_fn(
                    f"[straggler] step {step} took {dt:.3f}s "
                    f"(median {self.monitor.median:.3f}s)"
                )
            if step % self.log_every == 0 or step == end:
                rec = {"step": step, "loss": loss, "sec": dt}
                self.metrics_history.append(rec)
                self.log_fn(f"[train] step {step} loss {loss:.4f} ({dt:.3f}s)")
            if step % self.checkpoint_every == 0:
                self.checkpoints.save_async(step, self.state, extra={"step": step})

            if guard.preempted:
                # Barrier save: synchronous, then exit for restart.
                self.checkpoints.wait()
                self.checkpoints.save(step, self.state, extra={"step": step})
                self.log_fn(f"[preempt] checkpointed at step {step}; exiting")
                exit_reason = "preempted"
                break

        self.checkpoints.wait()
        if exit_reason == "completed" and (end % self.checkpoint_every) != 0:
            self.checkpoints.save(end, self.state, extra={"step": end})
        return {
            "final_step": step,
            "exit": exit_reason,
            "stragglers": list(self.monitor.flagged),
            "history": self.metrics_history,
        }
