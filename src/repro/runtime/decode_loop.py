"""Batched serving loop: continuous greedy decoding over request batches.

A deliberately small but real serving path: requests (prompts) are grouped
into fixed-size batches, prefilled once, then decoded token-by-token with a
shared jitted decode step and donated caches.  Per-request stop handling
masks finished rows (EOS or length); the loop reports aggregate throughput.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ServeLoop"]


@dataclasses.dataclass
class ServeLoop:
    prefill_step: Callable  # (params, batch, cache) -> (logits, cache)
    decode_step: Callable  # (params, cache, tokens, index) -> (logits, cache)
    params: Any
    init_cache: Callable[[], Any]  # fresh zeroed cache per batch
    eos_id: int = 1

    def generate(
        self,
        batch: Dict[str, jax.Array],  # {"tokens": (B,T), +modality stubs}
        max_new_tokens: int,
        *,
        prompt_len: Optional[int] = None,
        echo_metrics: bool = False,
    ) -> Dict[str, Any]:
        cache = self.init_cache()
        b, t = batch["tokens"].shape
        offset = t
        if "patches" in batch:
            offset += batch["patches"].shape[1]

        t0 = time.monotonic()
        logits, cache = self.prefill_step(self.params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        prefill_s = time.monotonic() - t0

        out_tokens: List[np.ndarray] = [np.asarray(next_tok)]
        finished = np.zeros((b,), bool)
        t1 = time.monotonic()
        index = jnp.int32(offset)
        for i in range(max_new_tokens - 1):
            logits, cache = self.decode_step(self.params, cache, next_tok, index)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            index = index + 1
            host_tok = np.asarray(next_tok)
            finished |= host_tok[:, 0] == self.eos_id
            out_tokens.append(host_tok)
            if finished.all():
                break
        decode_s = time.monotonic() - t1

        tokens = np.concatenate(out_tokens, axis=1)
        result: Dict[str, Any] = {"tokens": tokens}
        if echo_metrics:
            result["metrics"] = {
                "prefill_s": prefill_s,
                "decode_s": decode_s,
                "decoded": int(tokens.shape[1]),
                "tokens_per_s": tokens.size / max(decode_s, 1e-9),
            }
        return result
