"""Tuning-as-a-service daemon: a supervised `TuningService` with a
periodic JSON metrics snapshot.

This is the deployment wrapper around `repro.fleet.service.TuningService`
(which owns the actual scheduling — per-group dispatch threads, admission
backpressure, graceful drain): the daemon adds the operational shell a
long-running tuner needs — a background snapshot thread that serializes
`TuningService.metrics()` to disk at a fixed cadence (atomic
write-then-rename, so a scraper never reads a torn file) and a
stop-the-world `stop(drain=...)` that flushes a final snapshot.

    daemon = TuningDaemon(metrics_path="artifacts/tuning_metrics.json",
                          cache=ProfileCache(), max_in_flight=128)
    daemon.start()
    handle = daemon.submit(job, seed=0)
    ...
    daemon.stop(drain=True)       # drain, final snapshot, join threads

The token-decode serving loop that used to live here moved to
`repro.runtime.decode_loop` (re-exported below for compatibility — it is
a model-serving loop, not a tuning service, and the two share nothing
but the word "serve").
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional

from repro.fleet.service import TuningService
from repro.fleet.session import JobHandle, SearchOutcome
from repro.runtime.decode_loop import ServeLoop  # noqa: F401  (compat)

__all__ = ["ServeLoop", "TuningDaemon"]


class TuningDaemon:
    """Long-running tuning service with periodic metrics snapshots.

    Constructor keywords forward to `TuningService` (and through it to
    `TuningSession`) unless an existing ``service`` is passed.
    ``metrics_path`` (optional) is where the snapshot thread writes the
    JSON metrics surface every ``snapshot_every_s`` seconds; with no
    path, `metrics()` is still available on demand and nothing touches
    disk.  The daemon is a context manager: `with TuningDaemon(...) as d:`
    starts it and stops (draining) on clean exit.
    """

    def __init__(
        self,
        service: Optional[TuningService] = None,
        *,
        metrics_path: Optional[str] = None,
        snapshot_every_s: float = 5.0,
        **service_kwargs: object,
    ) -> None:
        if service is not None and service_kwargs:
            raise ValueError(
                "pass EITHER an existing service OR TuningService kwargs"
            )
        self.service = service or TuningService(**service_kwargs)
        self.metrics_path = metrics_path
        self.snapshot_every_s = float(snapshot_every_s)
        self._stop = threading.Event()
        self._snapshotter: Optional[threading.Thread] = None

    # --------------------------------------------------------- lifecycle

    def start(self) -> "TuningDaemon":
        """Idempotent; spins up the snapshot thread when a path is set."""
        if self.metrics_path is not None and self._snapshotter is None:
            self._snapshotter = threading.Thread(
                target=self._snapshot_loop, name="tuning-metrics", daemon=True
            )
            self._snapshotter.start()
        return self

    def stop(self, drain: bool = True) -> List[SearchOutcome]:
        """Shut the service down (``drain=True`` finishes live work
        first), stop the snapshot thread, and flush a final snapshot."""
        outcomes = self.service.shutdown(drain=drain)
        self._stop.set()
        if self._snapshotter is not None:
            self._snapshotter.join(timeout=5.0)
            self._snapshotter = None
        self.snapshot()
        return outcomes

    def __enter__(self) -> "TuningDaemon":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop(drain=exc_type is None)

    # ------------------------------------------------------- passthrough

    def submit(self, job, rng=None, **kwargs) -> JobHandle:
        return self.service.submit(job, rng, **kwargs)

    def drain(self) -> List[SearchOutcome]:
        return self.service.drain()

    def results(self) -> List[SearchOutcome]:
        return self.service.results()

    def metrics(self) -> dict:
        return self.service.metrics()

    # ----------------------------------------------------------- metrics

    def snapshot(self) -> Optional[str]:
        """Write one metrics snapshot now (atomic rename); returns the
        path, or None when no ``metrics_path`` is configured."""
        if self.metrics_path is None:
            return None
        payload = self.service.metrics()
        payload["snapshot_unix_s"] = time.time()
        directory = os.path.dirname(os.path.abspath(self.metrics_path))
        os.makedirs(directory, exist_ok=True)
        tmp = f"{self.metrics_path}.tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, self.metrics_path)
        return self.metrics_path

    def _snapshot_loop(self) -> None:
        while not self._stop.wait(self.snapshot_every_s):
            try:
                self.snapshot()
            except OSError:
                pass  # disk hiccups must not kill the scraper thread
