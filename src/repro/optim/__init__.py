"""Optimizers (AdamW, Adafactor) and LR schedules, pure JAX."""

from repro.optim.optimizers import (
    OptState,
    Optimizer,
    adamw,
    adafactor,
    make_optimizer,
    global_norm,
    clip_by_global_norm,
)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine

__all__ = [
    "OptState",
    "Optimizer",
    "adafactor",
    "adamw",
    "clip_by_global_norm",
    "cosine_schedule",
    "global_norm",
    "linear_warmup_cosine",
    "make_optimizer",
]
