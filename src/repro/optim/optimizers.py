"""Optimizers as (init, update) pairs over parameter pytrees.

Two families, chosen per architecture by ``ExecConfig.optimizer``:

  * ``adamw``     — AdamW with f32 moments; the default for ≤100 B models.
  * ``adafactor`` — factored second moment (row/col statistics for ≥2-D
                    tensors), no momentum, update-norm clipping — the
                    memory-frugal choice for the trillion-parameter MoE
                    cells (state ≈ bytes(params)/min(dims) instead of
                    8 bytes/param).

Optimizer state tensors inherit the *logical axes* of their parameters, so
``parallel.sharding`` shards them identically (ZeRO-style placement comes
from the same rule set — no separate partitioning logic to drift).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "OptState",
    "Optimizer",
    "adamw",
    "adafactor",
    "make_optimizer",
    "global_norm",
    "clip_by_global_norm",
]


class OptState(NamedTuple):
    step: jax.Array  # scalar int32
    inner: Any  # optimizer-specific pytree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jax.Array], Tuple[Any, OptState]]
    # state_specs mirrors param TensorSpecs so sharding rules apply to state.
    state_specs: Callable[[Any], Any]


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    def init(params: Any) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            inner={
                "mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
            },
        )

    def update(params: Any, state: OptState, grads: Any, lr: jax.Array):
        step = state.step + 1
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            mhat = mu / bc1
            nhat = nu / bc2
            delta = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(
                jnp.float32
            )
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_mu = jax.tree.leaves(state.inner["mu"])
        flat_nu = jax.tree.leaves(state.inner["nu"])
        new = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_params = jax.tree.unflatten(treedef, [t[0] for t in new])
        mu = jax.tree.unflatten(treedef, [t[1] for t in new])
        nu = jax.tree.unflatten(treedef, [t[2] for t in new])
        return new_params, OptState(step=step, inner={"mu": mu, "nu": nu})

    def state_specs(param_specs: Any) -> Any:
        from repro.models.spec import TensorSpec, is_spec

        f32 = lambda s: TensorSpec(s.shape, jnp.float32, s.axes)
        return {
            "mu": jax.tree.map(f32, param_specs, is_leaf=is_spec),
            "nu": jax.tree.map(f32, param_specs, is_leaf=is_spec),
        }

    return Optimizer(init=init, update=update, state_specs=state_specs)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored second moment, no momentum
# ---------------------------------------------------------------------------


def _factored_dims(shape: Tuple[int, ...]) -> Optional[Tuple[int, int]]:
    """Last two non-trivial dims to factor over, or None for <2-D tensors."""
    if len(shape) < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def adafactor(
    *,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params: Any) -> OptState:
        def zero_state(p):
            dims = _factored_dims(p.shape)
            if dims is None:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            r, c = dims
            row_shape = tuple(d for i, d in enumerate(p.shape) if i != c)
            col_shape = tuple(d for i, d in enumerate(p.shape) if i != r)
            return {
                "vr": jnp.zeros(row_shape, jnp.float32),
                "vc": jnp.zeros(col_shape, jnp.float32),
            }

        return OptState(
            step=jnp.zeros((), jnp.int32),
            inner=jax.tree.map(
                zero_state, params, is_leaf=lambda x: isinstance(x, jax.Array)
            ),
        )

    def update(params: Any, state: OptState, grads: Any, lr: jax.Array):
        step = state.step + 1
        # Step-dependent decay (Adafactor's \hat{beta2_t}).
        beta2t = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(p, g, st):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            dims = _factored_dims(p.shape)
            if dims is None:
                v = beta2t * st["v"] + (1 - beta2t) * g2
                new_st = {"v": v}
                precond = g * jax.lax.rsqrt(v + eps)
            else:
                r, c = dims
                vr = beta2t * st["vr"] + (1 - beta2t) * jnp.mean(g2, axis=c)
                vc = beta2t * st["vc"] + (1 - beta2t) * jnp.mean(g2, axis=r)
                new_st = {"vr": vr, "vc": vc}
                row_mean = jnp.mean(vr, axis=-1, keepdims=True)
                rfac = jax.lax.rsqrt(jnp.expand_dims(vr / jnp.maximum(row_mean, eps), c))
                cfac = jax.lax.rsqrt(jnp.expand_dims(vc, r))
                precond = g * rfac * cfac
            # Update-norm clipping (RMS ≤ clip_threshold).
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
            precond = precond / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr * (
                precond + weight_decay * p.astype(jnp.float32)
            )
            return newp.astype(p.dtype), new_st

        is_state_leaf = lambda x: isinstance(x, dict) and (
            "v" in x or "vr" in x
        )
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_s = treedef.flatten_up_to(state.inner)
        new = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = jax.tree.unflatten(treedef, [t[0] for t in new])
        new_state = jax.tree.unflatten(treedef, [t[1] for t in new])
        return new_params, OptState(step=step, inner=new_state)

    def state_specs(param_specs: Any) -> Any:
        from repro.models.spec import TensorSpec, is_spec

        def spec_state(s: TensorSpec):
            dims = _factored_dims(s.shape)
            axes = s.axes if s.axes else (None,) * len(s.shape)
            if dims is None:
                return {"v": TensorSpec(s.shape, jnp.float32, axes)}
            r, c = dims
            row_shape = tuple(d for i, d in enumerate(s.shape) if i != c)
            row_axes = tuple(a for i, a in enumerate(axes) if i != c)
            col_shape = tuple(d for i, d in enumerate(s.shape) if i != r)
            col_axes = tuple(a for i, a in enumerate(axes) if i != r)
            return {
                "vr": TensorSpec(row_shape, jnp.float32, row_axes),
                "vc": TensorSpec(col_shape, jnp.float32, col_axes),
            }

        return jax.tree.map(spec_state, param_specs, is_leaf=is_spec)

    return Optimizer(init=init, update=update, state_specs=state_specs)


def make_optimizer(name: str, *, weight_decay: float = 0.01) -> Optimizer:
    if name == "adamw":
        return adamw(weight_decay=weight_decay)
    if name == "adafactor":
        return adafactor(weight_decay=weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")
