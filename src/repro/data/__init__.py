"""Deterministic synthetic data pipeline."""

from repro.data.pipeline import SyntheticDataset, make_batch, shard_batch

__all__ = ["SyntheticDataset", "make_batch", "shard_batch"]
