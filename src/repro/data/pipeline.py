"""Deterministic synthetic token pipeline with sharded host feed.

Real text is irrelevant to a systems framework's correctness; what matters
is (a) determinism across restarts (fault-tolerance tests resume mid-stream
and must see identical batches), (b) non-degenerate token statistics (a
Zipfian unigram stream so losses move), and (c) batches placed with the
*same sharding the step function expects* (``shard_batch`` uses
``jax.device_put`` with the batch NamedSharding, the single-process analogue
of per-host ``make_array_from_process_local_data``).

Batches are a pure function of (seed, step) — no iterator state to
checkpoint beyond the step counter.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

__all__ = ["SyntheticDataset", "make_batch", "shard_batch"]


def _zipf_tokens(
    rng: np.random.Generator, shape, vocab: int, alpha: float = 1.1
) -> np.ndarray:
    """Zipf-distributed token ids in [0, vocab) (heavy head, long tail)."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    return rng.choice(vocab, size=shape, p=probs).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class SyntheticDataset:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The batch for a given step — deterministic, restart-stable."""
        return make_batch(
            self.cfg, self.global_batch, self.seq_len,
            seed=self.seed, step=step,
        )


def make_batch(
    cfg: ModelConfig,
    batch: int,
    seq_len: int,
    *,
    seed: int = 0,
    step: int = 0,
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    out: Dict[str, np.ndarray] = {}
    cdtype = np.dtype(cfg.cdtype)  # ml_dtypes handles bfloat16 in numpy
    text_len = seq_len
    if cfg.family == "vlm" and cfg.num_patch_tokens:
        text_len = seq_len - cfg.num_patch_tokens
        out["patches"] = (
            rng.standard_normal((batch, cfg.num_patch_tokens, cfg.d_model))
            * 0.02
        ).astype(cdtype)
    if cfg.family == "encdec":
        assert cfg.encoder is not None
        out["frames"] = (
            rng.standard_normal((batch, cfg.encoder.source_len, cfg.d_model))
            * 0.02
        ).astype(cdtype)
    # Cap the sampled vocab so Zipf tables stay small at 152k-vocab configs.
    vocab = min(cfg.vocab_size, 32_768)
    out["tokens"] = _zipf_tokens(rng, (batch, text_len), vocab)
    out["loss_mask"] = np.ones((batch, text_len), np.float32)
    return out


def shard_batch(
    batch: Dict[str, np.ndarray],
    shardings: Optional[Dict[str, Any]] = None,
) -> Dict[str, jax.Array]:
    """Place a host batch onto devices with the step's input shardings."""
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {
        k: jax.device_put(v, shardings[k]) if k in shardings else jnp.asarray(v)
        for k, v in batch.items()
    }
