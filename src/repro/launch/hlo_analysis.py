"""Cost analysis of optimized (post-SPMD) HLO text with correct loop scaling.

``compiled.cost_analysis()`` counts every while-loop body ONCE — a
scan-over-layers transformer under-reports FLOPs by ~num_layers, and the
FSDP all-gathers inside the layer loop disappear from any naive grep of the
module text.  This analyzer walks the computation graph of
``compiled.as_text()`` and multiplies loop-body costs by the
``known_trip_count`` XLA records in each while's backend_config, giving:

  * ``flops``            — 2·M·N·K per dot (batch dims included), loop-scaled
  * ``collective_bytes`` — result bytes of all-reduce / all-gather /
                           reduce-scatter / all-to-all / collective-permute
                           (and their -start forms), loop-scaled; these are
                           PER-PARTITION shapes, i.e. bytes through one chip
  * ``hbm_bytes``        — Σ (operand + result bytes) over materializing ops
                           (fusions, dots, collectives, slices, copies…),
                           loop-scaled: a buffer-traffic model of HBM bytes

Branches of ``conditional`` are counted at the maximum across branches
(upper bound; noted in EXPERIMENTS.md for the one arch that uses lax.cond —
zamba2's every-6-layers shared attention).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Ops that materialize buffers for the HBM-traffic model.  Elementwise ops
# appear inside fusions (counted as one unit); these are the top-level
# buffer producers/consumers.
_MATERIALIZING = (
    "fusion", "dot", "convolution", "copy", "convert", "transpose",
    "dynamic-slice", "dynamic-update-slice", "slice", "concatenate",
    "broadcast", "reduce", "reduce-window", "scatter", "gather", "select",
    "sort", "reverse", "pad", "iota", "add", "multiply", "subtract",
    "divide", "maximum", "minimum", "exponential", "rsqrt", "tanh",
    "compare", "reduce-precision", "bitcast-convert",
) + _COLLECTIVES


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    collective_bytes: float = 0.0
    hbm_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __add__(self, other: "HloCost") -> "HloCost":
        bd = dict(self.collective_breakdown)
        for k, v in other.collective_breakdown.items():
            bd[k] = bd.get(k, 0.0) + v
        return HloCost(
            self.flops + other.flops,
            self.collective_bytes + other.collective_bytes,
            self.hbm_bytes + other.hbm_bytes,
            bd,
        )

    def scaled(self, n: float) -> "HloCost":
        return HloCost(
            self.flops * n,
            self.collective_bytes * n,
            self.hbm_bytes * n,
            {k: v * n for k, v in self.collective_breakdown.items()},
        )


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (arrays and (possibly nested) tuples)."""
    total = 0
    for m in re.finditer(r"(\w+)\[([0-9,]*)\]", type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x]
        total += math.prod(dims) * _DTYPE_BYTES[dt] if dims else _DTYPE_BYTES[dt]
    return total


def _array_dims(type_str: str) -> List[int]:
    m = re.search(r"\w+\[([0-9,]*)\]", type_str)
    if not m:
        return []
    return [int(x) for x in m.group(1).split(",") if x]


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\/\* ]+?))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$"
)
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->")


def _parse_computations(text: str) -> Tuple[Dict[str, List[_Instr]], str]:
    comps: Dict[str, List[_Instr]] = {}
    current: Optional[str] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if not line.startswith(" ") and ("{" in line) and ("->" in line):
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, operands_str, attrs = m.groups()
        operands = re.findall(r"%([\w\.\-]+)", operands_str)
        comps[current].append(_Instr(name, type_str.strip(), op, operands, attrs))
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    return comps, entry


def _trip_count(attrs: str) -> float:
    m = re.search(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)', attrs)
    return float(m.group(1)) if m else 1.0


def _called_computations(attrs: str) -> List[str]:
    out = []
    m = re.search(r"calls=%?([\w\.\-]+)", attrs)
    if m:
        out.append(m.group(1))
    m = re.search(r"to_apply=%?([\w\.\-]+)", attrs)
    if m:
        out.append(m.group(1))
    return out


def _fusion_write_bytes(instr: _Instr, comps: Dict[str, List[_Instr]]) -> float:
    """Bytes a fusion writes.  In-place dynamic-update-slice fusions (XLA
    aliases input and output) only write the update slice — resolve the
    update operand's type inside the fused computation."""
    result = float(_type_bytes(instr.type_str))
    called = _called_computations(instr.attrs)
    if not called:
        return result
    body = comps.get(called[0], [])
    dus = [i for i in body if i.op == "dynamic-update-slice"]
    if not dus:
        return result
    written = 0.0
    for d in dus:
        if len(d.operands) > 1:
            for instr2 in body:
                if instr2.name == d.operands[1]:
                    written += float(_type_bytes(instr2.type_str))
                    break
    return written if written > 0 else result


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    memo: Dict[str, HloCost] = {}

    def shape_of(comp: List[_Instr], name: str) -> Optional[List[int]]:
        for instr in comp:
            if instr.name == name:
                return _array_dims(instr.type_str)
        return None

    def cost_of(comp_name: str) -> HloCost:
        if comp_name in memo:
            return memo[comp_name]
        memo[comp_name] = HloCost()  # cycle guard
        comp = comps.get(comp_name)
        if comp is None:
            return memo[comp_name]
        total = HloCost()
        for instr in comp:
            op = instr.op
            if op == "dot":
                out_elems = math.prod(_array_dims(instr.type_str) or [1])
                lhs_dims = shape_of(comp, instr.operands[0]) or []
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
                k = 1
                if cdims and lhs_dims:
                    for i in cdims.group(1).split(","):
                        if i:
                            k *= lhs_dims[int(i)]
                flops = 2.0 * out_elems * k
                total = total + HloCost(flops=flops)
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                b = float(_type_bytes(instr.type_str))
                bd = {base: b}
                total = total + HloCost(collective_bytes=b, collective_breakdown=bd)
            if op in _MATERIALIZING:
                # Traffic model: every materialized buffer is written once and
                # read once downstream → 2 × bytes-written.  In-place update
                # ops only write the updated slice (XLA aliases the buffer).
                def _operand_bytes(idx: int) -> float:
                    if idx >= len(instr.operands):
                        return 0.0
                    for instr2 in comp:
                        if instr2.name == instr.operands[idx]:
                            return float(_type_bytes(instr2.type_str))
                    return 0.0

                if op == "dynamic-update-slice":
                    wb = _operand_bytes(1)
                elif op == "scatter":
                    wb = _operand_bytes(2)
                elif op == "fusion":
                    wb = _fusion_write_bytes(instr, comps)
                else:
                    wb = float(_type_bytes(instr.type_str))
                total = total + HloCost(hbm_bytes=2.0 * wb)
            if op == "while":
                body = re.search(r"body=%?([\w\.\-]+)", instr.attrs)
                cond = re.search(r"condition=%?([\w\.\-]+)", instr.attrs)
                n = _trip_count(instr.attrs)
                inner = HloCost()
                if body:
                    inner = inner + cost_of(body.group(1))
                if cond:
                    inner = inner + cost_of(cond.group(1))
                total = total + inner.scaled(n)
            elif op == "conditional":
                branches = re.search(
                    r"branch_computations=\{([^}]*)\}", instr.attrs
                )
                names: List[str] = []
                if branches:
                    names = re.findall(r"%?([\w\.\-]+)", branches.group(1))
                else:
                    names = [
                        m.group(1)
                        for m in re.finditer(
                            r"(?:true|false)_computation=%?([\w\.\-]+)", instr.attrs
                        )
                    ]
                if names:
                    best = None
                    for nm in names:
                        c = cost_of(nm)
                        if best is None or c.flops > best.flops:
                            best = c
                    total = total + (best or HloCost())
            else:
                for called in _called_computations(instr.attrs):
                    inner = cost_of(called)
                    # Ops inside a fusion/apply computation do not touch HBM
                    # individually — the call site's operands+result (already
                    # counted via _MATERIALIZING) are the real traffic.
                    total = total + HloCost(
                        flops=inner.flops,
                        collective_bytes=inner.collective_bytes,
                        hbm_bytes=0.0,
                        collective_breakdown=inner.collective_breakdown,
                    )
        memo[comp_name] = total
        return total

    return cost_of(entry)
