import os

if "XLA_FLAGS" not in os.environ:  # tool needs the production device count
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Ruya-for-TPU: memory-aware iterative search over execution configurations.

This is the paper's algorithm (``repro.core``) applied beyond its original
domain: the "cluster configuration" becomes a TPU *execution configuration*
(microbatch count × remat policy × FSDP on/off × activation-sequence
sharding), the "job" is one (architecture × shape cell) on the production
mesh, and a *trial* is an AOT compile whose roofline step-time estimate
(max of the compute/memory/collective terms from the loop-scaled HLO cost
analysis) is the cost.  On real hardware each trial is a short profiled run
at scale — expensive — which is exactly the economics the paper's
search-iteration reduction targets.

The mapping of the paper's phases:

  1. *Profiling on reduced hardware* → compile the SAME model at reduced
     global batches (cheap chip-seconds at scale) and read
     ``memory_analysis().peak``; fit the §III-C OLS memory model of
     peak-bytes vs tokens-per-device per remat policy.
  2. *Categorization* → activations make training cells LINEAR in
     tokens-per-device with a flat params+optimizer offset; decode cells
     come out FLAT.  Unclear readings fall back to plain BO (the paper's
     §III-D fallback).
  3. *Search-space split* → configurations whose predicted peak exceeds the
     16 GiB/chip HBM are deprioritized (memory-bottleneck analogue: on TPU
     the penalty is OOM-or-remat, a hard cliff).
  4. *CherryPick BO with EI* → identical engine, cost = roofline seconds.

Usage:
  PYTHONPATH=src python -m repro.launch.autotune --arch granite-8b \
      --cell train_4k [--budget 10] [--exhaustive]
"""

import argparse
import dataclasses
import json
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

HBM_PER_CHIP = 16 * 2**30  # v5e
PEAKS = {"flops": 197e12, "hbm": 819e9, "ici": 50e9}


@dataclasses.dataclass(frozen=True)
class ExecVariant:
    """One point of the TPU execution-configuration search space."""

    num_microbatches: int
    remat: str  # none | dots | full
    fsdp: bool
    seq_shard: bool  # Megatron-style sequence parallelism on activations

    @property
    def name(self) -> str:
        return (f"micro{self.num_microbatches}-{self.remat}"
                f"{'-fsdp' if self.fsdp else ''}"
                f"{'-seqshard' if self.seq_shard else ''}")

    def features(self) -> Tuple[float, ...]:
        # CherryPick encodes configs "by their principal features".
        return (
            math.log2(self.num_microbatches),
            {"none": 0.0, "dots": 1.0, "full": 2.0}[self.remat],
            1.0 if self.fsdp else 0.0,
            1.0 if self.seq_shard else 0.0,
        )


def variant_space(cell_kind: str) -> List[ExecVariant]:
    if cell_kind != "train":
        # serving has no microbatch/remat axis; sweep sharding choices only
        return [
            ExecVariant(1, "none", fsdp, seq)
            for fsdp in (False, True)
            for seq in (False, True)
        ]
    out = []
    for micro in (1, 2, 4, 8, 16):
        for remat in ("none", "dots", "full"):
            for fsdp in (True, False):
                for seq in (False, True):
                    out.append(ExecVariant(micro, remat, fsdp, seq))
    return out


class TpuTunerEnv:
    """Profiling + trial execution against the AOT dry-run machinery."""

    def __init__(self, arch: str, cell_name: str, multi_pod: bool = False,
                 cache_path: Optional[str] = None) -> None:
        import repro.configs as C
        from repro.launch.mesh import make_production_mesh

        self.C = C
        self.arch = arch
        self.spec = C.get(arch)
        self.cell = C.CELLS[cell_name]
        self.mesh = make_production_mesh(multi_pod=multi_pod)
        self.chips = self.mesh.size
        self.trial_cache: Dict[str, Dict] = {}
        self.cache_path = cache_path
        if cache_path and os.path.exists(cache_path):
            with open(cache_path) as f:
                self.trial_cache = json.load(f)

    # -- shared plumbing -----------------------------------------------------

    def _built(self, variant: ExecVariant, cell=None):
        from repro.launch.build import build_cell, rules_for

        spec = dataclasses.replace(
            self.spec, model=self.spec.model.replace(remat_policy=variant.remat)
        )
        ex = spec.exec.replace(
            num_microbatches=variant.num_microbatches,
            remat=variant.remat,
            fsdp=variant.fsdp,
            seq_shard=variant.seq_shard,  # overrides the arch default
        )
        cell = cell or self.cell
        rules = rules_for(dataclasses.replace(spec, exec=ex), cell, self.mesh)
        return build_cell(spec, cell, self.mesh, rules=rules, exec_override=ex)

    def _compile_peak_and_cost(self, variant: ExecVariant, cell=None):
        from repro.launch.hlo_analysis import analyze_hlo

        built = self._built(variant, cell)
        compiled = built.lower(self.mesh).compile()
        ma = compiled.memory_analysis()
        peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
        cost = analyze_hlo(compiled.as_text())
        return peak, cost

    # -- phase 1: profiling runs ----------------------------------------------

    def profile_run_fn(self, variant: ExecVariant):
        """(tokens-per-device) -> (chip_seconds_cost, peak_bytes).

        The Ruya profiler drives this with small sample sizes — here small
        global batches of the full model, the analogue of dataset samples on
        one machine."""

        def run(tokens_per_device: float) -> Tuple[float, float]:
            total = int(tokens_per_device) * self.chips
            seq = min(self.cell.seq_len, max(256, total))
            gb = max(1, total // seq)
            cell = self.C.ShapeCell("profile", seq, gb, self.cell.kind)
            peak, cost = self._compile_peak_and_cost(variant, cell)
            est_seconds = max(cost.flops / PEAKS["flops"],
                              cost.hbm_bytes / PEAKS["hbm"],
                              cost.collective_bytes / PEAKS["ici"])
            return est_seconds * self.chips, float(peak)

        return run

    # -- phase 4: one search trial ---------------------------------------------

    def trial_cost_fn(self, space: List[ExecVariant]):
        def cost(idx: int) -> float:
            v = space[idx]
            if v.name not in self.trial_cache:
                try:
                    peak, c = self._compile_peak_and_cost(v)
                    step_s = max(c.flops / PEAKS["flops"],
                                 c.hbm_bytes / PEAKS["hbm"],
                                 c.collective_bytes / PEAKS["ici"])
                    # memory-bottleneck cliff: configs over HBM pay the
                    # remat/offload penalty (or are simply infeasible)
                    over = max(peak / HBM_PER_CHIP, 1.0)
                    penalty = 1.0 if over <= 1.0 else (2.0 + 4.0 * (over - 1.0))
                    self.trial_cache[v.name] = {
                        "peak_bytes": float(peak),
                        "roofline_s": float(step_s),
                        "cost_chip_s": float(step_s * penalty),
                        "terms": {
                            "compute": c.flops / PEAKS["flops"],
                            "memory": c.hbm_bytes / PEAKS["hbm"],
                            "collective": c.collective_bytes / PEAKS["ici"],
                        },
                    }
                except Exception as e:  # infeasible config = huge cost
                    self.trial_cache[v.name] = {
                        "error": str(e)[:200], "cost_chip_s": 1e9,
                    }
                if self.cache_path:
                    with open(self.cache_path, "w") as f:
                        json.dump(self.trial_cache, f, indent=1)
            return self.trial_cache[v.name]["cost_chip_s"]

        return cost

    def search_space(self):
        from repro.core.search_space import Configuration, SearchSpace

        space = variant_space(self.cell.kind)
        # "total memory" of a config = HBM it leaves for the job: constant
        # per chip — what varies is the REQUIREMENT, predicted per config by
        # the memory model.  We encode available memory so the §III-D split
        # can compare requirement vs availability per config.
        configs = [
            Configuration(
                name=v.name,
                features=v.features(),
                total_memory=float(HBM_PER_CHIP),
                num_nodes=self.chips,
                meta=v,
            )
            for v in space
        ]
        return space, SearchSpace(configs)


def predict_peaks(env: TpuTunerEnv, space: List[ExecVariant]):
    """Paper phases 1–2 for every (remat, fsdp, seq) combination: profile
    peak-vs-tokens at reduced batches, extrapolate to the full cell.

    Returns {variant.name: predicted_peak_bytes} and the fitted models."""
    from repro.core.memory_model import fit_memory_model

    cell = env.cell
    full_tokens_per_dev = cell.tokens / env.chips
    preds: Dict[str, float] = {}
    models = {}
    # Group variants: microbatching divides tokens-per-device per microbatch.
    base_keys = sorted({(v.remat, v.fsdp, v.seq_shard) for v in space})
    for remat, fsdp, seq in base_keys:
        probe = ExecVariant(1, remat, fsdp, seq)
        run = env.profile_run_fn(probe)
        fractions = (0.125, 0.25, 0.5)
        sizes, readings = [], []
        for frac in fractions:
            tpd = full_tokens_per_dev * frac
            _, peak = run(tpd)
            sizes.append(tpd)
            readings.append(peak)
        model = fit_memory_model(sizes, readings)
        models[(remat, fsdp, seq)] = model
        for v in space:
            if (v.remat, v.fsdp, v.seq_shard) != (remat, fsdp, seq):
                continue
            tpd = full_tokens_per_dev / v.num_microbatches
            if model.category.value == "linear":
                preds[v.name] = model.estimate(tpd)
            elif model.category.value == "flat":
                preds[v.name] = float(np.mean(readings))
            else:
                preds[v.name] = float("nan")
    return preds, models


def run_autotune(arch: str, cell: str, *, budget: int = 12,
                 multi_pod: bool = False, seed: int = 0,
                 cache_path: Optional[str] = None,
                 exhaustive: bool = False) -> Dict:
    from repro.core.bayesopt import BOSettings, ruya_search
    from repro.core.search_space import split_search_space
    from repro.core.memory_model import MemoryCategory, MemoryModel

    env = TpuTunerEnv(arch, cell, multi_pod=multi_pod, cache_path=cache_path)
    space, sspace = env.search_space()

    print(f"[autotune] {arch} × {cell}: {len(space)} configurations")
    preds, models = predict_peaks(env, space)

    # §III-D split: prioritize configs predicted to fit the per-chip HBM.
    prio, rest = [], []
    any_unclear = any(math.isnan(p) for p in preds.values())
    if any_unclear:
        prio = list(range(len(space)))  # fallback: plain BO
    else:
        for i, v in enumerate(space):
            (prio if preds[v.name] <= HBM_PER_CHIP * 1.05 else rest).append(i)
        if not prio:  # nothing fits → prioritize minimal-requirement extremes
            order = np.argsort([preds[v.name] for v in space])
            k = max(1, len(space) // 7)
            prio = sorted(int(i) for i in order[:k])
            rest = sorted(set(range(len(space))) - set(prio))
    print(f"[autotune] priority group: {len(prio)}/{len(space)} configs "
          f"predicted to fit {HBM_PER_CHIP/2**30:.0f} GiB/chip")

    cost_fn = env.trial_cost_fn(space)
    settings = BOSettings(max_iters=None if exhaustive else budget,
                          min_observations=min(6, len(prio)))
    trace = ruya_search(
        sspace, cost_fn, np.random.default_rng(seed), prio, rest,
        settings=settings, to_exhaustion=exhaustive,
    )
    best = space[trace.best_index]
    result = {
        "arch": arch,
        "cell": cell,
        "trials": len(trace.tried),
        "best": best.name,
        "best_cost_chip_s": trace.best_cost,
        "tried": [space[i].name for i in trace.tried],
        "costs": trace.costs,
        "priority_size": len(prio),
        "predicted_peaks_gib": {k: v / 2**30 for k, v in preds.items()},
        "trial_details": {space[i].name: env.trial_cache.get(space[i].name)
                          for i in trace.tried},
    }
    print(f"[autotune] best: {best.name} "
          f"(roofline {trace.best_cost:.2f} chip-s/step) "
          f"after {len(trace.tried)} trials")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", default="train_4k")
    ap.add_argument("--budget", type=int, default=12)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache", default=None)
    ap.add_argument("--exhaustive", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = run_autotune(
        args.arch, args.cell, budget=args.budget, multi_pod=args.multi_pod,
        seed=args.seed, cache_path=args.cache, exhaustive=args.exhaustive,
    )
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
