"""Serving driver: ``python -m repro.launch.serve --arch <id> --smoke``.

Prefill + batched greedy decode over synthetic request batches, reporting
prefill latency and decode throughput.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import repro.configs as C
    from repro.data import make_batch
    from repro.models import Model, init_tree
    from repro.models.spec import is_spec
    from repro.runtime.decode_loop import ServeLoop
    from repro.runtime.steps import make_serve_steps

    spec = C.smoke(args.arch) if args.smoke else C.get(args.arch)
    cfg = spec.model
    model = Model(cfg)
    params = init_tree(jax.random.key(args.seed), model.param_specs())
    prefill, decode = make_serve_steps(model)

    def init_cache():
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            model.cache_specs(args.batch, args.max_len),
            is_leaf=is_spec,
        )

    loop = ServeLoop(
        prefill_step=jax.jit(prefill),
        decode_step=jax.jit(decode, donate_argnums=(1,)),
        params=params,
        init_cache=init_cache,
        eos_id=-1,
    )
    seq = args.prompt_len
    if cfg.family == "vlm":
        seq += cfg.num_patch_tokens
    req = make_batch(cfg, args.batch, seq, seed=args.seed)
    batch = {k: jnp.asarray(v) for k, v in req.items() if k != "loss_mask"}
    out = loop.generate(batch, args.max_new_tokens, echo_metrics=True)
    m = out["metrics"]
    print(f"[serve] batch={args.batch} prompt={args.prompt_len} "
          f"new={m['decoded']} prefill={m['prefill_s']*1e3:.1f}ms "
          f"decode={m['decode_s']*1e3:.1f}ms "
          f"({m['tokens_per_s']:.0f} tok/s)")
    print("[tokens]", out["tokens"][0][:16].tolist())


if __name__ == "__main__":
    main()
