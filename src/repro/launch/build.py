"""Assemble (step_fn, abstract inputs, shardings) for any (arch × cell × mesh).

This is the single place where model specs, shape cells, sharding rules and
step factories meet; the dry-run, the roofline benchmark, the tuner and the
real train/serve drivers all call ``build_cell``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs import ArchSpec, ShapeCell, input_specs
from repro.configs.base import ExecConfig
from repro.models.model import Model
from repro.models.spec import abstract_tree
from repro.parallel.constraints import activation_sharding
from repro.parallel.sharding import ShardingRules, default_rules, named_sharding_tree
from repro.launch.mesh import data_axes, mesh_context, model_axis
from repro.runtime.steps import make_serve_steps, make_train_step, train_state_specs

__all__ = ["BuiltCell", "build_cell", "rules_for"]


@dataclasses.dataclass
class BuiltCell:
    """Everything needed to lower/compile/run one (arch × cell × mesh)."""

    step_fn: Callable
    abstract_args: Tuple[Any, ...]  # ShapeDtypeStruct pytrees, step_fn(*args)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    kind: str

    def lower(self, mesh: Mesh):
        jitted = jax.jit(
            self.step_fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        with mesh_context(mesh):
            return jitted.lower(*self.abstract_args)


def rules_for(
    spec: ArchSpec, cell: ShapeCell, mesh: Mesh, *, overrides: Optional[Dict] = None
) -> ShardingRules:
    """Default rules for a cell: FSDP per exec config; long-context decode
    (batch smaller than the data axes) shards the KV-cache length instead."""
    da = data_axes(mesh)
    rules = default_rules(
        data_axes=da,
        model_axis=model_axis(mesh) or "model",
        fsdp=spec.exec.fsdp,
    )
    if spec.exec.seq_shard:
        rules = rules.override(seq=model_axis(mesh) or "model")
    if spec.model.family == "hybrid":
        # The shared-attention site caches ride the layer scan's carry; a
        # model-axis-sharded carry makes GSPMD reshard it every iteration
        # (measured: zamba2 long_500k collectives 0.002→22.9 s).  Keep the
        # hybrid cache on the data axes only.
        rules = rules.override(cache_seq=da if len(da) > 1 else da[0])
    if overrides:
        rules = rules.override(**overrides)
    return rules


def _batch_pspec_tree(batch_specs: Dict[str, Any], rules: ShardingRules, mesh: Mesh):
    """Activation inputs shard on the batch dim only."""
    batch_axes = rules.get("batch")

    def pspec(leaf: jax.ShapeDtypeStruct) -> PartitionSpec:
        entry = batch_axes
        if entry is None:
            return PartitionSpec()
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        kept = []
        for a in axes:
            asize = int(mesh.shape[a])
            if leaf.shape and leaf.shape[0] % (size * asize) == 0:
                kept.append(a)
                size *= asize
            else:
                break
        if not kept:
            return PartitionSpec()
        first = kept[0] if len(kept) == 1 else tuple(kept)
        return PartitionSpec(*([first] + [None] * (len(leaf.shape) - 1)))

    return jax.tree.map(
        lambda l: NamedSharding(mesh, pspec(l)), batch_specs
    )


def build_cell(
    spec: ArchSpec,
    cell: ShapeCell,
    mesh: Mesh,
    *,
    rules: Optional[ShardingRules] = None,
    exec_override: Optional[ExecConfig] = None,
) -> BuiltCell:
    exec_cfg = exec_override or spec.exec
    cfg = spec.model
    model = Model(cfg)
    rules = rules or rules_for(spec, cell, mesh)
    replicated = NamedSharding(mesh, PartitionSpec())
    specs = input_specs(cfg, cell)

    def constrained(fn):
        """Trace the step under the activation-sharding context."""

        def wrapped(*args):
            with activation_sharding(rules, mesh):
                return fn(*args)

        return wrapped

    if cell.kind == "train":
        step = make_train_step(model, exec_cfg)
        state_specs = train_state_specs(model, exec_cfg)
        state_sh = named_sharding_tree(state_specs, rules, mesh)
        batch_sh = _batch_pspec_tree(specs["batch"], rules, mesh)
        abstract_state = abstract_tree(state_specs)
        return BuiltCell(
            step_fn=constrained(step),
            abstract_args=(abstract_state, specs["batch"]),
            in_shardings=(state_sh, batch_sh),
            # state keeps its shardings; metrics are replicated scalars
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
            kind="train",
        )

    prefill_step, decode_step = make_serve_steps(model)
    param_specs = model.param_specs()
    params_sh = named_sharding_tree(param_specs, rules, mesh)
    abstract_params = abstract_tree(param_specs)
    cache_specs = model.cache_specs(cell.global_batch, cell.seq_len)
    cache_sh = named_sharding_tree(cache_specs, rules, mesh)

    if cell.kind == "prefill":
        batch_sh = _batch_pspec_tree(specs["batch"], rules, mesh)
        return BuiltCell(
            step_fn=constrained(prefill_step),
            abstract_args=(abstract_params, specs["batch"], specs["cache"]),
            in_shardings=(params_sh, batch_sh, cache_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,),
            kind="prefill",
        )

    # decode
    tokens_sh = _batch_pspec_tree({"tokens": specs["tokens"]}, rules, mesh)["tokens"]
    return BuiltCell(
        step_fn=constrained(decode_step),
        abstract_args=(abstract_params, specs["cache"], specs["tokens"],
                       specs["index"]),
        in_shardings=(params_sh, cache_sh, tokens_sh, replicated),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
        kind="decode",
    )
