"""Launch layer: production meshes, AOT dry-run, train/serve drivers, autotuner."""
