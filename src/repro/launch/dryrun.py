import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod AOT dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step).lower(**abstract inputs).compile()`` must succeed on the
single-pod (16, 16) mesh AND the 2-pod (2, 16, 16) mesh for every assigned
architecture × input-shape cell.  For each cell the compiled artifact's
``memory_analysis()`` (bytes per device), ``cost_analysis()`` and the
loop-scaled HLO cost terms (FLOPs, collective bytes, HBM traffic — see
``hlo_analysis``) are written to a JSON artifact that EXPERIMENTS.md
§Dry-run / §Roofline and the perf loop read.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # full sweep
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --cell train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi_pod --skip-existing

Each cell runs in a subprocess so one failure cannot take down the sweep;
failures are recorded in the artifact with the exception text.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback


def run_cell(arch: str, cell_name: str, mesh_kind: str) -> dict:
    """Lower + compile one cell in-process; returns the artifact dict."""
    import jax

    import repro.configs as C
    from repro.launch.build import build_cell
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import active_params, total_params

    spec = C.get(arch)
    cell = C.CELLS[cell_name]
    ok, reason = C.cell_applicable(spec.model, cell)
    if not ok:
        return {"status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi_pod"))
    chips = mesh.size

    t0 = time.time()
    built = build_cell(spec, cell, mesh)
    lowered = built.lower(mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    peak = (
        ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        - ma.alias_size_in_bytes
        + ma.temp_size_in_bytes
    )
    ca = compiled.cost_analysis() or {}
    cost = analyze_hlo(compiled.as_text())

    art = {
        "status": "ok",
        "arch": arch,
        "cell": cell_name,
        "mesh": mesh_kind,
        "chips": chips,
        "kind": built.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes_per_device": int(peak),
            "fits_16g": bool(peak <= 16 * 2**30),
        },
        "xla_cost_analysis": {
            "flops_scan_body_once": float(ca.get("flops", -1.0)),
            "bytes_accessed_scan_body_once": float(ca.get("bytes accessed", -1.0)),
        },
        "hlo_cost": {
            "flops_per_device": cost.flops,
            "collective_bytes_per_device": cost.collective_bytes,
            "hbm_bytes_per_device": cost.hbm_bytes,
            "collective_breakdown": cost.collective_breakdown,
        },
        "model": {
            "total_params": total_params(spec.model),
            "active_params": active_params(spec.model),
            "tokens": cell.tokens if built.kind == "train" else cell.global_batch,
        },
    }
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single_pod", "multi_pod"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--single", action="store_true",
                    help="run one cell in-process and print JSON (internal)")
    args = ap.parse_args()

    if args.single:
        try:
            art = run_cell(args.arch, args.cell, args.mesh)
        except Exception:
            art = {"status": "failed", "error": traceback.format_exc()[-2000:]}
        print("JSON_ARTIFACT:" + json.dumps(art))
        return

    import repro.configs as C

    archs = [args.arch] if args.arch else C.ARCHS
    cells = [args.cell] if args.cell else list(C.CELLS)
    meshes = [args.mesh] if args.mesh else ["single_pod", "multi_pod"]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch in archs:
        for cell in cells:
            for mesh in meshes:
                path = os.path.join(args.out, f"{arch}__{cell}__{mesh}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {path}")
                    continue
                t0 = time.time()
                proc = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun", "--single",
                     "--arch", arch, "--cell", cell, "--mesh", mesh],
                    capture_output=True, text=True,
                    env={**os.environ, "PYTHONPATH": "src"},
                )
                art = None
                for line in proc.stdout.splitlines():
                    if line.startswith("JSON_ARTIFACT:"):
                        art = json.loads(line[len("JSON_ARTIFACT:"):])
                if art is None:
                    art = {"status": "failed",
                           "error": (proc.stderr or proc.stdout)[-2000:]}
                art.setdefault("arch", arch)
                art.setdefault("cell", cell)
                art.setdefault("mesh", mesh)
                with open(path, "w") as f:
                    json.dump(art, f, indent=1)
                status = art["status"]
                extra = ""
                if status == "ok":
                    gib = art["memory"]["peak_bytes_per_device"] / 2**30
                    extra = f" peak={gib:.2f}GiB compile={art['compile_s']}s"
                elif status == "skipped":
                    extra = f" ({art['reason'][:50]})"
                else:
                    failures.append((arch, cell, mesh))
                print(f"[{status}] {arch} × {cell} × {mesh}"
                      f" ({time.time()-t0:.0f}s){extra}", flush=True)

    if failures:
        print(f"\nFAILED cells ({len(failures)}):")
        for f_ in failures:
            print("  ", *f_)
        sys.exit(1)
    print("\nAll dry-run cells passed.")


if __name__ == "__main__":
    main()
