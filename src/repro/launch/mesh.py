"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* first jax
init, and smoke tests must keep seeing one CPU device.

Single pod:  (16, 16)        axes ("data", "model")      — 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") — 512 chips

The "pod" axis composes with "data" for gradient reduction (batch is
sharded over ("pod", "data")); "model" carries tensor/expert parallelism
inside a pod, where ICI is fastest.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "mesh_context"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(
    shape: Tuple[int, ...], axes: Tuple[str, ...]
) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests / the tuner's candidate configurations."""
    return jax.make_mesh(shape, axes)


def mesh_context(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    `jax.set_mesh` only exists from jax 0.6; on the pinned 0.4.37 the
    `Mesh` object itself is the context manager.  Lowering under the
    ambient mesh is what lets partially-manual `shard_map`s (auto axes)
    resolve their automatic dimensions.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The batch-parallel axes of a mesh ("pod" composes with "data")."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names) or (names[0],)


def model_axis(mesh: jax.sharding.Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None
