"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

Runs the fault-tolerant training loop (checkpoint/restart, preemption
handling, straggler monitor) for any registered architecture.  On this CPU
container use ``--smoke`` (reduced config); on a TPU pod the same driver
runs the full config across the production mesh by passing ``--mesh``.
"""

from __future__ import annotations

import argparse

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--learning-rate", type=float, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "single_pod", "multi_pod"],
                    default="none")
    args = ap.parse_args()

    import repro.configs as C
    from repro.checkpoint import CheckpointManager
    from repro.data import SyntheticDataset, shard_batch
    from repro.models import Model
    from repro.runtime.loop import PreemptionGuard, TrainLoop
    from repro.runtime.steps import init_train_state, make_train_step

    spec = C.smoke(args.arch) if args.smoke else C.get(args.arch)
    ex = spec.exec
    if args.learning_rate is not None:
        ex = ex.replace(learning_rate=args.learning_rate)
    if args.microbatches is not None:
        ex = ex.replace(num_microbatches=args.microbatches)
    ex = ex.replace(total_steps=max(args.steps, 1))

    model = Model(spec.model)
    state = init_train_state(model, ex, jax.random.key(args.seed))

    if args.mesh != "none":
        from repro.configs.shapes import ShapeCell
        from repro.launch.build import build_cell
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=(args.mesh == "multi_pod"))
        cell = ShapeCell("cli", args.seq_len, args.global_batch, "train")
        built = build_cell(spec, cell, mesh, exec_override=ex)
        step_fn = jax.jit(built.step_fn, in_shardings=built.in_shardings,
                          out_shardings=built.out_shardings,
                          donate_argnums=built.donate_argnums)
        state = jax.device_put(state, built.in_shardings[0])
    else:
        step_fn = jax.jit(make_train_step(model, ex), donate_argnums=(0,))

    ds = SyntheticDataset(spec.model, args.global_batch, args.seq_len,
                          seed=args.seed)
    loop = TrainLoop(
        train_step=step_fn,
        batch_at=ds.batch_at,
        place_batch=shard_batch,
        state=state,
        checkpoints=CheckpointManager(args.ckpt_dir, keep_n=3),
        checkpoint_every=args.ckpt_every,
        log_every=args.log_every,
        guard=PreemptionGuard(install=True),
    )
    loop.maybe_restore()
    result = loop.run(args.steps)
    print(f"[done] exit={result['exit']} final_step={result['final_step']} "
          f"stragglers={len(result['stragglers'])}")


if __name__ == "__main__":
    main()
