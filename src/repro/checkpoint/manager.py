"""Sharded checkpoints with atomic commits and elastic restore.

Layout (one directory per step, committed atomically by rename):

    <root>/step_00000100.tmp/        # written here ...
    <root>/step_00000100/            # ... then renamed (atomic on POSIX)
        manifest.json                # treedef paths, shapes, dtypes, step
        <leaf-path>.npy              # one array per leaf (np.save, mmap-able)

Design notes for the 1000-node target:
  * Arrays are stored as *logical* (global) arrays keyed by tree path, not
    by device — a checkpoint written on a (16,16) mesh restores onto a
    (2,16,16) mesh or a different chip count unchanged: the loader simply
    ``device_put``s each leaf with the *target* sharding ("elastic
    restore").  On a real multi-host pod each host would write its owned
    shards (process-local addressable data) with the same manifest format.
  * ``save_async`` snapshots to host memory synchronously (cheap) and does
    file I/O on a background thread — the train loop never blocks on disk.
  * ``keep_n`` bounds disk usage; the newest N step dirs survive.
  * bfloat16 round-trips via a raw-bytes view (npy has no bf16 dtype).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(entry: Any) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def _leaf_filename(key: str) -> str:
    return key.replace("/", ".") + ".npy"


def save_pytree(directory: str, tree: Any, *, extra: Optional[Dict] = None) -> None:
    """Write a pytree of arrays into ``directory`` (must not exist)."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    entries = {}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = _leaf_filename(key)
        dtype = str(arr.dtype)
        if arr.dtype == np.dtype("bfloat16"):
            # npy can't store bf16: persist a uint16 view + logical dtype.
            np.save(os.path.join(tmp, fname), arr.view(np.uint16))
        else:
            np.save(os.path.join(tmp, fname), arr)
        entries[key] = {"file": fname, "dtype": dtype, "shape": list(arr.shape)}
    manifest = {"entries": entries, "extra": extra or {}}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)  # atomic commit


def load_pytree(
    directory: str,
    target_tree: Any,
    *,
    shardings: Any = None,
) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional matching pytree of NamedSharding — each leaf is
    placed with its *target* sharding, which is what makes restore elastic
    across mesh shapes / device counts.
    """
    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    entries = manifest["entries"]

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (path, ref), sh in zip(flat, shard_leaves):
        key = "/".join(_path_str(p) for p in path)
        if key not in entries:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        meta = entries[key]
        raw = np.load(os.path.join(directory, meta["file"]))
        if meta["dtype"] == "bfloat16":
            raw = raw.view(np.dtype("bfloat16"))
        if tuple(raw.shape) != tuple(np.shape(ref)):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {raw.shape} != target "
                f"{np.shape(ref)}"
            )
        leaves.append(jax.device_put(raw, sh) if sh is not None else raw)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves]), manifest[
        "extra"
    ]


@dataclasses.dataclass
class CheckpointManager:
    """Step-indexed checkpoints with keep-N retention and async writes."""

    root: str
    keep_n: int = 3

    def __post_init__(self) -> None:
        os.makedirs(self.root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- paths ---------------------------------------------------------------

    def step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[len("step_"):]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree: Any, *, extra: Optional[Dict] = None) -> None:
        save_pytree(self.step_dir(step), tree, extra=(extra or {}) | {"step": step})
        self._gc()

    def save_async(self, step: int, tree: Any, *, extra: Optional[Dict] = None) -> None:
        """Snapshot to host now; write on a background thread."""
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _work():
            try:
                self.save(step, host_tree, extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore -----------------------------------------------------------------

    def restore(
        self, target_tree: Any, *, step: Optional[int] = None, shardings: Any = None
    ) -> Tuple[Any, Dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return load_pytree(self.step_dir(step), target_tree, shardings=shardings)

    # -- retention -----------------------------------------------------------------

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(len(steps) - self.keep_n, 0)]:
            shutil.rmtree(self.step_dir(s), ignore_errors=True)
