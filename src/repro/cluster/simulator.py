"""Deterministic cost-surface and profiling emulation (paper §IV).

`job_cost_table` produces, for one job, the execution cost (USD) of every
cluster configuration — the quantity CherryPick/Ruya observe one trial at a
time.  The model follows the paper's Background section:

  runtime_h = [ serial
              + cpu_hours   · ref_cores / total_cores        (data-parallel)
              + io_hours    · ref_nodes / nodes ]             (disk/shuffle)
              · (1 + coord·(nodes-1))                         (coordination)
              · spill(config)                                 (memory cliff)
              · exp(σ · z_{job,config})                       (cloud variance)
  cost$     = runtime_h · price_per_hour(config)

`spill` is 1.0 when the job's (full-dataset) memory requirement fits into the
usable cluster memory and jumps to `spill_base + spill_slope·missing_frac`
when it does not — the drastic, discontinuous slowdown of Fig. 1.

The per-(job, config) variance term is *deterministic* (hashed seed): the
paper evaluates against one fixed dataset of recorded runs, and repeats only
randomize the BO initialization, not the costs.

`make_profile_run_fn` emulates the single-laptop profiling runs of §III-B:
runtime proportional to the sample size (calibrated to land Table III), and
peak-memory readings whose noise level drives the job into its ground-truth
linear/flat/unclear category.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.cluster.faults import FaultPlan
from repro.cluster.nodes import (
    ClusterConfig,
    enumerate_cluster_configs,
    make_cluster_search_space,
)
from repro.cluster.pricing import PriceCatalog
from repro.cluster.workloads import JOBS, JobSpec, _scenario_catalog
from repro.core.search_space import SearchSpace

__all__ = [
    "REF_CORES",
    "REF_NODES",
    "USABLE_MEM_FRACTION",
    "PER_NODE_OVERHEAD_GB",
    "ClusterSimulator",
    "job_cost_table",
    "job_runtime_table",
    "make_profile_run_fn",
]

REF_CORES = 32  # reference parallelism for cpu_hours
REF_NODES = 8  # reference node count for io_hours
# Table I requirements are JOB memory; the framework/OS resident set is
# modeled separately as a flat per-node overhead, so the memory a job can
# actually use is  total · USABLE_MEM_FRACTION − overhead · nodes  (clamped
# at 0: a grid of nodes smaller than the overhead has NO usable memory —
# it must not wrap around into a saturated spill via the missing-fraction
# clamp).
USABLE_MEM_FRACTION = 1.0  # job-usable fraction of instance memory
PER_NODE_OVERHEAD_GB = 0.5  # framework+OS resident memory per node


def _hash_unit_normal(*parts: str) -> float:
    """Deterministic ~N(0,1) from a string key (Box–Muller over a hash)."""
    h = hashlib.sha256("/".join(parts).encode()).digest()
    u1 = (int.from_bytes(h[:8], "big") + 1) / (2**64 + 2)
    u2 = (int.from_bytes(h[8:16], "big") + 1) / (2**64 + 2)
    return float(np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2))


def _spill_factor(job: JobSpec, cfg: ClusterConfig) -> float:
    if job.spill_slope == 0.0 and job.spill_base <= 1.0:
        return 1.0
    # Usable = job-visible memory after the per-node framework/OS slice,
    # clamped at 0: on the committed c4/m4/r4 grid the smallest node
    # (3.75 GB) comfortably clears the 0.5 GB overhead, but the clamp is
    # the model's guarantee — a hypothetical grid of overhead-dominated
    # nodes spills at the full missing fraction instead of feeding a
    # negative "usable" into the ratio below.
    usable = max(
        cfg.total_memory_gb * USABLE_MEM_FRACTION
        - PER_NODE_OVERHEAD_GB * cfg.scale_out,
        0.0,
    )
    required = job.mem_requirement_gb
    if usable >= required:
        return 1.0
    missing = min(1.0, (required - usable) / required)
    return job.spill_base + job.spill_slope * missing


def runtime_hours(job: JobSpec, cfg: ClusterConfig) -> float:
    base = (
        job.serial_hours
        + job.cpu_hours * REF_CORES / cfg.total_cores
        + job.io_hours * REF_NODES / cfg.scale_out
    )
    coord = 1.0 + job.coord_per_node * (cfg.scale_out - 1)
    rug = np.exp(job.rugged_sigma * _hash_unit_normal(job.key, cfg.name))
    return base * coord * _spill_factor(job, cfg) * rug


def job_runtime_table(
    job: JobSpec, catalog: Optional[PriceCatalog] = None
) -> np.ndarray:
    """(69,) hours per configuration.  ``catalog`` applies its arch's
    runtime offset (`PriceCatalog.perf_factor`); None is the x86 baseline."""
    configs = enumerate_cluster_configs()
    rt = np.asarray([runtime_hours(job, c) for c in configs], np.float64)
    if catalog is not None and catalog.perf_factor != 1.0:
        rt = rt * catalog.perf_factor
    return rt


def job_cost_table(
    job: JobSpec, catalog: Optional[PriceCatalog] = None, epoch: int = 0
) -> np.ndarray:
    """(69,) USD execution cost per configuration, deterministic.

    With ``catalog=None`` (default) this is the legacy book — the
    committed x86 on-demand prices, bit-identical to every pinned trace.
    A catalog reprices the same configurations (runtime×price under its
    book at ``epoch``); the identity catalog (`pricing.on_demand()`)
    reproduces the legacy values bit-for-bit.
    """
    configs = enumerate_cluster_configs()
    if catalog is None:
        return np.asarray(
            [runtime_hours(job, c) * c.price_per_hour for c in configs],
            np.float64,
        )
    return job_runtime_table(job, catalog) * catalog.price_table(
        configs, epoch=epoch
    )


def make_profile_run_fn(job: JobSpec) -> Callable[[float], Tuple[float, float]]:
    """Single-machine profiling emulator: sample_gb -> (runtime_s, peak_gb).

    Runtime is linear in the sample size, scaled so the full §III-B driver
    (one calibration run + five sweep runs on {0.2..1.0}·sample) lands near
    the job's Table III profiling time.  Memory readings follow the job's
    ground-truth slope with category-appropriate noise: near-exact for linear
    jobs, input-independent for flat jobs, and GC-sawtooth-corrupted for the
    regression jobs the paper found unclear.
    """
    # total ≈ 4 × r_cal (see profiler.py); r_cal is the 1 %-sample runtime.
    # Clamp the calibration runtime into the paper's [30 s, 300 s] corridor so
    # the driver neither grows the sample nor cancels runs.
    first_sample_gb = 0.01 * job.input_gb
    r_cal = min(max(job.profile_time_s / 4.0, 31.0), 280.0)
    runtime_per_gb = r_cal / first_sample_gb

    def run(sample_gb: float) -> Tuple[float, float]:
        runtime_s = sample_gb * runtime_per_gb
        if job.category == "flat":
            # One-pass / disk-based jobs allocate fixed-size buffer pools;
            # the observed peak is the framework floor, quantized to JVM
            # heap-region granularity (128 MiB) — near-identical across
            # sample sizes, which is exactly why the paper's R² lands < 0.1.
            noise = 1.0 + job.profile_noise * 0.1 * _hash_unit_normal(
                job.key, "prof", f"{sample_gb:.6e}"
            )
            quantum = 0.125
            peak = round(job.base_mem_gb * noise / quantum) * quantum
        else:
            z = _hash_unit_normal(job.key, "prof", f"{sample_gb:.6e}")
            # GC sawtooth: multiplicative noise on the in-memory footprint.
            peak = job.mem_slope * sample_gb * (1.0 + job.profile_noise * z)
        return runtime_s, max(peak, 0.05)

    return run


@dataclasses.dataclass
class ClusterSimulator:
    """Bundles everything a searcher needs for one job.

    ``faults`` optionally attaches a `repro.cluster.faults.FaultPlan`:
    `profile_run_fn` then injects the plan's transient/permanent failures
    into the profiling/probe runs (successful readings are untouched — a
    retried run replays identical values, which is what lets the golden
    harness pin disturbed fleets bit-identical to undisturbed ones), and
    the plan's per-trial straggler schedule is surfaced by the fleet layer
    as reported latency, never fed back into the cost surface.
    """

    job: JobSpec
    space: SearchSpace
    costs: np.ndarray  # (69,) USD
    normalized: np.ndarray  # costs / min(costs) — the paper's metric
    faults: Optional[FaultPlan] = None
    # Cost-aware extras, populated only when a catalog is requested: the
    # raw runtime/price axes the fleet layer threads into priced
    # `FleetJob`s (Pareto fronts, USD reporting).
    catalog: Optional[PriceCatalog] = None
    runtime_h: Optional[np.ndarray] = None  # (69,) hours under the catalog
    price_hour: Optional[np.ndarray] = None  # (69,) USD/hour under the catalog

    @classmethod
    def for_job(
        cls,
        key: str,
        faults: Optional[FaultPlan] = None,
        catalog: Optional[PriceCatalog] = None,
        epoch: int = 0,
    ) -> "ClusterSimulator":
        # Table I catalog first, then the MEMOIZED adversarial/drift
        # scenario specs (same key space).  NOT `JOBS.get(key) or ...`:
        # the falsy-`or` shape silently re-routes falsy container values
        # (the PR-9 `session or TuningSession(...)` bug) and re-built the
        # whole scenario dict per lookup, with a typo'd key escaping as a
        # bare KeyError from the scenario dict.
        job = JOBS.get(key)
        if job is None:
            job = _scenario_catalog().get(key)
        if job is None:
            raise KeyError(
                f"unknown job key {key!r}: valid keys are the Table I "
                f"catalog {sorted(JOBS)} or the failure scenarios "
                f"{sorted(_scenario_catalog())}"
            )
        space = make_cluster_search_space()
        if catalog is None:
            costs = job_cost_table(job)
            return cls(
                job=job, space=space, costs=costs,
                normalized=costs / costs.min(), faults=faults,
            )
        rt = job_runtime_table(job, catalog)
        price = catalog.price_table(epoch=epoch)
        costs = rt * price
        return cls(
            job=job, space=space, costs=costs,
            normalized=costs / costs.min(), faults=faults,
            catalog=catalog, runtime_h=rt, price_hour=price,
        )

    def cost_fn(self) -> Callable[[int], float]:
        table = self.normalized

        def fn(index: int) -> float:
            return float(table[index])

        return fn

    def profile_run_fn(self) -> Callable[[float], Tuple[float, float]]:
        """Byte-denominated wrapper around the GB-denominated emulator.

        The core profiler traffics in bytes (like a real /proc reading); the
        emulator's ground truth is specified in GB — convert on both ends.
        """
        base = make_profile_run_fn(self.job)

        def run(sample_bytes: float) -> Tuple[float, float]:
            rt, peak_gb = base(sample_bytes / 1024.0**3)
            return rt, peak_gb * 1024.0**3  # bytes, like a real reading

        if self.faults is not None:
            return self.faults.wrap_run(run, self.job.key)
        return run

    def optimal_cost(self) -> float:
        return 1.0

    def optimal_index(self) -> int:
        return int(np.argmin(self.costs))
