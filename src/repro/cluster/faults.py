"""Seeded fault injection for the emulated cluster (adversarial fleets).

A `FaultPlan` disturbs a job's profiling/probe runs and its search trials
the way a real cluster does — preempted sample machines (transient, a retry
fixes it), broken job binaries (permanent, no retry can), and straggler
trials that take several times longer than their twins — while keeping the
whole disturbance a pure function of the plan.  Every injection decision is
either scripted (`transient_run_failures`: the first N wrapped calls fail)
or drawn from a sha256 hash of (seed, job key, call index) — the same
deterministic-randomness idiom as `repro.cluster.simulator`'s cost
variance — so a disturbed fleet run is exactly reproducible and the
golden-trace harness can pin its surviving searches bit-identical to an
undisturbed run.

Two invariants make that bit-identity possible, and this module is written
to preserve them:

  * a wrapped run NEVER alters the values a successful call returns — it
    only decides whether the call raises first.  The emulated run fns are
    deterministic in the sample size, so a retried profiling attempt
    replays the identical readings and fits the identical model;
  * straggler latency is REPORTED, never fed back: `straggler_factor` is a
    metric on the trial (surfaced as `TrialRecord.attempts` and the bench's
    straggler counts), not a perturbation of profile runtimes — runtimes
    feed the §III-B calibration loop, and touching them would change sweep
    sizes, profiles, splits, and finally traces.

Stall isolation: under the async service (`repro.fleet.service`) a
straggler-stalled trial slows only its own admission group's dispatch
thread — other groups keep stepping at their own pace, which is exactly
what the open-loop straggler bench (workload G, `benchmarks/fleet_bench`)
measures against the global-lockstep driver.

Stochastic transients are capped by ``max_injected`` so a retried call
site is GUARANTEED to succeed within ``max_injected + 1`` attempts — pick
it below the retry policy's ``max_attempts`` and an adversarial schedule
degrades throughput, never correctness (each aborted attempt consumes at
least one injected fault).  Scripted failures have the same property by
construction.  `PermanentRunError` plans model a broken job: every call
raises, retries fast-fail, and the job surfaces as a first-class failed
outcome.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Callable, Tuple

from repro.core.profiler import PermanentRunError, TransientRunError

__all__ = ["FaultPlan"]

RunFn = Callable[[float], Tuple[float, float]]


def _hash_unit(*parts: str) -> float:
    """Deterministic uniform in [0, 1) from a string key."""
    h = hashlib.sha256("/".join(parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0**64


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One job's deterministic disturbance schedule.

    ``transient_run_failures`` scripts the first N wrapped run calls to
    raise `TransientRunError` (exact, for pinned scenarios);
    ``transient_rate`` additionally injects hash-drawn transients, at most
    ``max_injected`` in total over the wrapper's lifetime (the termination
    guarantee — see the module docstring).  ``permanent=True`` makes every
    call raise `PermanentRunError`.  Stragglers are per-trial flags drawn
    at ``straggler_rate``; ``straggler_factor`` is the reported latency
    multiplier.
    """

    seed: int = 0
    transient_run_failures: int = 0
    transient_rate: float = 0.0
    max_injected: int = 3
    permanent: bool = False
    straggler_rate: float = 0.0
    straggler_factor: float = 4.0

    def __post_init__(self) -> None:
        if self.transient_run_failures < 0 or self.max_injected < 0:
            raise ValueError("fault counts must be non-negative")
        if not (0.0 <= self.transient_rate <= 1.0):
            raise ValueError(f"transient_rate={self.transient_rate}")
        if not (0.0 <= self.straggler_rate <= 1.0):
            raise ValueError(f"straggler_rate={self.straggler_rate}")
        if self.straggler_factor < 1.0:
            raise ValueError("straggler_factor < 1 is not a straggler")

    def wrap_run(self, run: RunFn, key: str = "job") -> RunFn:
        """Wrap a profiling/probe run fn with this plan's failures.

        The wrapper keeps a call counter (shared across retries — the
        whole point: a retried profiling attempt draws FRESH fault
        decisions while replaying identical successful readings) and an
        injected-fault budget.  Successful calls pass through untouched.

        The counters live behind a lock: seed-replica fleets alias one
        wrapped run fn across jobs, and with the async service those
        jobs submit from concurrent threads — the fault DECISION
        (counter read-increment plus injection-budget check) is atomic,
        while the successful ``run`` call itself executes outside the
        lock (it is deterministic in the sample size, so concurrent
        passes don't contend on profiling).
        """
        lock = threading.Lock()
        calls = [0]
        injected = [0]

        def faulty(sample: float) -> Tuple[float, float]:
            with lock:
                i = calls[0]
                calls[0] += 1
                if self.permanent:
                    raise PermanentRunError(
                        f"{key}: run {i} failed permanently (injected)"
                    )
                if i < self.transient_run_failures:
                    raise TransientRunError(
                        f"{key}: run {i} failed transiently (scripted)"
                    )
                if (
                    self.transient_rate > 0.0
                    and injected[0] < self.max_injected
                    and _hash_unit(
                        "fault", str(self.seed), key, "run", str(i)
                    )
                    < self.transient_rate
                ):
                    injected[0] += 1
                    raise TransientRunError(
                        f"{key}: run {i} failed transiently (injected "
                        f"{injected[0]}/{self.max_injected})"
                    )
            return run(sample)

        return faulty

    def is_straggler(self, key: str, trial: int) -> bool:
        """Deterministic per-trial straggler flag."""
        if self.straggler_rate <= 0.0:
            return False
        return (
            _hash_unit("straggler", str(self.seed), key, str(trial))
            < self.straggler_rate
        )

    def straggler_multiplier(self, key: str, trial: int) -> float:
        """Reported latency multiplier for one trial (1.0 = on time)."""
        return self.straggler_factor if self.is_straggler(key, trial) else 1.0
