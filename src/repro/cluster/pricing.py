"""Pricing catalogs over the 69-configuration grid (cost-aware tuning).

The paper's evaluation prices every configuration from ONE hard-coded book
— the c4/m4/r4 on-demand rates baked into `repro.cluster.nodes.NODE_TYPES`
— so "cheapest" and "fastest-per-normalized-dollar" collapse into a single
objective.  Real fleets choose between *price books*: on-demand vs spot
(discounted, volatile) billing, and x86 vs arm/Graviton-style instance
families that trade a per-hour discount against a per-core perf offset.
This module makes the book a first-class axis:

  * `SpotSchedule` — a deterministic spot-price-volatility schedule.  The
    per-(node, epoch) discount comes from a sha256 hash of the schedule
    seed (the `fleet/retry.py` idiom — no live RNG), so a spot-priced
    search is a pure function of (catalog, seed, epoch) and spot ≤
    on-demand is a *structural* guarantee, not a sampled one.
  * `PriceCatalog` — one priced view of the grid: a billing model, an
    architecture, per-family price ratios against the committed x86
    on-demand book, and the arch's runtime offset (`perf_factor`; arm
    parts run the CPU-bound phases slower per core but bill cheaper per
    hour — the perf-per-dollar trade the paper's single book never had).
  * `default_catalogs()` / `CATALOGS` — the named books the benchmarks
    and the `pytest -m pricing` property suite sweep.

The catalogs deliberately do NOT mint new `ClusterConfig`s: every book
prices the *same* 69-config search space, so cost tables from different
catalogs stay index-aligned with each other, with the legacy
`job_cost_table`, and with every committed golden trace.  The identity
book (`on_demand()`) reproduces the legacy prices bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cluster.nodes import (
    ClusterConfig,
    NodeType,
    enumerate_cluster_configs,
)

__all__ = [
    "CATALOGS",
    "PriceCatalog",
    "SpotSchedule",
    "default_catalogs",
    "family_indices",
]


def _hash_unit(*parts: str) -> float:
    """Deterministic uniform in [0, 1) from a string key (the
    `fleet/retry.py` idiom — sha256, never a live RNG, so spot volatility
    can never perturb the engines' scripted BO draws)."""
    h = hashlib.sha256("/".join(parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0**64


@dataclasses.dataclass(frozen=True)
class SpotSchedule:
    """Deterministic spot-discount schedule, hashed from ``seed``.

    The discount for (node, epoch) swings around ``base_discount`` by
    ±``volatility`` and is clamped to [``floor``, ``ceiling``] — with
    ``floor`` > 0 the spot price is *strictly* below on-demand at every
    point of the schedule, which is what the `pytest -m pricing` property
    suite asserts catalog-wide.
    """

    seed: int = 0
    base_discount: float = 0.62  # mean fraction knocked off on-demand
    volatility: float = 0.18  # half-width of the per-epoch swing
    floor: float = 0.05  # spot never closer than 5% to on-demand
    ceiling: float = 0.90  # … and never cheaper than 10% of it

    def __post_init__(self) -> None:
        if not (0.0 < self.floor <= self.ceiling < 1.0):
            raise ValueError(
                f"want 0 < floor <= ceiling < 1, got "
                f"floor={self.floor}, ceiling={self.ceiling}"
            )
        if self.volatility < 0.0:
            raise ValueError(f"volatility={self.volatility}: want >= 0")

    def discount(self, node_name: str, epoch: int = 0) -> float:
        """Fraction knocked off the on-demand price, in (0, 1)."""
        u = _hash_unit("spot", str(self.seed), node_name, str(int(epoch)))
        swing = self.volatility * (2.0 * u - 1.0)
        return float(
            min(max(self.base_discount + swing, self.floor), self.ceiling)
        )


@dataclasses.dataclass(frozen=True)
class PriceCatalog:
    """One priced view of the 69-config grid (see module docstring).

    ``family_price_ratio`` maps the node family ("c"/"m"/"r") to the
    catalog's per-hour price as a fraction of the x86 on-demand book;
    families not listed use ``price_ratio``.  ``perf_factor`` multiplies
    *runtime* (not price): > 1 means the arch runs the reference workload
    slower, so perf-per-dollar improves only when the price ratio drops
    faster than the perf factor rises.  ``spot`` must be present exactly
    for ``billing="spot"`` catalogs.
    """

    name: str
    arch: str = "x86"  # "x86" | "arm"
    billing: str = "ondemand"  # "ondemand" | "spot"
    price_ratio: float = 1.0
    family_price_ratio: Tuple[Tuple[str, float], ...] = ()
    perf_factor: float = 1.0
    spot: Optional[SpotSchedule] = None

    def __post_init__(self) -> None:
        if self.arch not in ("x86", "arm"):
            raise ValueError(f"unknown arch {self.arch!r}")
        if self.billing not in ("ondemand", "spot"):
            raise ValueError(f"unknown billing {self.billing!r}")
        if (self.spot is not None) != (self.billing == "spot"):
            raise ValueError(
                f"catalog {self.name!r}: a SpotSchedule is required for "
                f"billing='spot' and forbidden otherwise"
            )
        if self.price_ratio <= 0.0 or self.perf_factor <= 0.0:
            raise ValueError(
                f"catalog {self.name!r}: price_ratio and perf_factor "
                f"must be > 0"
            )
        for fam, ratio in self.family_price_ratio:
            if ratio <= 0.0:
                raise ValueError(
                    f"catalog {self.name!r}: family {fam!r} ratio {ratio}"
                    " must be > 0"
                )

    def _ratio(self, family: str) -> float:
        for fam, ratio in self.family_price_ratio:
            if fam == family:
                return ratio
        return self.price_ratio

    def node_price_per_hour(self, node: NodeType, epoch: int = 0) -> float:
        """USD/hour for one node under this book at ``epoch``."""
        p = node.price_per_hour * self._ratio(node.family)
        if self.spot is not None:
            p *= 1.0 - self.spot.discount(node.name, epoch)
        return p

    def price_per_hour(self, cfg: ClusterConfig, epoch: int = 0) -> float:
        """USD/hour for a whole cluster configuration at ``epoch``."""
        return self.node_price_per_hour(cfg.node, epoch) * cfg.scale_out

    def price_table(
        self,
        configs: Optional[Sequence[ClusterConfig]] = None,
        epoch: int = 0,
    ) -> np.ndarray:
        """(n,) float64 USD/hour, aligned with `enumerate_cluster_configs`."""
        if configs is None:
            configs = enumerate_cluster_configs()
        return np.asarray(
            [self.price_per_hour(c, epoch) for c in configs], np.float64
        )


def family_indices(
    families: Union[str, Sequence[str]],
    configs: Optional[Sequence[ClusterConfig]] = None,
) -> np.ndarray:
    """Indices (enumeration order) of the configs in the given families —
    the priority pool of a family-constrained search."""
    if isinstance(families, str):
        families = (families,)
    wanted = set(families)
    known = {"c", "m", "r"}
    if not wanted or not wanted <= known:
        raise ValueError(
            f"unknown families {sorted(wanted - known)}; want a subset of "
            f"{sorted(known)}"
        )
    if configs is None:
        configs = enumerate_cluster_configs()
    return np.asarray(
        [i for i, c in enumerate(configs) if c.node.family in wanted],
        np.int64,
    )


def on_demand() -> PriceCatalog:
    """The identity book: the committed x86 on-demand prices, bit-for-bit."""
    return PriceCatalog(name="ondemand")


def spot(seed: int = 0, **kw) -> PriceCatalog:
    """x86 spot billing under a deterministic volatility schedule."""
    return PriceCatalog(
        name=f"spot-s{seed}" if seed else "spot",
        billing="spot",
        spot=SpotSchedule(seed=seed, **kw),
    )


def graviton() -> PriceCatalog:
    """arm/Graviton-style on-demand book: per-family discounts vs the x86
    book (compute-heavy families discount deepest, memory-heavy least —
    the c6g/m6g/r6g pattern) against a uniform per-core runtime offset.
    The non-uniform family ratios are what lets the cost-optimal
    configuration cross families relative to the x86 book."""
    return PriceCatalog(
        name="graviton",
        arch="arm",
        family_price_ratio=(("c", 0.72), ("m", 0.78), ("r", 0.86)),
        perf_factor=1.08,
    )


def graviton_spot(seed: int = 0) -> PriceCatalog:
    """arm book under spot billing — both axes at once."""
    g = graviton()
    return dataclasses.replace(
        g,
        name=f"graviton-spot-s{seed}" if seed else "graviton-spot",
        billing="spot",
        spot=SpotSchedule(seed=seed),
    )


def default_catalogs(seed: int = 0) -> Dict[str, PriceCatalog]:
    """The named books the benchmarks and the property suite sweep."""
    cats = [on_demand(), spot(seed), graviton(), graviton_spot(seed)]
    return {c.name: c for c in cats}


CATALOGS: Mapping[str, PriceCatalog] = default_catalogs()
