"""The 16 evaluation jobs (paper Table I) as parameterized emulator specs.

Each spec fixes the job's *ground-truth* behaviour:
  * memory category + requirement at the full dataset size (Table I),
  * how the cost surface over the 69 configs is shaped (CPU/IO split, serial
    fraction, coordination overhead, spill severity at the memory cliff),
  * how noisy the single-machine memory readings are (which is what drives
    the linear/flat/unclear categorization, §IV-B),
  * the profiling-time scale (Table III).

HiBench input sizes are not printed in the paper; `input_gb` is chosen per
job so the implied bytes-in-memory-per-byte-of-input slopes are the 2–4×
JVM-object blowup typical for Spark caching.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

if TYPE_CHECKING:  # pricing is a peer module; keep import-time deps flat
    from repro.cluster.pricing import PriceCatalog

__all__ = [
    "JobSpec",
    "JOBS",
    "PricingScenario",
    "drift_spec",
    "failure_scenario_jobs",
    "family_constrained_scenarios",
    "pricing_scenarios",
    "spot_volatility_scenarios",
]


@dataclasses.dataclass(frozen=True)
class JobSpec:
    name: str  # e.g. "kmeans"
    framework: str  # "spark" | "hadoop"
    dataset: str  # "bigdata" | "huge"
    input_gb: float  # full input dataset size
    category: str  # ground truth: "linear" | "flat" | "unclear"
    mem_requirement_gb: float  # at full input (Table I for linear jobs)
    base_mem_gb: float  # framework-resident floor seen when profiling
    # --- cost-surface shape -------------------------------------------------
    serial_hours: float  # Amdahl serial part
    cpu_hours: float  # core-parallel work at the 8-core reference
    io_hours: float  # node-parallel (disk/shuffle) work at 4-node ref
    coord_per_node: float  # coordination overhead fraction per extra node
    spill_base: float  # instant runtime multiplier when the dataset
    spill_slope: float  # stops fitting + growth per missing fraction
    # --- profiling emulation -------------------------------------------------
    profile_noise: float  # relative noise of memory readings (GC churn)
    profile_time_s: float  # Table III target
    # --- objective ----------------------------------------------------------
    rugged_sigma: float = 0.10  # deterministic config-to-config variance

    @property
    def key(self) -> str:
        return f"{self.name}/{self.framework}/{self.dataset}"

    @property
    def mem_slope(self) -> float:
        """GB of job memory per GB of input (linear jobs)."""
        if self.category == "flat":
            return 0.0
        return self.mem_requirement_gb / self.input_gb


def _spark_ml(name, dataset, input_gb, req_gb, profile_time_s, *, unclear=False,
              cpu_hours=10.0, serial_hours=0.06, io_hours=1.0) -> JobSpec:
    return JobSpec(
        name=name,
        framework="spark",
        dataset=dataset,
        input_gb=input_gb,
        category="unclear" if unclear else "linear",
        mem_requirement_gb=req_gb,
        base_mem_gb=1.0,
        serial_hours=serial_hours,
        cpu_hours=cpu_hours,
        io_hours=io_hours,
        coord_per_node=0.006,
        spill_base=2.2,
        spill_slope=4.0,
        profile_noise=0.30 if unclear else 0.004,
        profile_time_s=profile_time_s,
    )


def _flat_job(name, framework, dataset, input_gb, profile_time_s, *,
              cpu_hours=6.0, io_hours=6.0, serial_hours=0.05) -> JobSpec:
    return JobSpec(
        name=name,
        framework=framework,
        dataset=dataset,
        input_gb=input_gb,
        category="flat",
        mem_requirement_gb=6.0,  # framework working set, input-independent
        base_mem_gb=4.0,
        serial_hours=serial_hours,
        cpu_hours=cpu_hours,
        io_hours=io_hours,
        coord_per_node=0.010,
        spill_base=1.0,  # no memory cliff: one-pass / disk-based
        spill_slope=0.0,
        profile_noise=0.04,
        profile_time_s=profile_time_s,
    )


def drift_spec(
    job: JobSpec,
    *,
    scale: float = 2.0,
    overhead_growth_gb: float = 0.0,
    slope_decay: float = 0.15,
    tag: str = "drift",
) -> JobSpec:
    """A recurring job whose memory behaviour has DRIFTED with its dataset.

    The streaming-system memory model (SNIPPETS.md snippet 1) is

        Memory = Overhead + Rows × Memory_Per_Row

    with the per-row slope *decreasing* as the dataset scales (dictionary
    encodings, shared buffers, and column compression amortize), while the
    fixed overhead creeps up with accumulated framework state.  This
    generator applies exactly that shift to a Table I spec: the input grows
    by ``scale``, the per-row slope decays as ``scale**-slope_decay``, and
    ``overhead_growth_gb`` is added to the resident floor.  The result is
    the drift-detection scenario's ground truth — a job whose fresh probe
    no longer matches the memory-signature class its old profile was filed
    under, so a Flora-style cache must re-profile instead of warm-seeding
    from the stale class.
    """
    if scale <= 0.0:
        raise ValueError(f"scale={scale}: want > 0")
    input_gb = job.input_gb * scale
    base_mem_gb = job.base_mem_gb + overhead_growth_gb
    if job.category == "flat":
        # Flat jobs have no per-row slope; drift is pure overhead creep.
        mem_requirement_gb = job.mem_requirement_gb + overhead_growth_gb
    else:
        slope = job.mem_slope * scale ** (-slope_decay)
        mem_requirement_gb = slope * input_gb + overhead_growth_gb
    return dataclasses.replace(
        job,
        name=f"{job.name}-{tag}",
        input_gb=input_gb,
        base_mem_gb=base_mem_gb,
        mem_requirement_gb=mem_requirement_gb,
    )


@functools.lru_cache(maxsize=1)
def _scenario_catalog() -> Dict[str, JobSpec]:
    """The memoized adversarial-scenario catalog (shared, do not mutate).

    `ClusterSimulator.for_job` consults this on every non-Table-I lookup;
    the specs are frozen dataclasses, so sharing one dict across lookups
    is safe — `failure_scenario_jobs()` hands callers their own copy.
    """
    kmeans = JOBS["kmeans/spark/bigdata"]
    terasort = JOBS["terasort/hadoop/bigdata"]
    out = {
        "flaky-kmeans": dataclasses.replace(kmeans, name="flaky-kmeans"),
        "broken-kmeans": dataclasses.replace(kmeans, name="broken-kmeans"),
        "drifted-kmeans": drift_spec(kmeans),
        "drifted-terasort": drift_spec(terasort, overhead_growth_gb=2.0),
    }
    return {spec.key: spec for spec in out.values()}


def failure_scenario_jobs() -> Dict[str, JobSpec]:
    """Named adversarial-scenario specs derived from the Table I catalog.

    These are the workloads the chaos lane (`pytest -m chaos`) and the
    adversarial fleet bench disturb: renamed clones whose profiling runs
    get a `repro.cluster.faults.FaultPlan` attached (flaky / broken), plus
    drifted recurrences of a linear and a flat job (see `drift_spec`).
    The specs themselves are ordinary `JobSpec`s — the faults live in the
    plan, not the workload, so the same spec serves both the disturbed and
    the undisturbed (reference) run.  Built once per process (the specs
    are immutable); each call returns a fresh dict over the shared specs.
    """
    return dict(_scenario_catalog())


@dataclasses.dataclass(frozen=True)
class PricingScenario:
    """One cost-aware search setup: a Table I job priced under a catalog.

    ``families`` optionally restricts the search to the named node
    families (the priority pool is `pricing.family_indices(families)`);
    ``epoch`` selects the point of the catalog's spot-volatility schedule.
    The interesting scenarios are exactly the ones where the same job's
    cost-optimal configuration (argmin runtime×price under the catalog)
    differs from its runtime-optimal one (argmin of the legacy book) —
    fleet_bench workload H asserts that movement.
    """

    name: str
    job_key: str
    catalog: "PriceCatalog"
    families: Optional[Tuple[str, ...]] = None
    epoch: int = 0


# Jobs whose cost surfaces probe the three pricing-sensitive regimes:
# a memory-cliff job (spill dominates — family choice is load-bearing),
# an IO-heavy flat job (scale-out dominates), and a CPU-heavy job
# (core price dominates).
_PRICING_JOB_KEYS = (
    "kmeans/spark/bigdata",
    "terasort/hadoop/bigdata",
    "pagerank/spark/huge",
)


def spot_volatility_scenarios(
    seed: int = 0, epochs: Tuple[int, ...] = (0, 1, 2)
) -> List[PricingScenario]:
    """Spot-billed searches across several schedule epochs: the same job
    re-priced as the deterministic discount schedule moves, so the
    cost-optimal configuration can migrate while the runtime-optimal one
    stays put."""
    from repro.cluster.pricing import spot

    cat = spot(seed)
    return [
        PricingScenario(
            name=f"spot/{key.split('/')[0]}-e{epoch}",
            job_key=key,
            catalog=cat,
            epoch=epoch,
        )
        for key in _PRICING_JOB_KEYS
        for epoch in epochs
    ]


def family_constrained_scenarios() -> List[PricingScenario]:
    """Family-constrained arm-book searches: the same job restricted to
    each node family under the graviton catalog, whose non-uniform
    per-family discounts move the cost optimum across family boundaries
    that the runtime objective never crosses."""
    from repro.cluster.pricing import graviton

    cat = graviton()
    return [
        PricingScenario(
            name=f"graviton/{key.split('/')[0]}-{fam}",
            job_key=key,
            catalog=cat,
            families=(fam,),
        )
        for key in _PRICING_JOB_KEYS
        for fam in ("c", "m", "r")
    ]


def pricing_scenarios(seed: int = 0) -> List[PricingScenario]:
    """The combined scenario set fleet_bench workload H sweeps."""
    return spot_volatility_scenarios(seed) + family_constrained_scenarios()


# Table I ground truth.  bigdata ≈ 2× huge for the same job.
JOBS: Dict[str, JobSpec] = {
    j.key: j
    for j in [
        _spark_ml("naivebayes", "bigdata", 220.0, 754.0, 373, cpu_hours=9.0),
        _spark_ml("naivebayes", "huge", 115.0, 395.0, 369, cpu_hours=4.8),
        _spark_ml("kmeans", "bigdata", 170.0, 503.0, 470, cpu_hours=14.0),
        _spark_ml("kmeans", "huge", 85.0, 252.0, 470, cpu_hours=7.5),
        _spark_ml("pagerank", "bigdata", 30.0, 86.0, 1292, cpu_hours=16.0),
        _spark_ml("pagerank", "huge", 15.0, 42.0, 1292, cpu_hours=8.5),
        _spark_ml("logregr", "bigdata", 130.0, 360.0, 675, unclear=True, cpu_hours=11.0),
        _spark_ml("logregr", "huge", 65.0, 180.0, 562, unclear=True, cpu_hours=6.0),
        _spark_ml("linregr", "bigdata", 120.0, 330.0, 372, unclear=True, cpu_hours=10.0),
        _spark_ml("linregr", "huge", 60.0, 165.0, 198, unclear=True, cpu_hours=5.5),
        _flat_job("join", "spark", "bigdata", 250.0, 136, cpu_hours=5.0, io_hours=7.0),
        _flat_job("join", "spark", "huge", 125.0, 110, cpu_hours=2.6, io_hours=3.6),
        _flat_job("pagerank", "hadoop", "bigdata", 30.0, 812, cpu_hours=9.0, io_hours=11.0),
        _flat_job("pagerank", "hadoop", "huge", 15.0, 812, cpu_hours=4.6, io_hours=5.8),
        _flat_job("terasort", "hadoop", "bigdata", 320.0, 547, cpu_hours=7.0, io_hours=13.0),
        _flat_job("terasort", "hadoop", "huge", 160.0, 547, cpu_hours=3.6, io_hours=6.8),
    ]
}
