"""Scout-like cluster evaluation substrate (paper §IV).

The paper evaluates on the Scout dataset (Hsu et al., "Arrow") — 1031 Spark
and Hadoop executions over 69 AWS cluster configurations.  That dataset is
not bundled in this offline container, so this package *emulates* it from the
paper's published structure: the 69-config grid (`nodes`), the 16 jobs of
Table I with their memory categories and GB requirements (`workloads`), and
deterministic cost surfaces exhibiting the Fig. 1 memory cliff (`simulator`).
"""

from repro.cluster.nodes import (
    ClusterConfig,
    NodeType,
    NODE_TYPES,
    enumerate_cluster_configs,
    make_cluster_search_space,
)
from repro.cluster.faults import FaultPlan
from repro.cluster.pricing import (
    CATALOGS,
    PriceCatalog,
    SpotSchedule,
    default_catalogs,
    family_indices,
)
from repro.cluster.workloads import (
    JOBS,
    JobSpec,
    PricingScenario,
    drift_spec,
    failure_scenario_jobs,
    family_constrained_scenarios,
    pricing_scenarios,
    spot_volatility_scenarios,
)
from repro.cluster.simulator import (
    ClusterSimulator,
    job_cost_table,
    job_runtime_table,
    make_profile_run_fn,
)

__all__ = [
    "CATALOGS",
    "ClusterConfig",
    "ClusterSimulator",
    "FaultPlan",
    "JOBS",
    "JobSpec",
    "NODE_TYPES",
    "NodeType",
    "PriceCatalog",
    "PricingScenario",
    "SpotSchedule",
    "default_catalogs",
    "drift_spec",
    "enumerate_cluster_configs",
    "failure_scenario_jobs",
    "family_constrained_scenarios",
    "family_indices",
    "job_cost_table",
    "job_runtime_table",
    "make_cluster_search_space",
    "make_profile_run_fn",
    "pricing_scenarios",
    "spot_volatility_scenarios",
]
