"""AWS node types and the 69-configuration grid of the paper's evaluation.

Paper §IV-A: machine types of classes c, m and r in sizes large, xlarge and
2xlarge; scale-outs between 4 and 48 machines; 69 configurations total.
Specs and on-demand prices are the 4th-generation (c4/m4/r4, us-east-1)
values of the CherryPick/Arrow era.

The exact scale-out lists per size are not enumerated in the paper; we choose
them so the grid (a) spans 4–48, (b) totals exactly 69, and (c) reproduces a
structural property the paper's narrative depends on: the *maximum* total
cluster memory of any configuration is 732 GB, which is below the 754 GB
requirement determined for Naive Bayes/Spark/bigdata (Table I) — "none of the
available configurations have enough total memory".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.search_space import Configuration, SearchSpace

__all__ = [
    "NodeType",
    "ClusterConfig",
    "NODE_TYPES",
    "SCALE_OUTS",
    "enumerate_cluster_configs",
    "make_cluster_search_space",
]

GiB = 1024**3


@dataclasses.dataclass(frozen=True)
class NodeType:
    name: str
    family: str  # "c" | "m" | "r"
    size: str  # "large" | "xlarge" | "2xlarge"
    cores: int
    memory_gb: float
    price_per_hour: float  # USD, on-demand


NODE_TYPES: Dict[str, NodeType] = {
    nt.name: nt
    for nt in [
        NodeType("c4.large", "c", "large", 2, 3.75, 0.100),
        NodeType("c4.xlarge", "c", "xlarge", 4, 7.5, 0.199),
        NodeType("c4.2xlarge", "c", "2xlarge", 8, 15.0, 0.398),
        NodeType("m4.large", "m", "large", 2, 8.0, 0.100),
        NodeType("m4.xlarge", "m", "xlarge", 4, 16.0, 0.200),
        NodeType("m4.2xlarge", "m", "2xlarge", 8, 32.0, 0.400),
        NodeType("r4.large", "r", "large", 2, 15.25, 0.133),
        NodeType("r4.xlarge", "r", "xlarge", 4, 30.5, 0.266),
        NodeType("r4.2xlarge", "r", "2xlarge", 8, 61.0, 0.532),
    ]
}

# 10 + 8 + 5 = 23 scale-outs per family → 69 configurations.
SCALE_OUTS: Dict[str, Tuple[int, ...]] = {
    "large": (4, 6, 8, 10, 12, 16, 24, 32, 40, 48),
    "xlarge": (4, 6, 8, 10, 12, 16, 20, 24),
    "2xlarge": (4, 6, 8, 10, 12),
}


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    node: NodeType
    scale_out: int

    @property
    def name(self) -> str:
        return f"{self.node.name}x{self.scale_out}"

    @property
    def total_cores(self) -> int:
        return self.node.cores * self.scale_out

    @property
    def total_memory_gb(self) -> float:
        return self.node.memory_gb * self.scale_out

    @property
    def price_per_hour(self) -> float:
        return self.node.price_per_hour * self.scale_out


def enumerate_cluster_configs() -> List[ClusterConfig]:
    configs = []
    for nt in NODE_TYPES.values():
        for so in SCALE_OUTS[nt.size]:
            configs.append(ClusterConfig(node=nt, scale_out=so))
    configs.sort(key=lambda c: (c.node.family, c.node.cores, c.scale_out))
    return configs


def make_cluster_search_space() -> SearchSpace:
    """Encode each configuration "by its principal features like the number
    of cores and the amount of memory" (paper §III-E / CherryPick §4)."""
    configs = enumerate_cluster_configs()
    return SearchSpace(
        [
            Configuration(
                name=c.name,
                features=(
                    float(c.total_cores),
                    float(c.total_memory_gb),
                    float(c.scale_out),
                    float(c.node.memory_gb / c.node.cores),  # mem per core
                ),
                total_memory=c.total_memory_gb * GiB,
                num_nodes=c.scale_out,
                meta=c,
            )
            for c in configs
        ]
    )
