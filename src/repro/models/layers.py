"""Shared neural-network layers for the model zoo (pure functional JAX).

Every layer follows the same convention:

  * ``<layer>_specs(cfg, ...) -> pytree[TensorSpec]`` — declarative parameter
    description carrying shapes, dtypes, logical sharding axes and inits;
  * ``<layer>_apply(params, cfg, x, ...) -> array`` — pure application.

Logical axes used across the zoo (mapped to mesh axes by parallel/sharding):

  "embed"       d_model                     — FSDP axis (sharded over data)
  "heads"       query heads                 — tensor-parallel (model)
  "kv_heads"    key/value heads             — tensor-parallel (model)
  "head_dim"    per-head dim                — replicated
  "ffn"         MLP hidden                  — tensor-parallel (model)
  "vocab"       vocabulary                  — tensor-parallel (model)
  "experts"     MoE expert count            — expert-parallel (model)
  "expert_ffn"  per-expert hidden           — replicated (experts carry TP)
  "ssm_inner"   Mamba2 inner channels       — tensor-parallel (model)
  "ssm_state"   Mamba2 state dim            — replicated
  "layers"      stacked scan-over-layers    — replicated (or pipeline stage)

Attention supports GQA (grouped KV heads), MQA (kv=1), qk-norm (qwen3), QKV
bias (qwen1.5), cross-attention (whisper decoder), causal/bidirectional
masking, KV-cache prefill and single-token decode.  The flash-attention
Pallas kernel is dispatched for the causal self-attention train/prefill path
when ``cfg.attention_impl`` requests it; the jnp path is the oracle.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models.spec import TensorSpec
from repro.parallel.constraints import shard_activation

__all__ = [
    "norm_specs",
    "norm_apply",
    "rope_tables",
    "apply_rope",
    "attn_specs",
    "attn_apply",
    "init_kv_cache_specs",
    "mlp_specs",
    "mlp_apply",
    "moe_specs",
    "moe_apply",
    "embedding_specs",
    "embed_apply",
    "unembed_apply",
]

# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------


def norm_specs(cfg: ModelConfig, d: Optional[int] = None) -> Dict[str, TensorSpec]:
    d = d or cfg.d_model
    specs = {"scale": TensorSpec((d,), cfg.pdtype, ("embed",), init="ones")}
    if cfg.norm == "layernorm":
        specs["bias"] = TensorSpec((d,), cfg.pdtype, ("embed",), init="zeros")
    return specs


def norm_apply(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """RMSNorm or LayerNorm with f32 statistics, output in compute dtype."""
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(cfg.cdtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_tables(
    positions: jax.Array, head_dim: int, theta: float
) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for integer ``positions`` (any shape), f32.

    Returns arrays of shape ``positions.shape + (head_dim // 2,)``.
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (split-half convention).  x: (..., heads, head_dim);
    cos/sin: broadcastable to (..., 1, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, Any]:
    """Projection parameters for one attention block.

    ``cross=True`` builds a cross-attention block (whisper decoder): the KV
    projections consume the encoder output (same d_model here).
    """
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = cfg.pdtype
    specs: Dict[str, Any] = {
        "wq": TensorSpec((d, h, hd), pd, ("embed", "heads", "head_dim"),
                         init="scaled_normal"),
        "wk": TensorSpec((d, kv, hd), pd, ("embed", "kv_heads", "head_dim"),
                         init="scaled_normal"),
        "wv": TensorSpec((d, kv, hd), pd, ("embed", "kv_heads", "head_dim"),
                         init="scaled_normal"),
        "wo": TensorSpec((h, hd, d), pd, ("heads", "head_dim", "embed"),
                         init="scaled_normal"),
    }
    if cfg.qkv_bias or cfg.use_bias:
        specs["bq"] = TensorSpec((h, hd), pd, ("heads", "head_dim"))
        specs["bk"] = TensorSpec((kv, hd), pd, ("kv_heads", "head_dim"))
        specs["bv"] = TensorSpec((kv, hd), pd, ("kv_heads", "head_dim"))
    if cfg.use_bias:
        specs["bo"] = TensorSpec((d,), pd, ("embed",))
    if cfg.qk_norm and not cross:
        specs["q_norm"] = TensorSpec((hd,), pd, ("head_dim",), init="ones")
        specs["k_norm"] = TensorSpec((hd,), pd, ("head_dim",), init="ones")
    return specs


def init_kv_cache_specs(
    cfg: ModelConfig, batch: int, max_len: int, num_layers: int
) -> Dict[str, TensorSpec]:
    """Stacked-over-layers KV cache for decode.  Length axis is logical
    "cache_seq" so long-context decode can shard it."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (num_layers, batch, max_len, kv, hd)
    axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {
        "k": TensorSpec(shape, cfg.cdtype, axes),
        "v": TensorSpec(shape, cfg.cdtype, axes),
    }


def _rms_head_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


def _project_qkv(
    p: Dict[str, jax.Array], cfg: ModelConfig, xq: jax.Array, xkv: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    cd = cfg.cdtype
    q = jnp.einsum("btd,dhk->bthk", xq, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(cd))
    q = shard_activation(q, ("batch", "seq", "heads", "head_dim"))
    k = shard_activation(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard_activation(v, ("batch", "seq", "kv_heads", "head_dim"))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if "q_norm" in p:
        q = _rms_head_norm(q, p["q_norm"])
        k = _rms_head_norm(k, p["k_norm"])
    return q, k, v


def _sdpa(
    q: jax.Array,  # (B, T, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    *,
    causal: bool,
    q_offset: Optional[jax.Array] = None,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Grouped-query scaled-dot-product attention, f32 softmax.

    ``q_offset``: absolute position of query 0 (for cached decode/prefill
    continuation) — causal mask compares (i + q_offset) ≥ j.
    ``kv_len``: only the first ``kv_len`` cache slots are valid.
    """
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    group = h // kv
    qg = q.reshape(b, t, kv, group, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32) * scale

    mask = None
    if causal:
        qpos = jnp.arange(t)[:, None] + (q_offset if q_offset is not None else 0)
        kpos = jnp.arange(s)[None, :]
        mask = qpos >= kpos  # (t, s)
    if kv_len is not None:
        valid = jnp.arange(s)[None, :] < kv_len
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        logits = jnp.where(mask[None, None, None, :, :], logits, -1e30)

    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs.astype(v.dtype), v)
    return out.reshape(b, t, h, hd)


def _chunked_sdpa(
    q: jax.Array,  # (B, T, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    *,
    causal: bool,
    chunk: int,
    q_offset: Optional[jax.Array] = None,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Online-softmax attention scanned over KV chunks ("flash in XLA").

    Never materializes the (T, S) score matrix: per scan step only a
    (T, chunk) tile exists, with running-max/denominator/accumulator carried
    in f32.  This is the §Perf memory-term lever for the 32k prefill cells —
    HBM traffic drops by ~chunk/head_dim and the O(T·S) buffer disappears —
    and the XLA twin of the Pallas flash kernel (same math, same tiling
    idea, compiler-scheduled instead of hand-scheduled).
    """
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    if s % chunk:
        # fall back on ragged tails — callers pick chunk | S
        return _sdpa(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len)
    group = h // kv
    qg = q.reshape(b, t, kv, group, hd)
    scale = 1.0 / math.sqrt(hd)
    nc = s // chunk

    kc = jnp.moveaxis(k.reshape(b, nc, chunk, kv, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, kv, hd), 1, 0)

    qpos = jnp.arange(t)[:, None] + (q_offset if q_offset is not None else 0)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        logits = jnp.einsum("btkgh,bckh->bkgtc", qg, kb).astype(jnp.float32)
        logits = logits * scale
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = None
        if causal:
            mask = qpos >= kpos
        if kv_len is not None:
            valid = kpos < kv_len
            mask = valid if mask is None else (mask & valid)
        if mask is not None:
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgtc,bckh->bkgth", p.astype(vb.dtype), vb)
        acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, group, t), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kv, group, t), jnp.float32)
    a0 = jnp.zeros((b, kv, group, t, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nc))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (b, kv, g, t, hd)
    out = jnp.transpose(out, (0, 3, 1, 2, 4))  # → (b, t, kv, g, hd)
    return out.reshape(b, t, h, hd).astype(q.dtype)


def _use_chunked(cfg: ModelConfig, t: int, s: int) -> bool:
    if cfg.attention_impl != "chunked":
        return False
    return t > 1 and s >= 2 * cfg.attention_chunk and s % cfg.attention_chunk == 0


def attn_apply(
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    x: jax.Array,  # (B, T, d) queries
    *,
    positions: jax.Array,  # (B, T) absolute positions (ints)
    causal: bool = True,
    kv_source: Optional[jax.Array] = None,  # cross-attention source (B, S, d)
    cache: Optional[Dict[str, jax.Array]] = None,  # {"k","v"} (B, S, KV, hd)
    cache_index: Optional[jax.Array] = None,  # scalar: valid cache length
    use_rope: bool = True,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """One attention block.  Returns (output, updated_cache_or_None).

    Modes:
      * train / encoder:     cache=None, kv_source=None (self) or set (cross)
      * prefill:             cache=zeros buffers, cache_index=0 → fills [0,T)
      * decode (T small):    cache=filled buffers, cache_index=current length
    """
    xkv = kv_source if kv_source is not None else x
    q, k, v = _project_qkv(p, cfg, x, xkv)

    if use_rope and kv_source is None:
        cos_q, sin_q = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos_q[:, :, None, :], sin_q[:, :, None, :])
        k = apply_rope(k, cos_q[:, :, None, :], sin_q[:, :, None, :])

    new_cache = None
    kv_len = None
    q_offset = positions[:, :1] * 0  # scalar-broadcast zero default
    if cache is not None:
        # Write the new keys/values at [cache_index, cache_index + T).
        idx = cache_index if cache_index is not None else jnp.int32(0)
        k_buf = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
        v_buf = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
        cache_axes = ("batch", "cache_seq", "kv_heads", "head_dim")
        k_buf = shard_activation(k_buf, cache_axes)
        v_buf = shard_activation(v_buf, cache_axes)
        new_cache = {"k": k_buf, "v": v_buf}
        k, v = k_buf, v_buf
        kv_len = idx + x.shape[1]
        q_offset = idx

    if cache is None and kv_source is None and causal and _use_flash(cfg, x.shape[1]):
        from repro.kernels.flash_attention import ops as flash_ops

        out = flash_ops.flash_attention(q, k, v, causal=True)
    elif kv_source is None and _use_chunked(cfg, x.shape[1], k.shape[1]):
        out = _chunked_sdpa(
            q, k, v, causal=causal, chunk=cfg.attention_chunk,
            q_offset=q_offset if cache is not None else None,
            kv_len=kv_len,
        )
    else:
        out = _sdpa(q, k, v, causal=causal and kv_source is None,
                    q_offset=q_offset if cache is not None else None,
                    kv_len=kv_len)

    out = shard_activation(out, ("batch", "seq", "heads", "head_dim"))
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(cfg.cdtype))
    if "bo" in p:
        y = y + p["bo"].astype(cfg.cdtype)
    return y, new_cache


def _use_flash(cfg: ModelConfig, seq_len: int) -> bool:
    if cfg.attention_impl == "pallas":
        return True
    if cfg.attention_impl == "auto":
        # Kernel path only on real TPUs (the CPU container lowers the jnp
        # oracle; the kernel itself is validated in interpret mode by tests).
        return jax.default_backend() == "tpu" and seq_len % 128 == 0
    return False


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, TensorSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pd = cfg.pdtype
    if cfg.mlp_act == "swiglu":
        specs = {
            "wi_gate": TensorSpec((d, f), pd, ("embed", "ffn"), init="scaled_normal"),
            "wi_up": TensorSpec((d, f), pd, ("embed", "ffn"), init="scaled_normal"),
            "wo": TensorSpec((f, d), pd, ("ffn", "embed"), init="scaled_normal"),
        }
    else:  # gelu
        specs = {
            "wi": TensorSpec((d, f), pd, ("embed", "ffn"), init="scaled_normal"),
            "wo": TensorSpec((f, d), pd, ("ffn", "embed"), init="scaled_normal"),
        }
        if cfg.use_bias:
            specs["bi"] = TensorSpec((f,), pd, ("ffn",))
            specs["bo"] = TensorSpec((d,), pd, ("embed",))
    return specs


def mlp_apply(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array) -> jax.Array:
    cd = cfg.cdtype
    ffn_axes = ("batch", "seq", "ffn")
    if cfg.mlp_act == "swiglu":
        gate = jnp.einsum("btd,df->btf", x, p["wi_gate"].astype(cd))
        up = jnp.einsum("btd,df->btf", x, p["wi_up"].astype(cd))
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(cd) * up
        h = shard_activation(h, ffn_axes)
        return jnp.einsum("btf,fd->btd", h, p["wo"].astype(cd))
    h = jnp.einsum("btd,df->btf", x, p["wi"].astype(cd))
    if "bi" in p:
        h = h + p["bi"].astype(cd)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(cd)
    h = shard_activation(h, ffn_axes)
    y = jnp.einsum("btf,fd->btd", h, p["wo"].astype(cd))
    if "bo" in p:
        y = y + p["bo"].astype(cd)
    return y


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig) -> Dict[str, Any]:
    assert cfg.moe is not None
    moe, d, pd = cfg.moe, cfg.d_model, cfg.pdtype
    e, f = moe.num_experts, moe.d_ff_expert
    specs: Dict[str, Any] = {
        "router": TensorSpec((d, e), jnp.float32, ("embed", "experts"),
                             init="scaled_normal"),
        "wi_gate": TensorSpec((e, d, f), pd, ("experts", "embed", "expert_ffn"),
                              init="scaled_normal"),
        "wi_up": TensorSpec((e, d, f), pd, ("experts", "embed", "expert_ffn"),
                            init="scaled_normal"),
        "wo": TensorSpec((e, f, d), pd, ("experts", "expert_ffn", "embed"),
                         init="scaled_normal"),
    }
    if moe.shared_experts:
        sf = f * moe.shared_experts
        specs["shared"] = {
            "wi_gate": TensorSpec((d, sf), pd, ("embed", "ffn"), init="scaled_normal"),
            "wi_up": TensorSpec((d, sf), pd, ("embed", "ffn"), init="scaled_normal"),
            "wo": TensorSpec((sf, d), pd, ("ffn", "embed"), init="scaled_normal"),
        }
    if moe.dense_residual:
        specs["dense"] = mlp_specs(cfg, d_ff=cfg.d_ff)
    return specs


def _expert_capacity(tokens: int, moe: MoEConfig) -> int:
    cap = int(math.ceil(tokens * moe.top_k * moe.capacity_factor / moe.num_experts))
    return max(cap, moe.top_k)


def moe_apply(
    p: Dict[str, Any], cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Top-k capacity-limited MoE.  Returns (output, aux_loss).

    Two dispatch paths share the routing math:

      * **expert-parallel shard_map** (distributed runs): tokens data-sharded,
        experts model-sharded, one psum combine — see
        ``parallel.expert_parallel`` for why GSPMD can't be trusted here;
      * **local scatter/gather** (single device / smoke tests): tokens are
        scattered into a per-expert slot buffer (E·C, d) by a flat slot id
        (expert·C + position-in-expert), run through the stacked expert
        matmuls, and gathered back.  (GShard's O(T·E·C) one-hot dispatch
        einsum is infeasible at E=384.)

    Deterministic shapes; tokens beyond capacity are dropped (their residual
    path passes through).
    """
    from repro.parallel.expert_parallel import (
        moe_apply_shard_map,
        moe_shard_map_available,
    )

    if moe_shard_map_available(cfg, x.shape):
        y, aux = moe_apply_shard_map(p, cfg, x)
        if "shared" in p:
            y = y + mlp_apply(p["shared"], cfg.replace(mlp_act="swiglu"), x)
        if "dense" in p:
            y = y + mlp_apply(p["dense"], cfg, x)
        return y, aux

    assert cfg.moe is not None
    moe, cd = cfg.moe, cfg.cdtype
    b, t, d = x.shape
    n = b * t
    e, k = moe.num_experts, moe.top_k
    cap = _expert_capacity(n, moe)

    xf = x.reshape(n, d)
    router_logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)  # (n, e)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (n, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Load-balancing auxiliary loss (Switch-style): e * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux_loss = moe.router_aux_weight * e * jnp.sum(me * ce)

    # Position-in-expert over the flattened (k-major) routing pairs so lower
    # k-slots win capacity first, GShard-style.
    flat_ids = expert_ids.T.reshape(-1)  # (k*n,) k-major
    flat_gates = gate_vals.T.reshape(-1)
    oh = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # (k*n, e)
    pos_in_expert = jnp.cumsum(oh, axis=0) - oh  # exclusive per-expert rank
    pos = jnp.sum(pos_in_expert * oh, axis=-1)  # (k*n,)
    keep = pos < cap
    slot = jnp.where(keep, flat_ids * cap + pos, e * cap)  # drop → overflow row

    # Scatter tokens (scaled later at combine) into the slot buffer.
    xk = jnp.tile(xf, (k, 1))  # (k*n, d), k-major to match flat_ids
    buf = jnp.zeros((e * cap + 1, d), cd).at[slot].add(xk.astype(cd))
    buf = buf[: e * cap].reshape(e, cap, d)
    buf = shard_activation(buf, ("experts", "capacity", "act_embed"))

    # Expert computation (stacked SwiGLU), experts sharded over "model".
    gate = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"].astype(cd))
    up = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"].astype(cd))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(cd) * up
    h = shard_activation(h, ("experts", "capacity", "expert_ffn"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cd))
    out_buf = shard_activation(out_buf, ("experts", "capacity", "act_embed"))

    # Gather back and combine with gates.
    out_flat = out_buf.reshape(e * cap, d)
    gathered = jnp.where(
        keep[:, None], out_flat[jnp.minimum(slot, e * cap - 1)], 0.0
    )  # (k*n, d)
    combined = jnp.sum(
        (gathered * flat_gates[:, None].astype(cd)).reshape(k, n, d), axis=0
    )
    y = shard_activation(combined.reshape(b, t, d), ("batch", "seq", "act_embed"))

    if "shared" in p:
        shared_cfg = cfg.replace(mlp_act="swiglu")
        y = y + mlp_apply(p["shared"], shared_cfg, x)
    if "dense" in p:
        y = y + mlp_apply(p["dense"], cfg, x)
    return y, aux_loss


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_specs(cfg: ModelConfig) -> Dict[str, TensorSpec]:
    specs = {
        "embedding": TensorSpec(
            (cfg.vocab_size, cfg.d_model), cfg.pdtype, ("vocab", "embed"),
            init="normal", init_scale=0.02,
        )
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = TensorSpec(
            (cfg.d_model, cfg.vocab_size), cfg.pdtype, ("embed", "vocab"),
            init="scaled_normal",
        )
    return specs


def embed_apply(p: Dict[str, jax.Array], cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    emb = p["embedding"].astype(cfg.cdtype)[tokens]
    return shard_activation(emb, ("batch", "seq", "act_embed"))


def unembed_apply(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Final logits in f32 (softmax stability at 152k vocabs)."""
    if cfg.tie_embeddings:
        w = p["embedding"].astype(cfg.cdtype).T
    else:
        w = p["unembed"].astype(cfg.cdtype)
    logits = jnp.einsum("btd,dv->btv", x, w).astype(jnp.float32)
    return shard_activation(logits, ("batch", "seq", "vocab"))
