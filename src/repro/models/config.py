"""Model configuration covering all ten assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["MoEConfig", "SSMConfig", "EncoderConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False  # Arctic: dense MLP residual alongside MoE
    shared_experts: int = 0  # Kimi-style always-on shared expert(s)
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder side of an encoder–decoder model (whisper).

    The modality frontend (conv-over-mel for whisper) is a STUB: the encoder
    consumes precomputed frame embeddings provided by ``input_specs()``.
    """

    num_layers: int
    source_len: int  # e.g. 1500 audio frames for whisper


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention/MLP flavor ------------------------------------------------
    qk_norm: bool = False  # qwen3
    qkv_bias: bool = False  # qwen1.5
    mlp_act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"  # rope | learned | none (encoder adds sinusoidal)
    max_position: int = 0  # learned pos-emb table size (0 = seq-dependent)
    tie_embeddings: bool = False
    use_bias: bool = False  # biases on projections (whisper)
    # --- family extensions ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0  # zamba2: shared attn block period (0 = off)
    encoder: Optional[EncoderConfig] = None
    num_patch_tokens: int = 0  # vlm: image patch tokens prepended
    # --- numerics ------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # --- execution -----------------------------------------------------------
    attention_impl: str = "auto"  # auto | dense | chunked | pallas
    attention_chunk: int = 1024
    remat_policy: str = "none"  # none | dots | full
    sub_quadratic: bool = False  # eligible for long_500k cells

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        if self.num_heads and self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError("num_heads must be divisible by num_kv_heads")
        if self.family in ("ssm",) and self.ssm is None:
            raise ValueError("ssm family requires SSMConfig")
        if self.family == "hybrid" and (self.ssm is None or not self.hybrid_attn_every):
            raise ValueError("hybrid family requires SSMConfig and attn period")
        if self.family == "encdec" and self.encoder is None:
            raise ValueError("encdec family requires EncoderConfig")
        if self.family == "moe" and self.moe is None:
            raise ValueError("moe family requires MoEConfig")
