"""Mamba-2 SSD (state-space duality) layer — chunked scan + O(1) decode.

Follows Dao & Gu, "Transformers are SSMs" (arXiv:2405.21060).  The layer:

    u (B,L,d) ──in-projections──► z, x, B, C, dt
    x,B,C    ──causal depthwise conv (width d_conv) + silu
    y  = SSD(x·dt, A·dt, B, C)  + D ⊙ x          (selective state space)
    out = out_proj( RMSNorm(y ⊙ silu(z)) )

SSD semantics per head h with state N and head dim P:

    h_t = exp(dt_t A) h_{t-1} + dt_t · B_t x_tᵀ      h ∈ R^{N×P}
    y_t = C_tᵀ h_t + D x_t

computed in O(L·Q) time by splitting L into chunks of Q (``chunk_size``):
an intra-chunk attention-like term (masked by the decay segment-sum) plus an
inter-chunk recurrence over per-chunk states (``jax.lax.scan``).  The
intra-chunk term is the compute hot-spot; ``repro.kernels.ssd`` provides the
Pallas TPU kernel for it, and this module is its jnp oracle.

Projections are kept separate (wz/wx/wB/wC/wdt) rather than fused so each
piece carries clean logical sharding axes (heads → tensor-parallel).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.spec import TensorSpec
from repro.parallel.constraints import shard_activation

__all__ = [
    "ssm_specs",
    "ssm_state_specs",
    "ssm_apply",
    "ssd_chunked",
    "ssd_decode_step",
]


# ---------------------------------------------------------------------------
# Parameters / state
# ---------------------------------------------------------------------------


def ssm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    assert cfg.ssm is not None
    s, d, pd = cfg.ssm, cfg.d_model, cfg.pdtype
    di = s.d_inner(d)
    h = s.num_heads(d)
    gn = s.n_groups * s.d_state
    return {
        "wz": TensorSpec((d, di), pd, ("embed", "ssm_inner"), init="scaled_normal"),
        "wx": TensorSpec((d, di), pd, ("embed", "ssm_inner"), init="scaled_normal"),
        "wB": TensorSpec((d, gn), pd, ("embed", None), init="scaled_normal"),
        "wC": TensorSpec((d, gn), pd, ("embed", None), init="scaled_normal"),
        "wdt": TensorSpec((d, h), pd, ("embed", "heads"), init="scaled_normal"),
        "conv_x": TensorSpec((s.d_conv, di), pd, (None, "ssm_inner"),
                             init="normal", init_scale=0.1),
        "conv_B": TensorSpec((s.d_conv, gn), pd, (None, None),
                             init="normal", init_scale=0.1),
        "conv_C": TensorSpec((s.d_conv, gn), pd, (None, None),
                             init="normal", init_scale=0.1),
        "conv_bias_x": TensorSpec((di,), pd, ("ssm_inner",)),
        "conv_bias_B": TensorSpec((gn,), pd, (None,)),
        "conv_bias_C": TensorSpec((gn,), pd, (None,)),
        # A_log init ~ log(uniform[1,16]) in real mamba2; a fixed spread here.
        "A_log": TensorSpec((h,), jnp.float32, ("heads",), init="ones"),
        "D": TensorSpec((h,), jnp.float32, ("heads",), init="ones"),
        "dt_bias": TensorSpec((h,), jnp.float32, ("heads",), init="zeros"),
        "norm_scale": TensorSpec((di,), pd, ("ssm_inner",), init="ones"),
        "out_proj": TensorSpec((di, d), pd, ("ssm_inner", "embed"),
                               init="scaled_normal"),
    }


def ssm_state_specs(
    cfg: ModelConfig, batch: int, num_layers: int
) -> Dict[str, TensorSpec]:
    """Decode-time recurrent state, stacked over layers.

    ``ssd``:  (layers, B, H, N, P) recurrent state — O(1) in sequence length.
    ``conv``: (layers, B, d_conv-1, channels) rolling conv inputs.
    """
    s = cfg.ssm
    assert s is not None
    di = s.d_inner(cfg.d_model)
    h = s.num_heads(cfg.d_model)
    gn = s.n_groups * s.d_state
    chans = di + 2 * gn
    return {
        "ssd": TensorSpec((num_layers, batch, h, s.d_state, s.head_dim),
                          jnp.float32,
                          ("layers", "batch", "heads", "ssm_state", None)),
        "conv": TensorSpec((num_layers, batch, s.d_conv - 1, chans),
                           cfg.cdtype, ("layers", "batch", None, "ssm_inner")),
    }


# ---------------------------------------------------------------------------
# SSD core — chunked scan (jnp oracle; kernels/ssd provides the Pallas path)
# ---------------------------------------------------------------------------


def _segsum(lA: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = Σ_{l=j+1..i} lA[..., l].

    lA: (..., Q) log-decays.  Returns (..., Q, Q) with -inf above diagonal.
    """
    q = lA.shape[-1]
    cs = jnp.cumsum(lA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # Σ_{l=j+1..i}
    ii = jnp.arange(q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P) inputs (pre-scaled by nothing; dt applied here)
    dt: jax.Array,  # (B, L, H) positive step sizes
    A: jax.Array,  # (H,) negative decay rates
    B_: jax.Array,  # (B, L, G, N)
    C_: jax.Array,  # (B, L, G, N)
    *,
    chunk_size: int,
    initial_state: Optional[jax.Array] = None,  # (B, H, N, P)
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y (B,L,H,P), final_state (B,H,N,P)).

    Heads are grouped: head h uses B/C group ``h // (H // G)``.
    """
    b, l, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    q = min(chunk_size, l)
    if l % q:
        # Pad to a chunk multiple with dt=0 steps: decay exp(0·A)=1 and the
        # input contribution dt·Bx = 0, so padding is exactly inert.
        pad = q - l % q
        y, st = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0))),
            chunk_size=chunk_size,
            initial_state=initial_state,
            use_kernel=use_kernel,
        )
        return y[:, :l], st
    nc = l // q
    rep = h // g

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B_.astype(jnp.float32), rep, axis=2)  # (B,L,H,N)
    Cf = jnp.repeat(C_.astype(jnp.float32), rep, axis=2)

    # Chunked views: (B, nc, Q, ...)
    xc = xf.reshape(b, nc, q, h, p)
    dtc = dtf.reshape(b, nc, q, h)
    Bc = Bf.reshape(b, nc, q, h, n)
    Cc = Cf.reshape(b, nc, q, h, n)
    lA = dtc * A  # (B, nc, Q, H) log decay per step

    # ----- intra-chunk (diagonal) term -------------------------------------
    if use_kernel:
        from repro.kernels.ssd import ops as ssd_ops

        y_diag = ssd_ops.ssd_diag_chunk(xc, dtc, lA, Bc, Cc)
    else:
        seg = _segsum(jnp.moveaxis(lA, -1, -2))  # (B, nc, H, Q, Q)
        decay = jnp.exp(seg)
        scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)
        y_diag = jnp.einsum(
            "bchqk,bckh,bckhp->bcqhp", scores * decay, dtc, xc
        )

    # ----- inter-chunk recurrence ------------------------------------------
    cum_lA = jnp.cumsum(lA, axis=2)  # (B, nc, Q, H)
    total_lA = cum_lA[:, :, -1, :]  # (B, nc, H)
    # State contributed by each chunk: decay from step j to chunk end.
    decay_to_end = jnp.exp(total_lA[:, :, None, :] - cum_lA)  # (B,nc,Q,H)
    chunk_states = jnp.einsum(
        "bcqh,bcqhn,bcqhp->bchnp", decay_to_end * dtc, Bc, xc
    )  # (B, nc, H, N, P)

    init = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, n, p), jnp.float32)
    )

    def step(carry, inp):
        tot, st = inp  # (B,H), (B,H,N,P)
        new = jnp.exp(tot)[..., None, None] * carry + st
        return new, carry  # emit the state *entering* this chunk

    final_state, prev_states = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(total_lA, 1, 0), jnp.moveaxis(chunk_states, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, N, P)

    # Off-diagonal: queries read the state entering their chunk.
    decay_from_start = jnp.exp(cum_lA)  # (B,nc,Q,H) — includes own dt·A
    y_off = jnp.einsum(
        "bcqhn,bcqh,bchnp->bcqhp", Cc, decay_from_start, prev_states
    )

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final_state


def ssd_decode_step(
    state: jax.Array,  # (B, H, N, P) f32
    x: jax.Array,  # (B, H, P)
    dt: jax.Array,  # (B, H)
    A: jax.Array,  # (H,)
    B_: jax.Array,  # (B, G, N)
    C_: jax.Array,  # (B, G, N)
) -> Tuple[jax.Array, jax.Array]:
    """One recurrent step.  Returns (y (B,H,P), new_state)."""
    b, h, n, p = state.shape
    g = B_.shape[1]
    rep = h // g
    Bf = jnp.repeat(B_.astype(jnp.float32), rep, axis=1)  # (B,H,N)
    Cf = jnp.repeat(C_.astype(jnp.float32), rep, axis=1)
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    decay = jnp.exp(dtf * A)  # (B,H)
    new_state = decay[..., None, None] * state + jnp.einsum(
        "bh,bhn,bhp->bhnp", dtf, Bf, xf
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cf, new_state)
    return y, new_state


# ---------------------------------------------------------------------------
# Full layer
# ---------------------------------------------------------------------------


def _causal_conv(
    seq: jax.Array,  # (B, L, C)
    w: jax.Array,  # (K, C) depthwise taps
    bias: jax.Array,  # (C,)
    prev: Optional[jax.Array] = None,  # (B, K-1, C) rolling inputs
) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  Returns (out (B,L,C), new_prev (B,K-1,C))."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((seq.shape[0], k - 1, seq.shape[2]), seq.dtype)
    ext = jnp.concatenate([prev, seq], axis=1)  # (B, K-1+L, C)
    out = jnp.zeros_like(seq, dtype=jnp.float32)
    for i in range(k):
        out = out + ext[:, i : i + seq.shape[1], :].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    out = out + bias.astype(jnp.float32)
    new_prev = ext[:, -(k - 1) :, :] if k > 1 else prev
    return out.astype(seq.dtype), new_prev


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    """RMSNorm(y * silu(z)) — mamba2's gated output norm (f32 stats)."""
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(y.dtype)


def ssm_apply(
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    u: jax.Array,  # (B, T, d)
    *,
    state: Optional[Dict[str, jax.Array]] = None,  # decode: {"ssd","conv"}
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """One Mamba-2 block.  ``state=None`` → train/prefill-from-scratch path
    (returns final state for cache handoff); state given + T==1 → decode."""
    s = cfg.ssm
    assert s is not None
    cd = cfg.cdtype
    b, t, d = u.shape
    di = s.d_inner(d)
    h = s.num_heads(d)
    g, n = s.n_groups, s.d_state
    pdim = s.head_dim

    z = jnp.einsum("btd,de->bte", u, p["wz"].astype(cd))
    x = jnp.einsum("btd,de->bte", u, p["wx"].astype(cd))
    z = shard_activation(z, ("batch", "seq", "ssm_inner"))
    x = shard_activation(x, ("batch", "seq", "ssm_inner"))
    Braw = jnp.einsum("btd,de->bte", u, p["wB"].astype(cd))
    Craw = jnp.einsum("btd,de->bte", u, p["wC"].astype(cd))
    dt_raw = jnp.einsum("btd,dh->bth", u, p["wdt"].astype(cd))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])  # (H,) strictly negative

    decode = state is not None and t == 1
    conv_prev = None
    if state is not None:
        cp = state["conv"]
        conv_prev = (
            cp[:, :, :di],
            cp[:, :, di : di + g * n],
            cp[:, :, di + g * n :],
        )

    x, cpx = _causal_conv(x, p["conv_x"], p["conv_bias_x"],
                          conv_prev[0] if conv_prev else None)
    Braw, cpb = _causal_conv(Braw, p["conv_B"], p["conv_bias_B"],
                             conv_prev[1] if conv_prev else None)
    Craw, cpc = _causal_conv(Craw, p["conv_C"], p["conv_bias_C"],
                             conv_prev[2] if conv_prev else None)
    x = jax.nn.silu(x.astype(jnp.float32)).astype(cd)
    Braw = jax.nn.silu(Braw.astype(jnp.float32)).astype(cd)
    Craw = jax.nn.silu(Craw.astype(jnp.float32)).astype(cd)

    xh = x.reshape(b, t, h, pdim)
    Bh = Braw.reshape(b, t, g, n)
    Ch = Craw.reshape(b, t, g, n)

    if decode:
        y1, new_ssd = ssd_decode_step(
            state["ssd"], xh[:, 0], dt[:, 0], A, Bh[:, 0], Ch[:, 0]
        )
        y = y1[:, None]  # (B,1,H,P)
    else:
        init = state["ssd"] if state is not None else None
        y, new_ssd = ssd_chunked(
            xh, dt, A, Bh, Ch, chunk_size=s.chunk_size,
            initial_state=init, use_kernel=use_kernel,
        )

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.astype(cd).reshape(b, t, di)
    y = _gated_norm(y, z, p["norm_scale"])
    y = shard_activation(y, ("batch", "seq", "ssm_inner"))
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(cd))
    out = shard_activation(out, ("batch", "seq", "act_embed"))

    new_state = None
    if state is not None or not decode:
        new_state = {
            "ssd": new_ssd,
            "conv": jnp.concatenate([cpx, cpb, cpc], axis=-1),
        }
    return out, new_state
