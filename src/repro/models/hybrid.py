"""Zamba2-style hybrid backbone: Mamba-2 layers + one weight-SHARED
attention block applied every ``cfg.hybrid_attn_every`` layers.

The shared block (attention + MLP, one parameter copy) fires at layers
0, every, 2·every, ...; each *application site* has its own KV cache at
decode time (activations differ per depth even though weights are shared).
Zamba2's per-site LoRA adapters on the shared block are omitted — weight
sharing itself is the architectural property the memory/roofline analysis
cares about; noted in DESIGN.md §Known deviations.

The stack is driven by one ``lax.scan`` over the stacked Mamba layer params;
the shared-attention application is a ``lax.cond`` inside the body, with the
site KV caches carried (constant shape) and updated via dynamic slices.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.spec import TensorSpec
from repro.models.transformer import stack_specs
from repro.parallel.remat import remat_wrap

__all__ = [
    "num_attn_sites",
    "hybrid_specs",
    "hybrid_state_specs",
    "hybrid_apply",
]


def num_attn_sites(cfg: ModelConfig) -> int:
    assert cfg.hybrid_attn_every > 0
    return math.ceil(cfg.num_layers / cfg.hybrid_attn_every)


def hybrid_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "mamba": stack_specs(
            {"norm": L.norm_specs(cfg), "ssm": S.ssm_specs(cfg)}, cfg.num_layers
        ),
        "shared_attn": {
            "attn_norm": L.norm_specs(cfg),
            "attn": L.attn_specs(cfg),
            "mlp_norm": L.norm_specs(cfg),
            "mlp": L.mlp_specs(cfg),
        },
    }


def hybrid_state_specs(
    cfg: ModelConfig, batch: int, max_len: int
) -> Dict[str, Any]:
    """Decode state: per-layer SSM states + per-site KV caches."""
    sites = num_attn_sites(cfg)
    ssm_state = S.ssm_state_specs(cfg, batch, cfg.num_layers)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (sites, batch, max_len, kv, hd)
    axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {
        "ssd": ssm_state["ssd"],
        "conv": ssm_state["conv"],
        "ak": TensorSpec(shape, cfg.cdtype, axes),
        "av": TensorSpec(shape, cfg.cdtype, axes),
    }


def _shared_attn_block(
    p: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Dict[str, jax.Array]],
    cache_index: Optional[jax.Array],
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    h = L.norm_apply(p["attn_norm"], cfg, x)
    attn_out, new_cache = L.attn_apply(
        p["attn"], cfg, h, positions=positions, causal=True,
        cache=cache, cache_index=cache_index,
    )
    x = x + attn_out
    h = L.norm_apply(p["mlp_norm"], cfg, x)
    return x + L.mlp_apply(p["mlp"], cfg, h), new_cache


def hybrid_apply(
    params: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,  # (B, T, d) embedded inputs
    *,
    positions: jax.Array,
    state: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Run the hybrid stack.  Returns (hidden, new_state_or_None).

    Modes: train (state=None) / prefill (state zero-initialized, index 0) /
    decode (state filled, T==1).
    """
    every = cfg.hybrid_attn_every
    shared = params["shared_attn"]
    has_state = state is not None
    use_cache = has_state  # attention sites cache KV whenever state is kept

    ak = state["ak"] if use_cache else None
    av = state["av"] if use_cache else None

    def body(carry, xs):
        h, ak_c, av_c = carry
        p = xs["params"]
        idx = xs["idx"]

        def with_attn(h, ak_c, av_c):
            site = idx // every
            if use_cache:
                cache = {
                    "k": jax.lax.dynamic_index_in_dim(ak_c, site, 0, keepdims=False),
                    "v": jax.lax.dynamic_index_in_dim(av_c, site, 0, keepdims=False),
                }
                h2, nc = _shared_attn_block(
                    shared, cfg, h, positions, cache, cache_index
                )
                ak_n = jax.lax.dynamic_update_index_in_dim(ak_c, nc["k"], site, 0)
                av_n = jax.lax.dynamic_update_index_in_dim(av_c, nc["v"], site, 0)
                return h2, ak_n, av_n
            h2, _ = _shared_attn_block(shared, cfg, h, positions, None, None)
            return h2, ak_c, av_c

        def without_attn(h, ak_c, av_c):
            return h, ak_c, av_c

        h, ak_c, av_c = jax.lax.cond(
            idx % every == 0, with_attn, without_attn, h, ak_c, av_c
        )

        # Mamba-2 block (pre-norm residual).
        hn = L.norm_apply(p["norm"], cfg, h)
        layer_state = (
            {"ssd": xs["ssd"], "conv": xs["conv"]} if has_state else None
        )
        out, new_state = S.ssm_apply(p["ssm"], cfg, hn, state=layer_state)
        h = h + out

        ys = {}
        if has_state:
            ys = {"ssd": new_state["ssd"], "conv": new_state["conv"]}
        return (h, ak_c, av_c), ys

    xs: Dict[str, Any] = {
        "params": params["mamba"],
        "idx": jnp.arange(cfg.num_layers),
    }
    if has_state:
        xs["ssd"], xs["conv"] = state["ssd"], state["conv"]

    if not use_cache:
        ak = jnp.zeros((1,), cfg.cdtype)  # dummy carries (unused)
        av = jnp.zeros((1,), cfg.cdtype)

    body = remat_wrap(body, cfg.remat_policy)
    (h, ak_f, av_f), ys = jax.lax.scan(body, (x, ak, av), xs)

    new_state = None
    if has_state:
        new_state = {"ssd": ys["ssd"], "conv": ys["conv"], "ak": ak_f, "av": av_f}
    return h, new_state
