"""Parameter/state specification trees.

Every model in the zoo describes its parameters once, as a pytree of
``TensorSpec`` (shape, dtype, logical axes, initializer).  The same spec tree
is then *materialized* three ways:

  * ``init_tree(key, specs)``        → real arrays (smoke tests, examples);
  * ``abstract_tree(specs)``         → ``jax.ShapeDtypeStruct`` stand-ins for
                                       AOT ``lower().compile()`` dry-runs —
                                       zero allocation, exactly the
                                       shannon/kernels pattern;
  * ``partition_tree(specs, rules)`` → ``PartitionSpec`` per leaf, by mapping
                                       each logical axis through the active
                                       sharding rules (see parallel/sharding).

Keeping shapes, dtypes and logical axes in ONE place removes the classic
"params and shardings drifted apart" failure mode of hand-rolled frameworks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

__all__ = [
    "TensorSpec",
    "is_spec",
    "init_tree",
    "abstract_tree",
    "partition_tree",
    "count_params",
    "tree_bytes",
]


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Declarative description of one parameter / state tensor."""

    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    # One logical axis name (or None) per dimension, e.g. ("embed", "ffn").
    axes: Tuple[Optional[str], ...] = ()
    init: str = "zeros"  # zeros | normal | scaled_normal | ones
    init_scale: float = 1.0

    def __post_init__(self) -> None:
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} do not match shape {self.shape}"
            )


def is_spec(x: Any) -> bool:
    return isinstance(x, TensorSpec)


def _initializer(spec: TensorSpec) -> Callable[[jax.Array], jax.Array]:
    if spec.init == "zeros":
        return lambda key: jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return lambda key: jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        return lambda key: (
            jax.random.normal(key, spec.shape, jnp.float32) * spec.init_scale
        ).astype(spec.dtype)
    if spec.init == "scaled_normal":
        # Fan-in scaled (LeCun) init: scale / sqrt(fan_in).
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.init_scale / math.sqrt(max(fan_in, 1))
        return lambda key: (
            jax.random.normal(key, spec.shape, jnp.float32) * std
        ).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_tree(key: jax.Array, specs: Any) -> Any:
    """Materialize a spec tree into real arrays with per-leaf RNG streams."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrays = [_initializer(s)(k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_tree(specs: Any) -> Any:
    """ShapeDtypeStruct stand-ins (no allocation) for AOT lowering."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def partition_tree(specs: Any, rules: dict) -> Any:
    """Map logical axes → mesh axes through ``rules`` (None = replicated).

    A rule value may be a mesh-axis name, a tuple of mesh axes, or None.
    Axes missing from ``rules`` are replicated.
    """

    def leaf_pspec(s: TensorSpec) -> PartitionSpec:
        if not s.axes:
            return PartitionSpec()
        entries = []
        for ax in s.axes:
            r = rules.get(ax) if ax is not None else None
            entries.append(r)
        # Trim trailing Nones for tidier specs.
        while entries and entries[-1] is None:
            entries.pop()
        return PartitionSpec(*entries)

    return jax.tree.map(leaf_pspec, specs, is_leaf=is_spec)


def count_params(specs: Any) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def tree_bytes(specs: Any) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(
        math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves
    )
