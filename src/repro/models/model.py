"""Unified model facade over the six architecture families.

``Model(cfg)`` exposes the same five entry points for every family —
dense / moe / ssm / hybrid / encdec / vlm — so the launcher, dry-run,
tuner and tests never special-case architectures:

  * ``param_specs()``               parameter TensorSpec tree
  * ``forward(params, batch)``      teacher-forced logits over text positions
  * ``loss_fn(params, batch)``      scalar loss + metrics (CE + MoE aux + z)
  * ``cache_specs(batch, max_len)`` decode-cache TensorSpec tree
  * ``prefill(params, batch, cache)`` / ``decode_step(params, cache, tokens, index)``

Batch convention: ``{"tokens": (B,T) int32}`` plus per-modality stubs —
``frames`` (B,S_enc,d) for encdec, ``patches`` (B,P,d) for vlm (precomputed
embeddings; the conv/vision frontends are STUBS per the assignment).  Loss
shifts internally (position i predicts token i+1) and respects an optional
``loss_mask``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import hybrid as H
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.spec import TensorSpec, count_params, is_spec

__all__ = ["Model", "total_params", "active_params"]


# ---------------------------------------------------------------------------
# Parameter accounting (roofline MODEL_FLOPS needs N and N_active)
# ---------------------------------------------------------------------------


def total_params(cfg: ModelConfig) -> int:
    return count_params(Model(cfg).param_specs())


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (= N for dense; routed subset for MoE)."""
    n = total_params(cfg)
    if cfg.family != "moe" or cfg.moe is None:
        return n
    moe = cfg.moe
    per_expert = 3 * cfg.d_model * moe.d_ff_expert
    inactive = (moe.num_experts - moe.top_k) * per_expert * cfg.num_layers
    return n - inactive


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        cfg.validate()
        specs: Dict[str, Any] = {"embed": L.embedding_specs(cfg)}
        if cfg.pos_emb == "learned":
            assert cfg.max_position > 0, "learned pos-emb needs max_position"
            specs["pos_table"] = TensorSpec(
                (cfg.max_position, cfg.d_model), cfg.pdtype, (None, "embed"),
                init="normal", init_scale=0.02,
            )
        if cfg.family in ("dense", "moe", "vlm"):
            specs["layers"] = T.decoder_stack_specs(cfg)
        elif cfg.family == "encdec":
            specs["encoder"] = T.encoder_stack_specs(cfg)
            specs["layers"] = T.decoder_stack_specs(cfg, cross=True)
        elif cfg.family == "ssm":
            specs["layers"] = T.stack_specs(
                {"norm": L.norm_specs(cfg), "ssm": S.ssm_specs(cfg)},
                cfg.num_layers,
            )
        elif cfg.family == "hybrid":
            specs["hybrid"] = H.hybrid_specs(cfg)
        else:
            raise ValueError(f"unknown family {cfg.family}")
        specs["final_norm"] = L.norm_specs(cfg)
        return specs

    # -- embedding helpers ----------------------------------------------------

    def _embed_inputs(
        self, params: Dict[str, Any], batch: Dict[str, jax.Array],
        positions: jax.Array,
    ) -> jax.Array:
        """Token embeddings (+ learned positions, + modality prefixes)."""
        cfg = self.cfg
        x = L.embed_apply(params["embed"], cfg, batch["tokens"])
        if cfg.family == "vlm" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(cfg.cdtype), x], axis=1)
        if cfg.pos_emb == "learned":
            x = x + params["pos_table"].astype(cfg.cdtype)[positions]
        return x

    def _positions(self, batch: Dict[str, jax.Array]) -> jax.Array:
        b, t = batch["tokens"].shape
        if self.cfg.family == "vlm" and "patches" in batch:
            t = t + batch["patches"].shape[1]
        return jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None, :], (b, t))

    # -- forward (teacher-forced) --------------------------------------------

    def forward(
        self, params: Dict[str, Any], batch: Dict[str, jax.Array]
    ) -> Tuple[jax.Array, jax.Array]:
        """Returns (logits aligned with batch["tokens"], aux_loss)."""
        cfg = self.cfg
        positions = self._positions(batch)
        x = self._embed_inputs(params, batch, positions)
        aux = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "moe", "vlm"):
            h, aux, _ = T.decoder_stack_apply(
                params["layers"], cfg, x, positions=positions
            )
        elif cfg.family == "encdec":
            enc = T.encoder_stack_apply(params["encoder"], cfg, batch["frames"])
            h, aux, _ = T.decoder_stack_apply(
                params["layers"], cfg, x, positions=positions, cross_source=enc
            )
        elif cfg.family == "ssm":
            h = self._ssm_forward(params, x)
        elif cfg.family == "hybrid":
            h, _ = H.hybrid_apply(params["hybrid"], cfg, x, positions=positions)
        else:
            raise ValueError(cfg.family)

        h = L.norm_apply(params["final_norm"], cfg, h)
        if cfg.family == "vlm" and "patches" in batch:
            h = h[:, batch["patches"].shape[1] :]  # logits over text positions
        logits = L.unembed_apply(params["embed"] | _unembed(params), cfg, h)
        return logits, aux

    def _ssm_forward(self, params: Dict[str, Any], x: jax.Array) -> jax.Array:
        cfg = self.cfg
        from repro.parallel.remat import remat_wrap

        def body(h, p):
            hn = L.norm_apply(p["norm"], cfg, h)
            out, _ = S.ssm_apply(p["ssm"], cfg, hn)
            return h + out, None

        h, _ = jax.lax.scan(remat_wrap(body, cfg.remat_policy), x, params["layers"])
        return h

    # -- loss -----------------------------------------------------------------

    def loss_fn(
        self, params: Dict[str, Any], batch: Dict[str, jax.Array]
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Shifted cross-entropy (f32) + z-loss + MoE aux."""
        logits, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        targets = tokens[:, 1:]
        logits = logits[:, :-1]
        mask = jnp.ones(targets.shape, jnp.float32)
        if "loss_mask" in batch:
            mask = mask * batch["loss_mask"][:, 1:].astype(jnp.float32)

        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt_logit = jnp.take_along_axis(
            logits, targets[..., None], axis=-1
        )[..., 0]
        nll = logz - tgt_logit
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(nll * mask) / denom
        z_loss = 1e-4 * jnp.sum(jnp.square(logz) * mask) / denom
        loss = ce + z_loss + aux
        metrics = {
            "loss": loss,
            "ce": ce,
            "z_loss": z_loss,
            "aux_loss": aux,
            "tokens": jnp.sum(mask),
        }
        return loss, metrics

    # -- decode cache ---------------------------------------------------------

    def cache_specs(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return L.init_kv_cache_specs(cfg, batch, max_len, cfg.num_layers)
        if cfg.family == "encdec":
            assert cfg.encoder is not None
            self_kv = L.init_kv_cache_specs(cfg, batch, max_len, cfg.num_layers)
            src = cfg.encoder.source_len
            cross_shape = (cfg.num_layers, batch, src, cfg.num_kv_heads, cfg.head_dim)
            axes = ("layers", "batch", None, "kv_heads", "head_dim")
            return {
                "k": self_kv["k"],
                "v": self_kv["v"],
                "xk": TensorSpec(cross_shape, cfg.cdtype, axes),
                "xv": TensorSpec(cross_shape, cfg.cdtype, axes),
            }
        if cfg.family == "ssm":
            return S.ssm_state_specs(cfg, batch, cfg.num_layers)
        if cfg.family == "hybrid":
            return H.hybrid_state_specs(cfg, batch, max_len)
        raise ValueError(cfg.family)

    # -- prefill / decode ------------------------------------------------------

    def _decoder_pass(
        self,
        params: Dict[str, Any],
        batch: Dict[str, jax.Array],
        cache: Dict[str, jax.Array],
        index: jax.Array,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Shared prefill/decode body: consume tokens at [index, index+T)."""
        cfg = self.cfg
        b, t = batch["tokens"].shape
        pos = index + jnp.arange(t, dtype=jnp.int32)
        positions = jnp.broadcast_to(pos[None, :], (b, t))
        if cfg.family == "vlm" and "patches" in batch:
            tp = batch["patches"].shape[1] + t
            pos = index + jnp.arange(tp, dtype=jnp.int32)
            positions = jnp.broadcast_to(pos[None, :], (b, tp))
        x = self._embed_inputs(params, batch, positions)

        if cfg.family in ("dense", "moe", "vlm"):
            h, _, new_cache = T.decoder_stack_apply(
                params["layers"], cfg, x, positions=positions,
                caches={"k": cache["k"], "v": cache["v"]}, cache_index=index,
            )
        elif cfg.family == "encdec":
            h, _, new_self = T.decoder_stack_apply(
                params["layers"], cfg, x, positions=positions,
                caches={"k": cache["k"], "v": cache["v"]}, cache_index=index,
                cross_caches={"k": cache["xk"], "v": cache["xv"]},
            )
            new_cache = new_self | {"xk": cache["xk"], "xv": cache["xv"]}
        elif cfg.family == "ssm":
            h, new_cache = self._ssm_pass(params, x, cache)
        elif cfg.family == "hybrid":
            h, new_cache = H.hybrid_apply(
                params["hybrid"], cfg, x, positions=positions,
                state=cache, cache_index=index,
            )
        else:
            raise ValueError(cfg.family)

        h = L.norm_apply(params["final_norm"], cfg, h)
        logits = L.unembed_apply(params["embed"] | _unembed(params), cfg, h)
        return logits, new_cache

    def _ssm_pass(
        self, params: Dict[str, Any], x: jax.Array, cache: Dict[str, jax.Array]
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg

        def body(h, xs):
            p = xs["params"]
            hn = L.norm_apply(p["norm"], cfg, h)
            out, new_state = S.ssm_apply(
                p["ssm"], cfg, hn, state={"ssd": xs["ssd"], "conv": xs["conv"]}
            )
            return h + out, {"ssd": new_state["ssd"], "conv": new_state["conv"]}

        xs = {"params": params["layers"], "ssd": cache["ssd"], "conv": cache["conv"]}
        h, ys = jax.lax.scan(body, x, xs)
        return h, {"ssd": ys["ssd"], "conv": ys["conv"]}

    def prefill(
        self,
        params: Dict[str, Any],
        batch: Dict[str, jax.Array],
        cache: Dict[str, jax.Array],
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Fill the cache from position 0; returns (last-pos logits, cache).

        For encdec the encoder runs here and the cross K/V caches are built.
        """
        cfg = self.cfg
        if cfg.family == "encdec":
            enc = T.encoder_stack_apply(params["encoder"], cfg, batch["frames"])
            cache = cache | _build_cross_caches(params["layers"], cfg, enc)
        logits, new_cache = self._decoder_pass(
            params, batch, cache, jnp.int32(0)
        )
        return logits[:, -1:], new_cache

    def decode_step(
        self,
        params: Dict[str, Any],
        cache: Dict[str, jax.Array],
        tokens: jax.Array,  # (B, 1)
        index: jax.Array,  # scalar int32: current cache length
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """One-token decode.  Returns (logits (B,1,V), updated cache)."""
        batch = {"tokens": tokens}
        return self._decoder_pass(params, batch, cache, index)


def _unembed(params: Dict[str, Any]) -> Dict[str, jax.Array]:
    # The unembedding lives inside the "embed" group; helper for clarity.
    return {}


def _build_cross_caches(
    stacked: Dict[str, Any], cfg: ModelConfig, enc: jax.Array
) -> Dict[str, jax.Array]:
    """Project encoder output through every decoder layer's cross K/V."""

    def body(carry, p):
        cd = cfg.cdtype
        k = jnp.einsum("bsd,dhk->bshk", enc, p["cross_attn"]["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", enc, p["cross_attn"]["wv"].astype(cd))
        if "bk" in p["cross_attn"]:
            k = k + p["cross_attn"]["bk"].astype(cd)
            v = v + p["cross_attn"]["bv"].astype(cd)
        return carry, {"xk": k, "xv": v}

    _, ys = jax.lax.scan(body, None, stacked)
    return {"xk": ys["xk"], "xv": ys["xv"]}
