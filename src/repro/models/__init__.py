"""Model zoo substrate: functional JAX models for the ten assigned archs."""

from repro.models.config import EncoderConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models.model import Model, active_params, total_params
from repro.models.spec import (
    TensorSpec,
    abstract_tree,
    count_params,
    init_tree,
    partition_tree,
    tree_bytes,
)

__all__ = [
    "EncoderConfig",
    "Model",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "TensorSpec",
    "abstract_tree",
    "active_params",
    "count_params",
    "init_tree",
    "partition_tree",
    "total_params",
    "tree_bytes",
]
