"""Transformer backbones: decoder-only LM and encoder–decoder (whisper).

Layer stacks are built the MaxText way: per-layer parameter trees are
*stacked* along a leading "layers" axis and the stack is traversed with
``jax.lax.scan`` — one compiled layer body regardless of depth (61-layer
kimi and 88-layer granite-34b compile in seconds, not minutes) — with the
remat policy from ``parallel.remat`` applied to the body.

Three block flavors share one scan driver:

  * dense block:   attn → MLP                         (granite, qwen, llava)
  * moe block:     attn → MoE (+shared/+dense paths)  (kimi, arctic)
  * hybrid/ssm blocks live in ``models.hybrid`` / are pure-SSM scans.

Caches: decode-time KV caches are stacked over layers and passed through the
scan as xs/ys, so the same driver serves train (no cache), prefill (filling
caches) and decode (one-token update).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.spec import TensorSpec, is_spec
from repro.parallel.constraints import shard_activation
from repro.parallel.remat import remat_wrap

__all__ = [
    "stack_specs",
    "block_specs",
    "block_apply",
    "decoder_stack_specs",
    "decoder_stack_apply",
    "encoder_stack_specs",
    "encoder_stack_apply",
    "sinusoidal_positions",
]


def stack_specs(tree: Any, n: int) -> Any:
    """Prepend a stacked "layers" axis of size ``n`` to every spec leaf."""

    def stack(s: TensorSpec) -> TensorSpec:
        axes = s.axes if s.axes else (None,) * len(s.shape)
        return TensorSpec((n,) + s.shape, s.dtype, ("layers",) + tuple(axes),
                          init=s.init, init_scale=s.init_scale)

    return jax.tree.map(stack, tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# One transformer block (dense or MoE)
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "attn_norm": L.norm_specs(cfg),
        "attn": L.attn_specs(cfg),
        "mlp_norm": L.norm_specs(cfg),
    }
    if cross:
        specs["cross_norm"] = L.norm_specs(cfg)
        specs["cross_attn"] = L.attn_specs(cfg, cross=True)
    if cfg.family == "moe":
        specs["moe"] = L.moe_specs(cfg)
    else:
        specs["mlp"] = L.mlp_specs(cfg)
    return specs


def block_apply(
    p: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool = True,
    use_rope: bool = True,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    cross_source: Optional[jax.Array] = None,
    cross_cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, jax.Array]]]:
    """Pre-norm block.  Returns (x, aux_loss, new_self_cache)."""
    h = L.norm_apply(p["attn_norm"], cfg, x)
    attn_out, new_cache = L.attn_apply(
        p["attn"], cfg, h, positions=positions, causal=causal,
        cache=cache, cache_index=cache_index, use_rope=use_rope,
    )
    x = x + attn_out

    if cross_source is not None or cross_cache is not None:
        h = L.norm_apply(p["cross_norm"], cfg, x)
        if cross_cache is not None:
            # Pre-projected encoder K/V (built once at prefill).
            q, _, _ = L._project_qkv(p["cross_attn"], cfg, h, h)
            out = L._sdpa(q, cross_cache["k"], cross_cache["v"], causal=False)
            cross_out = jnp.einsum(
                "bthk,hkd->btd", out, p["cross_attn"]["wo"].astype(cfg.cdtype)
            )
            if "bo" in p["cross_attn"]:
                cross_out = cross_out + p["cross_attn"]["bo"].astype(cfg.cdtype)
        else:
            cross_out, _ = L.attn_apply(
                p["cross_attn"], cfg, h, positions=positions, causal=False,
                kv_source=cross_source, use_rope=False,
            )
        x = x + cross_out

    h = L.norm_apply(p["mlp_norm"], cfg, x)
    if "moe" in p:
        mlp_out, aux = L.moe_apply(p["moe"], cfg, h)
    else:
        mlp_out = L.mlp_apply(p["mlp"], cfg, h)
        aux = jnp.zeros((), jnp.float32)
    return x + mlp_out, aux, new_cache


# ---------------------------------------------------------------------------
# Decoder stack (scan over layers)
# ---------------------------------------------------------------------------


def decoder_stack_specs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, Any]:
    return stack_specs(block_specs(cfg, cross=cross), cfg.num_layers)


def decoder_stack_apply(
    stacked: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    caches: Optional[Dict[str, jax.Array]] = None,  # stacked {"k","v"}
    cache_index: Optional[jax.Array] = None,
    cross_source: Optional[jax.Array] = None,
    cross_caches: Optional[Dict[str, jax.Array]] = None,  # stacked
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, jax.Array]]]:
    """Scan the block over stacked layer params (+ caches).

    Returns (hidden, total_aux_loss, updated_caches_or_None).
    """
    has_cache = caches is not None
    has_cross = cross_source is not None or cross_caches is not None

    def body(carry, xs):
        h, aux = carry
        p = xs["params"]
        cache = {"k": xs["ck"], "v": xs["cv"]} if has_cache else None
        ccache = (
            {"k": xs["xk"], "v": xs["xv"]} if cross_caches is not None else None
        )
        h, a, new_cache = block_apply(
            p, cfg, h,
            positions=positions,
            cache=cache,
            cache_index=cache_index,
            cross_source=cross_source if cross_caches is None else None,
            cross_cache=ccache,
            use_rope=(cfg.pos_emb == "rope"),
        )
        h = shard_activation(h, ("batch", "seq", "act_embed"))
        ys = {}
        if has_cache:
            ys = {"ck": new_cache["k"], "cv": new_cache["v"]}
        return (h, aux + a), ys

    xs: Dict[str, Any] = {"params": stacked}
    if has_cache:
        xs["ck"], xs["cv"] = caches["k"], caches["v"]
    if cross_caches is not None:
        xs["xk"], xs["xv"] = cross_caches["k"], cross_caches["v"]

    body = remat_wrap(body, cfg.remat_policy)
    (h, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    new_caches = {"k": ys["ck"], "v": ys["cv"]} if has_cache else None
    return h, aux, new_caches


# ---------------------------------------------------------------------------
# Encoder stack (whisper) — bidirectional, sinusoidal positions
# ---------------------------------------------------------------------------


def sinusoidal_positions(length: int, d: int) -> jax.Array:
    """Fixed sinusoidal table (length, d), f32."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def encoder_stack_specs(cfg: ModelConfig) -> Dict[str, Any]:
    assert cfg.encoder is not None
    enc_cfg = cfg.replace(family="dense")  # encoder blocks are dense
    tree = {
        "attn_norm": L.norm_specs(enc_cfg),
        "attn": L.attn_specs(enc_cfg),
        "mlp_norm": L.norm_specs(enc_cfg),
        "mlp": L.mlp_specs(enc_cfg),
    }
    return {
        "layers": stack_specs(tree, cfg.encoder.num_layers),
        "final_norm": L.norm_specs(cfg),
    }


def encoder_stack_apply(
    params: Dict[str, Any], cfg: ModelConfig, frames: jax.Array
) -> jax.Array:
    """frames: (B, S, d) precomputed frame embeddings (conv frontend STUB)."""
    enc_cfg = cfg.replace(family="dense")
    b, s, d = frames.shape
    x = frames.astype(cfg.cdtype) + sinusoidal_positions(s, d).astype(cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, p):
        h = carry
        h2 = L.norm_apply(p["attn_norm"], enc_cfg, h)
        attn_out, _ = L.attn_apply(
            p["attn"], enc_cfg, h2, positions=positions, causal=False,
            use_rope=False,
        )
        h = h + attn_out
        h2 = L.norm_apply(p["mlp_norm"], enc_cfg, h)
        h = h + L.mlp_apply(p["mlp"], enc_cfg, h2)
        return shard_activation(h, ("batch", "seq", "act_embed")), None

    body = remat_wrap(body, cfg.remat_policy)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.norm_apply(params["final_norm"], cfg, x)
