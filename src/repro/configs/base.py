"""Config plumbing shared by the per-architecture config modules.

``ExecConfig`` carries the execution-level knobs that are *not* part of the
architecture (optimizer family, microbatching, remat, FSDP) — exactly the
axes the Ruya TPU tuner searches over.  ``ArchSpec`` bundles a ModelConfig
with its default ExecConfig; ``smoke_variant`` mechanically shrinks any
architecture to a CPU-runnable size for the per-arch smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import EncoderConfig, ModelConfig, MoEConfig, SSMConfig

__all__ = ["ExecConfig", "ArchSpec", "smoke_variant"]


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution configuration for a training/serving job."""

    optimizer: str = "adamw"  # adamw | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    num_microbatches: int = 1
    accum_dtype: Optional[str] = None  # None = grad dtype; "bfloat16" halves it
    fsdp: bool = True
    remat: str = "dots"  # default train remat policy
    bf16_grad_reduce: bool = True  # cast grads to bf16 before cross-replica sum
    seq_shard: bool = False  # sequence-shard activations over the model axis

    def replace(self, **kw) -> "ExecConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    model: ModelConfig
    exec: ExecConfig = ExecConfig()
    notes: str = ""

    def replace_model(self, **kw) -> "ArchSpec":
        return dataclasses.replace(self, model=self.model.replace(**kw))


def smoke_variant(spec: ArchSpec) -> ArchSpec:
    """Reduced same-family config: tiny widths, few layers, small tables."""
    m = spec.model
    kw = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(m.num_kv_heads, 4) if m.num_kv_heads > 1 else 1,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        max_position=256 if m.pos_emb == "learned" else 0,
        num_patch_tokens=8 if m.family == "vlm" else 0,
        remat_policy="none",
    )
    if m.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=8,
            top_k=min(m.moe.top_k, 2),
            d_ff_expert=32,
            capacity_factor=m.moe.capacity_factor,
            dense_residual=m.moe.dense_residual,
            shared_experts=m.moe.shared_experts,
        )
    if m.ssm is not None:
        kw["ssm"] = SSMConfig(
            d_state=16, d_conv=4, expand=2, head_dim=16,
            n_groups=1, chunk_size=8,
        )
    if m.hybrid_attn_every:
        kw["hybrid_attn_every"] = 2
    if m.encoder is not None:
        kw["encoder"] = EncoderConfig(num_layers=2, source_len=16)
    return dataclasses.replace(
        spec,
        name=spec.name + "-smoke",
        model=m.replace(**kw),
        exec=spec.exec.replace(num_microbatches=1, fsdp=False, remat="none"),
    )
