"""llava-next-mistral-7b — VLM on a Mistral-7B backbone
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab 32000.  The
anyres vision tower + projector are a STUB: ``input_specs`` supplies
precomputed patch embeddings (up to 2880 tokens for a 2×2 anyres grid +
base tile), which the model prepends to the text embeddings.
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    name="llava-next-mistral-7b",
    model=ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14_336,
        vocab_size=32_000,
        head_dim=128,
        num_patch_tokens=2880,
        param_dtype="float32",
        compute_dtype="bfloat16",
        remat_policy="full",
    ),
    exec=ExecConfig(seq_shard=True, remat="full", num_microbatches=1),
    notes="vision frontend stubbed as precomputed patch embeddings",
)
