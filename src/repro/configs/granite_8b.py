"""granite-8b — IBM Granite code model, llama architecture [arXiv:2405.04324].

36L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab 49152.
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    name="granite-8b",
    model=ModelConfig(
        name="granite-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14_336,
        vocab_size=49_152,
        head_dim=128,
        param_dtype="float32",
        compute_dtype="bfloat16",
        remat_policy="full",
    ),
    exec=ExecConfig(seq_shard=True, remat="full", num_microbatches=1),
)
