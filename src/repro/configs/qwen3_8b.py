"""qwen3-8b — Qwen3 with per-head qk-norm [hf:Qwen/Qwen3-8B].

36L, d_model=4096, 32 heads (GQA kv=8), d_ff=12288, vocab 151936,
RMSNorm applied to q and k per head before RoPE.
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    name="qwen3-8b",
    model=ModelConfig(
        name="qwen3-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=12_288,
        vocab_size=151_936,
        head_dim=128,
        qk_norm=True,
        param_dtype="float32",
        compute_dtype="bfloat16",
        remat_policy="full",
    ),
    exec=ExecConfig(seq_shard=True, remat="full", num_microbatches=1),
)
