"""arctic-480b — Snowflake Arctic: dense-MoE hybrid
[hf:Snowflake/snowflake-arctic-base].

35L, d_model=7168, 56 heads (GQA kv=8), vocab 32000.  Every layer combines
a *dense residual* MLP (d_ff=4864) with a 128-expert top-2 MoE
(d_ff_expert=4864) — Arctic's signature architecture.  ~480 B total
parameters, ~17 B active.
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import ModelConfig, MoEConfig

SPEC = ArchSpec(
    name="arctic-480b",
    model=ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,  # dense-residual width
        vocab_size=32_000,
        head_dim=128,
        moe=MoEConfig(
            num_experts=128,
            top_k=2,
            d_ff_expert=4864,
            capacity_factor=1.25,
            dense_residual=True,
        ),
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat_policy="full",
        attention_impl="chunked",
        attention_chunk=2048,
    ),
    exec=ExecConfig(seq_shard=True, 
        optimizer="adafactor",
        num_microbatches=4,
        accum_dtype="bfloat16",
        fsdp=True,
        remat="full",
    ),
    notes="dense residual MLP + 128e top-2 MoE per layer",
)
