"""qwen1.5-32b — Qwen1.5 with QKV bias, full MHA [hf:Qwen/Qwen1.5 family].

64L, d_model=5120, 40 heads (kv=40 — no grouping), d_ff=27392,
vocab 152064, biases on the QKV projections.
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    name="qwen1.5-32b",
    model=ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        d_ff=27_392,
        vocab_size=152_064,
        head_dim=128,
        qkv_bias=True,
        param_dtype="float32",
        compute_dtype="bfloat16",
        remat_policy="full",
    ),
    exec=ExecConfig(seq_shard=True, remat="full", num_microbatches=1),
)
