"""mamba2-370m — pure SSD state-space model [arXiv:2405.21060].

48L, d_model=1024 (d_inner=2048, 32 SSD heads of dim 64, state=128),
attention-free, vocab 50280, tied embeddings.  Sub-quadratic → runs the
long_500k cell with O(1) decode state.
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import ModelConfig, SSMConfig

SPEC = ArchSpec(
    name="mamba2-370m",
    model=ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50_280,
        head_dim=64,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256),
        sub_quadratic=True,
        param_dtype="float32",
        compute_dtype="bfloat16",
        remat_policy="full",
    ),
    exec=ExecConfig(seq_shard=True, remat="full"),
    notes="attention-free; decode state is O(1) in sequence length",
)
