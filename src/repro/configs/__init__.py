"""Architecture registry: the ten assigned architectures as selectable
configs (``--arch <id>``), their smoke variants, and the shape cells.

The paper's own configuration space — the 69 AWS cluster configurations of
its evaluation — lives in ``repro.cluster`` (it is a cluster-resource grid,
not a model architecture).
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchSpec, ExecConfig, smoke_variant
from repro.configs.shapes import (
    CELLS,
    ShapeCell,
    cell_applicable,
    input_specs,
)

from repro.configs import (  # noqa: E402  (registry imports)
    arctic_480b,
    granite_34b,
    granite_8b,
    kimi_k2_1t_a32b,
    llava_next_mistral_7b,
    mamba2_370m,
    qwen15_32b,
    qwen3_8b,
    whisper_tiny,
    zamba2_1p2b,
)

_MODULES = [
    whisper_tiny,
    kimi_k2_1t_a32b,
    arctic_480b,
    zamba2_1p2b,
    granite_8b,
    granite_34b,
    qwen3_8b,
    qwen15_32b,
    mamba2_370m,
    llava_next_mistral_7b,
]

REGISTRY: Dict[str, ArchSpec] = {m.SPEC.name: m.SPEC for m in _MODULES}
ARCHS: List[str] = list(REGISTRY)


def get(arch: str) -> ArchSpec:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    return REGISTRY[arch]


def smoke(arch: str) -> ArchSpec:
    return smoke_variant(get(arch))


__all__ = [
    "ARCHS",
    "ArchSpec",
    "CELLS",
    "ExecConfig",
    "REGISTRY",
    "ShapeCell",
    "cell_applicable",
    "get",
    "input_specs",
    "smoke",
    "smoke_variant",
]
