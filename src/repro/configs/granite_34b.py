"""granite-34b — IBM Granite 34B code model, MQA [arXiv:2405.04324].

88L, d_model=6144, 48 heads with a SINGLE kv head (MQA), d_ff=24576,
vocab 49152.  The kv=1 head cannot shard over the 16-way model axis — the
divisibility-aware sharding rules keep K/V replicated while Q/O stay
tensor-parallel (see parallel/sharding.py).
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    name="granite-34b",
    model=ModelConfig(
        name="granite-34b",
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        d_ff=24_576,
        vocab_size=49_152,
        head_dim=128,
        param_dtype="float32",
        compute_dtype="bfloat16",
        remat_policy="full",
    ),
    exec=ExecConfig(seq_shard=True, remat="full", num_microbatches=1),
    notes="MQA: kv stays replicated on the model axis",
)
