"""whisper-tiny — encoder–decoder ASR backbone [arXiv:2212.04356].

4L encoder + 4L decoder, d_model=384, 6 heads (kv=6), d_ff=1536, vocab
51865.  LayerNorm, GELU, biased projections, learned decoder positions,
sinusoidal encoder positions.  The conv-over-mel frontend is a STUB: the
encoder consumes precomputed frame embeddings (1500 × 384) supplied by
``input_specs``.  Full attention → long_500k cell skipped (DESIGN §4.1).
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import EncoderConfig, ModelConfig

SPEC = ArchSpec(
    name="whisper-tiny",
    model=ModelConfig(
        name="whisper-tiny",
        family="encdec",
        num_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        head_dim=64,
        mlp_act="gelu",
        norm="layernorm",
        use_bias=True,
        pos_emb="learned",
        max_position=32_768,  # covers the decode_32k cell
        encoder=EncoderConfig(num_layers=4, source_len=1500),
        param_dtype="float32",
        compute_dtype="bfloat16",
        remat_policy="none",  # tiny model: remat buys nothing
    ),
    exec=ExecConfig(seq_shard=True, remat="none", fsdp=False),
    notes="audio frontend stubbed; encoder fixed at 1500 frames",
)
