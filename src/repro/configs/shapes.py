"""The assigned input-shape cells and abstract input specs per cell.

Four cells (LM-family shapes are seq_len × global_batch):

  train_4k      4,096 × 256   — training step
  prefill_32k  32,768 × 32    — inference prefill (fills the decode cache)
  decode_32k   32,768 × 128   — one new token, KV/state cache at 32k
  long_500k   524,288 × 1     — long-context decode; sub-quadratic archs only

``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a cache of
seq_len), not ``train_step``.  ``input_specs`` returns weak-type-correct
``jax.ShapeDtypeStruct`` stand-ins for every model input — no allocation —
which is what the multi-pod dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.models.spec import abstract_tree

__all__ = ["ShapeCell", "CELLS", "cell_applicable", "input_specs", "cache_len"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


CELLS: Dict[str, ShapeCell] = {
    c.name: c
    for c in [
        ShapeCell("train_4k", 4_096, 256, "train"),
        ShapeCell("prefill_32k", 32_768, 32, "prefill"),
        ShapeCell("decode_32k", 32_768, 128, "decode"),
        ShapeCell("long_500k", 524_288, 1, "decode"),
    ]
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """(applicable, reason-if-not).  long_500k needs a sub-quadratic arch."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k-token cache is O(L²) — skipped"
    return True, ""


def cache_len(cell: ShapeCell) -> int:
    return cell.seq_len


def _token_batch(
    cfg: ModelConfig, batch: int, seq: int, *, for_train: bool
) -> Dict[str, Any]:
    """Abstract batch dict for one forward/train step."""
    out: Dict[str, Any] = {}
    text_len = seq
    if cfg.family == "vlm" and cfg.num_patch_tokens:
        text_len = seq - cfg.num_patch_tokens
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_patch_tokens, cfg.d_model), cfg.cdtype
        )
    if cfg.family == "encdec":
        assert cfg.encoder is not None
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.source_len, cfg.d_model), cfg.cdtype
        )
    out["tokens"] = jax.ShapeDtypeStruct((batch, text_len), jnp.int32)
    if for_train:
        out["loss_mask"] = jax.ShapeDtypeStruct((batch, text_len), jnp.float32)
    return out


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn.

    train:   {"batch": {...}}                       → train_step(state, batch)
    prefill: {"batch": {...}, "cache": {...}}       → prefill_step
    decode:  {"tokens", "cache", "index"}           → serve_step
    """
    model = Model(cfg)
    if cell.kind == "train":
        return {"batch": _token_batch(cfg, cell.global_batch, cell.seq_len,
                                      for_train=True)}
    if cell.kind == "prefill":
        cache = abstract_tree(model.cache_specs(cell.global_batch, cell.seq_len))
        return {
            "batch": _token_batch(cfg, cell.global_batch, cell.seq_len,
                                  for_train=False),
            "cache": cache,
        }
    if cell.kind == "decode":
        cache = abstract_tree(model.cache_specs(cell.global_batch, cell.seq_len))
        return {
            "tokens": jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32),
            "cache": cache,
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(cell.kind)
