"""zamba2-1.2b — Mamba2 backbone + shared attention [arXiv:2411.15242].

38 Mamba-2 layers (d_model=2048, d_inner=4096, ssm_state=64, head_dim 64)
with ONE weight-shared attention+MLP block (32 heads, kv=32, d_ff=8192)
applied every 6 layers.  Sub-quadratic backbone → runs the long_500k cell
(the shared block's KV cache is the only attention state).
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import ModelConfig, SSMConfig

SPEC = ArchSpec(
    name="zamba2-1.2b",
    model=ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32_000,
        head_dim=64,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256),
        hybrid_attn_every=6,
        sub_quadratic=True,
        param_dtype="float32",
        compute_dtype="bfloat16",
        remat_policy="full",
    ),
    exec=ExecConfig(seq_shard=True, remat="full", num_microbatches=2),
    notes="shared attn block every 6 mamba layers; LoRA adapters omitted",
)
