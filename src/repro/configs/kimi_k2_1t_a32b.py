"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

61L, d_model=7168, 64 heads (GQA kv=8, head_dim 112), vocab 163840,
MoE: 384 experts, top-8, d_ff_expert=2048, one always-on shared expert
(Kimi/DeepSeek-V3 style).  ~1.04 T total / ~32 B active parameters.

Execution: at 1e12 parameters, AdamW's f32 master+moments (16 B/param)
cannot fit a 4 TB single pod — the config selects bf16 params + Adafactor
(factored second moment, no momentum) + full remat + bf16 gradient
accumulation, which is how trillion-parameter MoEs are actually trained.
"""

from repro.configs.base import ArchSpec, ExecConfig
from repro.models.config import ModelConfig, MoEConfig

SPEC = ArchSpec(
    name="kimi-k2-1t-a32b",
    model=ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=2048,  # shared-expert width
        vocab_size=163_840,
        head_dim=112,
        moe=MoEConfig(
            num_experts=384,
            top_k=8,
            d_ff_expert=2048,
            capacity_factor=1.25,
            shared_experts=1,
        ),
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat_policy="full",
    ),
    exec=ExecConfig(seq_shard=True, 
        optimizer="adafactor",
        num_microbatches=4,
        accum_dtype="bfloat16",
        fsdp=True,
        remat="full",
    ),
    notes="1T-param MoE; Adafactor+bf16 params to fit pod HBM",
)
