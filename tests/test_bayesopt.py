"""Bayesian-optimization engine tests: fast path vs readable reference,
convergence behavior, and the Ruya two-phase search semantics."""

import numpy as np
import pytest

from repro.core import fast_bo
from repro.core.acquisition import expected_improvement
from repro.core.bayesopt import BOSettings, cherrypick_search, ruya_search
from repro.core.gp import fit_gp, gp_predict
from repro.core.search_space import Configuration, SearchSpace

import jax.numpy as jnp


def quad_space(n=25):
    # 1-D quadratic cost surface over n configs; optimum in the middle.
    return SearchSpace(
        [
            Configuration(name=f"c{i}", features=(float(i),), total_memory=float(i))
            for i in range(n)
        ]
    )


def quad_cost(n=25, optimum=12):
    def fn(i):
        return 1.0 + 0.05 * (i - optimum) ** 2

    return fn


class TestFastBOAgainstReference:
    def test_posterior_matches_readable_gp(self):
        rng = np.random.default_rng(0)
        space = quad_space(20)
        x = np.asarray(space.encoded(), np.float32)
        obs_idx = [2, 7, 11, 15]
        cost = quad_cost(20)
        y_obs = np.array([cost(i) for i in obs_idx], np.float32)

        obs_mask = np.zeros(20, bool)
        obs_mask[obs_idx] = True
        y_full = np.zeros(20, np.float32)
        y_full[obs_idx] = y_obs

        pick, max_ei, best = fast_bo.bo_step(x, obs_mask, y_full, ~obs_mask)
        assert 0 <= int(pick) < 20 and not obs_mask[int(pick)]
        assert float(best) == pytest.approx(y_obs.min())

        # Reference: readable gp.py + acquisition.py — EI argmax must agree
        # on the pick under the same hyperparameter grid.
        post = fit_gp(jnp.asarray(x[obs_idx]), jnp.asarray(y_obs))
        mean, std = gp_predict(post, jnp.asarray(x))
        ei = np.array(
            expected_improvement(mean, std, jnp.asarray(y_obs.min()))
        )
        ei[obs_mask] = -np.inf
        assert int(np.argmax(ei)) == int(pick)

    def test_ei_positive_only_where_improvement_plausible(self):
        mean = jnp.array([1.0, 2.0, 0.5])
        std = jnp.array([0.1, 0.1, 0.1])
        ei = expected_improvement(mean, std, jnp.asarray(1.0))
        assert float(ei[1]) < 1e-6  # far above best
        assert float(ei[2]) > 0.4  # clearly below best


class TestSearchers:
    def test_cherrypick_finds_optimum_to_exhaustion(self):
        space = quad_space()
        tr = cherrypick_search(
            space, quad_cost(), np.random.default_rng(0), to_exhaustion=True
        )
        assert sorted(tr.tried) == list(range(25))  # covered everything
        assert tr.best_cost == pytest.approx(1.0)
        assert len(set(tr.tried)) == len(tr.tried)  # no re-evaluations

    def test_cherrypick_beats_random_on_average(self):
        space = quad_space()
        cost = quad_cost()
        bo_iters, rnd_iters = [], []
        for seed in range(20):
            tr = cherrypick_search(
                space, cost, np.random.default_rng(seed), to_exhaustion=True
            )
            bo_iters.append(tr.iterations_until(1.0))
            order = np.random.default_rng(1000 + seed).permutation(25)
            rnd_iters.append(1 + int(np.argmax(order == 12)))
        assert np.mean(bo_iters) < np.mean(rnd_iters)

    def test_ruya_priority_first_then_rest(self):
        space = quad_space()
        prio = [10, 11, 12, 13, 14]
        rest = [i for i in range(25) if i not in prio]
        tr = ruya_search(
            space, quad_cost(), np.random.default_rng(0), prio, rest,
            to_exhaustion=True,
        )
        assert set(tr.tried[: len(prio)]) == set(prio)
        assert tr.phase_boundary == len(prio)
        # optimum (12) is inside the priority group → found very early
        assert tr.iterations_until(1.0) <= len(prio)

    def test_ruya_with_empty_rest_equals_cherrypick(self):
        space = quad_space()
        cost = quad_cost()
        tr_ruya = ruya_search(
            space, cost, np.random.default_rng(7), list(range(25)), [],
            to_exhaustion=True,
        )
        tr_cp = cherrypick_search(
            space, cost, np.random.default_rng(7), to_exhaustion=True
        )
        assert tr_ruya.tried == tr_cp.tried  # identical trajectories

    def test_stopping_criterion_fires(self):
        space = quad_space()
        tr = cherrypick_search(
            space, quad_cost(), np.random.default_rng(3),
            settings=BOSettings(min_observations=6),
        )
        assert tr.stop_iteration is not None
        assert len(tr.tried) == tr.stop_iteration

    def test_max_iters_respected(self):
        space = quad_space()
        tr = cherrypick_search(
            space, quad_cost(), np.random.default_rng(3),
            settings=BOSettings(max_iters=5), to_exhaustion=True,
        )
        assert len(tr.tried) == 5
