"""Import hypothesis if installed; otherwise expose stubs that skip cleanly.

The CI container does not ship `hypothesis`, and test collection must never
hard-fail on an optional dev dependency.  Modules do

    from hypothesis_compat import given, settings, st

and their property tests run normally when hypothesis is available
(`pip install -r requirements-dev.txt`) or are reported as skipped when it
is not — the plain unit tests in the same modules run either way.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Any attribute is a strategy factory returning an inert placeholder."""

        def __getattr__(self, name):
            def factory(*args, **kwargs):
                return None

            return factory

    st = _StrategyStub()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
