"""`repro.fleet.retry` in isolation: deterministic capped-exponential
backoff with seeded jitter, transient retries, permanent fast-fail.

The whole module is pure functions of (policy, seed, attempt) — these
tests pin exactly that: the same inputs always give the same backoff, the
jitter stays inside its advertised band, `PermanentRunError` (and any
unlisted exception) never burns backoff budget, and `call_with_retry`'s
charged backoff equals the deterministic schedule prefix.  A hypothesis
lane (skipped when hypothesis is absent — `tests/hypothesis_compat.py`)
sweeps the bounds over random policies.
"""

import math

import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.profiler import PermanentRunError, TransientRunError
from repro.fleet.retry import (
    RetryPolicy, RetryStats, backoff_s, backoff_schedule, call_with_retry,
)

pytestmark = pytest.mark.chaos


def _raw(policy, attempt):
    return min(
        policy.base_s * policy.multiplier ** (attempt - 1),
        policy.max_backoff_s,
    )


class TestPolicy:
    def test_defaults_valid(self):
        p = RetryPolicy()
        assert p.max_attempts == 4 and p.jitter < 1.0

    @pytest.mark.parametrize(
        "kw",
        [
            {"max_attempts": 0},
            {"jitter": 1.0},
            {"jitter": -0.1},
            {"base_s": -1.0},
            {"multiplier": 0.5},
            {"max_backoff_s": -1.0},
        ],
    )
    def test_rejects_bad_parameters(self, kw):
        with pytest.raises(ValueError):
            RetryPolicy(**kw)


class TestBackoff:
    def test_deterministic(self):
        p = RetryPolicy()
        for seed in (0, 1, 17):
            for k in (1, 2, 3):
                assert backoff_s(p, seed, k) == backoff_s(p, seed, k)

    def test_seed_desynchronizes_clients(self):
        p = RetryPolicy()
        vals = {round(backoff_s(p, seed, 1), 12) for seed in range(16)}
        assert len(vals) > 1  # different seeds, different jitter

    def test_jitter_band(self):
        p = RetryPolicy(jitter=0.25)
        for seed in range(8):
            for k in (1, 2, 3):
                raw = _raw(p, k)
                b = backoff_s(p, seed, k)
                assert raw * (1 - p.jitter) <= b < raw * (1 + p.jitter)

    def test_zero_jitter_is_exact_exponential(self):
        p = RetryPolicy(jitter=0.0, base_s=1.5, multiplier=3.0)
        for k in (1, 2, 3):
            assert backoff_s(p, 0, k) == pytest.approx(_raw(p, k))

    def test_cap_applies(self):
        p = RetryPolicy(
            max_attempts=10, base_s=1.0, multiplier=10.0,
            max_backoff_s=5.0, jitter=0.0,
        )
        assert backoff_s(p, 0, 9) == 5.0

    def test_attempts_are_one_based(self):
        with pytest.raises(ValueError):
            backoff_s(RetryPolicy(), 0, 0)

    def test_schedule_matches_pointwise(self):
        p = RetryPolicy(max_attempts=5)
        sched = backoff_schedule(p, seed=3)
        assert len(sched) == p.max_attempts - 1
        assert sched == [backoff_s(p, 3, k) for k in range(1, 5)]


class TestCallWithRetry:
    def test_first_try_success(self):
        value, st_ = call_with_retry(
            lambda: 42, policy=RetryPolicy(), seed=0,
        )
        assert value == 42
        assert st_.attempts == 1 and st_.backoff_s == 0.0

    def test_transient_retried_with_charged_backoff(self):
        p = RetryPolicy(max_attempts=4)
        calls = [0]

        def flaky():
            calls[0] += 1
            if calls[0] <= 2:
                raise TransientRunError("preempted")
            return "ok"

        value, st_ = call_with_retry(flaky, policy=p, seed=7)
        assert value == "ok"
        assert st_.attempts == 3
        # Charged backoff is exactly the deterministic schedule prefix.
        assert st_.backoff_s == pytest.approx(sum(backoff_schedule(p, 7)[:2]))

    def test_exhaustion_reraises_last_transient(self):
        p = RetryPolicy(max_attempts=3)
        stats = RetryStats(attempts=0)  # caller-owned: starts at zero

        def always():
            raise TransientRunError("still down")

        with pytest.raises(TransientRunError):
            call_with_retry(always, policy=p, seed=0, stats=stats)
        assert stats.attempts == p.max_attempts
        # The final attempt re-raises without charging another delay.
        assert stats.backoff_s == pytest.approx(
            sum(backoff_schedule(p, 0))
        )

    def test_permanent_fast_fails(self):
        stats = RetryStats(attempts=0)

        def broken():
            raise PermanentRunError("bad binary")

        with pytest.raises(PermanentRunError):
            call_with_retry(
                broken, policy=RetryPolicy(), seed=0, stats=stats,
            )
        assert stats.attempts == 1
        assert stats.backoff_s == 0.0  # zero budget burned

    def test_unlisted_exception_propagates_immediately(self):
        def oops():
            raise KeyError("not a run failure")

        with pytest.raises(KeyError):
            call_with_retry(oops, policy=RetryPolicy(), seed=0)

    def test_sleep_injection_receives_charged_delays(self):
        p = RetryPolicy(max_attempts=3)
        slept = []
        calls = [0]

        def flaky():
            calls[0] += 1
            if calls[0] == 1:
                raise TransientRunError("once")
            return 1

        _, st_ = call_with_retry(
            flaky, policy=p, seed=5, sleep=slept.append,
        )
        assert slept == [backoff_s(p, 5, 1)]
        assert st_.backoff_s == pytest.approx(sum(slept))

    def test_stats_accumulate_across_calls(self):
        stats = RetryStats(attempts=0)
        p = RetryPolicy()
        call_with_retry(lambda: 1, policy=p, seed=0, stats=stats)
        call_with_retry(lambda: 2, policy=p, seed=0, stats=stats)
        assert stats.attempts == 2  # probe + profile aggregate in one object

    def test_never_retry_policy(self):
        p = RetryPolicy(max_attempts=1)

        def once():
            raise TransientRunError("no budget")

        with pytest.raises(TransientRunError):
            call_with_retry(once, policy=p, seed=0)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestBackoffProperties:
    @settings(max_examples=200, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**31),
        attempt=st.integers(min_value=1, max_value=12),
        base=st.floats(min_value=0.01, max_value=30.0),
        mult=st.floats(min_value=1.0, max_value=8.0),
        cap=st.floats(min_value=0.01, max_value=120.0),
        jitter=st.floats(min_value=0.0, max_value=0.99),
    )
    def test_backoff_in_band_and_finite(
        self, seed, attempt, base, mult, cap, jitter,
    ):
        p = RetryPolicy(
            max_attempts=13, base_s=base, multiplier=mult,
            max_backoff_s=cap, jitter=jitter,
        )
        raw = _raw(p, attempt)
        b = backoff_s(p, seed, attempt)
        assert math.isfinite(b) and b >= 0.0
        assert raw * (1 - jitter) - 1e-12 <= b <= raw * (1 + jitter)
        assert b == backoff_s(p, seed, attempt)  # pure function
