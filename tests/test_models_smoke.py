"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward + one real train step on CPU, asserting
output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

import repro.configs as C
from repro.data import SyntheticDataset, shard_batch
from repro.models import Model, init_tree
from repro.runtime.steps import init_train_state, make_train_step


def _batch_for(cfg, batch=2, seq=16, seed=0):
    return shard_batch(
        SyntheticDataset(cfg, global_batch=batch, seq_len=seq, seed=seed).batch_at(0)
    )


@pytest.mark.parametrize("arch", C.ARCHS)
def test_forward_shapes_and_finiteness(arch):
    spec = C.smoke(arch)
    cfg = spec.model
    model = Model(cfg)
    params = init_tree(jax.random.key(0), model.param_specs())
    batch = _batch_for(cfg)
    logits, aux = model.forward(params, batch)
    b, t = batch["tokens"].shape
    assert logits.shape == (b, t, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", C.ARCHS)
def test_one_train_step_decreases_nothing_nan(arch):
    spec = C.smoke(arch)
    cfg = spec.model
    model = Model(cfg)
    ex = spec.exec.replace(num_microbatches=1, warmup_steps=1, total_steps=10)
    state = init_train_state(model, ex, jax.random.key(0))
    step = jax.jit(make_train_step(model, ex))
    batch = _batch_for(cfg, batch=4, seq=16)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state2["opt"].step) == 1
    # a parameter actually moved
    before = jax.tree.leaves(state["params"])
    after = jax.tree.leaves(state2["params"])
    moved = any(bool(jnp.any(a != b)) for a, b in zip(before, after))
    assert moved


@pytest.mark.parametrize("arch", C.ARCHS)
def test_two_steps_keep_loss_finite_and_moving(arch):
    spec = C.smoke(arch)
    model = Model(spec.model)
    ex = spec.exec.replace(num_microbatches=1, learning_rate=5e-3,
                           warmup_steps=1, total_steps=100)
    state = init_train_state(model, ex, jax.random.key(1))
    step = jax.jit(make_train_step(model, ex))
    ds = SyntheticDataset(spec.model, global_batch=4, seq_len=16, seed=3)
    losses = []
    for i in range(3):
        state, m = step(state, shard_batch(ds.batch_at(i)))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))


def test_full_configs_match_assignment_table():
    """The FULL configs carry the exact published hyperparameters."""
    expect = {
        "whisper-tiny": dict(num_layers=4, d_model=384, num_heads=6,
                             num_kv_heads=6, d_ff=1536, vocab_size=51865),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, vocab_size=163840),
        "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                            num_kv_heads=8, d_ff=4864, vocab_size=32000),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, num_heads=32,
                            num_kv_heads=32, d_ff=8192, vocab_size=32000),
        "granite-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                           num_kv_heads=8, d_ff=14336, vocab_size=49152),
        "granite-34b": dict(num_layers=88, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24576, vocab_size=49152),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936),
        "qwen1.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                            num_kv_heads=40, d_ff=27392, vocab_size=152064),
        "mamba2-370m": dict(num_layers=48, d_model=1024, vocab_size=50280),
        "llava-next-mistral-7b": dict(num_layers=32, d_model=4096,
                                      num_heads=32, num_kv_heads=8,
                                      d_ff=14336, vocab_size=32000),
    }
    for arch, fields in expect.items():
        cfg = C.get(arch).model
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # family-specific extras
    kimi = C.get("kimi-k2-1t-a32b").model.moe
    assert kimi.num_experts == 384 and kimi.top_k == 8 and kimi.d_ff_expert == 2048
    arctic = C.get("arctic-480b").model.moe
    assert arctic.num_experts == 128 and arctic.top_k == 2 and arctic.dense_residual
    assert C.get("zamba2-1.2b").model.ssm.d_state == 64
    assert C.get("mamba2-370m").model.ssm.d_state == 128
    assert C.get("qwen3-8b").model.qk_norm
    assert C.get("qwen1.5-32b").model.qkv_bias
    assert C.get("llava-next-mistral-7b").model.num_patch_tokens == 2880


def test_param_counts_in_published_ballpark():
    from repro.models.model import active_params, total_params

    n_kimi = total_params(C.get("kimi-k2-1t-a32b").model)
    assert 0.9e12 < n_kimi < 1.3e12  # ~1 T
    a_kimi = active_params(C.get("kimi-k2-1t-a32b").model)
    assert 25e9 < a_kimi < 45e9  # ~32 B active
    n_arctic = total_params(C.get("arctic-480b").model)
    assert 0.4e12 < n_arctic < 0.56e12
    n_g8 = total_params(C.get("granite-8b").model)
    assert 7e9 < n_g8 < 9.5e9
    n_m2 = total_params(C.get("mamba2-370m").model)
    assert 0.3e9 < n_m2 < 0.5e9
