"""Optimizer tests: convergence on a quadratic, state-spec consistency,
microbatch-accumulation equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.spec import TensorSpec, abstract_tree
from repro.optim import (
    adafactor,
    adamw,
    clip_by_global_norm,
    global_norm,
    linear_warmup_cosine,
    make_optimizer,
)
from repro.parallel.microbatch import accumulate_gradients


def quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array([[1.0, -1.0]])}


def quad_loss(params):
    return jnp.sum(params["w"] ** 2) + jnp.sum(params["b"] ** 2)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_on_quadratic(name):
    opt = make_optimizer(name, weight_decay=0.0)
    params = quadratic_params()
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(quad_loss)(params)
        params, state = opt.update(params, state, grads, jnp.asarray(0.05))
    assert float(quad_loss(params)) < 1e-2
    assert int(state.step) == 200


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_state_specs_match_init_shapes(name):
    opt = make_optimizer(name)
    pspecs = {
        "w": TensorSpec((8, 4), jnp.float32, ("embed", "ffn")),
        "s": TensorSpec((4,), jnp.float32, ("ffn",)),
    }
    params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), pspecs,
        is_leaf=lambda x: isinstance(x, TensorSpec),
    )
    state = opt.init(params)
    specs = abstract_tree(opt.state_specs(pspecs))
    real = jax.tree.map(lambda x: (x.shape, x.dtype), state.inner)
    spec_shapes = jax.tree.map(lambda x: (x.shape, x.dtype), specs)
    assert real == spec_shapes


def test_adafactor_state_is_factored_and_small():
    opt = adafactor()
    params = {"w": jnp.zeros((128, 64))}
    state = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state.inner))
    assert n_state == 128 + 64  # vr + vc, not 128·64


def test_clip_by_global_norm():
    grads = {"a": jnp.ones((10,)) * 10.0}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below the threshold → unchanged
    small = {"a": jnp.ones((4,)) * 0.1}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.1)


def test_schedule_warmup_and_decay():
    lr0 = float(linear_warmup_cosine(jnp.asarray(0), 1e-3, 100, 1000))
    lr_mid = float(linear_warmup_cosine(jnp.asarray(100), 1e-3, 100, 1000))
    lr_end = float(linear_warmup_cosine(jnp.asarray(1000), 1e-3, 100, 1000))
    assert lr0 == pytest.approx(0.0, abs=1e-9)
    assert lr_mid == pytest.approx(1e-3, rel=1e-3)
    assert lr_end < 0.2 * 1e-3


class TestMicrobatchAccumulation:
    def test_equals_single_shot(self):
        key = jax.random.key(0)
        w = jax.random.normal(key, (8, 4))
        batch = {"x": jax.random.normal(jax.random.key(1), (16, 8)),
                 "y": jax.random.normal(jax.random.key(2), (16, 4))}

        def grad_fn(params, mb):
            def loss(p):
                pred = mb["x"] @ p
                return jnp.mean((pred - mb["y"]) ** 2)

            g = jax.grad(loss)(params)
            return g, {"loss": loss(params)}

        g1, m1 = accumulate_gradients(grad_fn, w, batch, 1)
        g4, m4 = accumulate_gradients(grad_fn, w, batch, 4)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g4), atol=1e-6)
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-6)

    def test_rejects_indivisible_batch(self):
        def grad_fn(p, mb):
            return p, {"loss": jnp.zeros(())}

        with pytest.raises(ValueError):
            accumulate_gradients(
                grad_fn, jnp.zeros(()), {"x": jnp.zeros((10, 2))}, 3
            )

    def test_bf16_accumulator_close_to_f32(self):
        w = jax.random.normal(jax.random.key(0), (8, 4))
        batch = {"x": jax.random.normal(jax.random.key(1), (16, 8)),
                 "y": jax.random.normal(jax.random.key(2), (16, 4))}

        def grad_fn(params, mb):
            def loss(p):
                return jnp.mean((mb["x"] @ p - mb["y"]) ** 2)

            return jax.grad(loss)(params), {"loss": loss(params)}

        g32, _ = accumulate_gradients(grad_fn, w, batch, 4)
        gbf, _ = accumulate_gradients(
            grad_fn, w, batch, 4, accum_dtype=jnp.bfloat16
        )
        np.testing.assert_allclose(
            np.asarray(g32), np.asarray(gbf, np.float32), atol=0.05
        )
