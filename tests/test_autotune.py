"""TPU-autotuner components that run without compiles: the variant space,
feature encoding, and the §III-D split over predicted peaks."""

import math

import numpy as np
import pytest

from repro.launch.autotune import ExecVariant, HBM_PER_CHIP, variant_space


class TestVariantSpace:
    def test_train_space_is_full_grid(self):
        space = variant_space("train")
        assert len(space) == 5 * 3 * 2 * 2
        names = [v.name for v in space]
        assert len(set(names)) == len(names)  # unique

    def test_serve_space_is_sharding_only(self):
        space = variant_space("decode")
        assert len(space) == 4
        assert all(v.num_microbatches == 1 for v in space)

    def test_features_are_principal_axes(self):
        v = ExecVariant(8, "full", True, False)
        f = v.features()
        assert f[0] == pytest.approx(math.log2(8))
        assert f[1] == 2.0  # remat level
        assert f[2] == 1.0 and f[3] == 0.0


class TestMemoryAwareSplit:
    def test_predicted_fit_prioritized(self):
        """Configs predicted under the HBM line go in the priority group —
        the §III-D split with requirement-per-config instead of
        memory-per-config (DESIGN.md §2.1)."""
        space = variant_space("train")
        # synthetic linear prediction: peak = flat + act/(microbatches)
        flat = 6 * 2**30
        act1 = 40 * 2**30
        preds = {
            v.name: flat + act1 / v.num_microbatches *
            (0.5 if v.remat == "full" else 1.0) *
            (0.25 if v.seq_shard else 1.0)
            for v in space
        }
        prio = [i for i, v in enumerate(space)
                if preds[v.name] <= HBM_PER_CHIP * 1.05]
        rest = [i for i in range(len(space)) if i not in prio]
        assert prio and rest
        # every high-microbatch + full-remat + seq-shard config fits
        for i, v in enumerate(space):
            if v.num_microbatches >= 8 and v.remat == "full" and v.seq_shard:
                assert i in prio
        # micro=1, no remat, no seq-shard cannot fit
        for i, v in enumerate(space):
            if v.num_microbatches == 1 and v.remat == "none" and not v.seq_shard:
                assert i in rest
