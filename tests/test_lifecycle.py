"""Job lifecycle under adversity: cancellation, preemption, mid-flight
failure, drain semantics (`FleetFailedError`), exactly-once results, and
live elastic re-sharding.

The load-bearing claim is the one the golden disturbed-fleet scenario
pins at full scale: retiring one row mid-flight (cancel / fail / preempt)
must not perturb its lockstep chunk-mates by a single bit, because the
engine's rows are vmap-independent and retirement is just the `done` flag.
These tests re-prove it on a small fleet and exercise every status path.

Part of the chaos lane (`pytest -m chaos`); runs in tier-1.
"""

import jax
import numpy as np
import pytest

from golden.scenarios import synth_space_table
from repro.core.bayesopt import BOSettings
from repro.fleet import FleetFailedError, FleetJob, TuningSession

pytestmark = pytest.mark.chaos

ST = BOSettings(max_iters=8)


def _job(name, n=30):
    space, table = synth_space_table(n)
    return FleetJob(name=name, space=space, cost_table=table)


def _session(**kw):
    kw.setdefault("settings", ST)
    kw.setdefault("mode", "cherrypick")
    kw.setdefault("warm_start", False)
    return TuningSession(**kw)


def _clean_outcomes(k=2):
    s = _session()
    for i in range(k):
        s.submit(_job(f"j{i}"), seed=i)
    return s.drain()


class TestCancel:
    def test_cancel_pending_publishes_empty_partial(self):
        s = _session()
        h = s.submit(_job("j0"), seed=0)
        assert h.status == "pending"
        assert h.cancel()
        assert h.status == "cancelled"
        out = h.outcome()
        assert out.status == "cancelled"
        assert out.records == []
        with pytest.raises(RuntimeError, match="cancelled"):
            out.best_cost
        assert not h.cancel()  # idempotent: already finished

    def test_cancel_midflight_keeps_partial_trials(self):
        s = _session()
        h = s.submit(_job("j0"), seed=0)
        for _ in range(3):
            s.step()
        assert h.status == "running"
        assert h.cancel()
        out = h.outcome()
        assert out.status == "cancelled"
        full = _clean_outcomes(1)[0]
        assert 0 < len(out.records) < len(full.records)
        # The partial trials are a prefix of the undisturbed trace.
        k = len(out.records)
        assert [r.as_dict() for r in out.records] == [
            r.as_dict() for r in full.records[:k]
        ]

    def test_cancel_does_not_perturb_chunk_mates(self):
        """Retire one row of a live chunk; its chunk-mate's final trace is
        bit-identical to an undisturbed fleet's."""
        clean = _clean_outcomes(2)
        s = _session()
        h0 = s.submit(_job("j0"), seed=0)
        h1 = s.submit(_job("j1"), seed=1)
        for _ in range(3):
            s.step()
        assert h0.cancel()
        s.drain()
        assert h1.outcome().as_dict() == clean[1].as_dict()

    def test_cancel_after_done_returns_false(self):
        s = _session()
        h = s.submit(_job("j0"), seed=0)
        s.drain()
        assert h.status == "done"
        assert not h.cancel()
        assert h.outcome().status == "converged"


class TestPreempt:
    def test_preempt_midflight(self):
        s = _session()
        h = s.submit(_job("j0"), seed=0)
        s.step()
        assert s.preempt(h)
        assert h.status == "preempted"
        assert h.outcome().status == "preempted"

    def test_preempt_below_evicts_by_job_priority(self):
        s = _session()
        low = [s.submit(_job(f"lo{i}"), seed=i) for i in range(2)]
        hi = s.submit(_job("hi"), seed=9, job_priority=5)
        s.step()
        victims = s.preempt_below(1)
        assert {v.uid for v in victims} == {h.uid for h in low}
        assert all(h.status == "preempted" for h in low)
        assert hi.status == "running"
        s.drain()
        assert hi.outcome().status == "converged"

    def test_preempt_below_noop_when_all_ranked(self):
        s = _session()
        s.submit(_job("j0"), seed=0, job_priority=3)
        s.step()
        assert s.preempt_below(1) == []


class TestFailAndDrainGuard:
    def test_all_live_failed_drain_raises(self):
        s = _session()
        h = s.submit(_job("j0"), seed=0)
        s.step()
        assert s.fail(h, "executor died")
        with pytest.raises(FleetFailedError, match="j0"):
            s.drain()
        # The outcome is still published and first-class.
        assert s.results()[0].status == "failed"
        assert "executor died" in s.results()[0].failure

    def test_second_drain_does_not_reraise(self):
        s = _session()
        h = s.submit(_job("j0"), seed=0)
        s.step()
        s.fail(h)
        with pytest.raises(FleetFailedError):
            s.drain()
        assert len(s.drain()) == 1  # failure already reported once

    def test_mixed_fleet_drain_returns_normally(self):
        s = _session()
        h0 = s.submit(_job("j0"), seed=0)
        s.submit(_job("j1"), seed=1)
        s.step()
        s.fail(h0)
        outs = s.drain()
        assert [o.status for o in outs] == ["failed", "converged"]


class TestResultsExactlyOnce:
    def test_every_terminal_status_appears_exactly_once(self):
        s = _session()
        h_ok = s.submit(_job("ok"), seed=0)
        h_cancel = s.submit(_job("cxl"), seed=1)
        h_fail = s.submit(_job("bad"), seed=2)
        h_pre = s.submit(_job("pre"), seed=3)
        s.step()
        h_cancel.cancel()
        s.fail(h_fail)
        s.preempt(h_pre)
        outs = s.drain()
        assert len(outs) == 4 == len(s.results())
        assert [o.status for o in outs] == [
            "converged", "cancelled", "failed", "preempted",
        ]
        # Stable across repeated calls — nothing duplicated or dropped.
        assert [o.name for o in s.results()] == ["ok", "cxl", "bad", "pre"]
        assert s.results() == outs
        assert h_ok.outcome() is outs[0]


class TestReshard:
    def test_live_device_join_is_bit_identical(self):
        if jax.device_count() < 2:
            pytest.skip("needs 2 devices; XLA_FLAGS force-count not in effect")
        clean = _clean_outcomes(4)
        s = _session()
        handles = [s.submit(_job(f"j{i}"), seed=i) for i in range(4)]
        for _ in range(3):
            s.step()
        assert s.reshard(shard=2) == 4  # all four rows survive the move
        s.drain()
        for h, ref in zip(handles, clean):
            assert h.outcome().as_dict() == ref.as_dict()

    def test_reshard_with_no_live_rows_is_noop(self):
        s = _session()
        s.submit(_job("j0"), seed=0)
        s.drain()
        assert s.reshard(shard=None) == 0
