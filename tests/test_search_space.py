"""Search-space split invariants (paper §III-D) — unit + hypothesis — and
the host↔device split identity that lets `TuningSession` narrow on device
while staying bit-identical to the host-split drivers."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.memory_model import MemoryCategory, MemoryModel, fit_memory_model
from repro.core.search_space import (
    Configuration,
    SearchSpace,
    split_masks_device,
    split_search_space,
)


def make_space(mems):
    return SearchSpace(
        [
            Configuration(
                name=f"c{i}", features=(float(i), float(m)), total_memory=float(m),
                num_nodes=1,
            )
            for i, m in enumerate(mems)
        ]
    )


def model_with(category, slope=1.0, intercept=0.0, readings=(1.0, 2.0)):
    return MemoryModel(
        category=category, slope=slope, intercept=intercept, r2=1.0,
        sizes=(1.0, 2.0), readings=readings,
    )


class TestSplit:
    def test_unclear_means_no_split(self):
        space = make_space([10, 20, 30])
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.UNCLEAR), 100.0
        )
        assert prio == [0, 1, 2] and rest == []

    def test_flat_picks_lowest_memory(self):
        space = make_space([50, 10, 40, 20, 30, 60, 70])
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.FLAT), 100.0, flat_fraction=2 / 7
        )
        assert prio == [1, 3]  # the two lowest-memory configs
        assert set(prio) | set(rest) == set(range(7))

    def test_linear_prioritizes_sufficient_memory(self):
        space = make_space([10, 50, 100, 200])
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.LINEAR, slope=1.0), 90.0, leeway=0.0
        )
        assert prio == [2, 3]

    def test_linear_requirement_above_all_goes_to_extremes(self):
        space = make_space(list(range(10, 110, 10)))  # 10..100
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.LINEAR, slope=10.0), 1000.0,
            leeway=0.0, extreme_fraction=0.2,
        )
        # both the lowest and the highest memory configs are prioritized
        assert 0 in prio and 1 in prio and 8 in prio and 9 in prio
        assert len(prio) == 4

    def test_linear_requirement_met_by_all_degrades_to_baseline(self):
        space = make_space([100, 200, 300])
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.LINEAR, slope=0.1), 10.0, leeway=0.0
        )
        assert prio == [0, 1, 2] and rest == []


class TestSplitProperties:
    @given(
        mems=st.lists(st.floats(1.0, 1e4), min_size=2, max_size=69),
        input_size=st.floats(1.0, 1e4),
        slope=st.floats(0.01, 10.0),
    )
    @settings(max_examples=60, deadline=None)
    def test_partition_is_exact(self, mems, input_size, slope):
        space = make_space(mems)
        for cat in MemoryCategory:
            prio, rest = split_search_space(
                space, model_with(cat, slope=slope), input_size
            )
            assert sorted(prio + rest) == list(range(len(mems)))
            assert not (set(prio) & set(rest))
            assert len(prio) >= 1

    @given(mems=st.lists(st.floats(1.0, 1e4), min_size=3, max_size=40))
    @settings(max_examples=40, deadline=None)
    def test_flat_group_is_memory_minimal(self, mems):
        space = make_space(mems)
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.FLAT), 1.0, flat_fraction=0.15
        )
        if rest:
            assert max(mems[i] for i in prio) <= min(mems[j] for j in rest) + 1e-9


def assert_masks_match_host(space, model, input_size, **kw):
    prio, rest = split_search_space(space, model, input_size, **kw)
    mask = np.asarray(split_masks_device(space, model, input_size, **kw))
    assert mask.dtype == bool and mask.shape == (len(space),)
    assert list(np.flatnonzero(mask)) == prio
    assert list(np.flatnonzero(~mask)) == rest


class TestDeviceSplitIdentity:
    """`split_masks_device` (float64 on device, stable sort) must reproduce
    `split_search_space` EXACTLY — the priority mask is the sorted-index
    host split bit-for-bit, every category and fallback included."""

    def random_space(self, n, seed, multi_node=True):
        rng = np.random.default_rng(seed)
        return SearchSpace(
            [
                Configuration(
                    name=f"c{i}",
                    features=(float(i),),
                    total_memory=float(rng.choice([1, 2, 4, 8, 16, 32, 64]))
                    * float(rng.integers(1, 9)) * 2.0**30,
                    num_nodes=int(rng.integers(1, 17)) if multi_node else 1,
                )
                for i in range(n)
            ]
        )

    def test_all_categories_and_fallbacks(self):
        for n in (3, 20, 69):
            for seed in range(4):
                space = self.random_space(n, seed)
                for cat in MemoryCategory:
                    for inp, slope in ((1.0, 0.01), (40 * 2.0**30, 1.0),
                                       (1e15, 10.0)):
                        assert_masks_match_host(
                            space, model_with(cat, slope=slope), inp,
                            per_node_overhead=0.5 * 2.0**30,
                        )

    def test_borderline_requirement_equality(self):
        """Configs whose memory EQUALS the float64 requirement must land on
        the same side of the ≥ as the host rule (this is what float32-on-
        device could get wrong, and why the device split runs in float64)."""
        model = model_with(MemoryCategory.LINEAR, slope=3.0,
                           intercept=1.23456789e9)
        inp = 17.123456789e9
        req = model.estimate(inp) * 1.1 + 0.5 * 2.0**30 * 4
        space = SearchSpace(
            [
                Configuration(name="eq", features=(0.0,),
                              total_memory=float(req), num_nodes=4),
                Configuration(name="below", features=(1.0,),
                              total_memory=float(np.nextafter(req, 0.0)),
                              num_nodes=4),
                Configuration(name="above", features=(2.0,),
                              total_memory=float(np.nextafter(req, np.inf)),
                              num_nodes=4),
            ]
        )
        assert_masks_match_host(
            space, model, inp, leeway=0.10,
            per_node_overhead=0.5 * 2.0**30,
        )

    def test_flat_stable_ties(self):
        """Equal memories: the stable argsort must break ties like
        np.argsort(kind='stable') — first occurrence wins."""
        space = make_space([5.0, 1.0, 1.0, 1.0, 5.0, 1.0, 9.0])
        assert_masks_match_host(
            space, model_with(MemoryCategory.FLAT), 1.0, flat_fraction=0.3
        )

    def test_cluster_catalog_splits(self):
        """The paper's real 69-config catalog, every profiled workload."""
        from repro.cluster.simulator import ClusterSimulator
        from repro.core.profiler import profile_job

        for key in ("kmeans/spark/huge", "terasort/hadoop/bigdata",
                    "pagerank/spark/huge"):
            sim = ClusterSimulator.for_job(key)
            GiB = 2.0**30
            prof = profile_job(sim.profile_run_fn(), sim.job.input_gb * GiB)
            assert_masks_match_host(
                sim.space, prof.model, sim.job.input_gb * GiB,
                per_node_overhead=0.5 * GiB,
            )

    @given(
        mems=st.lists(st.floats(1.0, 1e12), min_size=2, max_size=69),
        input_size=st.floats(1.0, 1e12),
        slope=st.floats(0.01, 10.0),
    )
    @settings(max_examples=40, deadline=None)
    def test_identity_property(self, mems, input_size, slope):
        space = make_space(mems)
        for cat in MemoryCategory:
            assert_masks_match_host(
                space, model_with(cat, slope=slope), input_size
            )

    def test_identity_seeded_lane(self):
        """Always-on randomized lane (mirrors the hypothesis property when
        hypothesis is unavailable)."""
        rng = np.random.default_rng(7)
        for _ in range(12):
            n = int(rng.integers(2, 40))
            mems = (10.0 ** rng.uniform(0, 12, size=n)).tolist()
            space = make_space(mems)
            for cat in MemoryCategory:
                assert_masks_match_host(
                    space,
                    model_with(cat, slope=float(10.0 ** rng.uniform(-2, 1))),
                    float(10.0 ** rng.uniform(0, 12)),
                )
