"""Search-space split invariants (paper §III-D) — unit + hypothesis."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.memory_model import MemoryCategory, MemoryModel, fit_memory_model
from repro.core.search_space import Configuration, SearchSpace, split_search_space


def make_space(mems):
    return SearchSpace(
        [
            Configuration(
                name=f"c{i}", features=(float(i), float(m)), total_memory=float(m),
                num_nodes=1,
            )
            for i, m in enumerate(mems)
        ]
    )


def model_with(category, slope=1.0, intercept=0.0, readings=(1.0, 2.0)):
    return MemoryModel(
        category=category, slope=slope, intercept=intercept, r2=1.0,
        sizes=(1.0, 2.0), readings=readings,
    )


class TestSplit:
    def test_unclear_means_no_split(self):
        space = make_space([10, 20, 30])
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.UNCLEAR), 100.0
        )
        assert prio == [0, 1, 2] and rest == []

    def test_flat_picks_lowest_memory(self):
        space = make_space([50, 10, 40, 20, 30, 60, 70])
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.FLAT), 100.0, flat_fraction=2 / 7
        )
        assert prio == [1, 3]  # the two lowest-memory configs
        assert set(prio) | set(rest) == set(range(7))

    def test_linear_prioritizes_sufficient_memory(self):
        space = make_space([10, 50, 100, 200])
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.LINEAR, slope=1.0), 90.0, leeway=0.0
        )
        assert prio == [2, 3]

    def test_linear_requirement_above_all_goes_to_extremes(self):
        space = make_space(list(range(10, 110, 10)))  # 10..100
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.LINEAR, slope=10.0), 1000.0,
            leeway=0.0, extreme_fraction=0.2,
        )
        # both the lowest and the highest memory configs are prioritized
        assert 0 in prio and 1 in prio and 8 in prio and 9 in prio
        assert len(prio) == 4

    def test_linear_requirement_met_by_all_degrades_to_baseline(self):
        space = make_space([100, 200, 300])
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.LINEAR, slope=0.1), 10.0, leeway=0.0
        )
        assert prio == [0, 1, 2] and rest == []


class TestSplitProperties:
    @given(
        mems=st.lists(st.floats(1.0, 1e4), min_size=2, max_size=69),
        input_size=st.floats(1.0, 1e4),
        slope=st.floats(0.01, 10.0),
    )
    @settings(max_examples=60, deadline=None)
    def test_partition_is_exact(self, mems, input_size, slope):
        space = make_space(mems)
        for cat in MemoryCategory:
            prio, rest = split_search_space(
                space, model_with(cat, slope=slope), input_size
            )
            assert sorted(prio + rest) == list(range(len(mems)))
            assert not (set(prio) & set(rest))
            assert len(prio) >= 1

    @given(mems=st.lists(st.floats(1.0, 1e4), min_size=3, max_size=40))
    @settings(max_examples=40, deadline=None)
    def test_flat_group_is_memory_minimal(self, mems):
        space = make_space(mems)
        prio, rest = split_search_space(
            space, model_with(MemoryCategory.FLAT), 1.0, flat_fraction=0.15
        )
        if rest:
            assert max(mems[i] for i in prio) <= min(mems[j] for j in rest) + 1e-9
