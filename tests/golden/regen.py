"""Regenerate the committed golden-trace fixtures.

    PYTHONPATH=src python -m tests.golden.regen [--check]

For each pinned scenario this runs the UNSHARDED feature-layout
`TuningSession` (the reference engine) and, where a per-job sequential
reference exists (the cold scenarios), cross-checks it trace-for-trace
with `cherrypick_search`/`ruya_search` before writing the fixture — a
fixture can only change when the reference numerics deliberately change.
Every scenario is ALSO replayed through the fused streaming-kernel lane
(``layout="fused"``, `repro.kernels.ei_argmax`) and must reproduce the
reference outcomes `as_dict`-identically before anything is written.
``--check`` verifies the committed fixtures instead of rewriting them
(exit 1 on drift).

The env must match the test environment: the CPU backend is forced to
multiple host devices before JAX initializes, exactly like
`tests/conftest.py` (device count does not affect single-device numerics,
but keeping the environments identical removes the variable entirely).
"""

import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.hostdevices import force_host_device_count  # noqa: E402

force_host_device_count(4)  # same topology as tests/conftest.py

import argparse
import json

import numpy as np


def _sequential_crosscheck(name, outcomes):
    """Pin the fixture to the per-job sequential engine where one exists."""
    from repro.core.bayesopt import BOSettings, cherrypick_search, ruya_search

    from . import scenarios as sc

    if name == "n69-exhaustion":
        space, table = sc.synth_space_table(69)
        refs = [
            cherrypick_search(
                space, lambda i: float(table[i]), np.random.default_rng(s),
                to_exhaustion=True,
            )
            for s in range(len(outcomes))
        ]
    elif name == "n512-budgeted":
        space, table = sc.synth_space_table(512)
        st = BOSettings(max_iters=10)
        prio = list(range(0, 50))
        rest = list(range(50, 512))
        refs = [
            ruya_search(
                space, lambda i: float(table[i]), np.random.default_rng(s),
                prio, rest, settings=st, to_exhaustion=True,
            )
            for s in range(len(outcomes))
        ]
    elif name == "elastic-fleet":
        # No sequential analogue either — instead, cross-check the fixture
        # against the DISTURBED replay (transient profiling faults, a
        # cancelled victim, a live shard-loss reshard): the survivors must
        # reproduce the undisturbed outcomes bit-for-bit, modulo the
        # fault-reporting fields.
        survivors, victim = sc.run_elastic_fleet_disturbed()
        assert victim.status == "cancelled", victim.status
        assert len(survivors) == len(outcomes)
        drop = ("profile_attempts", "retry_backoff_s")
        for j, (got, ref) in enumerate(zip(survivors, outcomes)):
            g, r = got.as_dict(), ref.as_dict()
            for key in drop:
                g.pop(key), r.pop(key)
            assert g == r, f"{name} job {j}: disturbed survivors diverged"
        assert survivors[0].profile_attempts == 3, "faults were not injected"
        return len(survivors)
    else:  # warm-session: no sequential analogue (seeding is session-only)
        return 0
    for j, (out, ref) in enumerate(zip(outcomes, refs)):
        tr = out.trace()
        assert tr.tried == ref.tried, f"{name} job {j}: session != sequential"
        assert tr.costs == ref.costs, f"{name} job {j}: session != sequential"
        assert tr.stop_iteration == ref.stop_iteration
        assert tr.phase_boundary == ref.phase_boundary
    return len(refs)


def _fused_crosscheck(name, outcomes):
    """Replay the scenario on the fused streaming-kernel lane: the fixture
    is only valid if ``layout="fused"`` reproduces every outcome dict
    bit-for-bit (the kernel-identity contract of `repro.kernels.ei_argmax`
    at the whole-session level)."""
    from .scenarios import SCENARIOS

    fused = SCENARIOS[name](layout="fused")
    assert len(fused) == len(outcomes)
    for j, (got, ref) in enumerate(zip(fused, outcomes)):
        assert got.as_dict() == ref.as_dict(), (
            f"{name} job {j}: fused lane diverged from feature reference"
        )


def _spill_surface_payload():
    """The pinned spill surface: `_spill_factor` for every Table I job ×
    every committed configuration.  Not a session scenario — a direct pin
    on the memory-cliff model, so any change to the usable-memory
    accounting (e.g. the overhead clamp) shows up as explicit fixture
    drift instead of silently moving every cost table."""
    from repro.cluster.nodes import enumerate_cluster_configs
    from repro.cluster.simulator import _spill_factor
    from repro.cluster.workloads import JOBS

    configs = enumerate_cluster_configs()
    return {
        "scenario": "spill-surface",
        "regen": "PYTHONPATH=src python -m tests.golden.regen",
        "configs": [c.name for c in configs],
        "spill": {
            key: [float(_spill_factor(job, c)) for c in configs]
            for key, job in sorted(JOBS.items())
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="verify committed fixtures instead of rewriting")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of scenario names")
    args = ap.parse_args(argv)

    from . import fixture_path
    from .scenarios import SCENARIOS

    names = args.only or (list(SCENARIOS) + ["spill-surface"])
    drift = []
    for name in names:
        if name == "spill-surface":
            payload = json.loads(json.dumps(_spill_surface_payload()))
            path = fixture_path(name)
            if args.check:
                with open(path) as f:
                    committed = json.load(f)
                same = committed == payload
                print(f"{name}: {'OK' if same else 'DRIFT'} "
                      f"({len(payload['spill'])} jobs x "
                      f"{len(payload['configs'])} configs)")
                if not same:
                    drift.append(name)
            else:
                with open(path, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                    f.write("\n")
                print(f"wrote {path} ({len(payload['spill'])} jobs x "
                      f"{len(payload['configs'])} configs)")
            continue
        if name not in SCENARIOS:
            print(f"unknown scenario {name!r}; have {list(SCENARIOS)}")
            return 2
        outcomes = SCENARIOS[name]()  # unsharded, feature layout
        checked = _sequential_crosscheck(name, outcomes)
        _fused_crosscheck(name, outcomes)
        payload = {
            "scenario": name,
            "engine": "TuningSession(layout='feature', shard=None)",
            "sequential_crosschecked_jobs": checked,
            "regen": "PYTHONPATH=src python -m tests.golden.regen",
            "outcomes": [
                json.loads(json.dumps(o.as_dict())) for o in outcomes
            ],
        }
        path = fixture_path(name)
        if args.check:
            with open(path) as f:
                committed = json.load(f)
            same = committed["outcomes"] == payload["outcomes"]
            print(f"{name}: {'OK' if same else 'DRIFT'} "
                  f"({len(outcomes)} jobs, {checked} sequential-checked, "
                  f"fused-checked)")
            if not same:
                drift.append(name)
            continue
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path} ({len(outcomes)} jobs, "
              f"{checked} sequential-checked, fused-checked)")
    if drift:
        print(f"FIXTURE DRIFT: {drift}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
