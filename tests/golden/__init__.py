"""Golden-trace differential harness for the fleet/session engines.

The committed JSON fixtures under this directory pin the engines'
bit-exact behavior on three scenarios (see `.scenarios`): every engine
variant — unsharded/sharded, feature/gather layout, session API or legacy
shim — must reproduce the fixture traces verbatim.  `assert_outcomes_match`
is THE assertion every lane uses; `assert_traces_match` adapts it to the
legacy `SearchTrace` view for the `batched_search`/`tune_fleet` shims.

Fixtures are regenerated with

    PYTHONPATH=src python -m tests.golden.regen

which re-derives every scenario from the unsharded feature-layout session
AND cross-checks the sequential reference engine (`cherrypick_search` /
`ruya_search`) against it before writing anything — so a fixture can only
change when the reference numerics deliberately change, and the diff shows
up in review.
"""

import json
import os

GOLDEN_DIR = os.path.dirname(os.path.abspath(__file__))


def fixture_path(name: str) -> str:
    return os.path.join(GOLDEN_DIR, f"{name}.json")


def load(name: str) -> dict:
    with open(fixture_path(name)) as f:
        return json.load(f)


def golden_outcome_dicts(name: str):
    """The fixture's outcomes, in submission order, as plain dicts
    (`SearchOutcome.as_dict` form — JSON round-tripped, so float-exact)."""
    return load(name)["outcomes"]


def assert_outcomes_match(name: str, outcomes, jobs=None, ignore=()) -> None:
    """Assert `SearchOutcome`s reproduce the golden fixture bit-for-bit.

    ``outcomes`` is the submission-ordered list an engine produced;
    ``jobs`` optionally selects a subset of fixture indices (for lanes
    that only run a prefix/slice of the pinned fleet).  ``ignore`` drops
    the named top-level keys from BOTH sides before comparing — the
    disturbed-fleet lanes use it for the fault-reporting fields
    ("profile_attempts", "retry_backoff_s"): a retried profile returns
    identical results but honestly reports more attempts, and the
    bit-identity claim is about the SEARCH trace.
    """
    want = golden_outcome_dicts(name)
    idx = list(range(len(want))) if jobs is None else list(jobs)
    assert len(outcomes) == len(idx), (
        f"{name}: got {len(outcomes)} outcomes for fixture rows {idx}"
    )
    for j, out in zip(idx, outcomes):
        got = json.loads(json.dumps(out.as_dict()))
        ref = dict(want[j])
        for key in ignore:
            got.pop(key, None)
            ref.pop(key, None)
        if got != ref:
            raise AssertionError(
                f"golden mismatch: scenario {name!r} job {j} "
                f"({want[j]['name']!r})\n  want: {ref}\n  got:  {got}"
            )


def golden_traces(name: str):
    """Fixture outcomes as legacy `SearchTrace`s (the `.trace()` view)."""
    from repro.fleet.session import SearchOutcome

    return [
        SearchOutcome.from_dict(d).trace() for d in golden_outcome_dicts(name)
    ]


def assert_traces_match(name: str, traces, jobs=None) -> None:
    """Assert legacy `SearchTrace`s match the fixture's `.trace()` views —
    the same fixture `assert_outcomes_match` pins, adapted for the
    pre-session shim types (`batched_search`, `run_*`, `tune_fleet`)."""
    want = golden_traces(name)
    idx = list(range(len(want))) if jobs is None else list(jobs)
    assert len(traces) == len(idx), (
        f"{name}: got {len(traces)} traces for fixture rows {idx}"
    )
    for j, tr in zip(idx, traces):
        ref = want[j]
        assert tr.tried == ref.tried, f"{name} job {j}: tried differ"
        assert tr.costs == ref.costs, f"{name} job {j}: costs differ"
        assert tr.stop_iteration == ref.stop_iteration, (
            f"{name} job {j}: stop_iteration differs"
        )
        assert tr.phase_boundary == ref.phase_boundary, (
            f"{name} job {j}: phase_boundary differs"
        )
