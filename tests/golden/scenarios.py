"""The three pinned golden-trace scenarios.

Each scenario builds a deterministic workload and runs it through a
`TuningSession`, returning the outcomes in submission order.  The session
variant under test is injected via ``layout`` / ``shard`` — the committed
fixtures are generated from the UNSHARDED feature-layout session after
`tests.golden.regen` cross-checks it against the sequential engine, and
every other lane (gather layout, shard counts 2/4, the legacy shims) must
reproduce the same bits.

Scenario catalog (ISSUE 5's pinned set):

  * ``n69-exhaustion`` — 4 CherryPick jobs over a synthetic 69-config
    space, run to exhaustion: the packed buffer completely full (B = n),
    the paper-replay regime.
  * ``n512-budgeted``  — 6 two-phase Ruya jobs over a 512-config space at
    max_iters = 10: the budgeted B ≪ n regime, with a phase boundary.
  * ``warm-session``   — a streaming session: a cold profiled wave is
    drained, then a second wave mixes warm-started same-class jobs with
    cold CherryPick jobs in the same lockstep chunks (seeding, padding
    inertness, and class-history determinism in one trace).
  * ``elastic-fleet``  — 8 two-class Ruya jobs whose profiles come from
    DETERMINISTIC linear run fns (exact fits, so retried profiling runs
    return identical models).  The fixture is the undisturbed run;
    `run_elastic_fleet_disturbed` replays it under adversity — transient
    profiling faults on two jobs, a ninth "victim" job cancelled
    mid-flight, and a live shard-loss `reshard` — and the survivors must
    be bit-identical to the fixture (modulo the fault-reporting fields;
    see `assert_outcomes_match(ignore=...)`).

Job counts are chosen so the sharded lanes really shard: at S = 2 every
scenario splits into ≥ 2 row-2/3 chunks, and n512 at S = 4 runs a 3-shard
bundle.
"""

import numpy as np

from repro.core.bayesopt import BOSettings
from repro.core.memory_model import fit_memory_model
from repro.core.profiler import ProfileResult
from repro.core.search_space import Configuration, SearchSpace
from repro.fleet import FleetJob, TuningSession

GiB = 1024.0**3


def synth_space_table(n, d=5, seed=0):
    """The repo's standard synthetic benchmark space (same generator as
    `tests/test_session.py` / `tests/test_fleet.py` — seeds must match so
    fixture traces line up with the engines' other identity tests)."""
    rng = np.random.default_rng(seed + n)
    feats = rng.normal(size=(n, d))
    space = SearchSpace(
        [
            Configuration(
                name=f"s{i}",
                features=tuple(float(v) for v in feats[i]),
                total_memory=float(i) * GiB,
            )
            for i in range(n)
        ]
    )
    w = rng.normal(size=d)
    z = feats @ w
    z = (z - z.mean()) / max(float(z.std()), 1e-9)
    return space, 1.0 + (z - 0.7) ** 2 + 0.05 * rng.random(n)


def flat_profile():
    model = fit_memory_model([1e9, 2e9, 3e9], [5e9, 5e9, 5e9])
    return ProfileResult(
        sizes=(1e9, 2e9, 3e9), readings=(5e9,) * 3, total_time_s=1.0,
        calibration_runs=1, model=model,
    )


def quad_space(n=20):
    return SearchSpace(
        [
            Configuration(name=f"c{i}", features=(float(i),),
                          total_memory=float(i) * GiB)
            for i in range(n)
        ]
    )


def quad_table(n=20, optimum=9):
    return np.array([1.0 + 0.05 * (i - optimum) ** 2 for i in range(n)])


def _session(layout, shard, engine=None, **kw):
    """``engine`` swaps the driver under a scenario: a factory called as
    ``engine(layout=..., shard=..., **session_kwargs)`` returning any
    object with the session's submit/drain/results surface — the async
    service lanes inject `TuningService` here and must reproduce the
    committed single-threaded fixtures bit-for-bit."""
    if engine is not None:
        return engine(layout=layout, shard=shard, **kw)
    return TuningSession(layout=layout, shard=shard, **kw)


def run_n69_exhaustion(layout="feature", shard=None, engine=None):
    space, table = synth_space_table(69)
    session = _session(layout, shard, engine,
                       mode="cherrypick", to_exhaustion=True)
    for s in range(4):
        session.submit(
            FleetJob(name=f"j{s}", space=space, cost_table=table), seed=s,
        )
    return session.drain()


def run_n512_budgeted(layout="feature", shard=None, engine=None):
    space, table = synth_space_table(512)
    st = BOSettings(max_iters=10)
    prio = list(range(0, 50))
    rest = list(range(50, 512))
    # 7 jobs: at S = 4 the group re-chunks to rows = 2 → a genuine 4-shard
    # bundle; at S = 2, rows = 4 → 2 shards.
    session = _session(layout, shard, engine, settings=st,
                       to_exhaustion=True)
    for s in range(7):
        session.submit(
            FleetJob(name=f"j{s}", space=space, cost_table=table),
            seed=s, priority=prio, remaining=rest,
        )
    return session.drain()


def run_warm_session(layout="feature", shard=None, engine=None):
    """Two waves through ONE warm-starting session; drained per wave so
    the class history every wave sees is shard-count-independent."""
    space, table = quad_space(), quad_table()
    prof = flat_profile()

    def job(name):
        return FleetJob(
            name=name, space=space, cost_table=table,
            full_input_size=10e9, profile_result=prof,
        )

    session = _session(layout, shard, engine,
                       warm_start=True, to_exhaustion=False)
    for s in range(3):  # cold profiled wave — builds the class history
        session.submit(job(f"cold{s}"), seed=s)
    session.drain()
    # Second wave: same-class warm starts sharing chunks with cold
    # CherryPick jobs (never seeded — no signature).
    for s in range(2):
        session.submit(job(f"warm{s}"), seed=10 + s)
    for s in range(2):
        session.submit(job(f"cp{s}"), seed=20 + s, mode="cherrypick")
    session.drain()
    return session.results()


def _linear_run(slope, runtime_per_byte=5e-7):
    """Deterministic single-machine profiling emulator: runtime linear in
    the sample (calibration run lands in the profiler's [30 s, 300 s]
    corridor at 1% of a 10 GB input), peak memory EXACTLY linear — the
    fit is noise-free, so a retried run returns the identical model."""

    def run(sample_bytes):
        return sample_bytes * runtime_per_byte, slope * sample_bytes + 1e9

    return run


def _elastic_job(name, idx):
    # Two memory classes (alternating): slope 0.8 → ~8.4 GiB requirement,
    # slope 1.2 → ~12.6 GiB — both split the 0..19 GiB catalog nontrivially.
    return FleetJob(
        name=name, space=quad_space(), cost_table=quad_table(),
        full_input_size=10e9, profile_run=_linear_run(0.8 if idx % 2 == 0 else 1.2),
    )


def run_elastic_fleet(layout="feature", shard=None, engine=None):
    """The undisturbed reference: 8 two-class Ruya jobs, profiled through
    the deterministic linear run fns, drained to completion."""
    session = _session(
        layout, shard, engine,
        settings=BOSettings(max_iters=12), warm_start=False,
    )
    for s in range(8):
        session.submit(_elastic_job(f"e{s}", s), seed=s)
    return session.drain()


def run_elastic_fleet_disturbed(
    layout="feature", shard=2, reshard_to=None, steps_before=3,
):
    """The adversarial replay of ``elastic-fleet``: transient profiling
    faults on jobs e0/e3 (retried — identical profiles, attempt counts
    surface in the outcome), a ninth victim job sharing the fleet, a
    mid-flight cancellation, and a live `reshard` from ``shard`` devices
    to ``reshard_to`` (shard loss by default; pass ``shard=None,
    reshard_to=2`` for a device JOIN).  Returns (survivor outcomes in
    submission order, victim outcome) — survivors must be bit-identical
    to the committed fixture modulo the fault-reporting fields."""
    from repro.cluster.faults import FaultPlan

    session = _session(
        layout, shard, settings=BOSettings(max_iters=12), warm_start=False,
    )
    handles = []
    for s in range(8):
        job = _elastic_job(f"e{s}", s)
        if s in (0, 3):
            plan = FaultPlan(seed=s, transient_run_failures=2)
            job.profile_run = plan.wrap_run(job.profile_run, job.name)
        handles.append(session.submit(job, seed=s))
    victim = session.submit(_elastic_job("victim", 0), seed=99)
    for _ in range(steps_before):
        session.step()
    assert victim.cancel()
    session.reshard(shard=reshard_to)
    session.drain()
    return [h.outcome() for h in handles], victim.outcome()


SCENARIOS = {
    "n69-exhaustion": run_n69_exhaustion,
    "n512-budgeted": run_n512_budgeted,
    "warm-session": run_warm_session,
    "elastic-fleet": run_elastic_fleet,
}
