"""Property tests: the jitted masked-posterior/EI fast path (`fast_bo`)
against the readable reference GP (`gp.py` + `acquisition.py`).

The fast path keeps every configuration in fixed-shape arrays and selects
the observed set with boolean masks; padding must be *exact* — masked-out
points contribute nothing to the posterior.  These tests check that claim
over randomized observation masks, plus the EI/pick agreement between
`bo_step` and the reference pipeline, and the dtype behavior of `fit_gp`.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fast_bo
from repro.core.acquisition import expected_improvement
from repro.core.fast_bo import _masked_posterior, bo_step
from repro.core.gp import GPParams, fit_gp, gp_predict, matern52

_JITTER = 1e-8


def random_case(seed, n=18, d=3, n_obs=6):
    # n_obs is fixed so the reference `fit_gp` compiles once across seeds.
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    obs_idx = rng.choice(n, size=n_obs, replace=False)
    obs_mask = np.zeros(n, bool)
    obs_mask[obs_idx] = True
    # A smooth-ish cost surface with noise.
    y = (np.sum(x**2, -1) + 0.3 * rng.normal(size=n)).astype(np.float32)
    return x, obs_mask, y


def reference_posterior(x, obs_mask, y_n, lengthscale, noise):
    """Readable dense-GP math on the observed subset only (float32)."""
    x = jnp.asarray(x, jnp.float32)
    obs = np.flatnonzero(obs_mask)
    params = GPParams(
        lengthscale=jnp.asarray(lengthscale, jnp.float32),
        amplitude=jnp.asarray(1.0, jnp.float32),
        noise=jnp.asarray(noise, jnp.float32),
    )
    x_obs = x[obs]
    k = matern52(x_obs, x_obs, params) + (noise + _JITTER) * jnp.eye(len(obs))
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y_n[obs])
    lml = (
        -0.5 * y_n[obs] @ alpha
        - jnp.sum(jnp.log(jnp.diagonal(chol)))
        - 0.5 * len(obs) * jnp.log(2.0 * jnp.pi)
    )
    k_star = matern52(x_obs, x, params)
    mean = k_star.T @ alpha
    v = jax.scipy.linalg.solve_triangular(chol, k_star, lower=True)
    var = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    return np.asarray(lml), np.asarray(mean), np.asarray(var)


class TestMaskedPosterior:
    @pytest.mark.parametrize("seed", range(8))
    def test_matches_reference_on_random_masks(self, seed):
        x, obs_mask, y = random_case(seed)
        m = obs_mask.astype(np.float32)
        y_mean = (y * m).sum() / m.sum()
        y_std = max(float(np.sqrt((m * (y - y_mean) ** 2).sum() / m.sum())), 1e-8)
        y_n = np.where(obs_mask, (y - y_mean) / y_std, 0.0).astype(np.float32)

        for ls, nz in [(0.5, 1e-2), (1.0, 1e-4), (2.0, 1e-1)]:
            lml, mean, var = jax.jit(_masked_posterior)(
                jnp.asarray(x), jnp.asarray(obs_mask), jnp.asarray(y_n),
                jnp.asarray(ls, jnp.float32), jnp.asarray(nz, jnp.float32),
            )
            ref_lml, ref_mean, ref_var = reference_posterior(x, obs_mask, y_n, ls, nz)
            assert np.asarray(lml) == pytest.approx(ref_lml, rel=1e-3, abs=1e-3)
            np.testing.assert_allclose(np.asarray(mean), ref_mean, rtol=2e-3, atol=2e-3)
            np.testing.assert_allclose(np.asarray(var), ref_var, rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("seed", range(5))
    def test_padded_points_contribute_nothing(self, seed):
        """Appending garbage rows outside the obs mask must leave the
        posterior over the real points unchanged (padding is exact)."""
        x, obs_mask, y = random_case(seed, n=14)
        rng = np.random.default_rng(1000 + seed)
        n_pad = 7
        x_pad = np.concatenate(
            [x, 100.0 * rng.normal(size=(n_pad, x.shape[1])).astype(np.float32)]
        )
        obs_pad = np.concatenate([obs_mask, np.zeros(n_pad, bool)])

        m = obs_mask.astype(np.float32)
        y_mean = (y * m).sum() / m.sum()
        y_std = max(float(np.sqrt((m * (y - y_mean) ** 2).sum() / m.sum())), 1e-8)
        y_n = np.where(obs_mask, (y - y_mean) / y_std, 0.0).astype(np.float32)
        y_n_pad = np.concatenate([y_n, np.zeros(n_pad, np.float32)])

        lml, mean, var = jax.jit(_masked_posterior)(
            jnp.asarray(x), jnp.asarray(obs_mask), jnp.asarray(y_n),
            jnp.asarray(1.0, jnp.float32), jnp.asarray(1e-2, jnp.float32),
        )
        lml_p, mean_p, var_p = jax.jit(_masked_posterior)(
            jnp.asarray(x_pad), jnp.asarray(obs_pad), jnp.asarray(y_n_pad),
            jnp.asarray(1.0, jnp.float32), jnp.asarray(1e-2, jnp.float32),
        )
        assert np.asarray(lml_p) == pytest.approx(float(lml), rel=1e-4, abs=1e-4)
        np.testing.assert_allclose(
            np.asarray(mean_p)[: len(x)], np.asarray(mean), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(var_p)[: len(x)], np.asarray(var), rtol=1e-4, atol=1e-4
        )


class TestBoStepAgainstReference:
    @pytest.mark.parametrize("seed", range(6))
    def test_pick_is_ei_optimal_under_reference(self, seed):
        """`bo_step`'s pick must (near-)maximize the EI computed by the
        readable fit_gp → gp_predict → expected_improvement pipeline."""
        x, obs_mask, y = random_case(seed, n=16)
        cand = ~obs_mask
        pick, max_ei, best = bo_step(
            jnp.asarray(x), jnp.asarray(obs_mask), jnp.asarray(y), jnp.asarray(cand)
        )
        pick = int(pick)
        assert cand[pick]
        obs_idx = np.flatnonzero(obs_mask)
        assert float(best) == pytest.approx(float(y[obs_idx].min()))

        post = fit_gp(jnp.asarray(x[obs_idx]), jnp.asarray(y[obs_idx]))
        mean, std = gp_predict(post, jnp.asarray(x))
        ref_ei = np.array(
            expected_improvement(mean, std, jnp.asarray(y[obs_idx].min()))
        )
        ref_ei[~cand] = -np.inf
        # Floating tie-breaks may differ between the two programs; the pick
        # must carry (numerically) maximal reference EI either way.
        gap = ref_ei.max() - ref_ei[pick]
        assert gap <= 1e-5 * max(1.0, abs(float(ref_ei.max())))

    def test_max_ei_reported_consistently(self):
        x, obs_mask, y = random_case(42, n=16)
        cand = ~obs_mask
        pick, max_ei, _ = bo_step(
            jnp.asarray(x), jnp.asarray(obs_mask), jnp.asarray(y), jnp.asarray(cand)
        )
        assert float(max_ei) >= 0.0
        # The returned max EI is attained at the returned pick.
        obs_idx = np.flatnonzero(obs_mask)
        post = fit_gp(jnp.asarray(x[obs_idx]), jnp.asarray(y[obs_idx]))
        mean, std = gp_predict(post, jnp.asarray(x))
        ref_ei = np.asarray(
            expected_improvement(mean, std, jnp.asarray(y[obs_idx].min()))
        )
        assert float(max_ei) == pytest.approx(float(ref_ei[int(pick)]), rel=5e-2, abs=1e-5)


class TestFitGpDtype:
    def test_respects_default_float32(self):
        """`fit_gp` must follow the runtime's canonical float width instead
        of poking at jax.config internals (fragile across JAX versions)."""
        x = jnp.asarray(np.random.default_rng(0).normal(size=(6, 2)))
        y = jnp.asarray(np.arange(6.0))
        post = fit_gp(x, y)
        expected = jax.dtypes.canonicalize_dtype(jnp.float64)
        assert post.x_train.dtype == expected
        assert post.chol.dtype == expected
        mean, std = gp_predict(post, x)
        assert mean.dtype == expected
        # And the posterior interpolates the training targets reasonably.
        np.testing.assert_allclose(np.asarray(mean), np.arange(6.0), atol=0.3)
