"""Property tests: the jitted packed-observation fast path (`fast_bo`)
against the readable reference GP (`gp.py` + `acquisition.py`).

The fast path packs the observed set into fixed-capacity (B,) buffers in
trial order and computes its kernel blocks from the packed (B,d) feature
buffer (or, on the retained d²-gather layout, gathers them from a
precomputed distance tensor); padding must be *exact* — padded packed
slots (and mask-level padded space points) contribute nothing to the
posterior, bit for bit.
These tests check that claim over randomized observation sets and buffer
capacities (including the full-buffer B = t and B = 1 edges), the EI/pick
agreement of `bo_step` with the reference pipeline and with the retained
dense full-extent step, the shared-d² kernel helpers, and the dtype
behavior of `fit_gp`.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fast_bo
from repro.core.acquisition import expected_improvement
from repro.core.fast_bo import (
    _masked_posterior,
    bo_step,
    bo_step_core,
    bo_step_core_dense,
    bo_step_core_gather,
    encode_features,
    precompute_d2,
)
from repro.core.gp import (
    GPParams,
    fit_gp,
    gp_predict,
    matern52,
    matern52_from_sqdist,
    pairwise_sqdist,
)

_JITTER = 1e-8


def random_case(seed, n=18, d=3, n_obs=6):
    # n_obs is fixed so the reference `fit_gp` compiles once across seeds.
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    obs_idx = rng.choice(n, size=n_obs, replace=False)
    obs_mask = np.zeros(n, bool)
    obs_mask[obs_idx] = True
    # A smooth-ish cost surface with noise.
    y = (np.sum(x**2, -1) + 0.3 * rng.normal(size=n)).astype(np.float32)
    return x, obs_mask, y


def reference_posterior(x, obs_mask, y_n, lengthscale, noise):
    """Readable dense-GP math on the observed subset only (float32)."""
    x = jnp.asarray(x, jnp.float32)
    obs = np.flatnonzero(obs_mask)
    params = GPParams(
        lengthscale=jnp.asarray(lengthscale, jnp.float32),
        amplitude=jnp.asarray(1.0, jnp.float32),
        noise=jnp.asarray(noise, jnp.float32),
    )
    x_obs = x[obs]
    k = matern52(x_obs, x_obs, params) + (noise + _JITTER) * jnp.eye(len(obs))
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y_n[obs])
    lml = (
        -0.5 * y_n[obs] @ alpha
        - jnp.sum(jnp.log(jnp.diagonal(chol)))
        - 0.5 * len(obs) * jnp.log(2.0 * jnp.pi)
    )
    k_star = matern52(x_obs, x, params)
    mean = k_star.T @ alpha
    v = jax.scipy.linalg.solve_triangular(chol, k_star, lower=True)
    var = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    return np.asarray(lml), np.asarray(mean), np.asarray(var)


class TestMaskedPosterior:
    @pytest.mark.parametrize("seed", range(8))
    def test_matches_reference_on_random_masks(self, seed):
        x, obs_mask, y = random_case(seed)
        m = obs_mask.astype(np.float32)
        y_mean = (y * m).sum() / m.sum()
        y_std = max(float(np.sqrt((m * (y - y_mean) ** 2).sum() / m.sum())), 1e-8)
        y_n = np.where(obs_mask, (y - y_mean) / y_std, 0.0).astype(np.float32)

        for ls, nz in [(0.5, 1e-2), (1.0, 1e-4), (2.0, 1e-1)]:
            lml, mean, var = jax.jit(_masked_posterior)(
                jnp.asarray(x), jnp.asarray(obs_mask), jnp.asarray(y_n),
                jnp.asarray(ls, jnp.float32), jnp.asarray(nz, jnp.float32),
            )
            ref_lml, ref_mean, ref_var = reference_posterior(x, obs_mask, y_n, ls, nz)
            assert np.asarray(lml) == pytest.approx(ref_lml, rel=1e-3, abs=1e-3)
            np.testing.assert_allclose(np.asarray(mean), ref_mean, rtol=2e-3, atol=2e-3)
            np.testing.assert_allclose(np.asarray(var), ref_var, rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("seed", range(5))
    def test_padded_points_contribute_nothing(self, seed):
        """Appending garbage rows outside the obs mask must leave the
        posterior over the real points unchanged (padding is exact)."""
        x, obs_mask, y = random_case(seed, n=14)
        rng = np.random.default_rng(1000 + seed)
        n_pad = 7
        x_pad = np.concatenate(
            [x, 100.0 * rng.normal(size=(n_pad, x.shape[1])).astype(np.float32)]
        )
        obs_pad = np.concatenate([obs_mask, np.zeros(n_pad, bool)])

        m = obs_mask.astype(np.float32)
        y_mean = (y * m).sum() / m.sum()
        y_std = max(float(np.sqrt((m * (y - y_mean) ** 2).sum() / m.sum())), 1e-8)
        y_n = np.where(obs_mask, (y - y_mean) / y_std, 0.0).astype(np.float32)
        y_n_pad = np.concatenate([y_n, np.zeros(n_pad, np.float32)])

        lml, mean, var = jax.jit(_masked_posterior)(
            jnp.asarray(x), jnp.asarray(obs_mask), jnp.asarray(y_n),
            jnp.asarray(1.0, jnp.float32), jnp.asarray(1e-2, jnp.float32),
        )
        lml_p, mean_p, var_p = jax.jit(_masked_posterior)(
            jnp.asarray(x_pad), jnp.asarray(obs_pad), jnp.asarray(y_n_pad),
            jnp.asarray(1.0, jnp.float32), jnp.asarray(1e-2, jnp.float32),
        )
        assert np.asarray(lml_p) == pytest.approx(float(lml), rel=1e-4, abs=1e-4)
        np.testing.assert_allclose(
            np.asarray(mean_p)[: len(x)], np.asarray(mean), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(var_p)[: len(x)], np.asarray(var), rtol=1e-4, atol=1e-4
        )


class TestBoStepAgainstReference:
    @pytest.mark.parametrize("seed", range(6))
    def test_pick_is_ei_optimal_under_reference(self, seed):
        """`bo_step`'s pick must (near-)maximize the EI computed by the
        readable fit_gp → gp_predict → expected_improvement pipeline."""
        x, obs_mask, y = random_case(seed, n=16)
        cand = ~obs_mask
        pick, max_ei, best = bo_step(
            jnp.asarray(x), jnp.asarray(obs_mask), jnp.asarray(y), jnp.asarray(cand)
        )
        pick = int(pick)
        assert cand[pick]
        obs_idx = np.flatnonzero(obs_mask)
        assert float(best) == pytest.approx(float(y[obs_idx].min()))

        post = fit_gp(jnp.asarray(x[obs_idx]), jnp.asarray(y[obs_idx]))
        mean, std = gp_predict(post, jnp.asarray(x))
        ref_ei = np.array(
            expected_improvement(mean, std, jnp.asarray(y[obs_idx].min()))
        )
        ref_ei[~cand] = -np.inf
        # Floating tie-breaks may differ between the two programs; the pick
        # must carry (numerically) maximal reference EI either way.
        gap = ref_ei.max() - ref_ei[pick]
        assert gap <= 1e-5 * max(1.0, abs(float(ref_ei.max())))

    def test_max_ei_reported_consistently(self):
        x, obs_mask, y = random_case(42, n=16)
        cand = ~obs_mask
        pick, max_ei, _ = bo_step(
            jnp.asarray(x), jnp.asarray(obs_mask), jnp.asarray(y), jnp.asarray(cand)
        )
        assert float(max_ei) >= 0.0
        # The returned max EI is attained at the returned pick.
        obs_idx = np.flatnonzero(obs_mask)
        post = fit_gp(jnp.asarray(x[obs_idx]), jnp.asarray(y[obs_idx]))
        mean, std = gp_predict(post, jnp.asarray(x))
        ref_ei = np.asarray(
            expected_improvement(mean, std, jnp.asarray(y[obs_idx].min()))
        )
        assert float(max_ei) == pytest.approx(float(ref_ei[int(pick)]), rel=5e-2, abs=1e-5)


def _reference_ei(x, obs_mask, y, cand):
    """EI over all points via the readable fit_gp → gp_predict pipeline."""
    obs_idx = np.flatnonzero(obs_mask)
    post = fit_gp(jnp.asarray(x[obs_idx]), jnp.asarray(y[obs_idx]))
    mean, std = gp_predict(post, jnp.asarray(x))
    ei = np.array(expected_improvement(mean, std, jnp.asarray(y[obs_idx].min())))
    ei[~cand] = -np.inf
    return ei


def _assert_pick_near_optimal(ei_ref, pick, tol=1e-5):
    gap = ei_ref.max() - ei_ref[pick]
    assert gap <= tol * max(1.0, abs(float(ei_ref.max())))


class TestPackedEngine:
    """The packed (B,B)/(B,n) layout: gp.py-reference agreement on random
    observed subsets, exact (bitwise-inert) slot padding, and the
    full-buffer / B=1 edge cases."""

    def _packed_inputs(self, x, obs_mask, y, capacity):
        order = np.flatnonzero(obs_mask)
        k = len(order)
        tried = np.full(capacity, -1, np.int32)
        tried[:k] = order
        py = np.zeros(capacity, np.float32)
        py[:k] = y[order]
        return tried, py, k

    @pytest.mark.parametrize("seed", range(4))
    def test_padded_slots_are_bitwise_inert(self, seed):
        """Finite garbage in packed slots ≥ t must not change a single bit
        of (pick, max_ei, best) — the padding is exact, not approximate —
        on BOTH packed layouts (feature buffer and the retained d²-gather).
        """
        x, obs_mask, y = random_case(seed)
        cand = ~obs_mask
        capacity = 12
        tried, py, k = self._packed_inputs(x, obs_mask, y, capacity)
        enc = encode_features(x)
        feats = np.zeros((capacity, enc.shape[1]), np.float32)
        feats[:k] = enc[tried[:k]]
        d2 = precompute_d2(x)
        core_f = jax.jit(bo_step_core)
        core_g = jax.jit(bo_step_core_gather)
        args_tail = (jnp.asarray(k, jnp.int32), jnp.asarray(obs_mask),
                     jnp.asarray(cand))

        ref = core_f(jnp.asarray(enc), jnp.asarray(feats),
                     jnp.asarray(tried), jnp.asarray(py), *args_tail)
        rng = np.random.default_rng(100 + seed)
        tried_g = tried.copy()
        py_g = py.copy()
        feats_g = feats.copy()
        tried_g[k:] = rng.integers(0, len(x), size=capacity - k)
        py_g[k:] = 1e6 * rng.standard_normal(capacity - k)
        feats_g[k:] = 1e6 * rng.standard_normal((capacity - k, enc.shape[1]))
        got = core_f(jnp.asarray(enc), jnp.asarray(feats_g),
                     jnp.asarray(tried_g), jnp.asarray(py_g), *args_tail)
        assert int(got[0]) == int(ref[0])
        assert float(got[1]) == float(ref[1])  # bitwise, no tolerance
        assert float(got[2]) == float(ref[2])

        # The retained gather layout: same inertness, and the same bits as
        # the feature layout.
        gat_ref = core_g(d2, jnp.asarray(tried), jnp.asarray(py), *args_tail)
        gat = core_g(d2, jnp.asarray(tried_g), jnp.asarray(py_g), *args_tail)
        assert int(gat[0]) == int(gat_ref[0]) == int(ref[0])
        assert float(gat[1]) == float(gat_ref[1]) == float(ref[1])
        assert float(gat[2]) == float(gat_ref[2]) == float(ref[2])

    @pytest.mark.parametrize("seed", range(4))
    def test_full_buffer_matches_reference(self, seed):
        """capacity == n_obs (no padded slots at all) against the readable
        reference pipeline."""
        x, obs_mask, y = random_case(seed, n=16)
        cand = ~obs_mask
        n_obs = int(obs_mask.sum())
        pick, max_ei, best = bo_step(x, obs_mask, y, cand, capacity=n_obs)
        assert cand[pick]
        assert best == pytest.approx(float(y[obs_mask].min()))
        _assert_pick_near_optimal(_reference_ei(x, obs_mask, y, cand), pick)

    @pytest.mark.parametrize("seed", range(4))
    def test_oversized_buffer_matches_reference(self, seed):
        """capacity > n_obs (the mid-search shape) against the reference."""
        x, obs_mask, y = random_case(seed, n=16)
        cand = ~obs_mask
        pick, max_ei, best = bo_step(x, obs_mask, y, cand, capacity=14)
        assert cand[pick]
        _assert_pick_near_optimal(_reference_ei(x, obs_mask, y, cand), pick)

    def test_single_observation_capacity_one(self):
        """B = 1: a (1,1) system, the smallest the packed engine can run."""
        x, _, y = random_case(5, n=12)
        obs_mask = np.zeros(12, bool)
        obs_mask[4] = True
        cand = ~obs_mask
        pick, max_ei, best = bo_step(x, obs_mask, y, cand, capacity=1)
        assert cand[pick]
        assert best == pytest.approx(float(y[4]))
        assert max_ei >= 0.0

    @pytest.mark.parametrize("seed", range(3))
    def test_trial_order_is_immaterial_to_the_pick_quality(self, seed):
        """The packed buffer is ordered by trial; any order must yield a
        (near-)EI-optimal pick and the identical best cost."""
        x, obs_mask, y = random_case(seed, n=16)
        cand = ~obs_mask
        order = np.flatnonzero(obs_mask)
        shuffled = np.random.default_rng(seed).permutation(order)
        ei_ref = _reference_ei(x, obs_mask, y, cand)
        p1, e1, b1 = bo_step(x, obs_mask, y, cand, trial_order=order)
        p2, e2, b2 = bo_step(x, obs_mask, y, cand, trial_order=shuffled)
        assert b1 == b2  # min is order-independent even in float32
        assert e2 == pytest.approx(e1, rel=1e-3, abs=1e-6)
        _assert_pick_near_optimal(ei_ref, p1)
        _assert_pick_near_optimal(ei_ref, p2)

    @pytest.mark.parametrize("seed", range(4))
    def test_packed_agrees_with_dense_step(self, seed):
        """Packed vs the retained dense full-extent step on the same state:
        same best, matching max-EI, and EI-equivalent picks."""
        x, obs_mask, y = random_case(seed, n=16)
        cand = ~obs_mask
        pick_p, ei_p, best_p = bo_step(x, obs_mask, y, cand)
        pick_d, ei_d, best_d = jax.jit(bo_step_core_dense)(
            jnp.asarray(x), jnp.asarray(obs_mask), jnp.asarray(y),
            jnp.asarray(cand),
        )
        assert best_p == pytest.approx(float(best_d))
        assert ei_p == pytest.approx(float(ei_d), rel=2e-3, abs=1e-6)
        ei_ref = _reference_ei(x, obs_mask, y, cand)
        _assert_pick_near_optimal(ei_ref, pick_p)
        _assert_pick_near_optimal(ei_ref, int(pick_d))


class TestSqdistKernelHelpers:
    def test_matern_from_sqdist_matches_matern52_scalar_ls(self):
        """One raw d² rescaled per lengthscale must reproduce matern52 for
        every scalar lengthscale of the hyperparameter grid."""
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(9, 3)), jnp.float32)
        d2 = pairwise_sqdist(x)
        for ls in (0.1, 0.25, 0.5, 1.0, 2.0, 4.0):
            params = GPParams(
                lengthscale=jnp.asarray(ls, jnp.float32),
                amplitude=jnp.asarray(1.0, jnp.float32),
                noise=jnp.asarray(0.0, jnp.float32),
            )
            ref = np.asarray(matern52(x, x, params))
            got = np.asarray(matern52_from_sqdist(d2, jnp.asarray(ls, jnp.float32)))
            # Small lengthscales put far pairs deep into the exponential
            # tail, where the two float32 evaluation orders diverge
            # relatively (but not absolutely) — hence the atol floor.
            np.testing.assert_allclose(got, ref, rtol=2e-3, atol=1e-6)

    def test_pairwise_sqdist_nonnegative_and_symmetric(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(7, 4)), jnp.float32)
        d2 = np.asarray(pairwise_sqdist(x))
        assert (d2 >= 0.0).all()
        np.testing.assert_allclose(d2, d2.T, rtol=0, atol=0)
        ref = ((np.asarray(x)[:, None] - np.asarray(x)[None]) ** 2).sum(-1)
        np.testing.assert_allclose(d2, ref, rtol=1e-4, atol=1e-5)


class TestFitGpDtype:
    def test_respects_default_float32(self):
        """`fit_gp` must follow the runtime's canonical float width instead
        of poking at jax.config internals (fragile across JAX versions)."""
        x = jnp.asarray(np.random.default_rng(0).normal(size=(6, 2)))
        y = jnp.asarray(np.arange(6.0))
        post = fit_gp(x, y)
        expected = jax.dtypes.canonicalize_dtype(jnp.float64)
        assert post.x_train.dtype == expected
        assert post.chol.dtype == expected
        mean, std = gp_predict(post, x)
        assert mean.dtype == expected
        # And the posterior interpolates the training targets reasonably.
        np.testing.assert_allclose(np.asarray(mean), np.arange(6.0), atol=0.3)
