"""Shard-invariance property suite: sharding the job axis is a pure
execution optimization.

Random job mixes of heterogeneous (space shape, packed capacity B) groups
are drained through an unsharded lockstep session and through sharded
sessions (2/3/4 shards), and every `TrialRecord` — index, cost, slot,
source — plus the stop/phase registers must be bitwise equal
(`SearchOutcome.as_dict` compared verbatim).  Because the sharded chunking
re-slices groups to rows = min(8, ceil(M/S)) and pads trailing rows with
inert dummy jobs, these mixes exercise exactly the two claims the sharded
engine rests on: batch-extent invariance of the float32 step in [2, 8]
and padded-slot/dummy-row inertness — now across device boundaries.

Hypothesis lane when the package is installed (`tests/hypothesis_compat`),
always-on seeded lane otherwise, same property; plus direct unit tests of
`repro.fleet.sharding.resolve_shard_devices` and the loud failure mode
when more shards are requested than devices exist.
"""

import numpy as np
import pytest

import jax

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings as hyp_settings, st

from repro.core.bayesopt import BOSettings
from repro.core.search_space import Configuration, SearchSpace
from repro.fleet import FleetJob, TuningSession, resolve_shard_devices

N_SPACES = ((12, 3), (18, 5))  # (n, d) — two shapes so groups really mix


def _spaces_tables():
    out = []
    for n, d in N_SPACES:
        rng = np.random.default_rng(n * 7 + d)
        feats = rng.normal(size=(n, d))
        space = SearchSpace(
            [
                Configuration(
                    name=f"s{i}",
                    features=tuple(float(v) for v in feats[i]),
                    total_memory=float(i),
                )
                for i in range(n)
            ]
        )
        w = rng.normal(size=d)
        z = feats @ w
        z = (z - z.mean()) / max(float(z.std()), 1e-9)
        out.append((space, 1.0 + (z - 0.7) ** 2 + 0.05 * rng.random(n)))
    return out


SPACES = _spaces_tables()
SETTINGS = BOSettings(max_iters=6)


def _drain_mix(mix, shard):
    """mix: [(space_idx, pool_size, seed)] — returns outcome dicts in
    submission order.  pool_size < n drives heterogeneous packed
    capacities B = min(pool, max_iters) inside one session."""
    session = TuningSession(
        mode="cherrypick", to_exhaustion=True, settings=SETTINGS,
        shard=shard,
    )
    handles = []
    for k, (si, pool, seed) in enumerate(mix):
        space, table = SPACES[si]
        handles.append(
            session.submit(
                FleetJob(name=f"m{k}", space=space, cost_table=table),
                seed=seed, priority=list(range(pool)),
            )
        )
    session.drain()
    return [h.outcome().as_dict() for h in handles]


def _assert_shard_invariant(mix, shards=(2, 3, 4)):
    ref = _drain_mix(mix, None)
    for s in shards:
        if jax.device_count() < s:
            pytest.skip(f"needs {s} devices")
        got = _drain_mix(mix, s)
        assert got == ref, (
            f"sharded (S={s}) outcomes diverged from lockstep on mix {mix}"
        )
    return ref


class TestShardInvariance:
    if HAVE_HYPOTHESIS:

        @given(
            mix=st.lists(
                st.tuples(
                    st.integers(0, len(SPACES) - 1),
                    st.integers(4, 6),
                    st.integers(0, 10**6),
                ),
                min_size=1, max_size=7,
            ),
            shard=st.sampled_from((2, 4)),
        )
        @hyp_settings(max_examples=8, deadline=None)
        def test_random_mix_shard_invariant_hypothesis(self, mix, shard):
            if jax.device_count() < shard:
                pytest.skip(f"needs {shard} devices")
            assert _drain_mix(mix, shard) == _drain_mix(mix, None)

    def test_random_mix_shard_invariant_seeded(self):
        rng = np.random.default_rng(4242)
        for _ in range(4):
            j = int(rng.integers(1, 8))
            mix = [
                (int(rng.integers(0, len(SPACES))),
                 int(rng.integers(4, 7)),
                 int(rng.integers(0, 10**6)))
                for _ in range(j)
            ]
            _assert_shard_invariant(mix, shards=(2, 4))

    def test_dummy_rows_and_chunk_splits_are_inert(self):
        """An odd group at S=2 re-chunks to [rows, rows-1+dummy]; every
        job's trace must equal BOTH the unsharded lockstep run and its own
        solo single-job session — dummy rows and bundle membership leak
        nothing."""
        mix = [(0, 5, 11), (0, 5, 22), (0, 5, 33)]  # one group of 3
        ref = _assert_shard_invariant(mix, shards=(2,))
        for k, (si, pool, seed) in enumerate(mix):
            solo = _drain_mix([(si, pool, seed)], None)[0]
            solo["name"] = ref[k]["name"]  # submission-order names differ
            assert solo == ref[k]

    def test_warm_and_cold_neighbors_shard_invariant(self):
        """Warm-start seeding composes with sharding: a seeded job sharing
        a sharded bundle with cold jobs reproduces the unsharded session's
        records exactly (seeds included)."""
        from golden.scenarios import run_warm_session

        if jax.device_count() < 3:
            pytest.skip("needs 3 devices")
        ref = [o.as_dict() for o in run_warm_session(shard=None)]
        got = [o.as_dict() for o in run_warm_session(shard=3)]
        assert got == ref


class TestResolveShardDevices:
    def test_default_is_unsharded(self):
        assert resolve_shard_devices() is None
        assert resolve_shard_devices(1) is None

    def test_auto_uses_local_devices(self):
        devs = resolve_shard_devices("auto")
        if jax.device_count() > 1:
            assert devs is not None and len(devs) == jax.device_count()
        else:
            assert devs is None

    def test_explicit_count(self):
        if jax.device_count() < 2:
            pytest.skip("needs 2 devices")
        devs = resolve_shard_devices(2)
        assert len(devs) == 2

    def test_too_many_shards_fails_loudly(self):
        with pytest.raises(ValueError, match="device"):
            resolve_shard_devices(jax.device_count() + 1)
        with pytest.raises(ValueError):
            resolve_shard_devices(0)

    def test_explicit_devices_win(self):
        devs = tuple(jax.devices()[:1])
        assert resolve_shard_devices(devices=devs) is None  # 1 device → ref
        if jax.device_count() >= 2:
            two = tuple(jax.devices()[:2])
            assert resolve_shard_devices(devices=two) == two
            with pytest.raises(ValueError, match="disagrees"):
                resolve_shard_devices(shard=3, devices=two)

    def test_session_rejects_impossible_shard_count(self):
        with pytest.raises(ValueError, match="device"):
            TuningSession(shard=jax.device_count() + 1)
