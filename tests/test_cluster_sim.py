"""Paper-faithful evaluation substrate tests: the 69-config grid, Table I
memory categorization, the Fig. 1 memory cliff, and profiling times."""

import numpy as np
import pytest

from repro.cluster import (
    ClusterSimulator,
    JOBS,
    enumerate_cluster_configs,
    make_cluster_search_space,
)
from repro.core import profile_job
from repro.core.memory_model import MemoryCategory

GiB = 1024**3


class TestConfigGrid:
    def test_exactly_69_configurations(self):
        assert len(enumerate_cluster_configs()) == 69

    def test_scaleouts_span_4_to_48(self):
        so = [c.scale_out for c in enumerate_cluster_configs()]
        assert min(so) == 4 and max(so) == 48

    def test_max_memory_below_naivebayes_bigdata_requirement(self):
        # Paper: none of the configs can hold the 754 GB requirement.
        max_mem = max(c.total_memory_gb for c in enumerate_cluster_configs())
        assert max_mem < 754.0

    def test_memory_per_core_ordering(self):
        space = make_cluster_search_space()
        by_name = {c.name: c for c in space.configs}
        r = by_name["r4.2xlarge" + "x4"]
        c = by_name["c4.2xlarge" + "x4"]
        m = by_name["m4.2xlarge" + "x4"]
        assert r.total_memory > m.total_memory > c.total_memory


class TestTable1Reproduction:
    """Profiling + categorization must land every job in its paper category
    (Table I), with linear estimates close to the paper's GB figures."""

    EXPECTED = {
        "naivebayes/spark/bigdata": ("linear", 754),
        "naivebayes/spark/huge": ("linear", 395),
        "kmeans/spark/bigdata": ("linear", 503),
        "kmeans/spark/huge": ("linear", 252),
        "pagerank/spark/bigdata": ("linear", 86),
        "pagerank/spark/huge": ("linear", 42),
        "logregr/spark/bigdata": ("unclear", None),
        "logregr/spark/huge": ("unclear", None),
        "linregr/spark/bigdata": ("unclear", None),
        "linregr/spark/huge": ("unclear", None),
        "join/spark/bigdata": ("flat", None),
        "join/spark/huge": ("flat", None),
        "pagerank/hadoop/bigdata": ("flat", None),
        "pagerank/hadoop/huge": ("flat", None),
        "terasort/hadoop/bigdata": ("flat", None),
        "terasort/hadoop/huge": ("flat", None),
    }

    @pytest.mark.parametrize("key", sorted(EXPECTED))
    def test_job_lands_in_paper_category(self, key):
        expected_cat, expected_gb = self.EXPECTED[key]
        sim = ClusterSimulator.for_job(key)
        prof = profile_job(sim.profile_run_fn(), sim.job.input_gb * GiB)
        assert prof.model.category.value == expected_cat
        if expected_gb is not None:
            est = prof.model.estimate(sim.job.input_gb * GiB) / GiB
            assert est == pytest.approx(expected_gb, rel=0.10)

    def test_profiling_time_corridor(self):
        # Paper Table III: 2 to ~22 minutes, mean ≈ 10 min.
        times = []
        for key in sorted(JOBS):
            sim = ClusterSimulator.for_job(key)
            prof = profile_job(sim.profile_run_fn(), sim.job.input_gb * GiB)
            times.append(prof.total_time_s)
        assert min(times) > 60
        assert max(times) < 1800
        assert 300 < np.mean(times) < 900


class TestCostSurface:
    def test_memory_cliff_exists_for_linear_jobs(self):
        """Fig. 1: for a memory-bound job, configs just below the memory
        requirement cost drastically more than configs just above."""
        sim = ClusterSimulator.for_job("kmeans/spark/huge")
        req = sim.job.mem_requirement_gb
        mems = np.array([c.meta.total_memory_gb for c in sim.space.configs])
        below = sim.normalized[(mems > req * 0.5) & (mems < req)]
        above = sim.normalized[mems >= req]
        assert below.min() > above.min() * 1.5

    def test_flat_jobs_have_no_cliff_and_cheap_low_memory(self):
        sim = ClusterSimulator.for_job("terasort/hadoop/huge")
        mems = np.array([c.meta.total_memory_gb for c in sim.space.configs])
        # The optimum for a flat job is NOT in the high-memory half.
        opt_mem = mems[sim.optimal_index()]
        assert opt_mem <= np.median(mems)

    def test_cost_surface_deterministic(self):
        a = ClusterSimulator.for_job("kmeans/spark/huge").costs
        b = ClusterSimulator.for_job("kmeans/spark/huge").costs
        np.testing.assert_array_equal(a, b)

    def test_normalized_min_is_one(self):
        sim = ClusterSimulator.for_job("join/spark/bigdata")
        assert sim.normalized.min() == pytest.approx(1.0)


class TestForJobLookup:
    """`ClusterSimulator.for_job` key routing: loud KeyError naming the
    valid key space, and the memoized scenario catalog (both halves of the
    falsy-`or` bugfix)."""

    def test_unknown_key_raises_with_valid_key_space(self):
        with pytest.raises(KeyError) as exc:
            ClusterSimulator.for_job("kmeans/spark/typo")
        msg = str(exc.value)
        assert "kmeans/spark/typo" in msg
        assert "kmeans/spark/bigdata" in msg  # Table I half
        assert "failure scenarios" in msg

    def test_scenario_keys_resolve(self):
        from repro.cluster import failure_scenario_jobs

        for key in failure_scenario_jobs():
            sim = ClusterSimulator.for_job(key)
            assert sim.job.key == key

    def test_scenario_catalog_is_memoized(self):
        from repro.cluster.workloads import _scenario_catalog

        assert _scenario_catalog() is _scenario_catalog()

    def test_failure_scenario_jobs_returns_a_copy(self):
        from repro.cluster import failure_scenario_jobs

        d = failure_scenario_jobs()
        d.clear()  # caller mutation must not poison the memo
        assert failure_scenario_jobs()


class TestSpillClamp:
    """`_spill_factor`'s usable-memory clamp: a grid whose per-node
    overhead exceeds node memory has NO usable memory — the job spills at
    the saturated missing fraction instead of feeding a negative
    "usable" into the ratio."""

    def _spilling_job(self):
        for job in JOBS.values():
            if job.spill_slope > 0.0:
                return job
        raise AssertionError("no spilling job in the catalog")

    def test_overhead_dominated_config_saturates(self):
        from repro.cluster.nodes import ClusterConfig, NodeType
        from repro.cluster.simulator import PER_NODE_OVERHEAD_GB, _spill_factor

        job = self._spilling_job()
        tiny = NodeType("tiny.sub-overhead", "c", "large", 2,
                        PER_NODE_OVERHEAD_GB / 2.0, 0.01)
        cfg = ClusterConfig(node=tiny, scale_out=8)
        assert cfg.total_memory_gb < PER_NODE_OVERHEAD_GB * cfg.scale_out
        # usable clamps to 0 → missing fraction saturates at 1.0.
        assert _spill_factor(job, cfg) == pytest.approx(
            job.spill_base + job.spill_slope
        )

    def test_spill_surface_matches_golden_fixture(self):
        """The fixed spill surface is pinned in tests/golden/: any change
        to the usable-memory accounting must show up as fixture drift."""
        import json

        from golden import load
        from repro.cluster.simulator import _spill_factor

        fix = load("spill-surface")
        configs = enumerate_cluster_configs()
        assert fix["configs"] == [c.name for c in configs]
        assert sorted(fix["spill"]) == sorted(JOBS)
        for key, want in fix["spill"].items():
            got = [float(_spill_factor(JOBS[key], c)) for c in configs]
            assert json.loads(json.dumps(got)) == want, key

    def test_committed_grid_clears_the_overhead(self):
        from repro.cluster.simulator import PER_NODE_OVERHEAD_GB

        # The clamp is behavior-neutral on the real grid: every node has
        # more memory than the per-node overhead slice (the committed
        # cost tables therefore cannot move; tests/golden/spill-surface
        # pins the actual spill values).
        for cfg in enumerate_cluster_configs():
            assert cfg.node.memory_gb > PER_NODE_OVERHEAD_GB


class TestPricedSimulator:
    def test_priced_costs_are_runtime_times_price(self):
        from repro.cluster.pricing import spot

        sim = ClusterSimulator.for_job(
            "kmeans/spark/huge", catalog=spot(seed=0), epoch=3
        )
        assert sim.runtime_h is not None and sim.price_hour is not None
        np.testing.assert_array_equal(sim.costs, sim.runtime_h * sim.price_hour)

    def test_identity_catalog_matches_legacy_simulator(self):
        from repro.cluster.pricing import on_demand

        legacy = ClusterSimulator.for_job("kmeans/spark/huge")
        priced = ClusterSimulator.for_job(
            "kmeans/spark/huge", catalog=on_demand()
        )
        np.testing.assert_array_equal(legacy.costs, priced.costs)
        np.testing.assert_array_equal(legacy.normalized, priced.normalized)
