"""Paper-faithful evaluation substrate tests: the 69-config grid, Table I
memory categorization, the Fig. 1 memory cliff, and profiling times."""

import numpy as np
import pytest

from repro.cluster import (
    ClusterSimulator,
    JOBS,
    enumerate_cluster_configs,
    make_cluster_search_space,
)
from repro.core import profile_job
from repro.core.memory_model import MemoryCategory

GiB = 1024**3


class TestConfigGrid:
    def test_exactly_69_configurations(self):
        assert len(enumerate_cluster_configs()) == 69

    def test_scaleouts_span_4_to_48(self):
        so = [c.scale_out for c in enumerate_cluster_configs()]
        assert min(so) == 4 and max(so) == 48

    def test_max_memory_below_naivebayes_bigdata_requirement(self):
        # Paper: none of the configs can hold the 754 GB requirement.
        max_mem = max(c.total_memory_gb for c in enumerate_cluster_configs())
        assert max_mem < 754.0

    def test_memory_per_core_ordering(self):
        space = make_cluster_search_space()
        by_name = {c.name: c for c in space.configs}
        r = by_name["r4.2xlarge" + "x4"]
        c = by_name["c4.2xlarge" + "x4"]
        m = by_name["m4.2xlarge" + "x4"]
        assert r.total_memory > m.total_memory > c.total_memory


class TestTable1Reproduction:
    """Profiling + categorization must land every job in its paper category
    (Table I), with linear estimates close to the paper's GB figures."""

    EXPECTED = {
        "naivebayes/spark/bigdata": ("linear", 754),
        "naivebayes/spark/huge": ("linear", 395),
        "kmeans/spark/bigdata": ("linear", 503),
        "kmeans/spark/huge": ("linear", 252),
        "pagerank/spark/bigdata": ("linear", 86),
        "pagerank/spark/huge": ("linear", 42),
        "logregr/spark/bigdata": ("unclear", None),
        "logregr/spark/huge": ("unclear", None),
        "linregr/spark/bigdata": ("unclear", None),
        "linregr/spark/huge": ("unclear", None),
        "join/spark/bigdata": ("flat", None),
        "join/spark/huge": ("flat", None),
        "pagerank/hadoop/bigdata": ("flat", None),
        "pagerank/hadoop/huge": ("flat", None),
        "terasort/hadoop/bigdata": ("flat", None),
        "terasort/hadoop/huge": ("flat", None),
    }

    @pytest.mark.parametrize("key", sorted(EXPECTED))
    def test_job_lands_in_paper_category(self, key):
        expected_cat, expected_gb = self.EXPECTED[key]
        sim = ClusterSimulator.for_job(key)
        prof = profile_job(sim.profile_run_fn(), sim.job.input_gb * GiB)
        assert prof.model.category.value == expected_cat
        if expected_gb is not None:
            est = prof.model.estimate(sim.job.input_gb * GiB) / GiB
            assert est == pytest.approx(expected_gb, rel=0.10)

    def test_profiling_time_corridor(self):
        # Paper Table III: 2 to ~22 minutes, mean ≈ 10 min.
        times = []
        for key in sorted(JOBS):
            sim = ClusterSimulator.for_job(key)
            prof = profile_job(sim.profile_run_fn(), sim.job.input_gb * GiB)
            times.append(prof.total_time_s)
        assert min(times) > 60
        assert max(times) < 1800
        assert 300 < np.mean(times) < 900


class TestCostSurface:
    def test_memory_cliff_exists_for_linear_jobs(self):
        """Fig. 1: for a memory-bound job, configs just below the memory
        requirement cost drastically more than configs just above."""
        sim = ClusterSimulator.for_job("kmeans/spark/huge")
        req = sim.job.mem_requirement_gb
        mems = np.array([c.meta.total_memory_gb for c in sim.space.configs])
        below = sim.normalized[(mems > req * 0.5) & (mems < req)]
        above = sim.normalized[mems >= req]
        assert below.min() > above.min() * 1.5

    def test_flat_jobs_have_no_cliff_and_cheap_low_memory(self):
        sim = ClusterSimulator.for_job("terasort/hadoop/huge")
        mems = np.array([c.meta.total_memory_gb for c in sim.space.configs])
        # The optimum for a flat job is NOT in the high-memory half.
        opt_mem = mems[sim.optimal_index()]
        assert opt_mem <= np.median(mems)

    def test_cost_surface_deterministic(self):
        a = ClusterSimulator.for_job("kmeans/spark/huge").costs
        b = ClusterSimulator.for_job("kmeans/spark/huge").costs
        np.testing.assert_array_equal(a, b)

    def test_normalized_min_is_one(self):
        sim = ClusterSimulator.for_job("join/spark/bigdata")
        assert sim.normalized.min() == pytest.approx(1.0)
