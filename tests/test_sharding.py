"""Sharding-rule resolution (unit) + multi-device equivalence (subprocess):
the sharded train step must produce the same numbers as single-device."""

import jax
import jax.numpy as jnp
import pytest

from repro.models.spec import TensorSpec
from repro.parallel.sharding import ShardingRules, default_rules


class TestRules:
    def test_override_and_get(self):
        r = default_rules(data_axes=("data",), model_axis="model")
        assert r.get("heads") == "model"
        r2 = r.override(seq="model")
        assert r2.get("seq") == "model"
        assert r.get("seq") is None  # original untouched

    def test_multi_pod_batch_axes(self):
        r = default_rules(data_axes=("pod", "data"), model_axis="model")
        assert r.get("batch") == ("pod", "data")


class TestResolvePspec:
    def test_divisibility_drops_axis(self, devices_runner):
        devices_runner(
            """
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.models.spec import TensorSpec
            from repro.parallel.sharding import default_rules, resolve_pspec
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            rules = default_rules(data_axes=("data",), model_axis="model")
            # heads=8 divides model=4 → sharded
            s = TensorSpec((16, 8, 4), None, ("embed", "heads", "head_dim"))
            assert resolve_pspec(s, rules, mesh) == P("data", "model"), resolve_pspec(s, rules, mesh)
            # heads=6 does NOT divide model=4 → dropped (whisper case)
            s2 = TensorSpec((16, 6, 4), None, ("embed", "heads", "head_dim"))
            assert resolve_pspec(s2, rules, mesh) == P("data"), resolve_pspec(s2, rules, mesh)
            # tuple axes degrade to the longest dividing prefix
            rules2 = default_rules(data_axes=("data", "model"))
            s3 = TensorSpec((2, 10), None, ("batch", None))
            ps = resolve_pspec(s3, rules2, mesh)
            assert ps == P("data"), ps
            # axis never reused across dims
            s4 = TensorSpec((8, 8), None, ("heads", "kv_heads"))
            ps4 = resolve_pspec(s4, rules, mesh)
            assert ps4 == P("model"), ps4
            print("RESOLVE OK")
            """
        )

    def test_sharded_train_step_matches_single_device(self, devices_runner):
        out = devices_runner(
            """
            import jax, jax.numpy as jnp, numpy as np
            import repro.configs as C
            from repro.launch.mesh import make_mesh
            from repro.launch.build import build_cell
            from repro.configs.shapes import ShapeCell
            from repro.models import Model
            from repro.runtime.steps import init_train_state, make_train_step
            from repro.data import SyntheticDataset

            spec = C.smoke("granite-8b")
            spec = spec.replace_model(compute_dtype="float32")
            model = Model(spec.model)
            ex = spec.exec.replace(num_microbatches=2)
            cell = ShapeCell("t", seq_len=16, global_batch=8, kind="train")
            ds = SyntheticDataset(spec.model, 8, 16, seed=0)
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

            # single device
            state = init_train_state(model, ex, jax.random.key(0))
            step = jax.jit(make_train_step(model, ex))
            _, m1 = step(state, batch)

            # 8-device mesh through the launcher path
            mesh = make_mesh((2, 4), ("data", "model"))
            built = build_cell(spec, cell, mesh, exec_override=ex)
            state2 = init_train_state(model, ex, jax.random.key(0))
            jitted = jax.jit(built.step_fn, in_shardings=built.in_shardings,
                             out_shardings=built.out_shardings)
            from repro.launch.mesh import mesh_context
            with mesh_context(mesh):
                _, m2 = jitted(state2, batch)
            l1, l2 = float(m1["loss"]), float(m2["loss"])
            print("LOSSES", l1, l2)
            assert abs(l1 - l2) < 1e-4, (l1, l2)
            g1, g2 = float(m1["grad_norm"]), float(m2["grad_norm"])
            assert abs(g1 - g2) / max(g1, 1e-9) < 1e-3, (g1, g2)
            print("SHARDED == SINGLE OK")
            """
        )
        assert "SHARDED == SINGLE OK" in out

    def test_moe_expert_parallel_matches_single_device(self, devices_runner):
        out = devices_runner(
            """
            import dataclasses
            import jax, jax.numpy as jnp
            import repro.configs as C
            from repro.launch.mesh import make_mesh
            from repro.launch.build import rules_for
            from repro.configs.shapes import ShapeCell
            from repro.parallel.constraints import activation_sharding
            from repro.models import Model, init_tree

            spec = C.smoke("kimi-k2-1t-a32b")
            cfg = spec.model.replace(
                compute_dtype="float32",
                moe=dataclasses.replace(spec.model.moe, capacity_factor=8.0),
            )
            model = Model(cfg)
            params = init_tree(jax.random.key(0), model.param_specs())
            batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 16),
                                                  0, cfg.vocab_size)}
            loss1, _ = model.loss_fn(params, batch)
            mesh = make_mesh((2, 4), ("data", "model"))
            cell = ShapeCell("t", 16, 8, "train")
            rules = rules_for(spec, cell, mesh)
            with activation_sharding(rules, mesh):
                loss2, _ = model.loss_fn(params, batch)
            l1, l2 = float(loss1), float(loss2)
            print("LOSSES", l1, l2)
            assert abs(l1 - l2) < 5e-3, (l1, l2)
            print("MOE EP OK")
            """
        )
        assert "MOE EP OK" in out

    @pytest.mark.slow  # ~80s: compiles 6 archs × 3 step kinds
    def test_tiny_mesh_dryrun_all_step_kinds(self, devices_runner):
        """lower+compile every step kind on an 8-device mesh using smoke
        configs — the dry-run machinery end to end, in miniature."""
        out = devices_runner(
            """
            import jax
            import repro.configs as C
            from repro.launch.mesh import make_mesh
            from repro.launch.build import build_cell
            from repro.configs.shapes import ShapeCell

            mesh = make_mesh((2, 4), ("data", "model"))
            cells = [ShapeCell("t", 16, 8, "train"),
                     ShapeCell("p", 32, 8, "prefill"),
                     ShapeCell("d", 32, 8, "decode")]
            for arch in ["granite-8b", "kimi-k2-1t-a32b", "mamba2-370m",
                         "zamba2-1.2b", "whisper-tiny",
                         "llava-next-mistral-7b"]:
                spec = C.smoke(arch)
                if spec.model.family == "vlm":
                    cells_a = [ShapeCell("t", 24, 8, "train"),
                               ShapeCell("p", 24, 8, "prefill"),
                               ShapeCell("d", 32, 8, "decode")]
                else:
                    cells_a = cells
                for cell in cells_a:
                    built = build_cell(spec, cell, mesh)
                    compiled = built.lower(mesh).compile()
                    assert compiled.memory_analysis() is not None
                    print("OK", arch, cell.kind)
            print("TINY DRYRUN OK")
            """
        )
        assert "TINY DRYRUN OK" in out
