"""Prefill + decode must agree with the teacher-forced forward pass for
every architecture family (the serving path's core invariant)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

import repro.configs as C
from repro.models import Model, init_tree
from repro.models.spec import is_spec


def zeros_tree(specs):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def _uncapped(spec):
    """Raise MoE capacity so token dropping can't differ between batch
    shapes (forward vs decode dispatch see different token counts)."""
    m = spec.model
    if m.moe is not None:
        m = m.replace(moe=dataclasses.replace(m.moe, capacity_factor=8.0))
    return m


@pytest.mark.parametrize("arch", C.ARCHS)
def test_prefill_matches_forward_and_decode_continues(arch):
    cfg = _uncapped(C.smoke(arch))
    model = Model(cfg)
    params = init_tree(jax.random.key(0), model.param_specs())
    B, T, MAX = 2, 8, 32
    key = jax.random.key(1)
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    offset = T
    if cfg.family == "vlm":
        batch["patches"] = (
            jax.random.normal(key, (B, cfg.num_patch_tokens, cfg.d_model))
            .astype(cfg.cdtype) * 0.02
        )
        offset += cfg.num_patch_tokens
    if cfg.family == "encdec":
        batch["frames"] = (
            jax.random.normal(key, (B, cfg.encoder.source_len, cfg.d_model))
            .astype(cfg.cdtype) * 0.02
        )

    full, _ = model.forward(params, batch)
    cache = zeros_tree(model.cache_specs(B, MAX))
    last, cache = model.prefill(params, batch, cache)
    assert last.shape == (B, 1, cfg.vocab_size)
    assert float(jnp.max(jnp.abs(last[:, 0] - full[:, -1]))) < 0.1

    # Greedy-decode two tokens; each must match a fresh forward pass.
    toks_so_far = toks
    index = offset
    nxt = jnp.argmax(last[:, 0], -1).astype(jnp.int32)[:, None]
    for _ in range(2):
        dec, cache = model.decode_step(params, cache, nxt, jnp.int32(index))
        toks_so_far = jnp.concatenate([toks_so_far, nxt], axis=1)
        ref_batch = dict(batch)
        ref_batch["tokens"] = toks_so_far
        ref, _ = model.forward(params, ref_batch)
        assert float(jnp.max(jnp.abs(dec[:, 0] - ref[:, -1]))) < 0.1
        nxt = jnp.argmax(dec[:, 0], -1).astype(jnp.int32)[:, None]
        index += 1


@pytest.mark.parametrize("arch", ["mamba2-370m", "zamba2-1.2b"])
def test_ssm_prefill_in_two_chunks_matches_single(arch):
    """Prefill(A+B) must equal prefill(A) then continue(B) — the state
    handoff property long-context serving relies on."""
    cfg = _uncapped(C.smoke(arch))
    model = Model(cfg)
    params = init_tree(jax.random.key(0), model.param_specs())
    B, T = 2, 16
    toks = jax.random.randint(jax.random.key(2), (B, T), 0, cfg.vocab_size)

    cache = zeros_tree(model.cache_specs(B, T))
    last_full, _ = model.prefill(params, {"tokens": toks}, cache)

    cache2 = zeros_tree(model.cache_specs(B, T))
    _, cache2 = model.prefill(params, {"tokens": toks[:, : T // 2]}, cache2)
    logits2, _ = model._decoder_pass(
        params, {"tokens": toks[:, T // 2 :]}, cache2, jnp.int32(T // 2)
    )
    assert float(jnp.max(jnp.abs(logits2[:, -1] - last_full[:, 0]))) < 0.1
