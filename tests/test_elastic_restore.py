"""Elastic restore: a checkpoint written on one topology restores onto a
different mesh with the target shardings applied (subprocess, 8 devices)."""

import jax
import jax.numpy as jnp
import pytest
import numpy as np

from repro.checkpoint import save_pytree

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane


class TestElasticRestore:
    def test_single_device_checkpoint_restores_sharded(self, tmp_path,
                                                       devices_runner):
        tree = {
            "w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones((16,), jnp.bfloat16),
        }
        save_pytree(str(tmp_path / "ck"), tree, extra={"step": 3})

        out = devices_runner(
            f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint import load_pytree

            mesh = jax.make_mesh((2, 4), ("data", "model"))
            target = {{
                "w": jnp.zeros((8, 8), jnp.float32),
                "b": jnp.zeros((16,), jnp.bfloat16),
            }}
            shardings = {{
                "w": NamedSharding(mesh, P("data", "model")),
                "b": NamedSharding(mesh, P("model")),
            }}
            restored, extra = load_pytree(r"{tmp_path / 'ck'}", target,
                                          shardings=shardings)
            assert extra["step"] == 3
            assert restored["w"].sharding == shardings["w"]
            assert restored["b"].sharding == shardings["b"]
            np.testing.assert_array_equal(
                np.asarray(restored["w"]),
                np.arange(64, dtype=np.float32).reshape(8, 8))
            # per-device shard shape proves real 8-way placement
            shard = restored["w"].addressable_shards[0]
            assert shard.data.shape == (4, 2), shard.data.shape
            print("ELASTIC OK")
            """
        )
        assert "ELASTIC OK" in out

    def test_train_state_roundtrip_across_meshes(self, tmp_path,
                                                 devices_runner):
        """Full train-state: save on a (4,2) mesh layout, restore on (2,4)."""
        out = devices_runner(
            f"""
            import jax, jax.numpy as jnp, numpy as np
            import repro.configs as C
            from repro.checkpoint import CheckpointManager
            from repro.launch.build import rules_for
            from repro.launch.mesh import make_mesh
            from repro.configs.shapes import ShapeCell
            from repro.models import Model
            from repro.parallel.sharding import named_sharding_tree
            from repro.runtime.steps import (init_train_state,
                                             train_state_specs)

            spec = C.smoke("qwen3-8b")
            model = Model(spec.model)
            ex = spec.exec
            cell = ShapeCell("t", 16, 8, "train")

            mesh_a = make_mesh((4, 2), ("data", "model"))
            rules_a = rules_for(spec, cell, mesh_a)
            specs = train_state_specs(model, ex)
            sh_a = named_sharding_tree(specs, rules_a, mesh_a)
            state = init_train_state(model, ex, jax.random.key(0))
            state = jax.device_put(state, sh_a)

            mgr = CheckpointManager(r"{tmp_path}")
            mgr.save(7, state, extra=dict(step=7))

            mesh_b = make_mesh((2, 4), ("data", "model"))
            rules_b = rules_for(spec, cell, mesh_b)
            sh_b = named_sharding_tree(specs, rules_b, mesh_b)
            restored, extra = mgr.restore(state, shardings=sh_b)
            assert extra["step"] == 7
            a0 = np.asarray(jax.tree.leaves(state["params"])[0])
            b0 = np.asarray(jax.tree.leaves(restored["params"])[0])
            np.testing.assert_array_equal(a0, b0)
            print("CROSS-MESH OK")
            """
        )
        assert "CROSS-MESH OK" in out
