"""Kernel-identity lane: the fused posterior+EI+argmax kernel vs the
unfused reference, bit for bit.

`repro.kernels.ei_argmax` streams the candidate axis in tiles with a
running (max EI, argmax) reduction so the (B,n) cross block never
materializes.  The claim these tests pin is IDENTITY, not closeness:
every byte of (pick, max_ei, best) from `bo_step_core_fused` — on the
production `lax.scan` lane AND under the Pallas interpreter — must equal
`bo_step_core`'s, across tile widths, buffer fill levels, manufactured
EI ties that span tile boundaries, garbage in padded packed slots, and
the d=1 / B=2 shape edges.  The final class proves the structural point
by inspection: the fused jaxpr contains no (B,n)-sized intermediate and
XLA's compiled-memory report shows the transient footprint collapsing,
while the reference lane demonstrably has both.

Everything here runs on the CPU test topology (interpret mode executes
the kernel body as ordinary XLA:CPU ops); the compiled-TPU lane shares
the same body with a forward-substitution solve and is covered by the
same calls when a TPU backend is present.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.fast_bo import bo_step_core, bo_step_core_fused
from repro.kernels.ei_argmax import ei_argmax
from repro.kernels.ei_argmax.ops import _pick_tile

pytestmark = pytest.mark.kernel

_REF = jax.jit(bo_step_core)
_FUSED = jax.jit(
    bo_step_core_fused, static_argnames=("tile", "interpret")
)


def _case(seed, n, d, k, capacity):
    """A packed BO-step instance: k observed points in a capacity-B buffer
    over an (n,d) standard-normal encoding with a smooth noisy cost."""
    rng = np.random.default_rng(seed)
    enc = rng.normal(size=(n, d)).astype(np.float32)
    y = (np.sum(enc**2, -1) + 0.3 * rng.normal(size=n)).astype(np.float32)
    picks = rng.choice(n, size=k, replace=False)
    tried = np.full(capacity, -1, np.int32)
    tried[:k] = picks
    py = np.zeros(capacity, np.float32)
    py[:k] = y[picks]
    obs = np.zeros(n, bool)
    obs[picks] = True
    cand = np.ones(n, bool)
    enc = jnp.asarray(enc)
    feats = enc[jnp.maximum(jnp.asarray(tried), 0)]
    return (
        enc, feats, jnp.asarray(tried), jnp.asarray(py),
        jnp.asarray(k, jnp.int32), jnp.asarray(obs), jnp.asarray(cand),
    )


def _assert_bitwise(ref, got, ctx=""):
    for name, a, b in zip(("pick", "max_ei", "best"), ref, got):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype, f"{ctx}{name}: dtype {a.dtype} != {b.dtype}"
        assert np.array_equal(a, b), f"{ctx}{name}: {a!r} != {b!r}"


class TestFusedIdentity:
    """fused == reference, byte for byte, across shapes and fill levels."""

    @pytest.mark.parametrize(
        "n,d,capacity",
        [(69, 5, 24), (256, 3, 16), (600, 7, 24), (1500, 4, 12)],
    )
    def test_seeded_sweep_bitwise(self, n, d, capacity):
        """The always-on lane: several fills per shape, scan + interpret."""
        for seed, k in ((0, 1), (1, capacity // 2), (2, capacity)):
            args = _case(seed, n, d, k, capacity)
            ref = _REF(*args)
            _assert_bitwise(ref, _FUSED(*args), f"n={n} k={k} scan: ")
            _assert_bitwise(
                ref, _FUSED(*args, interpret=True), f"n={n} k={k} interp: "
            )

    @pytest.mark.parametrize("tile", [128, 256, 512, 1024])
    def test_tile_size_invariance(self, tile):
        """The tile width is a pure performance knob: every width yields the
        reference bits, on the scan lane and under the interpreter — n=1500
        pads to a tile multiple at every width here."""
        args = _case(3, 1500, 3, 10, 16)
        ref = _REF(*args)
        _assert_bitwise(ref, _FUSED(*args, tile=tile), f"tile={tile} scan: ")
        _assert_bitwise(
            ref, _FUSED(*args, tile=tile, interpret=True),
            f"tile={tile} interp: ",
        )

    def test_padded_slots_bitwise_inert(self):
        """Finite garbage in packed slots ≥ t (features, indices, costs)
        must not change a single output bit — same exactness contract the
        unfused packed engine pins in test_core_bo.py."""
        enc, feats, tried, py, t, obs, cand = _case(4, 400, 4, 7, 20)
        k, capacity = 7, 20
        ref = _FUSED(enc, feats, tried, py, t, obs, cand)
        rng = np.random.default_rng(99)
        tried_g = np.asarray(tried).copy()
        py_g = np.asarray(py).copy()
        feats_g = np.asarray(feats).copy()
        tried_g[k:] = rng.integers(0, 400, size=capacity - k)
        py_g[k:] = 1e6 * rng.standard_normal(capacity - k)
        feats_g[k:] = 1e6 * rng.standard_normal((capacity - k, 4))
        for interpret in (None, True):
            got = _FUSED(
                enc, jnp.asarray(feats_g), jnp.asarray(tried_g),
                jnp.asarray(py_g), t, obs, cand, interpret=interpret,
            )
            _assert_bitwise(ref, got, f"garbage interpret={interpret}: ")

    def test_cross_tile_tie_takes_lowest_index(self):
        """Manufactured exact EI ties: duplicate encoding rows produce
        bitwise-equal EI columns, and when the duplicates sit in DIFFERENT
        tiles the strict-`>` streaming update must keep the first index —
        `jnp.argmax`'s contract in the reference."""
        n, d, k, capacity, tile = 1024, 3, 6, 12, 256
        enc, feats, tried, py, t, obs, cand = _case(5, n, d, k, capacity)
        enc = np.asarray(enc).copy()
        obs_np = np.asarray(obs)
        # A clone of candidate j1 placed three tiles later (both unobserved).
        j1, j2 = 40, 40 + 3 * tile
        assert not obs_np[j1] and not obs_np[j2]
        enc[j2] = enc[j1]
        enc = jnp.asarray(enc)
        feats = enc[jnp.maximum(tried, 0)]
        ref = _REF(enc, feats, tried, py, t, obs, cand)
        for kwargs in ({}, {"interpret": True}):
            got = _FUSED(enc, feats, tried, py, t, obs, cand,
                         tile=tile, **kwargs)
            _assert_bitwise(ref, got, f"tie {kwargs}: ")
        # If the winner IS the duplicated point, the tie-break was real:
        # the fused pick must be j1, never the equal-EI j2.
        if int(ref[0]) in (j1, j2):
            assert int(ref[0]) == j1

    def test_d1_delegates_to_reference(self):
        """d=1 degenerate matmuls fuse differently under XLA:CPU, so the
        fused entry point delegates wholesale — identical program,
        identical bits (and `quad_space`-based golden scenarios stay d=1)."""
        args = _case(6, 200, 1, 5, 12)
        _assert_bitwise(_REF(*args), _FUSED(*args), "d=1: ")

    def test_b2_and_d2_edges(self):
        """Smallest engine extents: B=2 buffers (the float32-discipline
        floor) and d=2 (the narrowest non-delegating width)."""
        for seed, (n, d, k, cap) in enumerate([(50, 2, 2, 2), (300, 2, 1, 2),
                                               (130, 6, 2, 2)]):
            args = _case(20 + seed, n, d, k, cap)
            ref = _REF(*args)
            _assert_bitwise(ref, _FUSED(*args), f"edge {n},{d},{cap} scan: ")
            _assert_bitwise(ref, _FUSED(*args, interpret=True),
                            f"edge {n},{d},{cap} interp: ")

    def test_all_masked_pool(self):
        """Every candidate observed or excluded: both lanes reduce over all
        -inf and must agree on (index 0, -inf) exactly."""
        enc, feats, tried, py, t, obs, cand = _case(7, 128, 3, 8, 16)
        none = jnp.zeros_like(cand)
        ref = _REF(enc, feats, tried, py, t, obs, none)
        for kwargs in ({}, {"interpret": True}):
            got = _FUSED(enc, feats, tried, py, t, obs, none, **kwargs)
            _assert_bitwise(ref, got, f"all-masked {kwargs}: ")
        assert int(ref[0]) == 0 and np.isneginf(float(ref[1]))

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        n=st.integers(2, 300),
        d=st.integers(1, 5),
        cap=st.integers(2, 16),
    )
    def test_property_fused_equals_reference(self, seed, n, d, cap):
        """Property lane (dev-only, skipped without hypothesis): random
        shapes and fills, fused == reference bitwise — d=1 draws exercise
        the delegation path."""
        k = 1 + seed % min(n, cap)
        args = _case(seed, n, d, k, cap)
        _assert_bitwise(_REF(*args), _FUSED(*args),
                        f"prop n={n} d={d} cap={cap} k={k}: ")


def _intermediate_sizes(jaxpr):
    """Element counts of every equation output across all nested jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)  # accept ClosedJaxpr too
    sizes = []
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                sizes.append(int(np.prod(aval.shape, dtype=np.int64)))
        for val in eqn.params.values():
            for sub in jax.core.jaxprs_in_params({"_": val}):
                sizes.extend(_intermediate_sizes(sub))
    return sizes


class TestNoCrossBlock:
    """The structural claim: the fused step never builds the (B,n) block."""

    N, B, D = 32768, 16, 6

    def _args(self):
        return _case(11, self.N, self.D, self.B // 2, self.B)

    def test_jaxpr_has_no_bn_intermediate(self):
        """No intermediate in the fused jaxpr reaches even half of B·n
        elements, while the reference lane provably materializes a full
        (B,n) — the guard fails loudly if a refactor reintroduces it."""
        args = self._args()
        threshold = self.B * self.N // 2
        fused_sizes = _intermediate_sizes(
            jax.make_jaxpr(bo_step_core_fused)(*args).jaxpr
        )
        assert fused_sizes and max(fused_sizes) < threshold, (
            f"fused lane materializes {max(fused_sizes)} elements "
            f"(threshold {threshold})"
        )
        ref_sizes = _intermediate_sizes(
            jax.make_jaxpr(bo_step_core)(*args).jaxpr
        )
        assert max(ref_sizes) >= self.B * self.N, (
            "positive control broke: reference lane no longer has a (B,n) "
            "intermediate — the guard above is not testing anything"
        )

    def test_compiled_transient_memory_collapses(self):
        """XLA's own compiled-memory report: the fused step's transient
        footprint is at least 8x below the reference at n=32768 (the
        measured gap is ~32x; 8x leaves slack for backend layout churn)."""
        args = self._args()
        def temp_bytes(fn):
            stats = jax.jit(fn).lower(*args).compile().memory_analysis()
            return int(stats.temp_size_in_bytes)
        ref, fused = temp_bytes(bo_step_core), temp_bytes(bo_step_core_fused)
        assert fused * 8 <= ref, (
            f"fused transients {fused}B vs reference {ref}B — "
            f"expected >=8x reduction"
        )
