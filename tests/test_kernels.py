"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps
per the assignment, plus custom-VJP gradient checks.

The flash-attention class runs in tier-1 (`-m kernel` lane): its streaming
running-max idiom is the template the fused EI/argmax kernel copies, so it
must stay green in the fast lane.  The rmsnorm/SSD suites remain in the
slow lane (minutes of interpret-mode sweeps, not load-bearing for the BO
engine)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fops, ref as fref
from repro.kernels.rmsnorm import ops as rops, ref as rref
from repro.kernels.ssd import ops as sops, ref as sref


@pytest.mark.kernel
class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,t,h,kv,d,causal",
        [
            (1, 128, 4, 4, 64, True),
            (2, 128, 4, 2, 64, True),   # GQA
            (1, 256, 8, 1, 32, True),   # MQA
            (2, 128, 4, 2, 128, True),  # MXU-width head_dim
            (1, 128, 4, 4, 64, False),  # bidirectional
            (1, 100, 4, 2, 64, False),  # padding path (non-multiple)
            (1, 200, 6, 3, 48, True),   # padding + causal
        ],
    )
    def test_matches_oracle(self, b, t, h, kv, d, causal):
        ks = jax.random.split(jax.random.key(t * h + d), 3)
        q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, t, kv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, t, kv, d), jnp.float32)
        out = fops.flash_attention(q, k, v, causal, None, 128, 128, True)
        ref = fref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4
        )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dtype)
        out = fops.flash_attention(q, k, v, True, None, 128, 128, True)
        ref = fref.attention_ref(q, k, v, causal=True)
        assert out.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=(2e-2 if dtype == jnp.bfloat16 else 2e-5),
        )

    def test_gradients_flow_through_custom_vjp(self):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 32))
        k = jax.random.normal(ks[1], (1, 128, 2, 32))
        v = jax.random.normal(ks[2], (1, 128, 2, 32))

        def loss_kernel(q, k, v):
            return jnp.sum(fops.flash_attention(q, k, v, True, None, 128, 128, True) ** 2)

        def loss_ref(q, k, v):
            return jnp.sum(fref.attention_ref(q, k, v, causal=True) ** 2)

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)

    def test_online_softmax_is_stable_at_large_logits(self):
        q = jnp.full((1, 128, 1, 64), 10.0)
        k = jnp.full((1, 128, 1, 64), 10.0)
        v = jax.random.normal(jax.random.key(0), (1, 128, 1, 64))
        out = fops.flash_attention(q, k, v, True, None, 128, 128, True)
        assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.slow
class TestRmsnorm:
    @pytest.mark.parametrize(
        "rows,d,dtype",
        [
            (256, 64, jnp.float32),
            (300, 128, jnp.float32),    # padding path
            (512, 384, jnp.bfloat16),
            (64, 1024, jnp.float32),    # pad rows < block
        ],
    )
    def test_matches_oracle(self, rows, d, dtype):
        x = jax.random.normal(jax.random.key(rows + d), (rows, d)).astype(dtype)
        s = jax.random.normal(jax.random.key(1), (d,)).astype(dtype)
        out = rops.rmsnorm(x, s, 1e-6, 256, True)
        ref = rref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=(3e-2 if dtype == jnp.bfloat16 else 1e-5),
        )

    def test_nd_input_reshape(self):
        x = jax.random.normal(jax.random.key(0), (2, 7, 96))
        s = jnp.ones((96,))
        out = rops.rmsnorm(x, s, 1e-6, 256, True)
        assert out.shape == x.shape
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(rref.rmsnorm_ref(x, s)), atol=1e-5
        )

    def test_gradients_match_reference(self):
        x = jax.random.normal(jax.random.key(2), (32, 64))
        s = jax.random.normal(jax.random.key(3), (64,))
        gk = jax.grad(lambda x, s: jnp.sum(rops.rmsnorm(x, s, 1e-6, 256, True) ** 2),
                      argnums=(0, 1))(x, s)
        gr = jax.grad(lambda x, s: jnp.sum(rref.rmsnorm_ref(x, s) ** 2),
                      argnums=(0, 1))(x, s)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.slow
class TestSSDKernel:
    @pytest.mark.parametrize(
        "b,nc,q,h,p,n",
        [
            (1, 2, 8, 2, 16, 16),
            (2, 2, 64, 4, 32, 32),
            (1, 1, 128, 2, 64, 64),
            (1, 1, 256, 1, 64, 128),  # production chunk shape
        ],
    )
    def test_matches_oracle(self, b, nc, q, h, p, n):
        ks = jax.random.split(jax.random.key(q * h), 5)
        x = jax.random.normal(ks[0], (b, nc, q, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, q, h)))
        lA = -jax.nn.softplus(jax.random.normal(ks[2], (b, nc, q, h)))
        B_ = jax.random.normal(ks[3], (b, nc, q, h, n))
        C_ = jax.random.normal(ks[4], (b, nc, q, h, n))
        out = sops.ssd_diag_chunk(x, dt, lA, B_, C_, True)
        ref = sref.ssd_diag_ref(x, dt, lA, B_, C_)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_gradients_match_reference(self):
        ks = jax.random.split(jax.random.key(9), 5)
        shapes = (1, 1, 8, 2, 4)
        x = jax.random.normal(ks[0], shapes)
        dt = jax.nn.softplus(jax.random.normal(ks[1], shapes[:4]))
        lA = -jax.nn.softplus(jax.random.normal(ks[2], shapes[:4]))
        B_ = jax.random.normal(ks[3], shapes[:4] + (4,))
        C_ = jax.random.normal(ks[4], shapes[:4] + (4,))

        gk = jax.grad(
            lambda *a: jnp.sum(sops.ssd_diag_chunk(*a, True) ** 2), argnums=(0, 3, 4)
        )(x, dt, lA, B_, C_)
        gr = jax.grad(
            lambda *a: jnp.sum(sref.ssd_diag_ref(*a) ** 2), argnums=(0, 3, 4)
        )(x, dt, lA, B_, C_)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
