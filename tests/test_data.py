"""Synthetic data pipeline: determinism, shapes, modality stubs, sharding."""

import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.data import SyntheticDataset, make_batch, shard_batch


class TestDeterminism:
    def test_same_seed_step_same_batch(self):
        cfg = C.smoke("granite-8b").model
        a = make_batch(cfg, 4, 16, seed=1, step=5)
        b = make_batch(cfg, 4, 16, seed=1, step=5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_different_steps_differ(self):
        cfg = C.smoke("granite-8b").model
        a = make_batch(cfg, 4, 16, seed=1, step=5)
        b = make_batch(cfg, 4, 16, seed=1, step=6)
        assert not np.array_equal(a["tokens"], b["tokens"])


class TestShapesPerFamily:
    def test_lm_batch(self):
        cfg = C.smoke("qwen3-8b").model
        b = make_batch(cfg, 4, 16)
        assert b["tokens"].shape == (4, 16)
        assert b["loss_mask"].shape == (4, 16)
        assert b["tokens"].dtype == np.int32

    def test_vlm_batch_splits_patch_budget(self):
        cfg = C.smoke("llava-next-mistral-7b").model
        b = make_batch(cfg, 2, 24)
        assert b["patches"].shape == (2, cfg.num_patch_tokens, cfg.d_model)
        assert b["tokens"].shape == (2, 24 - cfg.num_patch_tokens)
        assert b["patches"].dtype == np.dtype(cfg.cdtype)

    def test_encdec_batch_has_frames(self):
        cfg = C.smoke("whisper-tiny").model
        b = make_batch(cfg, 2, 16)
        assert b["frames"].shape == (2, cfg.encoder.source_len, cfg.d_model)

    def test_tokens_within_vocab(self):
        cfg = C.smoke("mamba2-370m").model
        b = make_batch(cfg, 8, 64)
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < cfg.vocab_size

    def test_zipf_head_is_heavy(self):
        cfg = C.smoke("granite-8b").model
        b = make_batch(cfg, 64, 64)
        # token 0 (rank 1) must appear far more often than a mid-rank token
        counts = np.bincount(b["tokens"].ravel(), minlength=cfg.vocab_size)
        assert counts[0] > 5 * max(counts[100], 1)


def test_shard_batch_places_arrays():
    cfg = C.smoke("granite-8b").model
    ds = SyntheticDataset(cfg, 4, 16)
    placed = shard_batch(ds.batch_at(0))
    assert isinstance(placed["tokens"], jnp.ndarray)
    assert placed["tokens"].shape == (4, 16)
