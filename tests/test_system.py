"""End-to-end system tests: the full Ruya pipeline against the emulated
Scout evaluation — the paper's headline behavior, in miniature.

The full 200-repetition Table II reproduction lives in
``benchmarks/table2_iterations.py``; here a reduced version asserts the
paper's three qualitative claims:

  1. Ruya is never (meaningfully) worse than CherryPick per job;
  2. for flat/linear jobs Ruya finds the optimum in fewer iterations;
  3. for unclear jobs Ruya degrades EXACTLY to the baseline (same trace).
"""

import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

from repro.cluster import ClusterSimulator
from repro.core import BOSettings, run_cherrypick, run_ruya
from repro.core.memory_model import MemoryCategory

GiB = 1024**3
REPS = 20


def iterations(sim, seeds=range(REPS), threshold=1.0):
    ruya, cp = [], []
    prof = None
    for seed in seeds:
        rep = run_ruya(
            profile_run=sim.profile_run_fn(),
            full_input_size=sim.job.input_gb * GiB,
            space=sim.space,
            cost_fn=sim.cost_fn(),
            rng=np.random.default_rng(seed),
            per_node_overhead=0.5 * GiB,
            to_exhaustion=True,
            profile_result=prof,
        )
        prof = rep.profile  # profile once, reuse (paper §IV-D)
        tr = run_cherrypick(
            space=sim.space, cost_fn=sim.cost_fn(),
            rng=np.random.default_rng(seed), to_exhaustion=True,
        )
        ruya.append(rep.trace.iterations_until(threshold))
        cp.append(tr.iterations_until(threshold))
    return np.mean(ruya), np.mean(cp), rep


class TestRuyaVsCherryPick:
    def test_flat_job_large_speedup(self):
        sim = ClusterSimulator.for_job("terasort/hadoop/bigdata")
        r, c, rep = iterations(sim)
        assert rep.memory_model.category is MemoryCategory.FLAT
        assert r < 0.6 * c  # paper Table II: flat jobs gain the most

    def test_linear_job_speedup(self):
        sim = ClusterSimulator.for_job("kmeans/spark/huge")
        r, c, rep = iterations(sim)
        assert rep.memory_model.category is MemoryCategory.LINEAR
        assert r < 0.8 * c

    def test_unclear_job_identical_to_baseline(self):
        sim = ClusterSimulator.for_job("logregr/spark/huge")
        rep = run_ruya(
            profile_run=sim.profile_run_fn(),
            full_input_size=sim.job.input_gb * GiB,
            space=sim.space, cost_fn=sim.cost_fn(),
            rng=np.random.default_rng(3), to_exhaustion=True,
        )
        assert rep.memory_model.category is MemoryCategory.UNCLEAR
        tr = run_cherrypick(
            space=sim.space, cost_fn=sim.cost_fn(),
            rng=np.random.default_rng(3), to_exhaustion=True,
        )
        assert rep.trace.tried == tr.tried  # exact fallback

    def test_never_substantially_worse(self):
        """Paper §IV-E: 'about as good or better … for each of the 16 jobs'."""
        for key in ["naivebayes/spark/huge", "join/spark/bigdata",
                    "pagerank/spark/huge", "linregr/spark/bigdata"]:
            sim = ClusterSimulator.for_job(key)
            r, c, _ = iterations(sim, seeds=range(10))
            assert r <= c * 1.25, (key, r, c)

    def test_requirement_above_all_configs_extremes_path(self):
        """naivebayes/bigdata: 754 GB requirement > any config (max 732)."""
        sim = ClusterSimulator.for_job("naivebayes/spark/bigdata")
        rep = run_ruya(
            profile_run=sim.profile_run_fn(),
            full_input_size=sim.job.input_gb * GiB,
            space=sim.space, cost_fn=sim.cost_fn(),
            rng=np.random.default_rng(0), per_node_overhead=0.5 * GiB,
            to_exhaustion=True,
        )
        est = rep.memory_model.estimate(sim.job.input_gb * GiB) / GiB
        assert est > 732.0  # exceeds every configuration
        # priority group = extremes: contains both min- and max-memory configs
        mems = sim.space.memories()
        assert int(np.argmin(mems)) in rep.priority
        assert int(np.argmax(mems)) in rep.priority


class TestStoppingEconomics:
    def test_stop_fires_before_exhaustion_on_easy_surface(self):
        sim = ClusterSimulator.for_job("join/spark/huge")
        rep = run_ruya(
            profile_run=sim.profile_run_fn(),
            full_input_size=sim.job.input_gb * GiB,
            space=sim.space, cost_fn=sim.cost_fn(),
            rng=np.random.default_rng(1),
            settings=BOSettings(min_observations=6),
        )
        assert len(rep.trace.tried) < len(sim.space)
