"""HLO cost analyzer: ground-truth flop counting with loop scaling, and
collective-byte accounting on explicitly-collective programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

from repro.launch.hlo_analysis import analyze_hlo


class TestFlops:
    def test_plain_matmul(self):
        m, k, n = 64, 128, 32
        f = jax.jit(lambda a, b: a @ b)
        c = f.lower(
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
        ).compile()
        cost = analyze_hlo(c.as_text())
        assert cost.flops == pytest.approx(2 * m * k * n, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        def f(x):
            def body(c, _):
                return jnp.tanh(c @ c), None

            y, _ = jax.lax.scan(body, x, None, length=17)
            return y

        c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        cost = analyze_hlo(c.as_text())
        assert cost.flops == pytest.approx(17 * 2 * 64**3, rel=0.01)

    def test_nested_scan(self):
        def f(x):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ ci, None

                ci, _ = jax.lax.scan(inner, c, None, length=3)
                return ci, None

            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y

        c = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
        cost = analyze_hlo(c.as_text())
        assert cost.flops == pytest.approx(15 * 2 * 32**3, rel=0.02)

    def test_batched_dot(self):
        f = jax.jit(lambda a, b: jnp.einsum("bij,bjk->bik", a, b))
        c = f.lower(
            jax.ShapeDtypeStruct((4, 16, 32), jnp.float32),
            jax.ShapeDtypeStruct((4, 32, 8), jnp.float32),
        ).compile()
        cost = analyze_hlo(c.as_text())
        assert cost.flops == pytest.approx(2 * 4 * 16 * 32 * 8, rel=0.01)


class TestCollectives:
    def test_psum_bytes_counted(self, devices_runner):
        devices_runner(
            """
            import jax, jax.numpy as jnp
            from functools import partial
            # jax.shard_map only exists from jax 0.6; on the pinned 0.4.37
            # the stable spelling is jax.experimental.shard_map.shard_map.
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.launch.hlo_analysis import analyze_hlo

            mesh = jax.make_mesh((8,), ("x",))

            @partial(shard_map, mesh=mesh, in_specs=P("x"), out_specs=P())
            def f(v):
                return jax.lax.psum(v, "x")

            c = jax.jit(f).lower(
                jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile()
            cost = analyze_hlo(c.as_text())
            # all-reduce of the per-device (1, 1024) f32 → ≥ 4 KiB counted
            assert cost.collective_bytes >= 1024 * 4, cost.collective_bytes
            assert "all-reduce" in cost.collective_breakdown
            print("PSUM OK", cost.collective_bytes)
            """
        )

    def test_collectives_inside_scan_are_loop_scaled(self, devices_runner):
        devices_runner(
            """
            import jax, jax.numpy as jnp
            from functools import partial
            # jax.shard_map and jax.lax.pvary only exist from jax 0.6; on
            # the pinned 0.4.37 use jax.experimental.shard_map.shard_map,
            # and carry the psum result directly — without pvary to devary
            # the replicated carry, the replication checker would reject
            # the scan body, so it is disabled (check_rep=False; the HLO
            # under test is identical).
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.launch.hlo_analysis import analyze_hlo

            mesh = jax.make_mesh((8,), ("x",))

            @partial(shard_map, mesh=mesh, in_specs=P("x"), out_specs=P(),
                     check_rep=False)
            def step(v):
                def body(c, _):
                    y = jax.lax.psum(c, "x") * (1.0 / 8.0)
                    return y, None
                y, _ = jax.lax.scan(body, v.sum(0), None, length=10)
                return jax.lax.psum(y, "x") * (1.0 / 8.0)

            c = jax.jit(step).lower(
                jax.ShapeDtypeStruct((8, 256), jnp.float32)).compile()
            cost = analyze_hlo(c.as_text())
            one = 256 * 4
            assert cost.collective_bytes >= 9 * one, cost.collective_bytes
            print("LOOPED PSUM OK", cost.collective_bytes)
            """
        )


class TestTrafficModel:
    def test_hbm_bytes_scale_with_tensor_size(self):
        small = jax.jit(lambda a: jnp.tanh(a) * 2.0).lower(
            jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
        big = jax.jit(lambda a: jnp.tanh(a) * 2.0).lower(
            jax.ShapeDtypeStruct((1024, 1024), jnp.float32)).compile()
        cs = analyze_hlo(small.as_text())
        cb = analyze_hlo(big.as_text())
        assert cb.hbm_bytes > 30 * cs.hbm_bytes
