"""Cost-aware tuning property suite (`pytest -m pricing`, part of tier-1).

Pins the `repro.cluster.pricing` catalogs and the objective-routing layer:

  * every configuration is priced (finite, positive) under every default
    catalog at every probed epoch;
  * price is strictly monotone in scale_out within a node type (more
    nodes always bill more under every book);
  * a spot book never exceeds its on-demand base at any schedule point,
    and its discount stays inside the schedule's [floor, ceiling];
  * the identity catalog reproduces the legacy cost tables bit-for-bit;
  * `objective="runtime"` reproduces the committed golden fixtures
    as_dict-equal — the objective plumbing must be a no-op on the
    default path;
  * `SearchOutcome.pareto()` is non-empty, mutually non-dominated,
    deterministic, and contains the per-axis argmins;
  * the batched and sequential engines stay trace-identical under
    `objective="cost"`.
"""

import numpy as np
import pytest

from repro.cluster import (
    CATALOGS,
    JOBS,
    default_catalogs,
    enumerate_cluster_configs,
    family_indices,
    job_cost_table,
)
from repro.cluster.pricing import SpotSchedule, graviton, on_demand, spot
from repro.cluster.workloads import (
    family_constrained_scenarios,
    pricing_scenarios,
    spot_volatility_scenarios,
)
from repro.fleet import (
    TuningSession,
    canonical_objective,
    cluster_fleet,
    objective_table,
    tune_fleet,
)

pytestmark = pytest.mark.pricing

_EPOCHS = (0, 1, 2, 7)
_KEYS = ["kmeans/spark/bigdata", "terasort/hadoop/bigdata"]


# --------------------------------------------------------------- catalogs


def test_all_configs_priced_under_all_catalogs():
    configs = enumerate_cluster_configs()
    for name, cat in default_catalogs().items():
        for epoch in _EPOCHS:
            prices = cat.price_table(configs, epoch=epoch)
            assert prices.shape == (len(configs),)
            assert np.all(np.isfinite(prices)), (name, epoch)
            assert np.all(prices > 0.0), (name, epoch)


def test_price_monotone_in_scale_out():
    configs = enumerate_cluster_configs()
    for name, cat in default_catalogs().items():
        for epoch in _EPOCHS:
            by_node = {}
            for i, c in enumerate(configs):
                by_node.setdefault(c.node.name, []).append(i)
            prices = cat.price_table(configs, epoch=epoch)
            for node, idx in by_node.items():
                idx = sorted(idx, key=lambda i: configs[i].scale_out)
                p = prices[idx]
                assert np.all(np.diff(p) > 0.0), (
                    f"{name}@{epoch}: price not strictly increasing in "
                    f"scale_out for {node}: {p}"
                )


def test_spot_never_exceeds_on_demand():
    configs = enumerate_cluster_configs()
    od, sp = on_demand(), spot(seed=0)
    for epoch in range(10):
        p_od = od.price_table(configs, epoch=epoch)
        p_sp = sp.price_table(configs, epoch=epoch)
        assert np.all(p_sp < p_od), f"spot >= on-demand at epoch {epoch}"


def test_spot_schedule_bounds_and_determinism():
    sched = SpotSchedule(seed=3, base_discount=0.5, volatility=0.4,
                         floor=0.1, ceiling=0.8)
    for node in ("c4.large", "r4.2xlarge"):
        for epoch in range(20):
            d = sched.discount(node, epoch)
            assert 0.1 <= d <= 0.8
            assert d == sched.discount(node, epoch)  # pure function
    # A different seed is a different schedule somewhere on the probe grid.
    other = SpotSchedule(seed=4, base_discount=0.5, volatility=0.4,
                         floor=0.1, ceiling=0.8)
    assert any(
        sched.discount("c4.large", e) != other.discount("c4.large", e)
        for e in range(20)
    )


def test_identity_catalog_bit_equal_to_legacy():
    ident = on_demand()
    for key, job in JOBS.items():
        legacy = job_cost_table(job)
        priced = job_cost_table(job, catalog=ident)
        assert np.array_equal(legacy, priced), key


def test_cost_objective_moves_table1_optima():
    sp = spot(seed=0)
    moved = sum(
        int(np.argmin(job_cost_table(j, catalog=sp)))
        != int(np.argmin(job_cost_table(j)))
        for j in JOBS.values()
    )
    assert moved >= 3, f"spot book moved only {moved} Table I optima"


def test_family_indices_partition_the_grid():
    configs = enumerate_cluster_configs()
    seen = []
    for fam in "cmr":
        idx = [int(i) for i in family_indices((fam,))]
        assert idx, fam
        assert all(configs[i].node.name.startswith(fam) for i in idx)
        seen.extend(idx)
    assert sorted(seen) == list(range(len(configs)))


def test_scenario_generators_are_deterministic():
    a, b = pricing_scenarios(seed=0), pricing_scenarios(seed=0)
    assert a == b
    assert len(spot_volatility_scenarios()) == 9
    fams = family_constrained_scenarios()
    assert len(fams) == 9
    assert all(s.families for s in fams)


# ------------------------------------------------------ objective routing


def test_canonical_objective_forms():
    assert canonical_objective("runtime") == "runtime"
    assert canonical_objective("cost") == "cost"
    tup = canonical_objective({"runtime": 1.0, "cost": 3.0})
    assert tup == (("cost", 3.0), ("runtime", 1.0))
    assert canonical_objective(tup) == tup


@pytest.mark.parametrize("bad", [
    "latency",
    {"runtime": -1.0, "cost": 1.0},
    {"runtime": 0.0, "cost": 0.0},
    {"carbon": 1.0},
    42,
])
def test_canonical_objective_rejects(bad):
    with pytest.raises((ValueError, TypeError)):
        canonical_objective(bad)


def test_objective_table_needs_pricing_axes():
    [job] = cluster_fleet(_KEYS[:1])  # unpriced: no runtime/price tables
    assert np.array_equal(objective_table(job, "runtime"), job.cost_table)
    with pytest.raises(ValueError):
        objective_table(job, "cost")


def test_objective_table_weighted_blend():
    [job] = cluster_fleet(_KEYS[:1], catalog=spot(seed=0))
    rt = objective_table(job, "runtime")
    cost = objective_table(job, "cost")
    half = objective_table(job, {"runtime": 1.0, "cost": 1.0})
    np.testing.assert_allclose(half, 0.5 * (rt / rt.min() + cost), rtol=1e-12)
    # Degenerate weights collapse to the pure axes.
    np.testing.assert_array_equal(
        objective_table(job, {"runtime": 2.0}), rt / rt.min()
    )
    np.testing.assert_array_equal(objective_table(job, {"cost": 2.0}), cost)


# ------------------------------------------------------------ Pareto front


def _cost_outcomes():
    jobs = cluster_fleet(_KEYS, catalog=spot(seed=0), epoch=1)
    session = TuningSession(objective="cost", warm_start=False)
    for i, job in enumerate(jobs):
        session.submit(job, seed=100 + i)
    return session.drain()


def test_pareto_front_invariants():
    for out in _cost_outcomes():
        front = out.pareto()
        obs = [
            r for r in out.observations
            if r.runtime_h is not None and r.usd is not None
        ]
        assert front, "empty Pareto front"
        assert out.pareto() == front, "pareto() is not deterministic"
        # Front members are observations, in trial order.
        positions = [obs.index(r) for r in front]
        assert positions == sorted(positions)
        # Mutually non-dominated.
        for i, a in enumerate(front):
            for j, b in enumerate(front):
                if i == j:
                    continue
                dominates = (
                    b.runtime_h <= a.runtime_h and b.usd <= a.usd
                    and (b.runtime_h < a.runtime_h or b.usd < a.usd)
                )
                assert not dominates, f"front member {i} dominated by {j}"
        # Contains the argmin of each raw axis.
        assert min(r.usd for r in front) == out.best_usd
        assert min(r.runtime_h for r in front) == out.best_runtime_h
        # Every non-front observation is dominated by some front member.
        for r in obs:
            if r in front:
                continue
            assert any(
                f.runtime_h <= r.runtime_h and f.usd <= r.usd
                and (f.runtime_h < r.runtime_h or f.usd < r.usd)
                for f in front
            ), "non-front trial is not dominated"


def test_pareto_requires_priced_observations():
    space_jobs = cluster_fleet(_KEYS[:1])  # unpriced
    session = TuningSession(warm_start=False)
    session.submit(space_jobs[0], seed=0)
    [out] = session.drain()
    with pytest.raises(RuntimeError):
        out.pareto()


def test_priced_outcome_serialization_round_trip():
    import json

    for out in _cost_outcomes():
        d = out.as_dict()
        assert d["objective"] == "cost"
        assert d["currency"] == "USD"
        assert all("usd" in r and "runtime_h" in r for r in d["records"])
        from repro.fleet import SearchOutcome

        rt = SearchOutcome.from_dict(json.loads(json.dumps(d)))
        assert rt.as_dict() == d
        assert rt.pareto() == out.pareto()


# --------------------------------------------------- engine/golden identity


def test_engines_identical_under_cost_objective():
    jobs = cluster_fleet(_KEYS, catalog=spot(seed=0), epoch=2)
    rngs = lambda: [np.random.default_rng(s) for s in (5, 6)]
    batched = tune_fleet(jobs, rngs(), objective="cost")
    sequential = tune_fleet(jobs, rngs(), objective="cost",
                            engine="sequential")
    for a, b in zip(batched, sequential):
        assert a.trace.tried == b.trace.tried
        assert a.trace.costs == b.trace.costs
        assert a.trace.stop_iteration == b.trace.stop_iteration
        assert a.trace.phase_boundary == b.trace.phase_boundary


def test_runtime_objective_matches_golden_fixtures():
    """objective="runtime" (passed EXPLICITLY) must reproduce every
    committed golden fixture as_dict-equal: the objective plumbing is
    required to be a no-op on the default path."""
    from tests.golden import assert_outcomes_match
    from tests.golden.scenarios import SCENARIOS

    def engine(layout, shard, **kw):
        return TuningSession(
            layout=layout, shard=shard, objective="runtime", **kw
        )

    for name, runner in SCENARIOS.items():
        assert_outcomes_match(name, runner(engine=engine))
