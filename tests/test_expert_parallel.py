"""shard_map expert parallelism: numerics vs the local dispatch, gradient
flow, and the documented capacity/aux deviations (subprocess, 8 devices)."""

import pytest



class TestExpertParallel:
    def test_matches_local_dispatch_uncapped(self, devices_runner):
        out = devices_runner(
            """
            import dataclasses
            import jax, jax.numpy as jnp
            import repro.configs as C
            from repro.configs.shapes import ShapeCell
            from repro.launch.build import rules_for
            from repro.launch.mesh import make_mesh
            from repro.models import Model, init_tree
            from repro.parallel.constraints import activation_sharding

            spec = C.smoke("arctic-480b")  # dense residual + top-2 MoE
            cfg = spec.model.replace(
                compute_dtype="float32",
                moe=dataclasses.replace(spec.model.moe, capacity_factor=16.0),
            )
            model = Model(cfg)
            params = init_tree(jax.random.key(0), model.param_specs())
            batch = {"tokens": jax.random.randint(
                jax.random.key(1), (8, 16), 0, cfg.vocab_size)}
            logits1, _ = model.forward(params, batch)
            mesh = make_mesh((2, 4), ("data", "model"))
            rules = rules_for(spec, ShapeCell("t", 16, 8, "train"), mesh)
            with activation_sharding(rules, mesh):
                logits2, _ = model.forward(params, batch)
                grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
            err = float(jnp.max(jnp.abs(logits1 - logits2)))
            assert err < 1e-3, err
            # router + expert weights receive nonzero gradients
            moe_layer = grads["layers"]["moe"]
            for name in ("router", "wi_gate", "wo"):
                g = float(jnp.sum(jnp.abs(moe_layer[name])))
                assert g > 0, name
            print("EP MATCH OK", err)
            """
        )
        assert "EP MATCH OK" in out

    def test_capacity_drops_are_local_per_shard(self, devices_runner):
        out = devices_runner(
            """
            import dataclasses
            import jax, jax.numpy as jnp
            import repro.configs as C
            from repro.configs.shapes import ShapeCell
            from repro.launch.build import rules_for
            from repro.launch.mesh import make_mesh
            from repro.models import Model, init_tree
            from repro.parallel.constraints import activation_sharding

            spec = C.smoke("kimi-k2-1t-a32b")
            cfg = spec.model.replace(
                compute_dtype="float32",
                moe=dataclasses.replace(spec.model.moe, capacity_factor=0.3),
            )
            model = Model(cfg)
            params = init_tree(jax.random.key(0), model.param_specs())
            batch = {"tokens": jax.random.randint(
                jax.random.key(1), (8, 16), 0, cfg.vocab_size)}
            mesh = make_mesh((2, 4), ("data", "model"))
            rules = rules_for(spec, ShapeCell("t", 16, 8, "train"), mesh)
            with activation_sharding(rules, mesh):
                logits, aux = model.forward(params, batch)
            assert bool(jnp.all(jnp.isfinite(logits)))
            assert float(aux) > 0
            print("EP CAPACITY OK")
            """
        )
        assert "EP CAPACITY OK" in out
