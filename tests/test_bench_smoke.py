"""Benchmark wiring smoke (`pytest -m bench_smoke`): runs the fleet bench
in its seconds-scale smoke mode — donation check (including the (B,d)
feature buffer), a small scaling-sweep point with trace verification AND
the n = 32768 feature-buffer point (the 10⁴–10⁵ regime must stay wired:
nothing of extent n² exists on that path, so it is seconds, not minutes),
the fused streaming-kernel lane at both points (trace-checked against the
feature lane, its transient-footprint collapse asserted at n = 32768),
the `--shards` job-axis sharding sweep (entries recorded, sharded traces
asserted identical to the lockstep reference), the streaming
`TuningSession` scenario (recurring jobs in waves, warm-start amortization
asserted), the open-loop Poisson workload G (async `TuningService` vs the
lockstep session under deterministic straggler injection — bit-identical
outcomes, sustained jobs/sec and sojourn percentiles, the smoke-mode
≥1.1× throughput floor), the cost-aware pricing workload H (catalog
repricing movement, runtime-vs-cost objective contrast with USD savings,
Pareto invariants), and the `BENCH_fleet.json` emission — so the bench
plumbing is exercised without the multi-minute full sweep.

Excluded from the default tier-1 lane (see pyproject addopts); selected
explicitly with `pytest -m bench_smoke`, and included in the full
`-m "slow or not slow"` suite.
"""

import json
import os
import sys

import pytest

pytestmark = pytest.mark.bench_smoke

# `benchmarks` is a repo-root package; `python -m pytest` from the root puts
# the root on sys.path, but make it explicit for other invocation styles.
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)


def test_fleet_bench_smoke(tmp_path):
    from benchmarks import fleet_bench

    path = tmp_path / "BENCH_fleet.json"
    out = fleet_bench.run(smoke=True, json_path=str(path))

    assert out["smoke"] is True
    assert out["donation"]["state_donated"]
    assert "feats" in out["donation"]["buffers_checked"]

    rows = out["scaling"]["sweep"]
    assert [r["n"] for r in rows] == [64, 32768]
    for r in rows:
        assert r["traces_identical"]
        assert r["feature_step_ms"] > 0.0

    small, large = rows
    # The small point exercises all four layouts; the feature step must
    # beat the dense full-extent step even at the smoke point (B=8, n=64);
    # the margin is large (>10x) so a loose bound survives this host's
    # ±2x wall-clock wobble.
    assert small["gather_traces_identical"]
    assert small["step_speedup_vs_dense"] > 2.0

    # The fused streaming-kernel lane is timed, transient-probed, and
    # trace-checked at EVERY sweep point — it has no n ceiling.
    for r in rows:
        assert r["fused_traces_identical"]
        assert r["fused_step_ms"] > 0.0
        assert r["fused_step_transient_mb"] > 0.0
    # At n=32768 the fused claims must hold even in smoke mode: XLA's
    # compiled transient footprint collapses (the (B,n) cross block is
    # gone — ≥5x here at the smoke budget B=8; >20x at the full B=24
    # protocol) and the fused step is no slower than the feature step
    # beyond this host's wall-clock wobble.
    assert large["fused_transient_reduction"] > 5.0
    assert large["fused_step_ms"] <= 1.25 * large["feature_step_ms"]

    # The n=32768 point runs the feature buffer only: the dense step
    # (O(18n³)) and the gather layout (a 4 GiB (n,n) tensor per job) are
    # exactly the walls it removes.
    assert large["dense_step_ms"] is None
    assert large["gather_step_ms"] is None
    assert large["gather_traces_identical"] is None
    # Memory reporting: the resident geometry is the (n,d) encoding — under
    # a few MB — while the d²-gather layout would need n²·4 bytes ≈ 4.3 GB;
    # and no live device buffer is anywhere near (n,n).
    assert large["geom_feature_mb"] < 4.0
    assert large["geom_gather_mb"] > 1000.0
    assert large["largest_live_buffer_mb"] < large["geom_gather_mb"] / 50.0
    # Peak RSS is monotone over the process, so it is reported once per
    # run, not per sweep point.
    assert out["peak_rss_mb"] > 0.0

    # The --shards axis: sharded entries must be recorded and the sharded
    # traces must have been verified identical to the lockstep reference
    # (conftest forces a multi-device CPU topology, so the lane really
    # shards here rather than recording a skip).
    import jax

    sh = out["sharding"]
    assert sh["workload"] == "synthetic_service"
    assert [row["shards"] for row in sh["shards"]] == [2]
    assert sh["unsharded_s"] > 0.0
    if jax.device_count() >= 2:
        row = sh["shards"][0]
        assert "skipped" not in row
        assert row["traces_identical"]
        assert row["batched_s"] > 0.0 and row["speedup_vs_unsharded"] > 0.0
    else:  # pragma: no cover - exotic invocation without forced devices
        assert "skipped" in sh["shards"][0]

    # Streaming-session scenario: recurring jobs in waves must produce both
    # cold and warm-started searches, the warm ones converging in strictly
    # fewer fresh trials (the bench itself asserts the strict inequality;
    # re-checked here against the emitted entry).
    d = out["session_streaming"]
    assert d["cold_jobs"] > 0 and d["warm_jobs"] > 0
    assert d["warm_seeded_trials"] > 0
    assert d["profile_cache_hits"] > 0
    assert d["warm_mean_fresh_trials"] < d["cold_mean_fresh_trials"]

    # Adversarial-fleet scenario: disturbed profiling (retried transients),
    # 10% cancellations, straggler reporting, and a shard-loss reshard —
    # completion must stay ≥ 95% and the retry/waste overheads must be
    # reported (the bench asserts the same bounds internally when check).
    adv = out["adversarial"]
    assert adv["completion_rate"] >= 0.95
    assert adv["converged"] + adv["failed"] + adv["cancelled"] == adv["n_jobs"]
    assert adv["cancelled"] >= 1 and adv["wasted_trials"] > 0
    assert adv["retry_attempts"] > 0 and adv["retry_backoff_s"] > 0.0
    assert adv["straggler_trials"] > 0
    if jax.device_count() >= 2:
        assert adv["shard"] == 2 and adv["reshard_survivors"] > 0

    # Open-loop workload G: async service vs lockstep session under
    # Poisson arrivals and straggler injection.  The bench itself asserts
    # per-job outcome bit-identity across the two drivers and the smoke
    # throughput floor (≥1.1x; the full protocol is held to ≥1.3x); the
    # structural checks here pin the emitted entry.
    g = out["open_loop"]
    assert g["traces_identical"]
    assert g["service_groups"] == len(g["space_ns"]) == 3
    assert g["speedup_jobs_per_sec"] >= g["speedup_floor"] >= 1.1
    for side in ("lockstep", "async"):
        s = g[side]
        assert s["jobs_per_sec"] > 0.0
        assert 0.0 < s["sojourn_p50_s"] <= s["sojourn_p99_s"]
    # The straggler stalls serialize through the lockstep barrier, so the
    # async side must also win on latency, not just throughput.
    assert g["async"]["sojourn_p50_s"] < g["lockstep"]["sojourn_p50_s"]

    # Workload H: cost-aware pricing.  The bench itself asserts the
    # repricing-movement floor (≥ 3 Table I optima on some catalog) and
    # the Pareto invariants; the structural checks here pin the emitted
    # entry — a USD savings field must be present and non-negative, and
    # the cost objective must actually diverge from the runtime objective
    # on at least one catalog job.
    h = out["pricing"]
    assert h["usd_saved_total"] >= 0.0
    assert h["usd_runtime_total"] >= h["usd_cost_total"] > 0.0
    assert h["contrast_jobs"] >= 1
    assert max(h["argmin_moved"].values()) >= 3
    assert h["argmin_moved"]["ondemand"] == 0  # the identity book
    assert all("usd_saved" in r for r in h["jobs"])
    assert all(r["pareto_size"] >= 1 for r in h["jobs"])
    assert all(f["family_penalty"] >= 1.0 for f in h["family"])

    data = json.loads(path.read_text())
    assert data["scaling"]["sweep"][0]["n"] == rows[0]["n"]
    assert data["session_streaming"]["warm_jobs"] == d["warm_jobs"]
    assert data["sharding"]["shards"] == sh["shards"]
    assert data["adversarial"]["completion_rate"] == adv["completion_rate"]
    assert data["open_loop"]["speedup_jobs_per_sec"] == g["speedup_jobs_per_sec"]
    assert data["pricing"]["usd_saved_total"] == h["usd_saved_total"]
