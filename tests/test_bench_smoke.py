"""Benchmark wiring smoke (`pytest -m bench_smoke`): runs the fleet bench
in its seconds-scale smoke mode — donation check, one small scaling-sweep
point with trace verification, and the `BENCH_fleet.json` emission — so the
bench plumbing is exercised without the multi-minute full sweep.

Excluded from the default tier-1 lane (see pyproject addopts); selected
explicitly with `pytest -m bench_smoke`, and included in the full
`-m "slow or not slow"` suite.
"""

import json
import os
import sys

import pytest

pytestmark = pytest.mark.bench_smoke

# `benchmarks` is a repo-root package; `python -m pytest` from the root puts
# the root on sys.path, but make it explicit for other invocation styles.
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)


def test_fleet_bench_smoke(tmp_path):
    from benchmarks import fleet_bench

    path = tmp_path / "BENCH_fleet.json"
    out = fleet_bench.run(smoke=True, json_path=str(path))

    assert out["smoke"] is True
    assert out["donation"]["state_donated"]

    rows = out["scaling"]["sweep"]
    assert rows
    for r in rows:
        assert r["traces_identical"]
        # The packed step must beat the dense full-extent step even at the
        # smoke point (B=8, n=64); the margin is large (>10x) so a loose
        # bound survives this host's ±2x wall-clock wobble.
        assert r["step_speedup_vs_dense"] > 2.0
        assert r["packed_step_ms"] > 0.0

    data = json.loads(path.read_text())
    assert data["scaling"]["sweep"][0]["n"] == rows[0]["n"]
