"""Async tuning-service lane: `TuningService` / `TuningDaemon`.

Three layers of guarantees, strongest first:

  * BIT-IDENTITY — every golden scenario replayed through the async
    service (per-group worker threads, no lockstep barrier) must equal
    the committed single-threaded fixtures byte-for-byte, unsharded and
    sharded, including the disturbed elastic fleet (victim cancelled and
    the fleet resharded while the pace gate holds the workers mid-
    flight).  The interleaving-fuzz tests then drive seeded adversarial
    sleeps through the pace hook and compare per-job `as_dict()` against
    a single-threaded reference drain of the same workload.
  * SCHEDULING CONTRACTS — bounded-queue backpressure ("block" parks the
    submitter until capacity frees; "raise" throws `ServiceSaturated`),
    graceful shutdown, thread-safe `ProfileCache` sharing.
  * OPERATIONAL SURFACE — the metrics snapshot schema (queue depth,
    per-group step latency, jobs/sec, PR-7 fault counters) and the
    `TuningDaemon` JSON snapshot file.

Every test here carries the ``service`` marker: conftest arms a 60 s
faulthandler watchdog, so a deadlock aborts with all-thread tracebacks
instead of wedging the suite.
"""

import json
import threading
import time

import pytest

from repro.core.bayesopt import BOSettings
from repro.fleet import (
    FleetJob,
    ProfileCache,
    ServiceSaturated,
    TuningService,
    TuningSession,
)
from repro.runtime.serve import TuningDaemon

from golden import assert_outcomes_match
from golden.scenarios import (
    SCENARIOS,
    _elastic_job,
    flat_profile,
    quad_space,
    quad_table,
    synth_space_table,
)
from test_golden_traces import FAULT_FIELDS

pytestmark = pytest.mark.service


class _ServiceEngine:
    """Session-surface adapter over a `TuningService` for the golden
    scenario runners.  ``paused=True`` parks the workers while a wave is
    being submitted and re-parks after every drain — the warm-session
    scenario needs each wave's class-history snapshots to be atomic
    (exactly what the synchronous session gives it); the no-history
    scenarios run unpaused so the lanes exercise REAL submit/step
    concurrency."""

    def __init__(self, paused=False, **kwargs):
        self.svc = TuningService(**kwargs)
        self.paused = paused
        if paused:
            self.svc.pause()

    def submit(self, *args, **kwargs):
        return self.svc.submit(*args, **kwargs)

    def drain(self):
        out = self.svc.drain()
        if self.paused:
            self.svc.pause()
        return out

    def results(self):
        return self.svc.results()

    def shutdown(self):
        self.svc.shutdown(drain=False)


def _run_through_service(scenario, layout, shard, paused):
    engines = []

    def engine(**kwargs):
        eng = _ServiceEngine(paused=paused, **kwargs)
        engines.append(eng)
        return eng

    try:
        return SCENARIOS[scenario](layout=layout, shard=shard, engine=engine)
    finally:
        for eng in engines:
            eng.shutdown()


@pytest.mark.golden
class TestGoldenThroughService:
    """The four committed scenarios through the async service — any
    worker interleaving must reproduce the lockstep fixtures exactly."""

    @pytest.mark.parametrize("scenario", sorted(SCENARIOS))
    def test_unsharded_matches_fixture(self, scenario):
        outs = _run_through_service(
            scenario, "feature", None, paused=(scenario == "warm-session")
        )
        assert_outcomes_match(scenario, outs)

    @pytest.mark.parametrize("scenario", ["n69-exhaustion", "n512-budgeted"])
    def test_sharded_matches_fixture(self, scenario):
        outs = _run_through_service(
            scenario, "feature", 2, paused=False
        )
        assert_outcomes_match(scenario, outs)


@pytest.mark.chaos
class TestDisturbedThroughService:
    def test_disturbed_elastic_fleet_survivors_match(self):
        """The adversarial elastic scenario driven through the service:
        the pace gate parks every group mid-flight (> 3 iterations in),
        the victim is cancelled and the fleet resharded 2 → 1 while the
        workers are held, then the gate opens and the drain finishes.
        Survivors must equal the UNDISTURBED fixture (modulo the fault-
        reporting fields), exactly like the synchronous disturbed test."""
        from repro.cluster.faults import FaultPlan

        gate = threading.Event()
        parked = set()
        parked_cv = threading.Condition()

        def pace(key, iteration):
            if gate.is_set() or iteration <= 3:
                return
            with parked_cv:
                parked.add(key)
                parked_cv.notify_all()
            gate.wait()

        svc = TuningService(
            layout="feature", shard=2,
            settings=BOSettings(max_iters=12), warm_start=False, pace=pace,
        )
        try:
            svc.pause()
            handles = []
            for s in range(8):
                job = _elastic_job(f"e{s}", s)
                if s in (0, 3):
                    plan = FaultPlan(seed=s, transient_run_failures=2)
                    job.profile_run = plan.wrap_run(job.profile_run, job.name)
                handles.append(svc.submit(job, seed=s))
            victim = svc.submit(_elastic_job("victim", 0), seed=99)
            keys = svc._session._pending_group_keys()
            svc.resume()
            deadline = time.monotonic() + 30.0
            with parked_cv:
                while parked != keys:
                    assert time.monotonic() < deadline, (parked, keys)
                    parked_cv.wait(0.1)
            assert victim.cancel()
            svc._session.reshard(shard=None)  # shard loss, mid-flight
            gate.set()
            svc.drain()
        finally:
            gate.set()
            svc.shutdown(drain=False)
        assert_outcomes_match(
            "elastic-fleet", [h.outcome() for h in handles],
            ignore=FAULT_FIELDS,
        )
        assert victim.status == "cancelled"
        assert victim.outcome().records  # trials landed before the cancel


def _fuzz_jobs():
    """A three-group mixed workload with unique names: cherrypick over
    n=69, explicit-split over n=512, profiled Ruya over n=20."""
    space69, table69 = synth_space_table(69)
    space512, table512 = synth_space_table(512)
    prof = flat_profile()
    jobs = []
    for s in range(4):
        jobs.append((FleetJob(name=f"a{s}", space=space69,
                              cost_table=table69), s, {"mode": "cherrypick"}))
    for s in range(4):
        jobs.append((
            FleetJob(name=f"b{s}", space=space512, cost_table=table512),
            10 + s,
            {"priority": list(range(0, 50)), "remaining": list(range(50, 512))},
        ))
    for s in range(4):
        jobs.append((
            FleetJob(name=f"c{s}", space=quad_space(), cost_table=quad_table(),
                     full_input_size=10e9, profile_result=prof),
            20 + s, {},
        ))
    return jobs


def _session_kwargs():
    return dict(
        layout="feature", settings=BOSettings(max_iters=10),
        warm_start=False,
    )


class TestInterleavingFuzz:
    @pytest.mark.parametrize("fuzz_seed", [0, 1, 2])
    def test_any_interleaving_matches_single_threaded(self, fuzz_seed):
        """Seeded adversarial scheduling: the pace hook injects a
        deterministic pseudo-random sleep per (group, iteration), skewing
        the three groups' relative progress differently per seed.  Every
        job's full `SearchOutcome.as_dict()` must equal the single-
        threaded lockstep drain of the identical workload."""
        reference = TuningSession(**_session_kwargs())
        for job, seed, kw in _fuzz_jobs():
            reference.submit(job, seed=seed, **kw)
        want = {o.name: o.as_dict() for o in reference.drain()}

        import hashlib

        def pace(key, iteration):
            h = hashlib.sha256(
                f"{fuzz_seed}/{key}/{iteration}".encode()
            ).digest()
            time.sleep((h[0] % 8) * 0.001)

        svc = TuningService(pace=pace, **_session_kwargs())
        try:
            # Unpaused: submissions race the workers' admission loops.
            handles = [
                svc.submit(job, seed=seed, **kw)
                for job, seed, kw in _fuzz_jobs()
            ]
            got = {o.name: o.as_dict() for o in svc.drain()}
        finally:
            svc.shutdown(drain=False)
        assert set(got) == set(want)
        for name in want:
            assert got[name] == want[name], f"job {name} diverged"
        assert all(h.status == "done" for h in handles)


class TestBackpressure:
    def test_saturation_raise(self):
        svc = TuningService(
            max_in_flight=2, saturation="raise", **_session_kwargs()
        )
        space, table = synth_space_table(69)
        try:
            svc.pause()  # nothing completes → the cap must bind
            for s in range(2):
                svc.submit(FleetJob(name=f"j{s}", space=space,
                                    cost_table=table),
                           seed=s, mode="cherrypick")
            with pytest.raises(ServiceSaturated):
                svc.submit(FleetJob(name="j2", space=space, cost_table=table),
                           seed=2, mode="cherrypick")
            outs = svc.drain()  # resumes, finishes the two admitted jobs
        finally:
            svc.shutdown(drain=False)
        assert [o.name for o in outs] == ["j0", "j1"]

    def test_saturation_block_parks_submitter_until_capacity(self):
        svc = TuningService(max_in_flight=1, **_session_kwargs())
        space, table = synth_space_table(69)

        def job(name):
            return FleetJob(name=name, space=space, cost_table=table)

        try:
            svc.pause()
            svc.submit(job("first"), seed=0, mode="cherrypick")
            second_done = threading.Event()

            def blocked_submit():
                svc.submit(job("second"), seed=1, mode="cherrypick")
                second_done.set()

            t = threading.Thread(target=blocked_submit, daemon=True)
            t.start()
            time.sleep(0.2)
            # Still parked: capacity is 1 and "first" cannot finish while
            # the service is paused.
            assert not second_done.is_set()
            svc.resume()  # "first" completes → capacity frees → unblocks
            assert second_done.wait(timeout=30.0)
            t.join(timeout=10.0)
            svc.drain()
        finally:
            svc.shutdown(drain=False)
        assert sorted(o.name for o in svc.results()) == ["first", "second"]

    def test_max_in_flight_validation(self):
        with pytest.raises(ValueError):
            TuningService(max_in_flight=0)
        with pytest.raises(ValueError):
            TuningService(saturation="drop")


class TestProfileCacheConcurrency:
    def test_concurrent_get_or_profile_single_class(self):
        """16 threads racing one empty cache with same-class jobs: the
        class must be profiled exactly once (one miss, 15 hits) and the
        store must not tear — the regression this pins is the unlocked
        probe→miss→store window double-profiling a class."""
        cache = ProfileCache()
        n_threads = 16
        barrier = threading.Barrier(n_threads)
        results, errors = [], []

        def run_fn(sample_bytes):
            time.sleep(0.001)  # widen the probe window
            return sample_bytes * 5e-7, 0.9 * sample_bytes + 1e9

        def worker():
            try:
                barrier.wait()
                results.append(cache.get_or_profile(run_fn, 10e9))
            except BaseException as e:  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30.0)
        assert not errors
        assert len(results) == n_threads
        assert cache.misses == 1
        assert cache.hits == n_threads - 1
        # Every thread got the one shared profile object.
        assert all(r is results[0] for r in results)

    def test_shared_cache_across_concurrent_services(self):
        """Two services submitting same-class profiled jobs concurrently
        through ONE cache: exactly one full profile run in total."""
        cache = ProfileCache()

        def make_svc():
            return TuningService(
                cache=cache, settings=BOSettings(max_iters=8),
                warm_start=False,
            )

        def run_fn(sample_bytes):
            return sample_bytes * 5e-7, 0.8 * sample_bytes + 1e9

        svcs = [make_svc(), make_svc()]
        try:
            barrier = threading.Barrier(2)

            def drive(svc, tag):
                barrier.wait()
                for s in range(3):
                    svc.submit(
                        FleetJob(name=f"{tag}{s}", space=quad_space(),
                                 cost_table=quad_table(),
                                 full_input_size=10e9, profile_run=run_fn),
                        seed=s,
                    )
                svc.drain()

            threads = [
                threading.Thread(target=drive, args=(svc, tag), daemon=True)
                for svc, tag in zip(svcs, "xy")
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=45.0)
                assert not t.is_alive()
        finally:
            for svc in svcs:
                svc.shutdown(drain=False)
        assert cache.misses == 1
        assert cache.hits == 5  # six same-class jobs, one full profile


class TestMetricsSurface:
    def test_metrics_schema_and_counters(self):
        svc = TuningService(max_in_flight=8, **_session_kwargs())
        space, table = synth_space_table(69)
        try:
            for s in range(3):
                svc.submit(FleetJob(name=f"j{s}", space=space,
                                    cost_table=table),
                           seed=s, mode="cherrypick")
            svc.drain()
            m = svc.metrics()
        finally:
            svc.shutdown(drain=False)
        json.dumps(m)  # the whole surface must be JSON-able
        assert m["submitted"] == 3
        assert m["completed"] == 3
        assert m["in_flight"] == 0
        assert m["queue_depth"] == 0
        assert m["statuses"] == {"converged": 3}
        assert m["jobs_per_sec"] > 0
        assert m["faults"]["profile_attempts_total"] == 3  # 1 clean try each
        assert m["faults"]["retry_backoff_s_total"] == 0.0
        assert m["faults"]["straggler_trials"] == 0
        groups = m["groups"]
        assert len(groups) == 1  # one admission group in this workload
        (g,) = groups.values()
        assert g["iterations"] > 0 and g["steps"] > 0
        assert g["mean_step_s"] > 0 and g["last_step_s"] > 0
        assert g["admitted"] == 3
        assert g["live_chunks"] == 0

    def test_zero_job_snapshot_has_no_rate(self):
        """A fresh service has no completion window: `jobs_per_sec` must
        be None, not a division artifact."""
        svc = TuningService(**_session_kwargs())
        try:
            m = svc.metrics()
        finally:
            svc.shutdown(drain=False)
        json.dumps(m)
        assert m["submitted"] == 0 and m["completed"] == 0
        assert m["jobs_per_sec"] is None

    def test_one_job_snapshot_has_no_rate(self):
        """One completion's 'window' is just that job's latency — the old
        truthiness check plus the `max(span, 1e-9)` clamp extrapolated it
        into absurd (near-infinite) jobs/sec.  A single-completion
        snapshot must report None and leave the rest of the surface
        intact."""
        svc = TuningService(**_session_kwargs())
        space, table = synth_space_table(69)
        try:
            svc.submit(FleetJob(name="only", space=space, cost_table=table),
                       seed=0, mode="cherrypick")
            svc.drain()
            m = svc.metrics()
        finally:
            svc.shutdown(drain=False)
        json.dumps(m)
        assert m["completed"] == 1
        assert m["statuses"] == {"converged": 1}
        assert m["jobs_per_sec"] is None

    def test_fault_counters_aggregate_from_outcomes(self):
        from repro.cluster.faults import FaultPlan

        svc = TuningService(
            settings=BOSettings(max_iters=12), warm_start=False,
        )
        try:
            job = _elastic_job("faulty", 0)
            plan = FaultPlan(seed=0, transient_run_failures=2)
            job.profile_run = plan.wrap_run(job.profile_run, job.name)
            svc.submit(job, seed=0)
            svc.submit(_elastic_job("clean", 1), seed=1)
            svc.drain()
            m = svc.metrics()
        finally:
            svc.shutdown(drain=False)
        # 3 attempts for the faulted job + 1 for the clean one.
        assert m["faults"]["profile_attempts_total"] == 4
        assert m["faults"]["profile_retries_total"] == 2
        assert m["faults"]["retry_backoff_s_total"] > 0


class TestDaemon:
    def test_daemon_snapshots_metrics_json(self, tmp_path):
        path = tmp_path / "tuning_metrics.json"
        space, table = synth_space_table(69)
        with TuningDaemon(
            metrics_path=str(path), snapshot_every_s=0.05,
            **_session_kwargs(),
        ) as daemon:
            for s in range(2):
                daemon.submit(FleetJob(name=f"j{s}", space=space,
                                       cost_table=table),
                              seed=s, mode="cherrypick")
            outs = daemon.drain()
            assert [o.name for o in outs] == ["j0", "j1"]
        # stop() (via __exit__) flushed a final snapshot.
        payload = json.loads(path.read_text())
        assert payload["completed"] == 2
        assert payload["in_flight"] == 0
        assert "snapshot_unix_s" in payload
        assert payload["groups"]

    def test_shutdown_without_drain_keeps_finished_results(self):
        space, table = synth_space_table(69)
        svc = TuningService(**_session_kwargs())
        svc.submit(FleetJob(name="j0", space=space, cost_table=table),
                   seed=0, mode="cherrypick")
        svc.drain()
        svc.shutdown(drain=False)
        assert [o.name for o in svc.results()] == ["j0"]
        with pytest.raises(RuntimeError):
            svc.submit(FleetJob(name="j1", space=space, cost_table=table),
                       seed=1, mode="cherrypick")
