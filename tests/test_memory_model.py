"""Unit + property tests for the paper's memory-usage categorization (§III-C)."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.memory_model import (
    FLAT_R2_THRESHOLD,
    LINEAR_R2_THRESHOLD,
    MemoryCategory,
    fit_memory_model,
)

GiB = 1024**3


class TestCategorization:
    def test_perfect_linear(self):
        sizes = [1 * GiB, 2 * GiB, 3 * GiB, 4 * GiB, 5 * GiB]
        readings = [3.0 * s + 0.5 * GiB for s in sizes]
        m = fit_memory_model(sizes, readings)
        assert m.category is MemoryCategory.LINEAR
        assert m.r2 > LINEAR_R2_THRESHOLD
        assert m.estimate(10 * GiB) == pytest.approx(30.5 * GiB, rel=1e-6)

    def test_constant_readings_are_flat(self):
        sizes = [1 * GiB, 2 * GiB, 3 * GiB, 4 * GiB, 5 * GiB]
        m = fit_memory_model(sizes, [4 * GiB] * 5)
        assert m.category is MemoryCategory.FLAT
        assert m.estimate(100 * GiB) == pytest.approx(4 * GiB)

    def test_noisy_mid_r2_is_unclear(self):
        rng = np.random.default_rng(0)
        sizes = np.linspace(1, 5, 5) * GiB
        # Heavy multiplicative noise → R² lands between the thresholds.
        readings = 3.0 * sizes * (1 + 0.35 * rng.standard_normal(5))
        m = fit_memory_model(sizes, readings)
        assert m.category in (MemoryCategory.UNCLEAR, MemoryCategory.LINEAR,
                              MemoryCategory.FLAT)  # depends on draw …
        # … but with this seed specifically:
        assert m.category is MemoryCategory.UNCLEAR

    def test_negative_slope_not_linear(self):
        sizes = [1.0, 2.0, 3.0, 4.0, 5.0]
        readings = [10.0, 8.0, 6.0, 4.0, 2.0]  # perfect negative line
        m = fit_memory_model(sizes, readings)
        assert m.category is not MemoryCategory.LINEAR

    def test_rejects_bad_inputs(self):
        with pytest.raises(ValueError):
            fit_memory_model([1.0], [1.0])
        with pytest.raises(ValueError):
            fit_memory_model([1.0, 2.0], [1.0])

    def test_total_cluster_requirement_adds_overhead_and_leeway(self):
        sizes = [1.0, 2.0, 3.0, 4.0, 5.0]
        m = fit_memory_model(sizes, [2.0 * s for s in sizes])
        req = m.total_cluster_requirement(
            10.0, per_node_overhead=0.5, num_nodes=4, leeway=0.10
        )
        assert req == pytest.approx(20.0 * 1.1 + 2.0)


class TestDegenerateProfiles:
    """Pin `fit_memory_model`'s fallback behavior on degenerate profiling
    runs BEFORE the large-space searches lean on the split it produces:
    each of these must fall back deterministically (never crash, never
    mis-categorize as LINEAR)."""

    def test_constant_memory_across_samples(self):
        """Flat readings over varying sizes: ss_tot = 0 is defined as R²=0
        (a constant model has no correlation with input size) → FLAT, and
        the estimate is the constant itself at any extrapolation."""
        sizes = [1.0 * GiB, 2.0 * GiB, 5.0 * GiB]
        m = fit_memory_model(sizes, [7.0 * GiB] * 3)
        assert m.category is MemoryCategory.FLAT
        assert m.r2 == 0.0
        assert m.slope == 0.0
        for probe in (0.0, 1.0 * GiB, 1e6 * GiB):
            assert m.estimate(probe) == pytest.approx(7.0 * GiB)

    def test_identical_sample_sizes_degenerate_ols(self):
        """All sample sizes equal: sxx = 0, OLS is undefined — the fallback
        is slope 0 / intercept mean / R² 0, which lands in FLAT (no
        extrapolation is ever attempted from a single abscissa)."""
        m = fit_memory_model([3.0 * GiB] * 4, [1.0, 2.0, 3.0, 4.0])
        assert m.category is MemoryCategory.FLAT
        assert m.r2 == 0.0
        assert m.slope == 0.0
        assert m.estimate(10.0 * GiB) == pytest.approx(2.5)

    def test_single_sample_rejected(self):
        """One profiling sample cannot be fit — must raise, not guess."""
        with pytest.raises(ValueError):
            fit_memory_model([1.0 * GiB], [2.0 * GiB])

    def test_negative_ols_slope_is_not_linear(self):
        """A perfect negative line has R² = 1 but is NOT the paper's linear
        growth pattern: the category must fall back to UNCLEAR (plain-BO
        fallback), the exported slope must be zeroed, and the estimate must
        be NaN so no caller can silently extrapolate from it."""
        sizes = [1.0, 2.0, 3.0, 4.0, 5.0]
        m = fit_memory_model(sizes, [10.0 - 2.0 * s for s in sizes])
        assert m.category is MemoryCategory.UNCLEAR
        assert m.r2 == pytest.approx(1.0)
        assert m.slope == 0.0
        assert np.isnan(m.estimate(10.0))

    @given(
        slope=st.floats(-10.0, -0.1),
        intercept=st.floats(50.0, 100.0),
    )
    @settings(max_examples=30, deadline=None)
    def test_negative_slopes_never_linear(self, slope, intercept):
        sizes = [float(i + 1) for i in range(5)]
        readings = [slope * s + intercept for s in sizes]
        m = fit_memory_model(sizes, readings)
        assert m.category is not MemoryCategory.LINEAR
        assert m.slope == 0.0


class TestProperties:
    @given(
        slope=st.floats(0.5, 10.0),
        intercept=st.floats(0.0, 5.0),
        base=st.floats(1.0, 100.0),
    )
    @settings(max_examples=50, deadline=None)
    def test_exact_linear_recovers_slope(self, slope, intercept, base):
        sizes = [base * (i + 1) for i in range(5)]
        readings = [slope * s + intercept for s in sizes]
        m = fit_memory_model(sizes, readings)
        assert m.category is MemoryCategory.LINEAR
        assert m.slope == pytest.approx(slope, rel=1e-6)

    @given(st.lists(st.floats(0.1, 1e6), min_size=2, max_size=10, unique=True))
    @settings(max_examples=50, deadline=None)
    def test_r2_bounded_above_by_one(self, sizes):
        rng = np.random.default_rng(42)
        readings = rng.uniform(0.1, 10.0, len(sizes))
        m = fit_memory_model(sizes, readings)
        assert m.r2 <= 1.0 + 1e-9

    @given(
        st.floats(0.5, 5.0), st.integers(2, 8),
    )
    @settings(max_examples=30, deadline=None)
    def test_estimate_monotone_for_linear(self, slope, n):
        sizes = [float(i + 1) for i in range(max(n, 2))]
        m = fit_memory_model(sizes, [slope * s for s in sizes])
        if m.category is MemoryCategory.LINEAR:
            assert m.estimate(20.0) >= m.estimate(10.0)
