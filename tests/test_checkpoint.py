"""Checkpoint manager: roundtrip (incl. bf16), atomicity, keep-N, async,
restore-latest, and structure validation."""

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def tree():
    return {
        "params": {
            "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "scale": jnp.ones((5,), jnp.bfloat16) * 1.5,
        },
        "step": jnp.asarray(7, jnp.int32),
    }


class TestRoundtrip:
    def test_save_load_identity(self, tmp_path):
        t = tree()
        save_pytree(str(tmp_path / "ck"), t, extra={"step": 7})
        restored, extra = load_pytree(str(tmp_path / "ck"), t)
        assert extra["step"] == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bf16_dtype_preserved(self, tmp_path):
        t = {"x": jnp.asarray([1.5, -2.25], jnp.bfloat16)}
        save_pytree(str(tmp_path / "ck"), t)
        r, _ = load_pytree(str(tmp_path / "ck"), t)
        assert r["x"].dtype == np.dtype("bfloat16")
        np.testing.assert_array_equal(
            np.asarray(r["x"], np.float32), np.asarray(t["x"], np.float32)
        )

    def test_shape_mismatch_rejected(self, tmp_path):
        save_pytree(str(tmp_path / "ck"), {"x": jnp.zeros((3,))})
        with pytest.raises(ValueError):
            load_pytree(str(tmp_path / "ck"), {"x": jnp.zeros((4,))})

    def test_missing_leaf_rejected(self, tmp_path):
        save_pytree(str(tmp_path / "ck"), {"x": jnp.zeros((3,))})
        with pytest.raises(KeyError):
            load_pytree(str(tmp_path / "ck"), {"x": jnp.zeros((3,)),
                                               "y": jnp.zeros((1,))})

    def test_no_tmp_dir_left_behind(self, tmp_path):
        save_pytree(str(tmp_path / "ck"), tree())
        assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


class TestManager:
    def test_latest_and_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=10)
        t = tree()
        for step in (5, 10, 15):
            t["step"] = jnp.asarray(step, jnp.int32)
            mgr.save(step, t, extra={"step": step})
        assert mgr.latest_step() == 15
        restored, extra = mgr.restore(t)
        assert extra["step"] == 15
        assert int(restored["step"]) == 15
        restored5, _ = mgr.restore(t, step=5)
        assert int(restored5["step"]) == 5

    def test_keep_n_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2)
        for step in range(1, 6):
            mgr.save(step, {"x": jnp.asarray(step)})
        assert mgr.all_steps() == [4, 5]

    def test_async_save_then_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=3)
        mgr.save_async(3, tree(), extra={"step": 3})
        mgr.wait()
        assert mgr.latest_step() == 3

    def test_async_overlapping_saves_serialize(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=5)
        for s in (1, 2, 3):
            mgr.save_async(s, {"x": jnp.ones((64, 64)) * s})
        mgr.wait()
        assert set(mgr.all_steps()) == {1, 2, 3}

    def test_restore_empty_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            mgr.restore({"x": jnp.zeros(())})

    def test_manifest_is_json(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, tree())
        with open(os.path.join(mgr.step_dir(1), "manifest.json")) as f:
            manifest = json.load(f)
        assert "entries" in manifest
        assert all("shape" in v for v in manifest["entries"].values())
