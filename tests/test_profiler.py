"""Profiling-run driver (§III-B): calibration corridor, cancel-and-restart
accounting, sample schedule — unit + property tests."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.profiler import profile_job, schedule_sample_sizes


class TestSampleSchedule:
    def test_five_equally_spaced(self):
        sizes = schedule_sample_sizes(100.0, 5)
        assert sizes == [20.0, 40.0, 60.0, 80.0, 100.0]
        steps = np.diff(sizes)
        assert np.allclose(steps, steps[0])

    def test_rejects_single_sample(self):
        with pytest.raises(ValueError):
            schedule_sample_sizes(100.0, 1)


def linear_job(rate_s_per_unit, mem_slope, noise=0.0, seed=0):
    rng = np.random.default_rng(seed)

    def run(size):
        z = 1.0 + noise * rng.standard_normal()
        return size * rate_s_per_unit, mem_slope * size * z

    return run


class TestCalibration:
    @given(rate=st.floats(1e-4, 50.0))
    @settings(max_examples=40, deadline=None)
    def test_final_sample_lands_in_corridor(self, rate):
        """Whatever the job's speed, calibration must land the largest
        sample's runtime inside [30 s, 300 s] (or hit the full dataset)."""
        run = linear_job(rate, 2.0)
        full = 10_000.0
        prof = profile_job(run, full)
        final_runtime = prof.sizes[-1] * rate
        assert final_runtime <= 300.0 + 1e-6
        assert final_runtime >= 30.0 - 1e-6 or prof.sizes[-1] >= full * 0.999

    def test_too_slow_job_cancels_and_shrinks(self):
        """1 % sample takes hours → must cancel at the 300 s cap and retry
        smaller, charging only the cap to the budget."""
        rate = 100.0  # 1% of 10k units = 100 u → 10 000 s
        prof = profile_job(linear_job(rate, 2.0), 10_000.0)
        assert prof.calibration_runs > 1
        assert prof.sizes[-1] * rate <= 300.0 + 1e-6
        # budget sane: no single charge above the cap per run
        assert prof.total_time_s <= 300.0 * (prof.calibration_runs + 5)

    def test_fast_job_grows_sample(self):
        rate = 1e-3  # 1% sample runs in 0.1 s → grow
        prof = profile_job(linear_job(rate, 2.0), 100_000.0)
        assert prof.sizes[-1] > 0.01 * 100_000.0

    def test_model_fit_from_profile(self):
        prof = profile_job(linear_job(0.5, 3.0), 10_000.0)
        assert prof.model.category.value == "linear"
        assert prof.model.slope == pytest.approx(3.0, rel=1e-6)

    @given(slope=st.floats(0.5, 8.0), noise=st.floats(0.0, 0.002))
    @settings(max_examples=25, deadline=None)
    def test_low_noise_always_linear(self, slope, noise):
        prof = profile_job(linear_job(0.5, slope, noise=noise), 5_000.0)
        assert prof.model.category.value == "linear"
