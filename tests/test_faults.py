"""Fault injection (`repro.cluster.faults`) and its session integration:
deterministic disturbance schedules, retried profiling that stays
bit-identical, permanent failures as first-class outcomes, straggler
reporting, and drift detection on the recurring-job scenarios.

Part of the chaos lane (`pytest -m chaos`); runs in tier-1.
"""

import numpy as np
import pytest

from repro.cluster import FaultPlan
from repro.cluster.workloads import JOBS, drift_spec, failure_scenario_jobs
from repro.core.bayesopt import BOSettings
from repro.core.profiler import PermanentRunError, TransientRunError
from repro.fleet import ProfileCache, TuningSession, cluster_fleet

pytestmark = pytest.mark.chaos

KM = "kmeans/spark/bigdata"
PR = "pagerank/spark/bigdata"


def _echo_run(sample):
    return sample * 1e-9, 2.0 * sample + 1e9


class TestFaultPlan:
    @pytest.mark.parametrize(
        "kw",
        [
            {"transient_run_failures": -1},
            {"max_injected": -1},
            {"transient_rate": 1.5},
            {"straggler_rate": -0.1},
            {"straggler_factor": 0.5},
        ],
    )
    def test_rejects_bad_parameters(self, kw):
        with pytest.raises(ValueError):
            FaultPlan(**kw)

    def test_no_faults_is_identity(self):
        wrapped = FaultPlan().wrap_run(_echo_run, "j")
        for s in (1e6, 5e8, 1e9):
            assert wrapped(s) == _echo_run(s)

    def test_scripted_transients_then_passthrough(self):
        wrapped = FaultPlan(transient_run_failures=2).wrap_run(_echo_run, "j")
        for _ in range(2):
            with pytest.raises(TransientRunError):
                wrapped(1e6)
        # Successful calls return the run fn's values untouched.
        assert wrapped(1e6) == _echo_run(1e6)
        assert wrapped(2e6) == _echo_run(2e6)

    def test_stochastic_injection_capped(self):
        # rate=1.0 would fail every call; max_injected bounds the damage so
        # a retrying caller is GUARANTEED to get through.
        plan = FaultPlan(seed=3, transient_rate=1.0, max_injected=2)
        wrapped = plan.wrap_run(_echo_run, "j")
        failures = 0
        for _ in range(2):
            with pytest.raises(TransientRunError):
                wrapped(1e6)
            failures += 1
        assert failures == 2
        for _ in range(10):  # budget spent: everything passes through now
            assert wrapped(1e6) == _echo_run(1e6)

    def test_injection_pattern_deterministic(self):
        plan = FaultPlan(seed=11, transient_rate=0.5, max_injected=3)

        def pattern():
            wrapped = plan.wrap_run(_echo_run, "j")
            out = []
            for _ in range(12):
                try:
                    wrapped(1e6)
                    out.append("ok")
                except TransientRunError:
                    out.append("fail")
            return out

        assert pattern() == pattern()

    def test_permanent_always_raises(self):
        wrapped = FaultPlan(permanent=True).wrap_run(_echo_run, "j")
        for _ in range(3):
            with pytest.raises(PermanentRunError):
                wrapped(1e6)

    def test_straggler_flags_deterministic_and_rate_bounded(self):
        plan = FaultPlan(seed=5, straggler_rate=0.25, straggler_factor=3.0)
        flags = [plan.is_straggler("j", t) for t in range(400)]
        assert flags == [plan.is_straggler("j", t) for t in range(400)]
        frac = sum(flags) / len(flags)
        assert 0.1 < frac < 0.4  # hash-uniform draw at rate 0.25
        t_on = flags.index(True)
        t_off = flags.index(False)
        assert plan.straggler_multiplier("j", t_on) == 3.0
        assert plan.straggler_multiplier("j", t_off) == 1.0
        assert not FaultPlan().is_straggler("j", 0)


class TestSessionUnderFaults:
    def test_retried_profiling_is_bit_identical(self):
        """Transient profiling faults are retried; the fleet's traces are
        bit-identical to a clean run — only the fault-reporting fields
        (attempts, charged backoff) differ."""
        plans = {KM: FaultPlan(seed=1, transient_run_failures=2)}
        faulted = cluster_fleet([KM, PR], faults=plans)
        clean = cluster_fleet([KM, PR])
        st = BOSettings(max_iters=6)
        s1 = TuningSession(settings=st, warm_start=False)
        s2 = TuningSession(settings=st, warm_start=False)
        for i, j in enumerate(faulted):
            s1.submit(j, seed=i)
        for i, j in enumerate(clean):
            s2.submit(j, seed=i)
        o1, o2 = s1.drain(), s2.drain()

        assert o1[0].profile_attempts == 3  # 2 scripted failures + success
        assert o1[0].retry_backoff_s > 0.0
        assert o2[0].profile_attempts == 1
        assert o1[1].profile_attempts == 1  # unfaulted fleet-mate untouched
        d1 = [o.as_dict() for o in o1]
        d2 = [o.as_dict() for o in o2]
        for d in d1 + d2:
            d.pop("profile_attempts"), d.pop("retry_backoff_s")
        assert d1 == d2

    def test_permanent_failure_is_first_class_outcome(self):
        jobs = cluster_fleet(
            [KM, PR], faults={KM: FaultPlan(permanent=True)},
        )
        s = TuningSession(settings=BOSettings(max_iters=6), warm_start=False)
        handles = [s.submit(j, seed=i) for i, j in enumerate(jobs)]
        outs = s.drain()  # mixed fleet: returns normally
        assert [o.status for o in outs] == ["failed", "converged"]
        assert "PermanentRunError" in outs[0].failure
        assert handles[0].status == "failed"
        assert outs[0].records == []
        with pytest.raises(RuntimeError, match="failed"):
            outs[0].best_cost  # no observations to rank

    def test_straggler_latency_reported_not_fed_back(self):
        plan = FaultPlan(seed=2, straggler_rate=0.3, straggler_factor=4.0)
        jobs = cluster_fleet([KM], faults={KM: plan})
        clean = cluster_fleet([KM])
        st = BOSettings(max_iters=8)
        s1 = TuningSession(settings=st, warm_start=False)
        s2 = TuningSession(settings=st, warm_start=False)
        s1.submit(jobs[0], seed=0)
        s2.submit(clean[0], seed=0)
        out, ref = s1.drain()[0], s2.drain()[0]
        atts = [r.attempts for r in out.records]
        assert any(a > 1 for a in atts)  # stragglers surfaced...
        assert all(r.attempts == 1 for r in ref.records)
        # ...but the search itself is untouched: costs/indices identical.
        assert [r.index for r in out.records] == [r.index for r in ref.records]
        assert [r.cost for r in out.records] == [r.cost for r in ref.records]


class TestDriftScenarios:
    def test_drift_spec_shifts_the_memory_model(self):
        base = JOBS[KM]
        drifted = drift_spec(base)
        assert drifted.input_gb == base.input_gb * 2.0
        assert drifted.mem_slope < base.mem_slope  # amortization
        flat = drift_spec(
            JOBS["terasort/hadoop/bigdata"], overhead_growth_gb=2.0,
        )
        assert flat.base_mem_gb > JOBS["terasort/hadoop/bigdata"].base_mem_gb
        with pytest.raises(ValueError):
            drift_spec(base, scale=0.0)

    def test_failure_scenario_catalog(self):
        cat = failure_scenario_jobs()
        assert {k.split("/")[0] for k in cat} == {
            "flaky-kmeans", "broken-kmeans", "kmeans-drift", "terasort-drift",
        }
        # cluster_fleet resolves these keys like any Table I job.
        jobs = cluster_fleet(["kmeans-drift/spark/bigdata"])
        assert jobs[0].profile_run is not None

    def test_drifted_recurrence_is_reprofiled_not_warm_seeded(self):
        """A recurring job whose probe stops matching its class signature
        is flagged, re-profiled, and NOT seeded from the stale class."""
        cache = ProfileCache()
        base = cluster_fleet([KM])[0]
        drift = cluster_fleet(["kmeans-drift/spark/bigdata"])[0]
        s = TuningSession(
            settings=BOSettings(max_iters=6), cache=cache,
            warm_start=True, drift_tolerance=0.05,
        )
        s.submit(base, seed=0)
        s.drain()
        h = s.submit(drift, seed=1)
        outs = s.drain()
        assert s.drift_events == ["kmeans-drift/spark/bigdata"]
        assert cache.drift_reprofiles == 1
        assert s.warm_trials == 0  # stale class history NOT injected
        assert len(h.outcome().seeded) == 0
        assert len(outs) == 2 and all(o.status == "converged" for o in outs)

    def test_undrifted_recurrence_still_warm_starts(self):
        """Control for the drift lane: the same job resubmitted with the
        same memory behaviour DOES warm-start from its class."""
        cache = ProfileCache()
        s = TuningSession(
            settings=BOSettings(max_iters=6), cache=cache,
            warm_start=True, drift_tolerance=0.05,
        )
        s.submit(cluster_fleet([KM])[0], seed=0)
        s.drain()
        h = s.submit(cluster_fleet([KM])[0], seed=1)
        s.drain()
        assert s.drift_events == []
        assert len(h.outcome().seeded) > 0
        assert s.warm_hits == 1
