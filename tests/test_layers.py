"""Layer-level correctness: attention variants, RoPE, norms, MoE dispatch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

from repro.models import layers as L
from repro.models.config import ModelConfig, MoEConfig
from repro.models.spec import init_tree


def cfg_base(**kw):
    d = dict(
        name="t", family="dense", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=128, head_dim=8,
        param_dtype="float32", compute_dtype="float32",
    )
    d.update(kw)
    return ModelConfig(**d)


def rand_params(specs, key=0):
    return init_tree(jax.random.key(key), specs)


class TestAttention:
    def test_gqa_equals_mha_when_kv_heads_equal(self):
        """GQA with group=1 must be exactly MHA."""
        cfg = cfg_base(num_kv_heads=4)
        p = rand_params(L.attn_specs(cfg))
        x = jax.random.normal(jax.random.key(1), (2, 10, 32))
        pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
        out1, _ = L.attn_apply(p, cfg, x, positions=pos)
        # simulate MHA by repeating kv weights per head group — identical here
        out2, _ = L.attn_apply(p, cfg, x, positions=pos)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))

    def test_causality(self):
        """Changing a future token must not change past outputs."""
        cfg = cfg_base()
        p = rand_params(L.attn_specs(cfg))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
        x1 = jax.random.normal(jax.random.key(2), (1, 8, 32))
        x2 = x1.at[:, -1].set(jax.random.normal(jax.random.key(3), (1, 32)))
        o1, _ = L.attn_apply(p, cfg, x1, positions=pos)
        o2, _ = L.attn_apply(p, cfg, x2, positions=pos)
        np.testing.assert_allclose(
            np.asarray(o1[:, :-1]), np.asarray(o2[:, :-1]), atol=1e-5
        )
        assert float(jnp.max(jnp.abs(o1[:, -1] - o2[:, -1]))) > 1e-4

    def test_bidirectional_attention_sees_future(self):
        cfg = cfg_base()
        p = rand_params(L.attn_specs(cfg))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
        x1 = jax.random.normal(jax.random.key(2), (1, 8, 32))
        x2 = x1.at[:, -1].set(0.0)
        o1, _ = L.attn_apply(p, cfg, x1, positions=pos, causal=False,
                             use_rope=False)
        o2, _ = L.attn_apply(p, cfg, x2, positions=pos, causal=False,
                             use_rope=False)
        assert float(jnp.max(jnp.abs(o1[:, 0] - o2[:, 0]))) > 1e-5

    def test_mqa_kv1(self):
        cfg = cfg_base(num_kv_heads=1)
        p = rand_params(L.attn_specs(cfg))
        x = jax.random.normal(jax.random.key(1), (2, 6, 32))
        pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
        out, _ = L.attn_apply(p, cfg, x, positions=pos)
        assert out.shape == (2, 6, 32)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_qkv_bias_and_qknorm_change_output(self):
        x = jax.random.normal(jax.random.key(1), (1, 6, 32))
        pos = jnp.broadcast_to(jnp.arange(6)[None], (1, 6))
        for flag in ("qkv_bias", "qk_norm"):
            cfg0 = cfg_base()
            cfg1 = cfg_base(**{flag: True})
            p1 = rand_params(L.attn_specs(cfg1), key=5)
            o1, _ = L.attn_apply(p1, cfg1, x, positions=pos)
            assert bool(jnp.all(jnp.isfinite(o1)))
            extra = set(jax.tree_util.tree_leaves_with_path(L.attn_specs(cfg1))) \
                and len(jax.tree.leaves(L.attn_specs(cfg1)))
            assert extra > len(jax.tree.leaves(L.attn_specs(cfg0)))

    def test_kv_cache_decode_matches_full(self):
        cfg = cfg_base(num_kv_heads=2)
        p = rand_params(L.attn_specs(cfg))
        x = jax.random.normal(jax.random.key(7), (1, 5, 32))
        pos = jnp.broadcast_to(jnp.arange(5)[None], (1, 5))
        full, _ = L.attn_apply(p, cfg, x, positions=pos)

        cache = {
            "k": jnp.zeros((1, 8, 2, 8)), "v": jnp.zeros((1, 8, 2, 8)),
        }
        out_p, cache = L.attn_apply(
            p, cfg, x[:, :4], positions=pos[:, :4], cache=cache,
            cache_index=jnp.int32(0),
        )
        np.testing.assert_allclose(
            np.asarray(out_p), np.asarray(full[:, :4]), atol=1e-5
        )
        out_d, _ = L.attn_apply(
            p, cfg, x[:, 4:5], positions=pos[:, 4:5], cache=cache,
            cache_index=jnp.int32(4),
        )
        np.testing.assert_allclose(
            np.asarray(out_d[:, 0]), np.asarray(full[:, 4]), atol=1e-5
        )


class TestRope:
    def test_rotation_preserves_norm(self):
        cos, sin = L.rope_tables(jnp.arange(16), 8, 10_000.0)
        x = jax.random.normal(jax.random.key(0), (1, 16, 2, 8))
        y = L.apply_rope(x, cos[None, :, None, :], sin[None, :, None, :])
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-5,
        )

    def test_relative_position_property(self):
        """q·k after RoPE depends only on relative distance."""
        cfg = cfg_base(num_heads=1, num_kv_heads=1, head_dim=8)
        q = jax.random.normal(jax.random.key(1), (8,))
        k = jax.random.normal(jax.random.key(2), (8,))

        def dot_at(pq, pk):
            cq, sq = L.rope_tables(jnp.asarray([pq]), 8, 10_000.0)
            ck, sk = L.rope_tables(jnp.asarray([pk]), 8, 10_000.0)
            qr = L.apply_rope(q[None], cq, sq)[0]
            kr = L.apply_rope(k[None], ck, sk)[0]
            return float(qr @ kr)

        assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)
        assert dot_at(3, 1) != pytest.approx(dot_at(3, 2), rel=1e-3)

    def test_position_zero_is_identity(self):
        cos, sin = L.rope_tables(jnp.zeros((1,), jnp.int32), 8, 10_000.0)
        x = jax.random.normal(jax.random.key(0), (1, 2, 8))
        y = L.apply_rope(x, cos[:, None, :], sin[:, None, :])
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


class TestNorms:
    def test_rmsnorm_unit_rms(self):
        cfg = cfg_base(norm="rmsnorm")
        p = {"scale": jnp.ones((32,))}
        x = jax.random.normal(jax.random.key(0), (4, 10, 32)) * 7.0
        y = L.norm_apply(p, cfg, x)
        rms = np.sqrt(np.mean(np.square(np.asarray(y, np.float32)), -1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_layernorm_zero_mean_unit_var(self):
        cfg = cfg_base(norm="layernorm")
        p = {"scale": jnp.ones((32,)), "bias": jnp.zeros((32,))}
        x = jax.random.normal(jax.random.key(0), (4, 10, 32)) * 3.0 + 5.0
        y = np.asarray(L.norm_apply(p, cfg, x), np.float32)
        np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-3)
        np.testing.assert_allclose(y.std(-1), 1.0, rtol=1e-2)


def moe_cfg(e=8, k=2, cf=1.5, **kw):
    return cfg_base(
        family="moe",
        moe=MoEConfig(num_experts=e, top_k=k, d_ff_expert=16,
                      capacity_factor=cf, **kw),
    )


class TestMoE:
    def test_output_shape_and_aux(self):
        cfg = moe_cfg()
        p = rand_params(L.moe_specs(cfg))
        x = jax.random.normal(jax.random.key(1), (2, 12, 32))
        y, aux = L.moe_apply(p, cfg, x)
        assert y.shape == x.shape
        assert float(aux) > 0.0  # aux loss strictly positive for soft router

    def test_uncapped_moe_is_full_topk_mixture(self):
        """With huge capacity, output == explicit top-k mixture of experts."""
        cfg = moe_cfg(e=4, k=2, cf=16.0)
        p = rand_params(L.moe_specs(cfg))
        x = jax.random.normal(jax.random.key(3), (1, 6, 32))
        y, _ = L.moe_apply(p, cfg, x)

        xf = x.reshape(-1, 32)
        probs = jax.nn.softmax(xf @ p["router"], -1)
        gates, ids = jax.lax.top_k(probs, 2)
        gates = gates / gates.sum(-1, keepdims=True)
        outs = []
        for t in range(xf.shape[0]):
            acc = jnp.zeros((32,))
            for j in range(2):
                e = int(ids[t, j])
                h = jax.nn.silu(xf[t] @ p["wi_gate"][e]) * (xf[t] @ p["wi_up"][e])
                acc = acc + gates[t, j] * (h @ p["wo"][e])
            outs.append(acc)
        ref = jnp.stack(outs).reshape(1, 6, 32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)

    def test_capacity_drops_tokens_but_stays_finite(self):
        cfg = moe_cfg(e=4, k=2, cf=0.26)  # tiny capacity → heavy dropping
        p = rand_params(L.moe_specs(cfg))
        x = jax.random.normal(jax.random.key(4), (2, 16, 32))
        y, aux = L.moe_apply(p, cfg, x)
        assert bool(jnp.all(jnp.isfinite(y)))
        # with drops, output magnitude is below the uncapped version's
        cfg2 = moe_cfg(e=4, k=2, cf=16.0)
        y2, _ = L.moe_apply(p, cfg2, x)
        assert float(jnp.sum(jnp.abs(y))) < float(jnp.sum(jnp.abs(y2)))

    def test_shared_expert_and_dense_residual_paths(self):
        cfg = moe_cfg(shared_experts=1)
        p = rand_params(L.moe_specs(cfg))
        assert "shared" in p
        x = jax.random.normal(jax.random.key(5), (1, 8, 32))
        y, _ = L.moe_apply(p, cfg, x)
        assert bool(jnp.all(jnp.isfinite(y)))

        cfg2 = moe_cfg(dense_residual=True)
        p2 = rand_params(L.moe_specs(cfg2))
        assert "dense" in p2
        y2, _ = L.moe_apply(p2, cfg2, x)
        assert bool(jnp.all(jnp.isfinite(y2)))
