"""Property suite for the feature-buffer packed geometry (`fast_bo`).

The feature-buffer engine's whole correctness story is ONE claim: the
(B,B)/(B,n) raw squared-distance blocks computed on the fly from the packed
(B,d) feature buffer are **bit-identical** to (a) gathering the same
entries out of the precomputed (n,n) tensor (the retained PR-2 layout) and
(b) the readable `gp.pairwise_sqdist` on the gathered point set — and that
finite garbage in packed slots ≥ t changes nothing.  Everything downstream
of the blocks is shared op-for-op (`fast_bo._packed_core`), so block
identity ⇒ pick identity ⇒ trace identity.

Randomized draws run twice: as Hypothesis properties when hypothesis is
installed (`hypothesis_compat`), and as a fixed seed-parametrized lane that
always runs in tier-1 (the container ships no hypothesis).  Shapes are kept
small and clustered so each jitted helper compiles a handful of programs.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis_compat import given, settings, st
from repro.core.fast_bo import (
    FleetState,
    bo_step_core,
    bo_step_core_gather,
    encode_features,
    fleet_step,
    gather_sqdist_blocks,
    packed_sqdist_blocks,
    precompute_d2,
)
from repro.core.gp import pairwise_sqdist

_blocks_feature = jax.jit(packed_sqdist_blocks)
_blocks_gather = jax.jit(gather_sqdist_blocks)
_core_feature = jax.jit(bo_step_core)
_core_gather = jax.jit(bo_step_core_gather)


def _draw_case(seed: int, n: int, d: int, capacity: int, t: int):
    """One randomized search state: space features, t observed points in a
    random trial order, finite garbage in every padded slot."""
    rng = np.random.default_rng(seed)
    x = encode_features(rng.normal(size=(n, d)))
    t = min(t, capacity, n)
    order = rng.choice(n, size=t, replace=False).astype(np.int32)
    tried = np.full(capacity, -1, np.int32)
    tried[:t] = order
    feats = np.zeros((capacity, d), np.float32)
    feats[:t] = x[order]
    # Finite garbage in padded slots — must be exactly inert.
    feats[t:] = 1e6 * rng.standard_normal((capacity - t, d)).astype(np.float32)
    tried_garbage = tried.copy()
    tried_garbage[t:] = rng.integers(0, n, size=capacity - t)
    py = np.zeros(capacity, np.float32)
    py[:t] = rng.normal(size=t).astype(np.float32) ** 2 + 1.0
    py_garbage = py.copy()
    py_garbage[t:] = 1e6 * rng.standard_normal(capacity - t)
    obs = np.zeros(n, bool)
    obs[order] = True
    return x, order, tried, tried_garbage, feats, py, py_garbage, obs, t


def _assert_blocks_identical(seed, n, d, capacity, t):
    x, order, tried, tried_g, feats, py, py_g, obs, t = _draw_case(
        seed, n, d, capacity, t
    )
    xj = jnp.asarray(x)
    d2 = precompute_d2(x)

    bb_f, bn_f = _blocks_feature(jnp.asarray(feats), xj, jnp.asarray(tried))
    bb_g, bn_g = _blocks_gather(d2, jnp.asarray(tried))
    bb_f, bn_f, bb_g, bn_g = map(np.asarray, (bb_f, bn_f, bb_g, bn_g))

    # Valid slots: feature blocks == d²-gather blocks, bit for bit.  (The
    # padded rows legitimately differ — gather reads row 0, feature reads
    # the garbage features — and are masked exactly downstream.)
    np.testing.assert_array_equal(bb_f[:t, :t], bb_g[:t, :t])
    np.testing.assert_array_equal(bn_f[:t], bn_g[:t])

    # … and both match the readable gp.py reference: the cross block IS
    # `gp.pairwise_sqdist` on the observed subset, bit for bit, and the
    # training block is its column gather.  (A direct (B,B) self-call of
    # pairwise_sqdist can fuse differently at d = 1 — the very divergence
    # the column-gather construction removes — so it is compared with a
    # last-ulp tolerance, not bitwise.)
    ref_bn = np.asarray(jax.jit(pairwise_sqdist)(xj[order], xj))
    np.testing.assert_array_equal(bn_f[:t], ref_bn)
    np.testing.assert_array_equal(bb_f[:t, :t], ref_bn[:, order])
    ref_bb_self = np.asarray(jax.jit(pairwise_sqdist)(xj[order], xj[order]))
    np.testing.assert_allclose(bb_f[:t, :t], ref_bb_self, rtol=1e-6, atol=1e-6)


def _assert_cores_identical_and_padding_inert(seed, n, d, capacity, t):
    x, order, tried, tried_g, feats, py, py_g, obs, t = _draw_case(
        seed, n, d, capacity, t
    )
    if t == 0:
        return  # no observations: the step is init-scripted, nothing to pin
    xj = jnp.asarray(x)
    d2 = precompute_d2(x)
    cand = jnp.asarray(~obs)
    obs_j = jnp.asarray(obs)
    tj = jnp.asarray(t, jnp.int32)

    ref = _core_feature(xj, jnp.asarray(feats), jnp.asarray(tried),
                        jnp.asarray(py), tj, obs_j, cand)

    # Padded-slot inertness: garbage features, garbage tried indices AND
    # garbage targets in slots ≥ t must not flip a single bit of
    # (pick, max_ei, best).
    got = _core_feature(xj, jnp.asarray(feats), jnp.asarray(tried_g),
                        jnp.asarray(py_g), tj, obs_j, cand)
    assert int(got[0]) == int(ref[0])
    assert float(got[1]) == float(ref[1])  # bitwise, no tolerance
    assert float(got[2]) == float(ref[2])

    # Cross-layout identity: the retained d²-gather core (with its own
    # garbage in padded tried slots) lands on the identical bits.
    gat = _core_gather(d2, jnp.asarray(tried_g), jnp.asarray(py_g), tj,
                       obs_j, cand)
    assert int(gat[0]) == int(ref[0])
    assert float(gat[1]) == float(ref[1])
    assert float(gat[2]) == float(ref[2])


# Fixed shape pool — drawn cases index into it so the jitted helpers
# compile a handful of programs instead of one per example.
_SHAPES = [
    (18, 3, 12, 6),   # the mid-search shape
    (18, 3, 12, 12),  # full buffer, no padded slots
    (18, 3, 12, 1),   # single observation
    (40, 6, 24, 10),  # paper-regime capacity
    (12, 1, 6, 3),    # d = 1
    (9, 4, 1, 1),     # B = 1 edge
]


class TestBlockIdentity:
    @pytest.mark.parametrize("shape_i", range(len(_SHAPES)))
    @pytest.mark.parametrize("seed", range(4))
    def test_blocks_bitwise_identical(self, shape_i, seed):
        n, d, cap, t = _SHAPES[shape_i]
        _assert_blocks_identical(seed, n, d, cap, t)

    @given(data=st.data())
    @settings(max_examples=25, deadline=None)
    def test_blocks_bitwise_identical_hypothesis(self, data):
        shape_i = data.draw(st.integers(0, len(_SHAPES) - 1))
        seed = data.draw(st.integers(0, 2**31 - 1))
        n, d, cap, _ = _SHAPES[shape_i]
        t = data.draw(st.integers(0, min(cap, n)))
        _assert_blocks_identical(seed, n, d, cap, t)


class TestCoreIdentity:
    @pytest.mark.parametrize("shape_i", range(len(_SHAPES)))
    @pytest.mark.parametrize("seed", range(3))
    def test_cores_identical_padding_inert(self, shape_i, seed):
        n, d, cap, t = _SHAPES[shape_i]
        _assert_cores_identical_and_padding_inert(seed, n, d, cap, t)

    @given(data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_cores_identical_hypothesis(self, data):
        shape_i = data.draw(st.integers(0, len(_SHAPES) - 1))
        seed = data.draw(st.integers(0, 2**31 - 1))
        n, d, cap, _ = _SHAPES[shape_i]
        t = data.draw(st.integers(1, min(cap, n)))
        _assert_cores_identical_and_padding_inert(seed, n, d, cap, t)


class TestLockstepExtents:
    """The blocks must stay bit-identical when computed inside the vmapped
    lockstep program — the fleet engine runs chunks of 2–8 jobs, and batch
    extent must not perturb the float32 distance math."""

    @pytest.mark.parametrize("extent", [2, 8])
    @pytest.mark.parametrize("shape_i", [0, 4])  # d = 3 and the d = 1 edge
    def test_blocks_invariant_under_vmap(self, extent, shape_i):
        n, d, cap, t = _SHAPES[shape_i]
        x, order, tried, _, feats, _, _, _, t = _draw_case(7, n, d, cap, t)
        ref_bb, ref_bn = map(np.asarray, _blocks_feature(
            jnp.asarray(feats), jnp.asarray(x), jnp.asarray(tried)))
        fb = jnp.stack([jnp.asarray(feats)] * extent)
        xb = jnp.stack([jnp.asarray(x)] * extent)
        tb = jnp.stack([jnp.asarray(tried)] * extent)
        bb, bn = jax.jit(jax.vmap(packed_sqdist_blocks))(fb, xb, tb)
        for e in range(extent):
            np.testing.assert_array_equal(np.asarray(bb)[e], ref_bb)
            np.testing.assert_array_equal(np.asarray(bn)[e], ref_bn)


class TestNoQuadraticIntermediates:
    """The acceptance-criterion guard: the traced feature-buffer lockstep
    program at n = 32768 must not contain ANY intermediate of extent n² —
    checked structurally on the jaxpr, so it costs a trace, not a run."""

    def test_fleet_step_jaxpr_has_no_n_squared(self):
        n, b, d, j = 32768, 24, 6, 2
        state = FleetState(
            obs=jnp.zeros((j, n), bool),
            tried=jnp.full((j, b), -1, jnp.int32),
            py=jnp.zeros((j, b), jnp.float32),
            feats=jnp.zeros((j, b, d), jnp.float32),
            t=jnp.zeros(j, jnp.int32),
            stop=jnp.full(j, -1, jnp.int32),
            pb=jnp.full(j, -1, jnp.int32),
            done=jnp.zeros(j, bool),
            last_ei=jnp.zeros(j, jnp.float32),
            last_best=jnp.full(j, jnp.inf, jnp.float32),
        )

        def step(s, g, c, p, r, ip, ic, mt):
            return jax.vmap(
                lambda *a: fleet_step(
                    *a,
                    jnp.asarray(0, jnp.int32),
                    jnp.asarray(0.0, jnp.float32),
                    jnp.asarray(True),
                    0.0,
                    "feature",
                )
            )(s, g, c, p, r, ip, ic, mt)

        jaxpr = jax.make_jaxpr(step)(
            state,
            jnp.zeros((j, n, d), jnp.float32),
            jnp.zeros((j, n), jnp.float32),
            jnp.ones((j, n), bool),
            jnp.zeros((j, n), bool),
            jnp.zeros((j, 1), jnp.int32),
            jnp.zeros(j, jnp.int32),
            jnp.full(j, b, jnp.int32),
        )

        largest = 0

        def scan(jx):
            nonlocal largest
            for eqn in jx.eqns:
                for v in eqn.outvars:
                    aval = getattr(v, "aval", None)
                    if aval is not None and hasattr(aval, "shape"):
                        size = int(np.prod(aval.shape)) if aval.shape else 1
                        largest = max(largest, size)
                for p in eqn.params.values():
                    if hasattr(p, "jaxpr"):
                        scan(p.jaxpr)

        scan(jaxpr.jaxpr)
        # The biggest legitimate tensor is the (j, B, n) cross block; n²
        # would be ~1400x larger.
        assert largest <= 4 * j * b * n, (
            f"feature-buffer program materializes a {largest:,}-element "
            f"intermediate at n={n} — the O(n²) wall is back"
        )
