"""Pytest config: make `repro` importable without install; keep 1 CPU device.

Tests that need many devices (sharding equivalence, tiny-mesh dry-runs)
spawn subprocesses with their own XLA_FLAGS — the main test process must NOT
set xla_force_host_platform_device_count (per the dry-run contract).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet in a subprocess with N fake host devices.

    The snippet should print its assertions' evidence; raises on failure.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
        )
    return proc.stdout


@pytest.fixture
def devices_runner():
    return run_with_devices
