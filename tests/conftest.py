"""Pytest config: make `repro` importable without install; keep 1 CPU device.

Tests that need many devices (sharding equivalence, tiny-mesh dry-runs)
spawn subprocesses with their own XLA_FLAGS — the main test process must NOT
set xla_force_host_platform_device_count (per the dry-run contract).
"""

import os
import subprocess
import sys
import textwrap
import time

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))

# Tier-1 wall-clock budget (warn, not fail): the default `pytest -q` lane
# must stay fast enough to run on every change.  Slow/bench lanes opt out
# by selecting different markers.
TIER1_BUDGET_S = 200.0
_SESSION_T0 = {"t0": None}


def pytest_sessionstart(session):
    _SESSION_T0["t0"] = time.time()


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    t0 = _SESSION_T0["t0"]
    if t0 is None:
        return
    elapsed = time.time() - t0
    # Only the tier-1 lane carries the budget: a custom -m selection (slow
    # sweeps, bench smoke) is expected to take longer.
    markexpr = getattr(config.option, "markexpr", "") or ""
    is_tier1 = markexpr.strip() == "not slow and not bench_smoke"
    if is_tier1 and elapsed > TIER1_BUDGET_S:
        terminalreporter.write_line(
            f"WARNING: tier-1 session took {elapsed:.0f}s > "
            f"{TIER1_BUDGET_S:.0f}s budget — move new long-running tests "
            "to the slow lane (@pytest.mark.slow) or speed them up",
            yellow=True,
        )


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet in a subprocess with N fake host devices.

    The snippet should print its assertions' evidence; raises on failure.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
        )
    return proc.stdout


@pytest.fixture
def devices_runner():
    return run_with_devices
