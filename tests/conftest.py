"""Pytest config: make `repro` importable without install; multi-device CPU.

The shard lanes (golden-trace differential tests, shard-invariance
properties) run IN-PROCESS across 1/2/4 shards, so the CPU backend must
expose several host devices before it initializes — this module is
imported before any test module, which makes it the one reliable place to
set the flag (appended only when the caller has not already forced a
count).  Single-device numerics do not depend on the forced count: the
pre-PR-5 tier-1 process already ran with 512 forced devices whenever
XLA_FLAGS was unset (`repro.launch.autotune` sets it at collection time —
its guard now never fires in-process because this file runs first), and
the full suite passes identically at 4.  Subprocess suites (sharding
equivalence, tiny-mesh dry-runs) still spawn with their own XLA_FLAGS via
`run_with_devices`.

Timing: instead of a single noisy wall-clock warning (the host wobbles
±2×, so a fixed budget produced unattributable alarms), every run of the
tier-1 lane reports its top-10 slowest tests and writes the full per-test
timing table to `artifacts/tier1_timing.json` — regressions are pinned to
a test, not to the weather.  Tests carrying the `kernel` marker (the
Pallas kernel-identity lane) additionally get a per-test 30 s attention
flag in the summary.
"""

import faulthandler
import json
import os
import sys
import time

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))

from repro.hostdevices import force_host_device_count  # noqa: E402

force_host_device_count(4)  # shard lanes run 1/2/4 shards in-process

TIMING_JSON = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "artifacts",
                 "tier1_timing.json")
)
_SESSION_T0 = {"t0": None}
_DURATIONS = {}  # nodeid -> summed setup+call+teardown seconds
_KERNEL_NODES = set()  # nodeids carrying the `kernel` marker
_KERNEL_BUDGET_S = 30.0  # per-test ceiling for the kernel-identity lane


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.get_closest_marker("kernel") is not None:
            _KERNEL_NODES.add(item.nodeid)


_SERVICE_WATCHDOG_S = 60.0  # per-test ceiling for threaded service tests


@pytest.fixture(autouse=True)
def _service_watchdog(request):
    """Deadlock insurance for the threaded `service` lane: a wedged
    worker/CV interaction must abort the process WITH all-thread
    tracebacks after 60 s, not hang the suite.  Stdlib `faulthandler`
    (pytest-timeout is not a dependency); armed only for tests carrying
    the ``service`` marker, disarmed on the way out either way."""
    if request.node.get_closest_marker("service") is None:
        yield
        return
    faulthandler.dump_traceback_later(_SERVICE_WATCHDOG_S, exit=True)
    try:
        yield
    finally:
        faulthandler.cancel_dump_traceback_later()


def pytest_sessionstart(session):
    _SESSION_T0["t0"] = time.time()


def pytest_runtest_logreport(report):
    _DURATIONS[report.nodeid] = (
        _DURATIONS.get(report.nodeid, 0.0) + report.duration
    )


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    t0 = _SESSION_T0["t0"]
    if t0 is None or not _DURATIONS:
        return
    elapsed = time.time() - t0
    markexpr = (getattr(config.option, "markexpr", "") or "").strip()
    is_tier1 = markexpr == "not slow and not bench_smoke"
    top = sorted(_DURATIONS.items(), key=lambda kv: kv[1], reverse=True)[:10]
    terminalreporter.write_line(
        f"{'tier-1' if is_tier1 else 'lane'} wall clock {elapsed:.0f}s — "
        "10 slowest tests:"
    )
    for nodeid, dur in top:
        terminalreporter.write_line(f"  {dur:7.2f}s  {nodeid}")
    # The kernel-identity lane rides tier-1, so each of its tests carries a
    # hard attention budget: flag (don't fail) any kernel test over 30 s so
    # a compile-time or interpreter regression is pinned the run it lands.
    slow_kernel = sorted(
        ((n, d) for n, d in _DURATIONS.items()
         if n in _KERNEL_NODES and d > _KERNEL_BUDGET_S),
        key=lambda kv: kv[1], reverse=True,
    )
    for nodeid, dur in slow_kernel:
        terminalreporter.write_line(
            f"KERNEL-LANE SLOW: {dur:.1f}s > {_KERNEL_BUDGET_S:.0f}s budget "
            f"— {nodeid}", yellow=True,
        )
    # Machine-readable trail for FULL tier-1 runs only: a file/-k-restricted
    # invocation (or another -m selection) has a different test population
    # and would overwrite the baseline with non-comparable numbers.
    partial = bool(getattr(config.option, "keyword", "")) or bool(
        getattr(config.option, "file_or_dir", [])
    )
    if not is_tier1 or partial:
        return
    payload = {
        "total_s": elapsed,
        "markexpr": markexpr,
        "exitstatus": int(exitstatus),
        "n_tests": len(_DURATIONS),
        "top10": [{"nodeid": n, "s": d} for n, d in top],
        "tests": {n: d for n, d in sorted(_DURATIONS.items())},
    }
    try:
        os.makedirs(os.path.dirname(TIMING_JSON), exist_ok=True)
        with open(TIMING_JSON, "w") as f:
            json.dump(payload, f, indent=1)
        terminalreporter.write_line(f"wrote {TIMING_JSON}")
    except OSError as e:  # never fail the suite over a timing artifact
        terminalreporter.write_line(f"could not write {TIMING_JSON}: {e}",
                                    yellow=True)


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet in a subprocess with N fake host devices.

    The snippet should print its assertions' evidence; raises on failure.
    """
    import subprocess
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
        )
    return proc.stdout


@pytest.fixture
def devices_runner():
    return run_with_devices
