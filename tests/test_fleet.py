"""Fleet subsystem tests: batched↔sequential trace equivalence, the fleet
driver's single-code-path API, and Flora-style profile-cache behavior.

The equivalence tests assert *identical* `tried`/`costs`/`stop_iteration`
sequences between `batched_search` (J jobs advanced in device-resident
lockstep) and J runs of the sequential engine with the same seeds — the
contract that makes fleet mode a pure execution optimization — including
across packed-buffer capacities (heterogeneous trial budgets group by
(shape, B)), space extents (n = 69 exhaustion = full buffer, synthetic
n = 512 and n = 8192 in the budgeted B ≪ n regime), and packed geometry
layouts (the default feature buffer vs the retained d²-gather path,
`layout="gather"` — both must land on identical bits).  The fast tests
mostly share array shapes so the engine compiles few programs; the
exhaustive 69-config cluster sweep and the n = 8192 identity are marked
`slow`.
"""

import numpy as np
import pytest

from golden import assert_traces_match
from repro.core.bayesopt import BOSettings, cherrypick_search, ruya_search
from repro.core.memory_model import fit_memory_model
from repro.core.search_space import Configuration, SearchSpace
from repro.fleet import (
    MemorySignature,
    ProfileCache,
    batched_search,
    cluster_fleet,
    replay_seeds,
    tune_fleet,
)

GiB = 1024**3
N = 20
SEEDS = range(4)


def quad_space(n=N):
    return SearchSpace(
        [
            Configuration(name=f"c{i}", features=(float(i),), total_memory=float(i))
            for i in range(n)
        ]
    )


def quad_table(n=N, optimum=9):
    return np.array([1.0 + 0.05 * (i - optimum) ** 2 for i in range(n)])


def synth_space_table(n, d=5, seed=0):
    """Random-feature space + smooth synthetic cost table (scaling tests)."""
    rng = np.random.default_rng(seed + n)
    feats = rng.normal(size=(n, d))
    space = SearchSpace(
        [
            Configuration(
                name=f"s{i}",
                features=tuple(float(v) for v in feats[i]),
                total_memory=float(i),
            )
            for i in range(n)
        ]
    )
    w = rng.normal(size=d)
    z = feats @ w
    z = (z - z.mean()) / max(float(z.std()), 1e-9)
    return space, 1.0 + (z - 0.7) ** 2 + 0.05 * rng.random(n)


def assert_traces_equal(batched_trace, reference):
    assert batched_trace.tried == reference.tried
    assert batched_trace.costs == reference.costs
    assert batched_trace.stop_iteration == reference.stop_iteration
    assert batched_trace.phase_boundary == reference.phase_boundary


class TestTraceEquivalence:
    space = quad_space()
    table = quad_table()

    def cost_fn(self):
        table = self.table
        return lambda i: float(table[i])

    def test_cherrypick_identical_to_exhaustion(self):
        seq = [
            cherrypick_search(
                self.space, self.cost_fn(), np.random.default_rng(s),
                to_exhaustion=True,
            )
            for s in SEEDS
        ]
        bt = batched_search(
            self.space, [self.table] * len(seq),
            [np.random.default_rng(s) for s in SEEDS], to_exhaustion=True,
        )
        for j, ref in enumerate(seq):
            assert_traces_equal(bt.job_trace(j), ref)

    def test_cherrypick_identical_with_early_stop(self):
        seq = [
            cherrypick_search(
                self.space, self.cost_fn(), np.random.default_rng(s),
                to_exhaustion=False,
            )
            for s in SEEDS
        ]
        bt = batched_search(
            self.space, [self.table] * len(seq),
            [np.random.default_rng(s) for s in SEEDS], to_exhaustion=False,
        )
        for j, ref in enumerate(seq):
            assert_traces_equal(bt.job_trace(j), ref)
            assert bt.job_trace(j).stop_iteration is not None

    def test_ruya_two_phase_identical(self):
        prio = [7, 8, 9, 10, 11]
        rest = [i for i in range(N) if i not in prio]
        seq = [
            ruya_search(
                self.space, self.cost_fn(), np.random.default_rng(s), prio, rest,
                to_exhaustion=True,
            )
            for s in SEEDS
        ]
        bt = batched_search(
            self.space, [self.table] * len(seq),
            [np.random.default_rng(s) for s in SEEDS],
            priority=[prio] * len(seq), remaining=[rest] * len(seq),
            to_exhaustion=True,
        )
        for j, ref in enumerate(seq):
            assert_traces_equal(bt.job_trace(j), ref)
            assert bt.job_trace(j).phase_boundary == len(prio)

    def test_mixed_splits_in_one_batch(self):
        """Ruya and CherryPick jobs co-exist in one batched call."""
        prio = [0, 1, 2, 18, 19]
        rest = [i for i in range(N) if i not in prio]
        refs = [
            ruya_search(self.space, self.cost_fn(), np.random.default_rng(0),
                        prio, rest, to_exhaustion=True),
            cherrypick_search(self.space, self.cost_fn(),
                              np.random.default_rng(1), to_exhaustion=True),
            ruya_search(self.space, self.cost_fn(), np.random.default_rng(2),
                        list(range(N)), [], to_exhaustion=True),
            cherrypick_search(self.space, self.cost_fn(),
                              np.random.default_rng(3), to_exhaustion=True),
        ]
        bt = batched_search(
            self.space, [self.table] * 4,
            [np.random.default_rng(s) for s in range(4)],
            priority=[prio, list(range(N)), list(range(N)), list(range(N))],
            remaining=[rest, [], [], []],
            to_exhaustion=True,
        )
        for j, ref in enumerate(refs):
            assert_traces_equal(bt.job_trace(j), ref)

    def test_single_job_fleet(self):
        """J=1 must behave like any other fleet size (dummy-padding)."""
        ref = cherrypick_search(
            self.space, self.cost_fn(), np.random.default_rng(11),
            to_exhaustion=True,
        )
        bt = batched_search(
            self.space, [self.table], [np.random.default_rng(11)],
            to_exhaustion=True,
        )
        assert len(bt) == 1
        assert_traces_equal(bt.job_trace(0), ref)

    def test_max_iters_at_phase_boundary_records_it(self):
        """max_iters landing exactly on the phase-0/phase-1 boundary must
        still record phase_boundary, like the sequential engine does."""
        prio = [7, 8, 9, 10, 11]
        rest = [i for i in range(N) if i not in prio]
        st = BOSettings(max_iters=len(prio))
        seq = [
            ruya_search(self.space, self.cost_fn(), np.random.default_rng(s),
                        prio, rest, settings=st, to_exhaustion=True)
            for s in SEEDS
        ]
        bt = batched_search(
            self.space, [self.table] * len(seq),
            [np.random.default_rng(s) for s in SEEDS],
            priority=[prio] * len(seq), remaining=[rest] * len(seq),
            settings=st, to_exhaustion=True,
        )
        for j, ref in enumerate(seq):
            assert ref.phase_boundary == len(prio)
            assert_traces_equal(bt.job_trace(j), ref)

    def test_max_iters_below_init_count(self):
        """The sequential engine observes all scripted init picks before its
        first budget check; the fleet engine must match."""
        st = BOSettings(max_iters=2)  # < default n_init=3
        seq = [
            cherrypick_search(self.space, self.cost_fn(),
                              np.random.default_rng(s), settings=st,
                              to_exhaustion=True)
            for s in SEEDS
        ]
        bt = batched_search(
            self.space, [self.table] * len(seq),
            [np.random.default_rng(s) for s in SEEDS], settings=st,
            to_exhaustion=True,
        )
        for j, ref in enumerate(seq):
            assert len(ref.tried) == 3
            assert_traces_equal(bt.job_trace(j), ref)

    def test_max_iters_budget(self):
        st = BOSettings(max_iters=7)
        seq = [
            cherrypick_search(self.space, self.cost_fn(),
                              np.random.default_rng(s), settings=st,
                              to_exhaustion=True)
            for s in SEEDS
        ]
        bt = batched_search(
            self.space, [self.table] * len(seq),
            [np.random.default_rng(s) for s in SEEDS], settings=st,
            to_exhaustion=True,
        )
        for j, ref in enumerate(seq):
            assert len(bt.job_trace(j).tried) == 7
            assert_traces_equal(bt.job_trace(j), ref)

    def test_heterogeneous_budgets_group_by_capacity(self):
        """Jobs with different trial budgets (→ different packed capacities
        B) in one batched call: each must factorize at exactly the capacity
        the sequential engine uses for it (grouping by (shape, B)), so every
        trace stays identical — including the singleton dummy-pad path each
        one-job capacity group takes."""
        pools = [list(range(10)), list(range(N)), list(range(5, 12))]
        refs = [
            ruya_search(self.space, self.cost_fn(), np.random.default_rng(s),
                        pool, [], to_exhaustion=True)
            for s, pool in enumerate(pools)
        ]
        bt = batched_search(
            self.space, [self.table] * 3,
            [np.random.default_rng(s) for s in range(3)],
            priority=pools, remaining=[[], [], []],
            to_exhaustion=True,
        )
        for j, ref in enumerate(refs):
            assert len(ref.tried) == len(pools[j])  # budgets really differ
            assert_traces_equal(bt.job_trace(j), ref)


class TestTraceEquivalenceScaling:
    """Packed-engine identity at the paper's space extent and beyond it,
    pinned against the golden fixtures (`tests/golden/` — regenerated from
    the sequential reference, so these shim lanes still close the
    sequential↔batched loop, now through one committed artifact).

    n=69 runs to exhaustion (capacity B = n: the packed buffer completely
    full); n=512 runs the budgeted B ≪ n regime the packed layout targets.
    One set of shapes per test so each compiles once.
    """

    def test_n69_exhaustion_matches_golden(self):
        space, table = synth_space_table(69)
        bt = batched_search(
            space, [table] * 2, [np.random.default_rng(s) for s in range(2)],
            to_exhaustion=True,
        )
        # The retained d²-gather layout must land on the identical traces —
        # batched↔feature↔gather↔golden, all bit-for-bit.
        bt_g = batched_search(
            space, [table] * 2, [np.random.default_rng(s) for s in range(2)],
            to_exhaustion=True, layout="gather",
        )
        for b in (bt, bt_g):
            assert all(len(b.job_trace(j).tried) == 69 for j in range(2))
            assert_traces_match("n69-exhaustion", b.traces(), jobs=[0, 1])

    def test_n512_budgeted_matches_golden(self):
        space, table = synth_space_table(512)
        st = BOSettings(max_iters=10)
        prio = list(range(0, 50))
        rest = list(range(50, 512))
        for layout in ("feature", "gather"):
            bt = batched_search(
                space, [table] * 3,
                [np.random.default_rng(s) for s in range(3)],
                priority=[prio] * 3, remaining=[rest] * 3, settings=st,
                to_exhaustion=True, layout=layout,
            )
            assert all(len(bt.job_trace(j).tried) == 10 for j in range(3))
            assert_traces_match("n512-budgeted", bt.traces(), jobs=[0, 1, 2])


@pytest.mark.slow
class TestTraceEquivalenceLargeSpace:
    """The 10⁴-regime identity (slow lane): a budgeted search over n = 8192
    must produce bit-identical traces from the sequential feature-buffer
    engine, the batched feature-buffer engine, and the retained d²-gather
    engine (which at this extent holds a 268 MB (n,n) tensor — the memory
    wall the feature buffer removes; this is the largest space the gather
    cross-check runs on)."""

    def test_n8192_budgeted_identical(self):
        space, table = synth_space_table(8192)
        st = BOSettings(max_iters=12)
        prio = list(range(0, 64))
        rest = list(range(64, 8192))
        refs = [
            ruya_search(space, lambda i: float(table[i]),
                        np.random.default_rng(s), prio, rest, settings=st,
                        to_exhaustion=True)
            for s in range(2)
        ]
        bt = batched_search(
            space, [table] * 2, [np.random.default_rng(s) for s in range(2)],
            priority=[prio] * 2, remaining=[rest] * 2, settings=st,
            to_exhaustion=True,
        )
        bt_g = batched_search(
            space, [table] * 2, [np.random.default_rng(s) for s in range(2)],
            priority=[prio] * 2, remaining=[rest] * 2, settings=st,
            to_exhaustion=True, layout="gather",
        )
        for j, ref in enumerate(refs):
            assert len(ref.tried) == 12
            assert_traces_equal(bt.job_trace(j), ref)
            assert_traces_equal(bt_g.job_trace(j), ref)


@pytest.mark.slow
class TestTraceEquivalenceClusterSweep:
    """Exhaustive identity on the paper's real 69-config jobs."""

    def test_cluster_jobs_identical(self):
        from repro.core.profiler import profile_job
        from repro.core.search_space import split_search_space

        keys = ["kmeans/spark/huge", "terasort/hadoop/bigdata",
                "logregr/spark/huge"]
        jobs = cluster_fleet(keys)
        refs, rngs, prios, rests, tables, spaces = [], [], [], [], [], []
        for job in jobs:
            prof = profile_job(job.profile_run, job.full_input_size)
            prio, rest = split_search_space(
                job.space, prof.model, job.full_input_size,
                per_node_overhead=job.per_node_overhead,
            )
            for seed in range(3):
                refs.append(
                    ruya_search(
                        job.space,
                        lambda i, t=job.cost_table: float(t[i]),
                        np.random.default_rng(seed), prio, rest,
                        to_exhaustion=True,
                    )
                )
                rngs.append(np.random.default_rng(seed))
                prios.append(list(prio))
                rests.append(list(rest))
                tables.append(job.cost_table)
                spaces.append(job.space)
        bt = batched_search(
            spaces, tables, rngs, priority=prios, remaining=rests,
            to_exhaustion=True,
        )
        for j, ref in enumerate(refs):
            assert_traces_equal(bt.job_trace(j), ref)


class TestFleetDriver:
    def test_replay_seeds_cherrypick_reports(self):
        from repro.fleet.driver import FleetJob

        job = FleetJob(name="quad", space=quad_space(), cost_table=quad_table())
        jobs, rngs = replay_seeds(job, range(3))
        reports = tune_fleet(jobs, rngs, mode="cherrypick",
                             settings=BOSettings(max_iters=8),
                             to_exhaustion=True)
        assert len(reports) == 3
        for rep in reports:
            assert rep.profile is None
            assert len(rep.priority) == N and not rep.remaining
            assert len(rep.trace.tried) == 8

    def test_engine_flags_agree(self):
        from repro.fleet.driver import FleetJob

        job = FleetJob(name="quad", space=quad_space(), cost_table=quad_table())
        jobs, _ = replay_seeds(job, range(3))
        st = BOSettings(max_iters=10)
        bat = tune_fleet(jobs, [np.random.default_rng(s) for s in range(3)],
                         mode="cherrypick", settings=st, to_exhaustion=True)
        seq = tune_fleet(jobs, [np.random.default_rng(s) for s in range(3)],
                         mode="cherrypick", settings=st, to_exhaustion=True,
                         engine="sequential")
        for b, s in zip(bat, seq):
            assert b.trace.tried == s.trace.tried
            assert b.trace.costs == s.trace.costs

    def test_rejects_mismatched_rngs(self):
        from repro.fleet.driver import FleetJob

        job = FleetJob(name="quad", space=quad_space(), cost_table=quad_table())
        with pytest.raises(ValueError):
            tune_fleet([job, job], [np.random.default_rng(0)])


def linear_run_fn(slope_gb, base_gb=0.5, rate_s_per_gb=50.0):
    """Emulates a clean linear-memory job: sample_bytes -> (runtime, peak)."""

    def run(sample_bytes):
        gb = sample_bytes / GiB
        return rate_s_per_gb * gb, (slope_gb * gb + base_gb) * GiB

    return run


def flat_run_fn(base_gb=4.0, rate_s_per_gb=50.0):
    def run(sample_bytes):
        return rate_s_per_gb * sample_bytes / GiB, base_gb * GiB

    return run


class TestProfileCache:
    def test_same_pattern_hits(self):
        cache = ProfileCache()
        p1 = cache.get_or_profile(linear_run_fn(3.0), 100.0 * GiB)
        p2 = cache.get_or_profile(linear_run_fn(3.0), 100.0 * GiB)
        assert cache.misses == 1 and cache.hits == 1
        assert p2 is p1  # the expensive profile ran once

    def test_similar_slope_hits_same_bucket(self):
        cache = ProfileCache()
        cache.get_or_profile(linear_run_fn(3.0), 100.0 * GiB)
        cache.get_or_profile(linear_run_fn(3.2), 120.0 * GiB)
        assert cache.hits == 1 and cache.misses == 1

    def test_different_category_misses(self):
        cache = ProfileCache()
        cache.get_or_profile(linear_run_fn(3.0), 100.0 * GiB)
        cache.get_or_profile(flat_run_fn(), 100.0 * GiB)
        assert cache.hits == 0 and cache.misses == 2
        assert len(cache) == 2

    def test_very_different_slope_misses(self):
        cache = ProfileCache()
        cache.get_or_profile(linear_run_fn(1.0), 100.0 * GiB)
        cache.get_or_profile(linear_run_fn(8.0), 100.0 * GiB)
        assert cache.hits == 0 and cache.misses == 2

    def test_signature_of_model(self):
        m_lin = fit_memory_model([1.0, 2.0, 3.0], [3.0, 6.0, 9.0])
        m_flat = fit_memory_model([1.0, 2.0, 3.0], [5.0, 5.0, 5.0])
        s_lin = MemorySignature.of(m_lin)
        s_flat = MemorySignature.of(m_flat)
        assert s_lin.category == "linear"
        assert s_flat.category == "flat"
        assert s_lin != s_flat
