"""Mamba-2 SSD correctness: chunked scan vs naive recurrence, decode parity,
chunk-size invariance, padding, state handoff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models.ssm import ssd_chunked, ssd_decode_step


def naive_ssd(x, dt, A, B_, C_, initial_state=None):
    """O(L·N·P) literal recurrence — the ground truth."""
    b, l, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    rep = h // g
    Bf = np.repeat(np.asarray(B_, np.float64), rep, axis=2)
    Cf = np.repeat(np.asarray(C_, np.float64), rep, axis=2)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    state = (
        np.asarray(initial_state, np.float64)
        if initial_state is not None
        else np.zeros((b, h, n, p))
    )
    ys = np.zeros((b, l, h, p))
    for t in range(l):
        decay = np.exp(dtf[:, t] * Af)  # (b, h)
        state = decay[..., None, None] * state + np.einsum(
            "bh,bhn,bhp->bhnp", dtf[:, t], Bf[:, t], xf[:, t]
        )
        ys[:, t] = np.einsum("bhn,bhnp->bhp", Cf[:, t], state)
    return ys, state


def rand_inputs(key, b=2, l=16, h=2, p=4, g=1, n=4):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B_ = jax.random.normal(ks[3], (b, l, g, n))
    C_ = jax.random.normal(ks[4], (b, l, g, n))
    return x, dt, A, B_, C_


class TestSSDChunked:
    def test_matches_naive_recurrence(self):
        x, dt, A, B_, C_ = rand_inputs(jax.random.key(0))
        y, st = ssd_chunked(x, dt, A, B_, C_, chunk_size=4)
        y_ref, st_ref = naive_ssd(x, dt, A, B_, C_)
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st), st_ref, atol=1e-4)

    @pytest.mark.parametrize("chunk", [1, 2, 4, 8, 16])
    def test_chunk_size_invariance(self, chunk):
        x, dt, A, B_, C_ = rand_inputs(jax.random.key(1))
        y_ref, _ = ssd_chunked(x, dt, A, B_, C_, chunk_size=16)
        y, _ = ssd_chunked(x, dt, A, B_, C_, chunk_size=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)

    def test_non_divisible_length_padding(self):
        x, dt, A, B_, C_ = rand_inputs(jax.random.key(2), l=13)
        y, st = ssd_chunked(x, dt, A, B_, C_, chunk_size=4)
        y_ref, st_ref = naive_ssd(x, dt, A, B_, C_)
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st), st_ref, atol=1e-4)

    def test_initial_state_continuation(self):
        """chunked(A;B) == chunked(A) then chunked(B, initial_state)."""
        x, dt, A, B_, C_ = rand_inputs(jax.random.key(3), l=16)
        y_full, st_full = ssd_chunked(x, dt, A, B_, C_, chunk_size=4)
        y1, st1 = ssd_chunked(
            x[:, :8], dt[:, :8], A, B_[:, :8], C_[:, :8], chunk_size=4
        )
        y2, st2 = ssd_chunked(
            x[:, 8:], dt[:, 8:], A, B_[:, 8:], C_[:, 8:], chunk_size=4,
            initial_state=st1,
        )
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
            atol=1e-4,
        )
        np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), atol=1e-4)

    def test_decode_step_matches_last_position(self):
        x, dt, A, B_, C_ = rand_inputs(jax.random.key(4), l=9)
        y_ref, st_ref = naive_ssd(x, dt, A, B_, C_)
        _, st_prefix = ssd_chunked(
            x[:, :8], dt[:, :8], A, B_[:, :8], C_[:, :8], chunk_size=4
        )
        y_dec, st_dec = ssd_decode_step(
            st_prefix, x[:, 8], dt[:, 8], A, B_[:, 8], C_[:, 8]
        )
        np.testing.assert_allclose(np.asarray(y_dec), y_ref[:, 8], atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_dec), st_ref, atol=1e-4)

    def test_groups_broadcast_over_heads(self):
        x, dt, A, B_, C_ = rand_inputs(jax.random.key(5), h=4, g=2, n=4)
        y, st = ssd_chunked(x, dt, A, B_, C_, chunk_size=4)
        y_ref, st_ref = naive_ssd(x, dt, A, B_, C_)
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)

    @given(
        l=st.integers(2, 24),
        chunk=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_matches_naive(self, l, chunk, seed):
        x, dt, A, B_, C_ = rand_inputs(jax.random.key(seed), l=l)
        y, _ = ssd_chunked(x, dt, A, B_, C_, chunk_size=chunk)
        y_ref, _ = naive_ssd(x, dt, A, B_, C_)
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4)


class TestDecaySanity:
    def test_strong_decay_forgets_history(self):
        """With dt·A ≪ 0 the state forgets: output depends only on recent
        inputs (the SSM can't cheat a long-range copy)."""
        x, dt, A, B_, C_ = rand_inputs(jax.random.key(6), l=16)
        A_strong = A * 100.0
        y1, _ = ssd_chunked(x, dt, A_strong, B_, C_, chunk_size=4)
        x2 = x.at[:, 0].set(x[:, 0] + 10.0)  # perturb the distant past
        y2, _ = ssd_chunked(x2, dt, A_strong, B_, C_, chunk_size=4)
        assert float(jnp.max(jnp.abs(y1[:, -1] - y2[:, -1]))) < 1e-3
