"""Fault-tolerance behaviors: restart determinism, preemption, straggler
flagging, NaN guard, serve loop."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

import repro.configs as C
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticDataset, shard_batch
from repro.models import Model, init_tree
from repro.models.spec import is_spec
from repro.runtime.loop import PreemptionGuard, StragglerMonitor, TrainLoop
from repro.runtime.serve import ServeLoop
from repro.runtime.steps import (
    init_train_state,
    make_serve_steps,
    make_train_step,
)


def make_loop(tmp_path, arch="granite-8b", **loop_kw):
    spec = C.smoke(arch)
    model = Model(spec.model)
    ex = spec.exec.replace(num_microbatches=1, warmup_steps=2, total_steps=50,
                           learning_rate=3e-3)
    state = init_train_state(model, ex, jax.random.key(0))
    step = jax.jit(make_train_step(model, ex))
    ds = SyntheticDataset(spec.model, global_batch=4, seq_len=16)
    return TrainLoop(
        train_step=step,
        batch_at=ds.batch_at,
        place_batch=shard_batch,
        state=state,
        checkpoints=CheckpointManager(str(tmp_path), keep_n=3),
        checkpoint_every=5,
        log_every=100,
        log_fn=lambda s: None,
        **loop_kw,
    )


class TestRestartDeterminism:
    def test_restart_reproduces_uninterrupted_run(self, tmp_path):
        """10 straight steps == 5 steps + restart + 5 steps (same data,
        same state) — the checkpoint/restart contract."""
        loop_a = make_loop(tmp_path / "a")
        res_a = loop_a.run(10)
        loss_a = float(jax.device_get(
            loop_a.train_step(loop_a.state, shard_batch(loop_a.batch_at(10)))[1]["loss"]
        ))

        loop_b1 = make_loop(tmp_path / "b")
        loop_b1.run(5)
        loop_b2 = make_loop(tmp_path / "b")
        start = loop_b2.maybe_restore()
        assert start == 5
        loop_b2.run(5)
        loss_b = float(jax.device_get(
            loop_b2.train_step(loop_b2.state, shard_batch(loop_b2.batch_at(10)))[1]["loss"]
        ))
        assert loss_a == pytest.approx(loss_b, rel=1e-5)

    def test_data_pipeline_replays_identically(self):
        ds = SyntheticDataset(C.smoke("granite-8b").model, 4, 16, seed=9)
        a = ds.batch_at(123)
        b = ds.batch_at(123)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


class TestPreemption:
    def test_preemption_checkpoints_and_exits(self, tmp_path):
        guard = PreemptionGuard(install=False)
        loop = make_loop(tmp_path, guard=guard)
        guard.trigger()
        res = loop.run(50)
        assert res["exit"] == "preempted"
        assert res["final_step"] == 1  # one in-flight step completes
        assert loop.checkpoints.latest_step() == 1


class TestStragglerMonitor:
    def test_flags_slow_steps(self):
        mon = StragglerMonitor(window=20, threshold=1.5)
        for i in range(10):
            mon.observe(i, 0.1)
        assert mon.observe(10, 0.5) is True
        assert 10 in mon.flagged
        assert mon.observe(11, 0.11) is False

    def test_no_flag_before_warmup(self):
        mon = StragglerMonitor()
        assert mon.observe(0, 100.0) is False  # not enough history


class TestNaNGuard:
    def test_nonfinite_loss_aborts_with_checkpoint(self, tmp_path):
        loop = make_loop(tmp_path)

        def poisoned_step(state, batch):
            state2, metrics = loop.train_step(state, batch)
            metrics = dict(metrics)
            metrics["loss"] = jnp.asarray(float("nan"))
            return state2, metrics

        loop2 = make_loop(tmp_path)
        loop2.train_step = poisoned_step
        with pytest.raises(FloatingPointError):
            loop2.run(3)
        assert loop2.checkpoints.latest_step() is not None


class TestServeLoop:
    def test_batched_greedy_generation(self):
        spec = C.smoke("granite-8b")
        model = Model(spec.model)
        params = init_tree(jax.random.key(0), model.param_specs())
        prefill, decode = make_serve_steps(model)
        MAX = 32

        def init_cache():
            return jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                model.cache_specs(2, MAX), is_leaf=is_spec,
            )

        loop = ServeLoop(
            prefill_step=jax.jit(prefill),
            decode_step=jax.jit(decode),
            params=params,
            init_cache=init_cache,
            eos_id=-1,  # never fires → full length
        )
        toks = jax.random.randint(jax.random.key(1), (2, 8), 0,
                                  spec.model.vocab_size)
        out = loop.generate({"tokens": toks}, max_new_tokens=6,
                            echo_metrics=True)
        assert out["tokens"].shape == (2, 6)
        assert out["metrics"]["decoded"] == 6
        # greedy decode must match the model's own step-by-step argmax
        full, _ = model.forward(
            params, {"tokens": jnp.concatenate(
                [toks, jnp.asarray(out["tokens"][:, :-1])], axis=1)}
        )
        expect_last = np.argmax(np.asarray(full[:, -1]), -1)
        np.testing.assert_array_equal(out["tokens"][:, -1], expect_last)
