"""Golden-trace differential tests (`pytest -m golden`): every engine
variant must reproduce the committed fixtures bit-for-bit.

This is the acceptance harness of the sharded fleet engine: the three
pinned scenarios (n = 69 exhaustion, n = 512 budgeted two-phase, and a
streaming warm-start session — `tests/golden/scenarios.py`) are replayed
through the unsharded reference AND across shard counts 2/4, on all three
packed-geometry layouts — "feature", the retained d²-"gather", and the
"fused" streaming-kernel lane (`repro.kernels.ei_argmax`) — and compared
to `tests/golden/*.json` with the shared `assert_outcomes_match` helper.  The sequential per-job engine is
pinned against the same fixtures, which closes the loop:

    sequential == golden == session(layout × shard count)

Fixtures regenerate via `PYTHONPATH=src python -m tests.golden.regen`
(which re-runs the sequential cross-check before writing); drift in a
regenerated fixture means the reference numerics changed and must be an
explicit, reviewed decision.

These tests run in the default tier-1 lane and are additionally selectable
alone with `-m golden`.  Shard lanes need the multi-device CPU topology
`conftest.py` forces (guarded with a skip for exotic invocations).
"""

import numpy as np
import pytest

import jax

from golden import assert_outcomes_match, assert_traces_match, load
from golden.scenarios import (
    SCENARIOS, run_elastic_fleet_disturbed, synth_space_table,
)
from repro.core.bayesopt import BOSettings, cherrypick_search, ruya_search

pytestmark = pytest.mark.golden

# The fault-reporting fields honestly differ under injected faults (a
# retried profile returns identical results but more attempts); the
# bit-identity claim is about the search trace.
FAULT_FIELDS = ("profile_attempts", "retry_backoff_s")

SHARD_COUNTS = (None, 2, 4)  # None = the single-device reference path


def _need_devices(shard):
    if shard is not None and jax.device_count() < shard:
        pytest.skip(
            f"needs {shard} devices; XLA_FLAGS force-count not in effect"
        )


@pytest.mark.parametrize("shard", SHARD_COUNTS)
@pytest.mark.parametrize("layout", ("feature", "gather", "fused"))
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_scenario_matches_golden(scenario, layout, shard):
    _need_devices(shard)
    outcomes = SCENARIOS[scenario](layout=layout, shard=shard)
    assert_outcomes_match(scenario, outcomes)


class TestSequentialReference:
    """The per-job sequential engine reproduces the golden fixtures (a
    2-job prefix keeps the Python-loop engine's cost down; the full-width
    fleet identity rides the session lanes above)."""

    def test_n69_exhaustion_sequential(self):
        space, table = synth_space_table(69)
        traces = [
            cherrypick_search(
                space, lambda i: float(table[i]), np.random.default_rng(s),
                to_exhaustion=True,
            )
            for s in range(2)
        ]
        assert_traces_match("n69-exhaustion", traces, jobs=[0, 1])

    def test_n69_exhaustion_sequential_gather_layout(self):
        space, table = synth_space_table(69)
        trace = cherrypick_search(
            space, lambda i: float(table[i]), np.random.default_rng(0),
            to_exhaustion=True, layout="gather",
        )
        assert_traces_match("n69-exhaustion", [trace], jobs=[0])

    def test_n512_budgeted_sequential(self):
        space, table = synth_space_table(512)
        st = BOSettings(max_iters=10)
        prio = list(range(0, 50))
        rest = list(range(50, 512))
        traces = [
            ruya_search(
                space, lambda i: float(table[i]), np.random.default_rng(s),
                prio, rest, settings=st, to_exhaustion=True,
            )
            for s in range(2)
        ]
        assert_traces_match("n512-budgeted", traces, jobs=[0, 1])


@pytest.mark.chaos
class TestDisturbedFleet:
    """The adversarial replay of ``elastic-fleet``: survivors of a fleet
    hit by transient profiling faults, a mid-flight cancellation, and live
    device churn must reproduce the undisturbed fixture bit-for-bit."""

    def test_shard_loss_survivors_bit_identical(self):
        _need_devices(2)
        survivors, victim = run_elastic_fleet_disturbed(
            shard=2, reshard_to=None,
        )
        assert_outcomes_match("elastic-fleet", survivors, ignore=FAULT_FIELDS)
        assert victim.status == "cancelled"
        assert victim.records, "victim should have partial trials"

    def test_device_join_survivors_bit_identical(self):
        _need_devices(2)
        survivors, victim = run_elastic_fleet_disturbed(
            shard=None, reshard_to=2,
        )
        assert_outcomes_match("elastic-fleet", survivors, ignore=FAULT_FIELDS)
        assert victim.status == "cancelled"

    def test_fault_reporting_surfaces(self):
        _need_devices(2)
        survivors, _ = run_elastic_fleet_disturbed()
        # e0 and e3 were wrapped with 2 scripted transient failures each:
        # 3 attempts, positive charged backoff, identical profile (the
        # trace identity above is the proof), clean jobs untouched.
        for j in (0, 3):
            assert survivors[j].profile_attempts == 3
            assert survivors[j].retry_backoff_s > 0.0
        for j in (1, 2, 4, 5, 6, 7):
            assert survivors[j].profile_attempts == 1
            assert survivors[j].retry_backoff_s == 0.0
        assert all(s.status == "converged" for s in survivors)


class TestFixtureIntegrity:
    def test_fixtures_declare_their_regen_path(self):
        for name in SCENARIOS:
            d = load(name)
            assert d["scenario"] == name
            assert "tests.golden.regen" in d["regen"]
            assert d["outcomes"], f"{name}: empty fixture"

    def test_warm_session_fixture_is_really_warm(self):
        """The streaming scenario must pin actual warm-start behavior:
        seeded jobs exist, their seeds carry donor costs, and the cold
        CherryPick neighbors sharing their chunks are unseeded."""
        outs = load("warm-session")["outcomes"]
        warm = [o for o in outs if o["seeded"]]
        cold = [o for o in outs if not o["seeded"]]
        assert len(warm) == 2 and len(cold) == 5
        for o in warm:
            assert all(s["source"] == "warm" for s in o["seeded"])
            assert len(o["records"]) == 0  # fully amortized on this class
