"""GPipe pipeline over the pod axis: forward parity with the sequential
stack and gradient flow through the ppermute schedule (subprocess, 8 dev)."""

import pytest



class TestPipeline:
    def test_forward_matches_sequential_and_grads_flow(self, devices_runner):
        out = devices_runner(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.parallel.pipeline import pipeline_apply

            mesh = jax.make_mesh((2, 4), ("pod", "data"))
            L, M, B, D = 4, 3, 2, 8  # 4 layers → 2 stages of 2
            key = jax.random.key(0)
            w = jax.random.normal(key, (L, D, D)) * 0.3
            xs = jax.random.normal(jax.random.key(1), (M, B, D))

            def stage_fn(w_local, h):
                def body(h, wi):
                    return jnp.tanh(h @ wi), None
                h, _ = jax.lax.scan(body, h, w_local)
                return h

            # sequential reference: all layers in order
            def reference(w, xs):
                def full(h):
                    def body(h, wi):
                        return jnp.tanh(h @ wi), None
                    h, _ = jax.lax.scan(body, h, w)
                    return h
                return jax.vmap(full)(xs)

            out_pipe = pipeline_apply(stage_fn, w, xs, mesh=mesh)
            out_ref = reference(w, xs)
            err = float(jnp.max(jnp.abs(out_pipe - out_ref)))
            assert err < 1e-5, err

            # gradients through the pipeline match the sequential grads
            def loss_pipe(w):
                return jnp.sum(pipeline_apply(stage_fn, w, xs, mesh=mesh) ** 2)

            def loss_ref(w):
                return jnp.sum(reference(w, xs) ** 2)

            gp = jax.grad(loss_pipe)(w)
            gr = jax.grad(loss_ref)(w)
            gerr = float(jnp.max(jnp.abs(gp - gr)))
            assert gerr < 1e-4, gerr
            print("PIPELINE OK", err, gerr)
            """
        )
        assert "PIPELINE OK" in out

    def test_single_stage_degenerates_to_plain_scan(self, devices_runner):
        out = devices_runner(
            """
            import jax, jax.numpy as jnp
            from repro.parallel.pipeline import pipeline_apply
            mesh = jax.make_mesh((1, 8), ("pod", "data"))
            w = jax.random.normal(jax.random.key(0), (2, 4, 4)) * 0.3
            xs = jax.random.normal(jax.random.key(1), (2, 3, 4))

            def stage_fn(wl, h):
                def body(h, wi):
                    return jnp.tanh(h @ wi), None
                return jax.lax.scan(body, h, wl)[0]

            out = pipeline_apply(stage_fn, w, xs, mesh=mesh)
            ref = jax.vmap(lambda x: stage_fn(w, x))(xs)
            assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
            print("SINGLE STAGE OK")
            """
        )
        assert "SINGLE STAGE OK" in out
