"""`TuningSession` tests: static-drain bit-identity with the pre-redesign
engines, streaming lifecycle (submit-after-step admission, heterogeneous
grouping), cross-job warm-start seeding/determinism, and the
`TrialRecord`/`SearchOutcome` round-trip property lane.

The identity tests pin the acceptance contract of the session redesign:
draining a statically submitted fleet must reproduce the golden-trace
fixtures (`tests/golden/` — themselves cross-checked against the
sequential engine at regen time, and re-pinned against it by
`tests/test_golden_traces.py`), for both packed geometry layouts, on
n = 69 (exhaustion, full packed buffer) and n = 512 (budgeted B ≪ n) —
and the legacy shims (`run_ruya`, `run_cherrypick`, `tune_fleet`,
`batched_search`) must keep returning the same bits now that they route
through the session.
"""

import json

import numpy as np
import pytest

from golden import assert_outcomes_match
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings as hyp_settings, st

from repro.core.bayesopt import (
    BOSettings,
    cherrypick_search,
    ruya_search,
)
from repro.core.memory_model import fit_memory_model
from repro.core.profiler import ProfileResult
from repro.core.search_space import (
    Configuration,
    SearchSpace,
    split_search_space,
)
from repro.core.tuner import run_cherrypick, run_ruya
from repro.fleet import FleetJob, TuningSession, tune_fleet
from repro.fleet.session import SearchOutcome, TrialRecord

GiB = 1024.0**3
N = 20


def quad_space(n=N):
    return SearchSpace(
        [
            Configuration(name=f"c{i}", features=(float(i),),
                          total_memory=float(i) * GiB)
            for i in range(n)
        ]
    )


def quad_table(n=N, optimum=9):
    return np.array([1.0 + 0.05 * (i - optimum) ** 2 for i in range(n)])


def synth_space_table(n, d=5, seed=0):
    rng = np.random.default_rng(seed + n)
    feats = rng.normal(size=(n, d))
    space = SearchSpace(
        [
            Configuration(
                name=f"s{i}",
                features=tuple(float(v) for v in feats[i]),
                total_memory=float(i) * GiB,
            )
            for i in range(n)
        ]
    )
    w = rng.normal(size=d)
    z = feats @ w
    z = (z - z.mean()) / max(float(z.std()), 1e-9)
    return space, 1.0 + (z - 0.7) ** 2 + 0.05 * rng.random(n)


def flat_profile():
    """A FLAT ProfileResult whose §III-D split is deterministic."""
    model = fit_memory_model([1e9, 2e9, 3e9], [5e9, 5e9, 5e9])
    return ProfileResult(
        sizes=(1e9, 2e9, 3e9), readings=(5e9,) * 3, total_time_s=1.0,
        calibration_runs=1, model=model,
    )


def linear_profile(slope=3.0):
    sizes = (1e9, 2e9, 3e9)
    readings = tuple(slope * s + 0.5 * GiB for s in sizes)
    return ProfileResult(
        sizes=sizes, readings=readings, total_time_s=1.0,
        calibration_runs=1, model=fit_memory_model(sizes, readings),
    )


def flat_job(name="job", n=N):
    return FleetJob(
        name=name, space=quad_space(n), cost_table=quad_table(n),
        full_input_size=10e9, profile_result=flat_profile(),
    )


def assert_trace_equal(trace, ref):
    assert trace.tried == ref.tried
    assert trace.costs == ref.costs
    assert trace.stop_iteration == ref.stop_iteration
    assert trace.phase_boundary == ref.phase_boundary


class TestStaticDrainIdentity:
    """drain() of a statically submitted fleet == the golden fixtures (the
    pre-redesign engines' pinned bits — `tests/golden/`)."""

    def test_drain_matches_golden_n69_exhaustion(self):
        """n = 69 to exhaustion: packed buffer completely full (B = n).
        A 2-job prefix of the pinned fleet, submitted through handles —
        lockstep extent 2 here vs 4 in the fixture run, so this also
        re-pins the batch-extent invariance the chunking rests on.  (The
        gather-layout and sharded variants ride `tests/test_golden_traces`;
        `batched_search` is now a session shim.)"""
        space, table = synth_space_table(69)
        session = TuningSession(mode="cherrypick", to_exhaustion=True)
        handles = [
            session.submit(
                FleetJob(name=f"j{s}", space=space, cost_table=table),
                seed=s,
            )
            for s in range(2)
        ]
        session.drain()
        outs = [h.outcome() for h in handles]
        for out in outs:
            assert len(out.records) == 69
            assert not out.seeded
        assert_outcomes_match("n69-exhaustion", outs, jobs=[0, 1])

    def test_drain_matches_golden_n512_budgeted_two_phase(self):
        space, table = synth_space_table(512)
        st_ = BOSettings(max_iters=10)
        prio = list(range(0, 50))
        rest = list(range(50, 512))
        for layout in ("feature", "gather"):
            session = TuningSession(settings=st_, to_exhaustion=True,
                                    layout=layout)
            handles = [
                session.submit(
                    FleetJob(name=f"j{s}", space=space, cost_table=table),
                    seed=s, priority=prio, remaining=rest,
                )
                for s in range(3)
            ]
            session.drain()
            outs = [h.outcome() for h in handles]
            for out in outs:
                assert len(out.records) == 10
            assert_outcomes_match("n512-budgeted", outs, jobs=[0, 1, 2])

    def test_shims_pin_ruya_pipeline_bits(self):
        """run_ruya(cost_table) — now session-backed, with the on-device
        split — must reproduce the pre-redesign host-split sequential
        pipeline exactly, profile reuse and report fields included."""
        job = flat_job()
        for seed in range(3):
            rep = run_ruya(
                space=job.space, cost_table=job.cost_table,
                rng=np.random.default_rng(seed),
                full_input_size=job.full_input_size,
                profile_result=job.profile_result,
                to_exhaustion=True,
            )
            prio, rest = split_search_space(
                job.space, job.profile_result.model, job.full_input_size,
            )
            ref = ruya_search(
                job.space,
                lambda i: float(job.cost_table[i]),
                np.random.default_rng(seed), prio, rest, to_exhaustion=True,
            )
            assert rep.priority == tuple(prio)
            assert rep.remaining == tuple(rest)
            assert rep.profile is job.profile_result
            assert_trace_equal(rep.trace, ref)

    def test_shims_pin_cherrypick_bits(self):
        space, table = quad_space(), quad_table()
        for seed in range(3):
            tr = run_cherrypick(
                space=space, cost_table=table,
                rng=np.random.default_rng(seed), to_exhaustion=True,
            )
            ref = cherrypick_search(
                space, lambda i: float(table[i]),
                np.random.default_rng(seed), to_exhaustion=True,
            )
            assert_trace_equal(tr, ref)

    def test_tune_fleet_cache_none_profiles_per_job(self):
        """cache=None must mean per-job profiling in BOTH engines — two
        distinct jobs whose cheap probes share a MemorySignature but whose
        full profiles differ must NOT silently share a profile (that is the
        opt-in `cache=ProfileCache()` behavior)."""

        def linear_run(slope):
            def run(sample_bytes):
                return 1.0, slope * sample_bytes + 0.5 * GiB

            return run

        # Memories up to 38 GiB so the two extrapolated requirements
        # (~33.6 vs ~35.8 GiB with leeway) cut the catalog differently.
        wide = SearchSpace(
            [
                Configuration(name=f"c{i}", features=(float(i),),
                              total_memory=2.0 * i * GiB)
                for i in range(20)
            ]
        )

        def job_for(slope, name):
            return FleetJob(
                name=name, space=wide, cost_table=quad_table(),
                full_input_size=10.0 * GiB, profile_run=linear_run(slope),
            )

        jobs = [job_for(3.0, "a"), job_for(3.2, "b")]  # same probe bucket
        bat = tune_fleet(jobs, [np.random.default_rng(s) for s in range(2)],
                         to_exhaustion=True)
        seq = tune_fleet(
            [job_for(3.0, "a"), job_for(3.2, "b")],
            [np.random.default_rng(s) for s in range(2)],
            to_exhaustion=True, engine="sequential",
        )
        assert bat[0].priority != bat[1].priority  # profiles really differ
        for b, s in zip(bat, seq):
            assert b.priority == s.priority
            assert_trace_equal(b.trace, s.trace)

        # Explicit cache: sharing is opted in, and both engines share alike.
        from repro.fleet import ProfileCache

        cache_b, cache_s = ProfileCache(), ProfileCache()
        bat_c = tune_fleet(
            [job_for(3.0, "a"), job_for(3.2, "b")],
            [np.random.default_rng(s) for s in range(2)],
            to_exhaustion=True, cache=cache_b,
        )
        seq_c = tune_fleet(
            [job_for(3.0, "a"), job_for(3.2, "b")],
            [np.random.default_rng(s) for s in range(2)],
            to_exhaustion=True, cache=cache_s, engine="sequential",
        )
        assert cache_b.hits == 1 and cache_s.hits == 1
        assert bat_c[0].priority == bat_c[1].priority
        for b, s in zip(bat_c, seq_c):
            assert b.priority == s.priority
            assert_trace_equal(b.trace, s.trace)

    def test_session_releases_per_job_state_at_retirement(self):
        """Finished jobs must not pin cost tables / encodings / geometry:
        the refcounted per-space and per-job cache entries are evicted when
        their last active submission retires."""
        session = TuningSession(mode="cherrypick", to_exhaustion=True,
                                settings=BOSettings(max_iters=4),
                                layout="gather")
        for s in range(2):
            session.submit(
                FleetJob(name=f"j{s}", space=quad_space(),
                         cost_table=quad_table()),
                seed=s,
            )
        session.drain()
        assert len(session.results()) == 2
        assert not session._spaces and not session._jobs

    def test_tune_fleet_engines_agree_in_ruya_mode(self):
        """tune_fleet batched (session, device split) vs sequential (host
        split): identical reports on flat AND linear profiled jobs."""
        jobs = [
            flat_job("flat"),
            FleetJob(
                name="linear", space=quad_space(), cost_table=quad_table(),
                full_input_size=4.0 * GiB, profile_result=linear_profile(),
            ),
        ] * 2
        rngs = lambda: [np.random.default_rng(s) for s in range(len(jobs))]
        bat = tune_fleet(jobs, rngs(), to_exhaustion=True)
        seq = tune_fleet(jobs, rngs(), to_exhaustion=True,
                         engine="sequential")
        for b, s in zip(bat, seq):
            assert b.priority == s.priority
            assert b.remaining == s.remaining
            assert_trace_equal(b.trace, s.trace)


class TestSessionLifecycle:
    def test_empty_session(self):
        session = TuningSession()
        assert session.step() == 0
        assert session.drain() == []
        assert len(session) == 0

    def test_submit_requires_exactly_one_rng_source(self):
        session = TuningSession()
        job = flat_job()
        with pytest.raises(ValueError):
            session.submit(job)
        with pytest.raises(ValueError):
            session.submit(job, np.random.default_rng(0), seed=1)

    def test_handle_status_transitions(self):
        session = TuningSession(mode="cherrypick", to_exhaustion=True,
                                settings=BOSettings(max_iters=4))
        h = session.submit(flat_job(), seed=0)
        assert h.status == "pending" and not h.done
        with pytest.raises(RuntimeError):
            h.outcome()
        session.step()
        assert h.status == "running"
        session.drain()
        assert h.status == "done" and h.done
        assert len(h.outcome().records) == 4

    def test_submit_after_step_admission_is_bit_exact(self):
        """A job admitted mid-flight joins its own lockstep chunk and must
        produce the identical trace a statically submitted job would."""
        space, table = quad_space(), quad_table()
        ref = cherrypick_search(
            space, lambda i: float(table[i]), np.random.default_rng(7),
            to_exhaustion=True,
        )
        session = TuningSession(mode="cherrypick", to_exhaustion=True)
        session.submit(FleetJob(name="a", space=space, cost_table=table),
                       seed=0)
        for _ in range(3):
            session.step()
        late = session.submit(
            FleetJob(name="late", space=space, cost_table=table), seed=7,
        )
        session.drain()
        assert_trace_equal(late.outcome().trace(), ref)

    def test_heterogeneous_shapes_group_exactly(self):
        """Jobs with different space shapes in ONE session must each
        factorize at the sequential engine's extents — including the
        singleton-chunk dummy-pad path every one-job group takes.
        (Heterogeneous trial budgets on one shape are covered by
        `tests/test_fleet.py`, which routes through the same session.)"""
        sp_a, tb_a = synth_space_table(40, d=3)
        sp_b, tb_b = synth_space_table(24, d=6)
        st_ = BOSettings(max_iters=8)
        refs = [
            cherrypick_search(sp_a, lambda i: float(tb_a[i]),
                              np.random.default_rng(0), settings=st_,
                              to_exhaustion=True),
            cherrypick_search(sp_b, lambda i: float(tb_b[i]),
                              np.random.default_rng(1), settings=st_,
                              to_exhaustion=True),
        ]
        session = TuningSession(settings=st_, mode="cherrypick",
                                to_exhaustion=True)
        handles = [
            session.submit(FleetJob(name="a", space=sp_a, cost_table=tb_a),
                           seed=0),
            session.submit(FleetJob(name="b", space=sp_b, cost_table=tb_b),
                           seed=1),
        ]
        session.drain()
        for h, ref in zip(handles, refs):
            assert_trace_equal(h.outcome().trace(), ref)

    def test_results_in_submission_order(self):
        session = TuningSession(mode="cherrypick", to_exhaustion=True,
                                settings=BOSettings(max_iters=4))
        names = ["x", "y", "z"]
        for i, name in enumerate(names):
            session.submit(
                FleetJob(name=name, space=quad_space(),
                         cost_table=quad_table()),
                seed=i,
            )
        outs = session.drain()
        assert [o.name for o in outs] == names

    def test_step_counts_down_to_zero(self):
        session = TuningSession(mode="cherrypick", to_exhaustion=True,
                                settings=BOSettings(max_iters=3))
        session.submit(flat_job(), seed=0)
        remaining = session.step()
        assert remaining == 1  # budget 3 → needs 4 steps
        while remaining:
            remaining = session.step()
        assert session.step() == 0
        assert len(session.results()) == 1


class TestWarmStart:
    def mk_session(self, **kw):
        kw.setdefault("warm_start", True)
        kw.setdefault("to_exhaustion", False)
        return TuningSession(**kw)

    def test_same_class_seeds_and_converges_faster(self):
        session = self.mk_session()
        job = flat_job()
        cold = session.submit(job, seed=0)
        session.drain()
        warm = session.submit(job, seed=1)
        session.drain()
        c, w = cold.outcome(), warm.outcome()
        assert not c.seeded
        assert w.seeded, "same-signature job must be warm-started"
        assert all(r.source == "warm" for r in w.seeded)
        # Seeds are the class history: the cold job's trials, in completion
        # order, deduplicated by config index.
        assert [s.index for s in w.seeded] == [r.index for r in c.records]
        assert len(w.records) < len(c.records)
        assert session.warm_hits == 1
        assert session.warm_trials == len(w.seeded)

    def test_capacity_aware_seeding_respects_reserve(self):
        """History longer than B − reserve is truncated: seeded slots plus
        the reserve never exceed the packed capacity B."""
        n = 24
        job = FleetJob(
            name="big", space=quad_space(n), cost_table=quad_table(n),
            full_input_size=10e9, profile_result=flat_profile(),
        )
        st_ = BOSettings(max_iters=10)
        session = self.mk_session(settings=st_, to_exhaustion=True)
        session.submit(job, seed=0)
        session.drain()  # 10 completed trials in the class history
        warm = session.submit(job, seed=1)
        session.drain()
        w = warm.outcome()
        budget = 10
        reserve = max(st_.n_init, 1)
        assert len(w.seeded) == budget - reserve
        assert len(w.seeded) + len(w.records) <= budget

    def test_warm_start_is_deterministic_and_consumes_no_rng(self):
        """A warm-started search is a function of (class history, seed);
        with seeding active no RNG is drawn, so even different seeds give
        the identical trace when the history matches."""
        def run_pair(seed2):
            session = self.mk_session()
            session.submit(flat_job(), seed=0)
            session.drain()
            h = session.submit(flat_job(), seed=seed2)
            session.drain()
            return h.outcome()

        a, b = run_pair(1), run_pair(999)
        assert a.as_dict() == b.as_dict()

    def test_warm_neighbor_does_not_perturb_cold_jobs(self):
        """A seeded job sharing a lockstep chunk with cold jobs must leave
        the cold traces bit-identical to solo runs (padding exactness)."""
        job = flat_job()
        session = self.mk_session()
        session.submit(job, seed=0)
        session.drain()
        # Same chunk: one warm (same class) + one cold (cherrypick — no
        # signature, so never seeded); both share (shape, B).
        warm = session.submit(job, seed=1)
        cold = session.submit(job, seed=2, mode="cherrypick")
        session.drain()
        assert warm.outcome().seeded and not cold.outcome().seeded
        ref = cherrypick_search(
            job.space, lambda i: float(job.cost_table[i]),
            np.random.default_rng(2), to_exhaustion=False,
        )
        assert_trace_equal(cold.outcome().trace(), ref)

    def test_warm_start_disabled_session_never_seeds(self):
        session = self.mk_session(warm_start=False)
        session.submit(flat_job(), seed=0)
        session.drain()
        h = session.submit(flat_job(), seed=1)
        session.drain()
        assert not h.outcome().seeded

    def test_per_submit_warm_override(self):
        session = self.mk_session()
        session.submit(flat_job(), seed=0)
        session.drain()
        h = session.submit(flat_job(), seed=1, warm_start=False)
        session.drain()
        assert not h.outcome().seeded

    def test_different_class_is_not_seeded(self):
        session = self.mk_session()
        session.submit(flat_job(), seed=0)
        session.drain()
        other = FleetJob(
            name="linear", space=quad_space(), cost_table=quad_table(),
            full_input_size=4.0 * GiB, profile_result=linear_profile(),
        )
        h = session.submit(other, seed=1)
        session.drain()
        assert h.outcome().signature is not None
        assert not h.outcome().seeded


def _record_roundtrip(index, cost, slot, source):
    rec = TrialRecord(index=index, cost=cost, slot=slot, source=source)
    back = TrialRecord.from_dict(json.loads(json.dumps(rec.as_dict())))
    assert back == rec


class TestRecordRoundTrip:
    """`TrialRecord`/`SearchOutcome` JSON round-tripping — hypothesis lane
    when available, always-on seeded lane otherwise (same property)."""

    SOURCES = ("init", "search", "warm")

    if HAVE_HYPOTHESIS:

        @given(
            index=st.integers(min_value=0, max_value=10**6),
            cost=st.floats(allow_nan=False, allow_infinity=False,
                           width=32),
            slot=st.integers(min_value=0, max_value=4096),
            source=st.sampled_from(("init", "search", "warm")),
        )
        @hyp_settings(max_examples=100, deadline=None)
        def test_trial_record_roundtrip_hypothesis(self, index, cost, slot,
                                                   source):
            _record_roundtrip(index, float(cost), slot, source)

    def test_trial_record_roundtrip_seeded(self):
        rng = np.random.default_rng(1234)
        for _ in range(200):
            _record_roundtrip(
                int(rng.integers(0, 10**6)),
                float(np.float32(rng.normal() * 10.0 ** rng.integers(-3, 6))),
                int(rng.integers(0, 4096)),
                self.SOURCES[int(rng.integers(0, 3))],
            )

    def test_trial_record_rejects_unknown_source(self):
        with pytest.raises(ValueError):
            TrialRecord.from_dict(
                {"index": 0, "cost": 1.0, "slot": 0, "source": "psychic"}
            )

    def test_outcome_roundtrip_seeded(self):
        rng = np.random.default_rng(99)
        for _ in range(25):
            k, w = int(rng.integers(0, 8)), int(rng.integers(0, 5))
            recs = [
                TrialRecord(index=int(rng.integers(0, 50)),
                            cost=float(rng.random()), slot=w + i,
                            source="init" if i < 2 else "search")
                for i in range(k)
            ]
            seeds = [
                TrialRecord(index=int(rng.integers(0, 50)),
                            cost=float(rng.random()), slot=i, source="warm")
                for i in range(w)
            ]
            out = SearchOutcome(
                name="job",
                records=recs,
                seeded=seeds,
                stop_iteration=(None if rng.random() < 0.5
                                else int(rng.integers(0, w + k + 1))),
                phase_boundary=(None if rng.random() < 0.5
                                else int(rng.integers(0, w + k + 1))),
                priority=tuple(int(i) for i in rng.integers(0, 50, size=5)),
                remaining=tuple(int(i) for i in rng.integers(0, 50, size=5)),
            )
            back = SearchOutcome.from_dict(
                json.loads(json.dumps(out.as_dict()))
            )
            assert back.as_dict() == out.as_dict()

    def test_outcome_real_search_roundtrip_and_views(self):
        session = TuningSession(mode="cherrypick", to_exhaustion=True,
                                settings=BOSettings(max_iters=6))
        h = session.submit(flat_job(), seed=3)
        session.drain()
        out = h.outcome()
        back = SearchOutcome.from_dict(json.loads(json.dumps(out.as_dict())))
        assert back.as_dict() == out.as_dict()
        # Views agree with the record list.
        tr = out.trace()
        assert tr.tried == [r.index for r in out.records]
        assert tr.costs == [r.cost for r in out.records]
        assert out.best_cost == min(tr.costs)
        assert out.best_index == tr.best_index
        rep = out.report()
        assert rep.trace.tried == tr.tried
        assert rep.priority == out.priority
        # Sources: the first n_init trials are scripted random picks.
        assert [r.source for r in out.records[:3]] == ["init"] * 3
        assert all(r.source == "search" for r in out.records[3:])
