"""Table I: determined job memory requirement (category + GB for linear)."""

from __future__ import annotations

import csv

from benchmarks.common import GiB, JOB_ORDER, artifact_path, get_sim, job_profile

# Paper Table I ground truth for validation.
PAPER = {
    "naivebayes/spark/bigdata": ("linear", 754),
    "naivebayes/spark/huge": ("linear", 395),
    "kmeans/spark/bigdata": ("linear", 503),
    "kmeans/spark/huge": ("linear", 252),
    "pagerank/spark/bigdata": ("linear", 86),
    "pagerank/spark/huge": ("linear", 42),
    "logregr/spark/bigdata": ("unclear", None),
    "logregr/spark/huge": ("unclear", None),
    "linregr/spark/bigdata": ("unclear", None),
    "linregr/spark/huge": ("unclear", None),
    "join/spark/bigdata": ("flat", None),
    "join/spark/huge": ("flat", None),
    "pagerank/hadoop/bigdata": ("flat", None),
    "pagerank/hadoop/huge": ("flat", None),
    "terasort/hadoop/bigdata": ("flat", None),
    "terasort/hadoop/huge": ("flat", None),
}


def run() -> dict:
    rows = []
    matches = 0
    for key in JOB_ORDER:
        sim = get_sim(key)
        prof = job_profile(key)
        cat = prof.model.category.value
        est_gb = (
            prof.model.estimate(sim.job.input_gb * GiB) / GiB
            if cat == "linear" else None
        )
        paper_cat, paper_gb = PAPER[key]
        ok = cat == paper_cat and (
            paper_gb is None or abs(est_gb - paper_gb) / paper_gb < 0.10
        )
        matches += ok
        rows.append({
            "job": key, "category": cat,
            "estimate_gb": round(est_gb, 1) if est_gb else "",
            "paper_category": paper_cat,
            "paper_gb": paper_gb or "",
            "match": ok,
            "r2": round(prof.model.r2, 4),
        })

    path = artifact_path("paper", "table1.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)

    print(f"\n== Table I: memory categorization ({matches}/16 match paper) ==")
    for r in rows:
        mark = "✓" if r["match"] else "✗"
        print(f"  {mark} {r['job']:28s} {r['category']:8s} "
              f"{r['estimate_gb'] or '-':>7} (paper: {r['paper_category']}"
              f"{' ' + str(r['paper_gb']) + ' GB' if r['paper_gb'] else ''})")
    return {"rows": rows, "matches": matches, "csv": path}


if __name__ == "__main__":
    run()
