"""Fig. 1: total cluster RAM vs normalized cost for K-Means on Spark —
the memory cliff that motivates the whole paper."""

from __future__ import annotations

import csv

import numpy as np

from benchmarks.common import artifact_path, fleet_job, get_sim


def run(job: str = "kmeans/spark/huge") -> dict:
    # Space and cost table come from the shared fleet-job pool (the same
    # FleetJob every replay suite uses); the memoized simulator only
    # supplies the job spec's memory requirement.
    fj = fleet_job(job)
    sim = get_sim(job)
    rows = []
    for cfg, cost in zip(fj.space.configs, fj.cost_table):
        rows.append({
            "config": cfg.name,
            "family": cfg.meta.node.family,
            "total_ram_gb": round(cfg.meta.total_memory_gb, 1),
            "normalized_cost": round(float(cost), 4),
        })
    rows.sort(key=lambda r: r["total_ram_gb"])

    path = artifact_path("paper", "fig1_memory_cliff.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)

    req = sim.job.mem_requirement_gb
    mems = np.array([r["total_ram_gb"] for r in rows])
    costs = np.array([r["normalized_cost"] for r in rows])
    below = costs[(mems < req) & (mems > req * 0.4)]
    above = costs[mems >= req]
    cliff = float(below.min() / above.min()) if len(below) and len(above) else 0
    print(f"\n== Fig. 1: memory cliff ({job}) ==")
    print(f"  requirement {req:.0f} GB; cheapest-below/cheapest-above cost "
          f"ratio = {cliff:.2f}× (cliff exists: {cliff > 1.5})")
    return {"rows": rows, "cliff_ratio": cliff, "csv": path}


if __name__ == "__main__":
    run()
