"""Fig. 4: normalized cost of the best configuration found so far, per
iteration, averaged over all 16 jobs — CherryPick vs Ruya."""

from __future__ import annotations

import csv

import numpy as np

from benchmarks.common import (
    DEFAULT_REPS,
    JOB_ORDER,
    artifact_path,
    best_cost_curve,
    search_traces,
)


def run(reps: int = DEFAULT_REPS, horizon: int = 69) -> dict:
    ruya_curves, cp_curves = [], []
    for key in JOB_ORDER:
        ruya, cp, _ = search_traces(key, reps=reps)
        ruya_curves.append(best_cost_curve(ruya, horizon))
        cp_curves.append(best_cost_curve(cp, horizon))
    ruya_mean = np.mean(ruya_curves, axis=0)
    cp_mean = np.mean(cp_curves, axis=0)

    path = artifact_path("paper", "fig4_convergence.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["iteration", "ruya_best_cost", "cherrypick_best_cost"])
        for i in range(horizon):
            w.writerow([i + 1, round(ruya_mean[i], 4), round(cp_mean[i], 4)])

    # Paper: Ruya reaches optimal ≈ iteration 12, CherryPick ≈ 24.
    def first_below(curve, eps=1.005):
        idx = np.argmax(curve <= eps)
        return int(idx) + 1 if curve[idx] <= eps else horizon

    r_opt, c_opt = first_below(ruya_mean), first_below(cp_mean)
    print("\n== Fig. 4: convergence (mean over 16 jobs) ==")
    for it in (1, 3, 6, 12, 24, 48):
        print(f"  iter {it:3d}: Ruya {ruya_mean[it-1]:.3f} | "
              f"CherryPick {cp_mean[it-1]:.3f}")
    print(f"  mean best cost reaches ≤1.005 at: Ruya {r_opt}, CherryPick {c_opt} "
          f"(paper: ≈12 vs ≈24)")
    return {"ruya": ruya_mean.tolist(), "cherrypick": cp_mean.tolist(),
            "ruya_opt_iter": r_opt, "cp_opt_iter": c_opt, "csv": path}


if __name__ == "__main__":
    run()
