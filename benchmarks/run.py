"""Benchmark driver: one module per paper table/figure + roofline + tuner.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table2 roofline
    PYTHONPATH=src python -m benchmarks.run --only fleet --smoke

`--only fleet` (re)writes the machine-readable perf baseline
`BENCH_fleet.json` at the repo root — including the streaming
`TuningSession` scenario (workload D: 64 recurring jobs in 8 waves,
warm-start amortization; standalone via `python -m benchmarks.fleet_bench
--session`).  `--smoke` runs suites that support it in a seconds-scale
wiring mode (currently: fleet) — the same mode `pytest -m bench_smoke`
exercises.

Env: RUYA_BENCH_REPS (default 50; the paper used 200 repetitions).
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: table1 table2 table3 fig1 fig4 fig5 "
                         "roofline kernels fleet tuner")
    ap.add_argument("--skip-tuner", action="store_true",
                    help="skip the compile-heavy tuner benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale wiring mode for suites that support it")
    args = ap.parse_args()

    from benchmarks import (
        fig1_memory_cliff,
        fig4_convergence,
        fig5_cumulative_cost,
        fleet_bench,
        kernel_bench,
        roofline,
        table1_memory_categorization,
        table2_iterations,
        table3_profiling_time,
    )

    suites = {
        "table1": table1_memory_categorization.run,
        "table2": table2_iterations.run,
        "table3": table3_profiling_time.run,
        "fig1": fig1_memory_cliff.run,
        "fig4": fig4_convergence.run,
        "fig5": fig5_cumulative_cost.run,
        "roofline": roofline.run,
        "kernels": kernel_bench.run,
        "fleet": fleet_bench.run,
    }
    if not args.skip_tuner:
        from benchmarks import tuner_vs_baseline

        suites["tuner"] = tuner_vs_baseline.run

    selected = args.only or list(suites)
    failures = []
    for name in selected:
        if name not in suites:
            print(f"unknown suite {name!r}; have {list(suites)}")
            sys.exit(2)
        t0 = time.time()
        print(f"\n{'='*72}\nBENCH {name}\n{'='*72}")
        try:
            fn = suites[name]
            if args.smoke and "smoke" in inspect.signature(fn).parameters:
                fn(smoke=True)
            else:
                fn()
            print(f"[{name}] done in {time.time()-t0:.0f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED suites: {failures}")
        sys.exit(1)
    print("\nAll benchmark suites completed.")


if __name__ == "__main__":
    main()
