"""Benchmark driver: one module per paper table/figure + roofline + tuner.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table2 roofline
    PYTHONPATH=src python -m benchmarks.run --only fleet --smoke

`--only fleet` (re)writes the machine-readable perf baseline
`BENCH_fleet.json` at the repo root — including the streaming
`TuningSession` scenario (workload D: 64 recurring jobs in 8 waves,
warm-start amortization; standalone via `python -m benchmarks.fleet_bench
--session`) and the job-axis sharding sweep (workload E; `--shards N ...`
is passed through to the fleet bench, default 2 — when the fleet suite is
selected, and only then, this driver forces
--xla_force_host_platform_device_count=max(--shards, 2) before JAX
initializes so the shard lanes have devices to run on).  `--smoke` runs suites that
support it in a seconds-scale wiring mode (currently: fleet) — the same
mode `pytest -m bench_smoke` exercises.

Env: RUYA_BENCH_REPS (default 50; the paper used 200 repetitions).
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: table1 table2 table3 fig1 fig4 fig5 "
                         "roofline kernels fleet tuner")
    ap.add_argument("--skip-tuner", action="store_true",
                    help="skip the compile-heavy tuner benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale wiring mode for suites that support it")
    ap.add_argument("--shards", type=int, nargs="*", default=None,
                    help="shard counts for the fleet bench's job-axis "
                         "sharding sweep (passed through to --only fleet)")
    args = ap.parse_args()

    if args.only is None or "fleet" in args.only:
        # The fleet suite's sharded lanes need a multi-device CPU topology,
        # forced before the jax-importing benchmark modules below can
        # initialize the backend.  Only the fleet suite pays for it: extra
        # forced devices dilute the intra-op thread pool, and the other
        # suites' absolute numbers must stay comparable to their baselines.
        from repro.hostdevices import force_host_device_count

        force_host_device_count(max([2] + list(args.shards or [])))

    from benchmarks import (
        fig1_memory_cliff,
        fig4_convergence,
        fig5_cumulative_cost,
        fleet_bench,
        kernel_bench,
        roofline,
        table1_memory_categorization,
        table2_iterations,
        table3_profiling_time,
    )

    suites = {
        "table1": table1_memory_categorization.run,
        "table2": table2_iterations.run,
        "table3": table3_profiling_time.run,
        "fig1": fig1_memory_cliff.run,
        "fig4": fig4_convergence.run,
        "fig5": fig5_cumulative_cost.run,
        "roofline": roofline.run,
        "kernels": kernel_bench.run,
        "fleet": fleet_bench.run,
    }
    if not args.skip_tuner:
        from benchmarks import tuner_vs_baseline

        suites["tuner"] = tuner_vs_baseline.run

    selected = args.only or list(suites)
    failures = []
    for name in selected:
        if name not in suites:
            print(f"unknown suite {name!r}; have {list(suites)}")
            sys.exit(2)
        t0 = time.time()
        print(f"\n{'='*72}\nBENCH {name}\n{'='*72}")
        try:
            fn = suites[name]
            kwargs = {}
            params = inspect.signature(fn).parameters
            if args.smoke and "smoke" in params:
                kwargs["smoke"] = True
            if args.shards is not None and "shards" in params:
                kwargs["shards"] = tuple(args.shards)
            fn(**kwargs)
            print(f"[{name}] done in {time.time()-t0:.0f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED suites: {failures}")
        sys.exit(1)
    print("\nAll benchmark suites completed.")


if __name__ == "__main__":
    main()
