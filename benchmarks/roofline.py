"""§Roofline: three-term roofline per (arch × shape) from dry-run artifacts.

Reads ``artifacts/dryrun/*__single_pod.json`` (the roofline table is
single-pod per the assignment; multi-pod artifacts prove the pod axis
shards) and reports, per cell:

    compute    = HLO_FLOPs_per_device / 197e12           (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9            (HBM bandwidth)
    collective = collective_bytes_per_device / 50e9      (ICI per link)

FLOPs/bytes are the loop-scaled HLO costs (see launch/hlo_analysis.py —
XLA's own cost_analysis counts scan bodies once).  MODEL_FLOPS uses
6·N·D for training (N_active for MoE) and 2·N_active·tokens for serving;
the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy waste.
"""

from __future__ import annotations

import csv
import glob
import json
import os

from benchmarks.common import artifact_path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

MOVE_HINTS = {
    "compute": "reduce recompute (remat policy) or shard more model axes",
    "memory": "avoid materializing O(T·S) attention (chunked/flash path), "
              "fewer remat passes, bf16 residuals",
    "collective": "cut TP all-reduces (sequence-parallel residuals), fewer "
                  "microbatch re-gathers (FSDP), bigger per-shard tiles",
}


def model_flops(art: dict) -> float:
    cell = art["cell"]
    kind = art["kind"]
    n_active = art["model"]["active_params"]
    if kind == "train":
        return 6.0 * n_active * art["model"]["tokens"]
    if kind == "prefill":
        # tokens field holds global_batch for serve cells; recover tokens
        seq = {"prefill_32k": 32768}.get(cell, 0)
        return 2.0 * n_active * art["model"]["tokens"] * seq
    # decode: one new token per sequence
    return 2.0 * n_active * art["model"]["tokens"]


def load_cells(mesh: str = "single_pod"):
    pattern = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "dryrun", f"*__{mesh}.json")
    cells = []
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            art = json.load(f)
        cells.append(art)
    return cells


def analyze(art: dict) -> dict:
    h = art["hlo_cost"]
    chips = art["chips"]
    compute = h["flops_per_device"] / PEAK_FLOPS
    memory = h["hbm_bytes_per_device"] / HBM_BW
    collective = h["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(art)
    hlo_global = h["flops_per_device"] * chips
    useful = mf / hlo_global if hlo_global > 0 else 0.0
    # roofline fraction: useful model FLOPs per chip-second of the
    # roofline-estimated step vs the chip's peak.
    frac = (mf / chips / max(step_s, 1e-12)) / PEAK_FLOPS
    return {
        "arch": art["arch"],
        "cell": art["cell"],
        "kind": art["kind"],
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "step_s": step_s,
        "model_flops": mf,
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "peak_gib": art["memory"]["peak_bytes_per_device"] / 2**30,
        "fits_16g": art["memory"]["fits_16g"],
        "hint": MOVE_HINTS[dominant],
    }


def run(mesh: str = "single_pod") -> dict:
    cells = load_cells(mesh)
    rows = []
    skipped = 0
    for art in cells:
        if art["status"] == "skipped":
            skipped += 1
            continue
        if art["status"] != "ok":
            print(f"  !! {art.get('arch')}×{art.get('cell')}: {art['status']}")
            continue
        rows.append(analyze(art))

    rows.sort(key=lambda r: (r["arch"], r["cell"]))
    path = artifact_path("roofline", f"roofline_{mesh}.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)

    print(f"\n== §Roofline ({mesh}, {len(rows)} cells, {skipped} skipped) ==")
    print(f"  {'arch':24s}{'cell':13s}{'cmp(s)':>8}{'mem(s)':>8}{'coll(s)':>9}"
          f"{'dom':>6}{'useful':>8}{'roofl%':>8}{'GiB/dev':>9}")
    for r in rows:
        print(f"  {r['arch']:24s}{r['cell']:13s}{r['compute_s']:8.3f}"
              f"{r['memory_s']:8.3f}{r['collective_s']:9.3f}"
              f"{r['dominant'][:4]:>6}{r['useful_flops_ratio']:8.2f}"
              f"{r['roofline_fraction']*100:8.2f}{r['peak_gib']:9.2f}")
    return {"rows": rows, "csv": path}


if __name__ == "__main__":
    run()
