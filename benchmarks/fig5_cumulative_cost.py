"""Fig. 5: cumulative normalized execution cost over recurring executions,
averaged over all jobs — the exploration investment amortizing."""

from __future__ import annotations

import csv

import numpy as np

from benchmarks.common import (
    DEFAULT_REPS,
    JOB_ORDER,
    artifact_path,
    search_traces,
)


def cumulative_curve(traces, horizon: int) -> np.ndarray:
    """Each iteration's cost is the trial's cost while searching; after the
    stop the job keeps running on the best-found configuration."""
    curves = []
    for t in traces:
        costs = list(t.costs)
        stop = t.stop_iteration or len(costs)
        per_iter = []
        best_so_far = np.inf
        for i in range(horizon):
            if i < stop and i < len(costs):
                best_so_far = min(best_so_far, costs[i])
                per_iter.append(costs[i])
            else:
                per_iter.append(best_so_far)
        curves.append(np.cumsum(per_iter))
    return np.mean(curves, axis=0)


def run(reps: int = DEFAULT_REPS, horizon: int = 100) -> dict:
    ruya_curves, cp_curves = [], []
    for key in JOB_ORDER:
        ruya, cp, _ = search_traces(key, reps=reps)
        ruya_curves.append(cumulative_curve(ruya, horizon))
        cp_curves.append(cumulative_curve(cp, horizon))
    ruya_mean = np.mean(ruya_curves, axis=0)
    cp_mean = np.mean(cp_curves, axis=0)

    path = artifact_path("paper", "fig5_cumulative.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["execution", "ruya_cumulative", "cherrypick_cumulative"])
        for i in range(horizon):
            w.writerow([i + 1, round(ruya_mean[i], 3), round(cp_mean[i], 3)])

    print("\n== Fig. 5: cumulative cost over recurrences ==")
    for n in (5, 10, 25, 50, 100):
        adv = (cp_mean[n - 1] - ruya_mean[n - 1]) / cp_mean[n - 1] * 100
        print(f"  after {n:3d} executions: Ruya {ruya_mean[n-1]:8.2f} | "
              f"CherryPick {cp_mean[n-1]:8.2f}  (Ruya {adv:+.1f}%)")
    return {"csv": path,
            "advantage_at_25": float((cp_mean[24] - ruya_mean[24]) / cp_mean[24])}


if __name__ == "__main__":
    run()
