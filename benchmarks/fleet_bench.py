"""Fleet-scale search benchmark: packed batched engine vs sequential loop.

Four measurements; A–C are trace-checked against the sequential engine:

  A. **Paper replay** — the 16 evaluation jobs × 4 seeds, full two-phase
     Ruya search over the 69-config space, to exhaustion (the Table II
     protocol as a fleet).
  B. **Priority-only service fleet** — 64 runs of the recurring flat-memory
     jobs (terasort, join, Hadoop pagerank) tuned *within their
     memory-derived priority group only* (10 configs each).  This is the
     paper's own observation (the optimum lands in the priority group for
     every categorized job) run the way Blink-style systems run tuning:
     small spaces, cheap trials, as a routine re-tuning service.
  C. **Search-space scaling sweep** — synthetic spaces of n ∈ {69, 256,
     512, 1024, 8192, 32768} configurations plus a step-only n = 131072
     catalog-scale point, a 64-job fleet with the paper-regime trial
     budget (B = 24): per-BO-step time of the feature-buffer engine vs
     the fused streaming-kernel lane (``layout="fused"``,
     `repro.kernels.ei_argmax` — its tiled (max EI, argmax) reduction
     never materializes the (B,n) cross block, and XLA's compiled
     transient footprint is reported for both layouts to show it) vs the
     retained d²-gather step (n ≤ 8192 — its (n,n) tensor is the memory
     wall the feature buffer removed) vs the dense full-extent step
     (n ≤ 1024, O(18n³)), plus end-to-end batched vs sequential and
     per-point memory reporting (analytic geometry bytes, largest live
     device buffer, peak RSS).  The fused lane is trace-checked against
     the feature lane at EVERY extent — it has no n ceiling, which is its
     point.  This is the engine's target regime — B ≪ n, n up to
     10⁴–10⁵ — where the gather engine was memory-bound and the dense
     engine flops-bound.
  D. **Streaming session** (`--session` to run it alone) — 64 recurring
     paper jobs arriving in 8 waves against one `TuningSession` with
     warm-starting on: wave 0 is cold, later waves hit the probe cache and
     are seeded from their memory-signature class's completed trials.
     Asserts warm-started searches reach the EI convergence threshold in
     strictly fewer fresh trials than cold starts, and reports cache hit
     rates and the seeded-trial counts.
  E. **Job-axis sharding** (`--shards N [N ...]`) — the service fleet (B)
     re-run with the lockstep chunks sharded across JAX devices
     (`repro.fleet.sharding`): per shard count, best-of wall clock vs the
     single-device reference and a bit-identity assertion on every trace.
     On CPU the devices come from --xla_force_host_platform_device_count
     (forced at the top of this module and by `benchmarks/run.py` when
     nothing set it).  Target on the 2-core container: ≥ 1.5× at 2 shards.
  F. **Adversarial fleet** — the paper fleet re-run through a disturbance
     schedule (`repro.cluster.faults`): Poisson transient profiling
     failures (hash-drawn at rate 0.25, retried with deterministic
     backoff), 10% straggler trials (reported, never fed back), 10% of the
     fleet cancelled mid-flight, one permanently broken job (full runs
     only), and one shard-loss event (a live `reshard` from 2 devices to
     1 mid-drain).  Reports completion rate (converged / non-cancelled,
     asserted ≥ 95% under the schedule), wasted trials (the cancelled
     jobs' partial work), retry overhead (extra profiling attempts and
     charged backoff seconds), and straggler counts.
  G. **Open-loop service fleet** — Poisson arrivals against the async
     `TuningService` (`repro.fleet.service`) vs the global-lockstep
     `TuningSession`, same pre-drawn arrival times on both sides, three
     heterogeneous admission groups (24/96/384-config spaces → distinct
     chunk shapes), and deterministic per-(group, iteration) straggler
     stalls injected through the service's ``pace`` seam on one side
     and an equivalent inline sleep in the single-threaded barrier loop
     on the other.  Under lockstep every straggling group's stall
     serializes through the barrier; under the service it stalls only
     that group's dispatch thread.  Reports sustained jobs/sec
     (completions over the first-arrival → last-completion window) and
     p50/p99 job sojourn (completion − scheduled arrival); outcomes are
     asserted bit-identical per job across the two drivers, and the
     async side must sustain ≥ 1.3× the lockstep jobs/sec at the full
     protocol (≥ 1.1× in smoke).
  H. **Cost-aware pricing** — the `repro.cluster.pricing` catalogs:
     per-catalog USD-argmin movement over the Table I jobs (≥ 3 must move
     on at least one book), the spot-volatility fleets searched under
     both `objective="runtime"` and `objective="cost"` (reported USD
     savings of the cost picks, ≥ 1 job where the objectives diverge,
     Pareto-front invariants asserted on every cost outcome), and the
     family-constrained Graviton scenarios at table level.

The sweep also asserts **buffer donation**: the lockstep update consumes
(donates) its input state, so each fleet iteration updates the observation
mask and the packed trial/target/(B,d)-feature buffers in place — the old
state's device buffers are deleted after one update, i.e. no per-iteration
device copies remain.

`benchmarks/run.py --only fleet` (and running this module directly, at the
default 64 jobs) writes the machine-readable perf baseline to
`BENCH_fleet.json` at the repo root: per-step ms, end-to-end seconds,
speedups, and memory numbers, so the perf trajectory is tracked PR over PR.
Smoke or reduced-job runs never touch the committed baseline (their numbers
are not comparable); `--smoke` (or `run(smoke=True)`) is the seconds-scale
wiring check used by `pytest -m bench_smoke` — it includes an n = 32768
sweep point so the 10⁴–10⁵ regime stays wired.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--jobs 64] [--no-check]
                                                    [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import resource
import sys
import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

# The sharded lanes need a multi-device CPU topology, which must be forced
# before the JAX backend initializes.  Under pytest, conftest.py has done
# it; `benchmarks.run` does it when (and only when) the fleet suite is
# selected; the block below covers `python -m benchmarks.fleet_bench`
# directly — gated on __main__ so that merely IMPORTING this module (e.g.
# `benchmarks.run --only table2` imports every suite) never changes
# another benchmark's device topology.  Forcing MORE devices than needed
# is not free — the single-device baseline loses wall clock to the extra
# device plumbing — so exactly max(--shards, 2) are forced, and only when
# the caller forced nothing.
def shard_device_count(argv: Sequence[str]) -> int:
    """max(requested --shards, 2), pre-parsed from raw argv — this must
    run before argparse (and therefore before the jax-importing module
    body) can."""
    want = [2]
    argv = list(argv)
    for i, a in enumerate(argv):
        if a == "--shards":  # space-separated: --shards 2 4
            tail = argv[i + 1:]
        elif a.startswith("--shards="):  # argparse's --shards=4 spelling
            tail = [a.split("=", 1)[1]]
        else:
            continue
        for v in tail:
            if v.startswith("-"):
                break
            try:
                want.append(int(v))
            except ValueError:  # argparse will reject it properly later
                break
    return max(want)


if __name__ == "__main__":
    from repro.hostdevices import force_host_device_count

    force_host_device_count(shard_device_count(sys.argv[1:]))

import jax
import jax.numpy as jnp

from benchmarks.common import JOB_ORDER, artifact_path
from repro.core.bayesopt import BOSettings, cherrypick_search
from repro.core.fast_bo import (
    FleetState,
    bo_step_core_dense,
    encode_features,
    precompute_d2,
)
from repro.core.profiler import profile_job
from repro.core.search_space import Configuration, SearchSpace, split_search_space
from repro.fleet import batched_search, cluster_fleet, tune_fleet
from repro.fleet.batched_engine import _CHUNK, _fleet_update

BENCH_JSON = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json")
)

# Per-step timing caps for the retained layouts.  The dense step is O(18n³)
# flops; the gather step is cheap per step but holds a resident (n,n)
# float32 tensor per job in the chunk — at n = 32768 that would be 4 GiB
# per job, which is precisely the wall the feature buffer removes.
_DENSE_MAX_N = 1024
_GATHER_MAX_N = 8192


def build_fleet(n_jobs: int):
    keys = [JOB_ORDER[i % len(JOB_ORDER)] for i in range(n_jobs)]
    jobs = cluster_fleet(keys)
    # Profile once per distinct job up front: the bench times the *search*
    # engines, and both must see identical splits.
    profiles = {}
    for job in jobs:
        if job.name not in profiles:
            profiles[job.name] = profile_job(job.profile_run, job.full_input_size)
        job.profile_result = profiles[job.name]
    return jobs


def _rngs(n: int) -> List[np.random.Generator]:
    return [np.random.default_rng(1000 + i) for i in range(n)]


def _peak_rss_mb() -> float:
    """Process high-water RSS in MB — MONOTONE over the process lifetime,
    so it is reported once per run, not per sweep point (a per-point value
    would inherit earlier points' gather/dense allocations).  ru_maxrss is
    kilobytes on Linux, bytes on macOS."""
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":
        return rss / 1024.0**2
    return rss / 1024.0


def _live_device_mb() -> Tuple[float, float]:
    """(total, largest) live device-buffer MB — the on-device footprint."""
    sizes = [a.nbytes for a in jax.live_arrays()]
    if not sizes:
        return 0.0, 0.0
    return sum(sizes) / 1e6, max(sizes) / 1e6


def synthetic_space(n: int, d: int = 6, seed: int = 7) -> Tuple[SearchSpace, np.ndarray]:
    """An n-config space with random features and a smooth cost surface."""
    rng = np.random.default_rng(seed + n)
    feats = rng.normal(size=(n, d))
    space = SearchSpace(
        [
            Configuration(
                name=f"s{i}",
                features=tuple(float(v) for v in feats[i]),
                total_memory=float(i),
            )
            for i in range(n)
        ]
    )
    w = rng.normal(size=d)
    z = feats @ w
    z = (z - z.mean()) / max(float(z.std()), 1e-9)
    table = 1.0 + (z - 0.7) ** 2 + 0.05 * rng.random(n)
    return space, table


def check_buffer_donation() -> dict:
    """Assert the lockstep update donates its state: after one jitted call
    the *input* state's device buffers are deleted (XLA aliased them to the
    outputs), so fleet iterations update in place — no per-iteration device
    copies of the observation mask or the packed trial/target/feature
    buffers (the (B,d) feature buffer rides the same donation contract)."""
    n, j, b = 16, 2, 6
    space, table = synthetic_space(n)
    enc = encode_features(space.encoded())
    d = enc.shape[1]
    geom = jnp.asarray(np.stack([enc] * j))
    state = FleetState(
        obs=jnp.zeros((j, n), bool),
        tried=jnp.full((j, b), -1, jnp.int32),
        py=jnp.zeros((j, b), jnp.float32),
        feats=jnp.zeros((j, b, d), jnp.float32),
        t=jnp.zeros(j, jnp.int32),
        stop=jnp.full(j, -1, jnp.int32),
        pb=jnp.full(j, -1, jnp.int32),
        done=jnp.zeros(j, bool),
        last_ei=jnp.zeros(j, jnp.float32),
        last_best=jnp.full(j, jnp.inf, jnp.float32),
    )
    args = (
        geom, jnp.asarray(np.stack([table] * j), jnp.float32),
        jnp.ones((j, n), bool), jnp.zeros((j, n), bool),
        jnp.zeros((j, 1), jnp.int32), jnp.zeros(j, jnp.int32),
        jnp.full(j, b, jnp.int32), jnp.asarray(0, jnp.int32),
        jnp.asarray(0.0, jnp.float32), jnp.asarray(True),
    )
    old = (state.obs, state.tried, state.py, state.feats)
    new = _fleet_update(state, *args, xi=0.0, layout="feature")
    jax.block_until_ready(new.t)
    deleted = [bool(buf.is_deleted()) for buf in old]
    assert all(deleted), (
        f"state buffers survived the donated lockstep call: {deleted} — "
        "per-iteration device copies are back"
    )
    return {
        "state_donated": True,
        "buffers_checked": ["obs", "tried", "py", "feats"],
    }


def _packed_state_args(space, table, budget: int, layout: str):
    """A warm lockstep (state, args) pair for `_fleet_update` — buffer
    nearly full, budget live — shared by the step timer and the
    compiled-transient-footprint probe so both measure the same program."""
    n = len(space)
    j = _CHUNK
    k = max(budget - 1, 1)  # warm state: buffer nearly full, budget live
    enc = encode_features(space.encoded())
    geom_one = (
        enc if layout in ("feature", "fused")
        else np.asarray(precompute_d2(enc))
    )
    # broadcast_to is a host-side view — the chunk replication only
    # materializes once, on device (at n=8192 the gather layout's stacked
    # (8,n,n) geometry is ~2 GiB there; that resident tensor is exactly
    # the cost being measured, so don't also pay it in host RAM).
    geom = jnp.asarray(np.broadcast_to(geom_one, (j,) + geom_one.shape))
    obs = np.zeros((j, n), bool)
    obs[:, :k] = True
    tried = np.full((j, budget), -1, np.int32)
    tried[:, :k] = np.arange(k)
    py = np.zeros((j, budget), np.float32)
    py[:, :k] = np.asarray(table[:k], np.float32)
    feats = np.zeros((j, budget, enc.shape[1]), np.float32)
    feats[:, :k] = enc[:k]
    state = FleetState(
        obs=jnp.asarray(obs),
        tried=jnp.asarray(tried),
        py=jnp.asarray(py),
        feats=jnp.asarray(feats),
        t=jnp.full(j, k, jnp.int32),
        stop=jnp.full(j, -1, jnp.int32),
        pb=jnp.full(j, -1, jnp.int32),
        done=jnp.zeros(j, bool),
        last_ei=jnp.zeros(j, jnp.float32),
        last_best=jnp.full(j, jnp.inf, jnp.float32),
    )
    args = (
        geom, jnp.asarray(np.stack([table] * j), jnp.float32),
        jnp.ones((j, n), bool), jnp.zeros((j, n), bool),
        jnp.zeros((j, 1), jnp.int32), jnp.zeros(j, jnp.int32),
        jnp.full(j, budget, jnp.int32), jnp.asarray(0, jnp.int32),
        jnp.asarray(0.0, jnp.float32), jnp.asarray(True),
    )
    return state, args


def _time_packed_step(space, table, budget: int, reps: int,
                      layout: str = "feature") -> Tuple[float, float, float]:
    """(seconds/iter, live-device MB, largest-buffer MB) of the packed
    lockstep update, one warm chunk, for any packed geometry layout
    ("feature", "gather", or the streaming-kernel "fused").  Memory is
    sampled while the engine state and geometry are resident — the
    steady-state on-device footprint."""
    state, args = _packed_state_args(space, table, budget, layout)
    state = _fleet_update(state, *args, xi=0.0, layout=layout)  # warm the jit
    jax.block_until_ready(state.t)
    live_mb, largest_mb = _live_device_mb()
    t0 = time.perf_counter()
    for _ in range(reps):
        state = _fleet_update(state, *args, xi=0.0, layout=layout)
    jax.block_until_ready(state.t)
    return (time.perf_counter() - t0) / reps, live_mb, largest_mb


def _step_transient_mb(space, table, budget: int, layout: str) -> float:
    """XLA's compiled transient footprint (temp buffers, MB) of one lockstep
    update — the compiler's own accounting of scratch the step allocates
    beyond its inputs/outputs.  This is where the fused layout's streaming
    reduction shows up: the feature layout's transients hold the (B,n)
    cross block (plus peers) per chunk row, the fused layout's only the
    (B,tile) working set."""
    state, args = _packed_state_args(space, table, budget, layout)
    stats = (
        _fleet_update.lower(state, *args, xi=0.0, layout=layout)
        .compile()
        .memory_analysis()
    )
    return float(stats.temp_size_in_bytes) / 1e6


_dense_chunk_step = jax.jit(jax.vmap(bo_step_core_dense))


def _time_dense_step(space, table, budget: int, reps: int) -> float:
    """Per-iteration seconds of the retained dense full-extent step (the
    pre-packed engine's O(18n³) layout), same chunk extent."""
    n = len(space)
    j = _CHUNK
    k = max(budget - 1, 1)
    encoded = encode_features(space.encoded())
    obs = np.zeros(n, bool)
    obs[:k] = True
    enc8 = jnp.asarray(np.stack([encoded] * j))
    obs8 = jnp.asarray(np.stack([obs] * j))
    y8 = jnp.asarray(np.stack([np.asarray(table, np.float32)] * j))
    cand8 = jnp.asarray(np.stack([~obs] * j))
    out = _dense_chunk_step(enc8, obs8, y8, cand8)  # warm the jit
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = _dense_chunk_step(enc8, obs8, y8, cand8)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def bench_scaling_point(
    n: int, n_jobs: int, budget: int, check: bool,
    packed_reps: int = 20, dense_reps: int = 2, step_only: bool = False,
) -> dict:
    """One sweep point: budgeted CherryPick over an n-config synthetic space.

    ``step_only`` skips the end-to-end sequential/batched timing (the
    catalog-scale extension points, n ≥ 10⁵, where a 64-job sequential
    Python loop would dominate the whole bench) — per-step timing, the
    transient-footprint probes, and the fused-vs-feature trace identity
    check still run.
    """
    space, table = synthetic_space(n)
    d = space.encoded().shape[1]
    settings = BOSettings(max_iters=budget)
    tables = [table] * n_jobs
    cost_fn = lambda i: float(table[i])

    t_seq = t_bat = None
    trials = None
    identical = None
    if not step_only:
        rng_seq = _rngs(n_jobs)
        rng_bat = _rngs(n_jobs)
        # Warm both engines' compiles outside the timed region (the batched
        # warm-up must cover the full-extent chunk shape, not a prefix).
        cherrypick_search(space, cost_fn, np.random.default_rng(0),
                          settings=settings, to_exhaustion=True)
        batched_search([space] * n_jobs, tables, _rngs(n_jobs),
                       settings=settings, to_exhaustion=True)

        t0 = time.perf_counter()
        seq = [
            cherrypick_search(space, cost_fn, r, settings=settings,
                              to_exhaustion=True)
            for r in rng_seq
        ]
        t_seq = time.perf_counter() - t0
        t0 = time.perf_counter()
        bat = batched_search([space] * n_jobs, tables, rng_bat,
                             settings=settings, to_exhaustion=True)
        t_bat = time.perf_counter() - t0

        identical = True
        if check:
            for jdx, ref in enumerate(seq):
                tr = bat.job_trace(jdx)
                identical &= tr.tried == ref.tried and tr.costs == ref.costs
            assert identical, f"engines diverged at n={n}"
        trials = sum(len(t.tried) for t in seq)

    gather_identical = None
    fused_identical = None
    if check:
        # Cross-layout identity at every point: each retained/alternative
        # layout must reproduce the feature-buffer traces bit-for-bit (few
        # jobs — the point is the check, not layout throughput).
        g_jobs = min(n_jobs, 2)
        bat_f = batched_search(
            [space] * g_jobs, tables[:g_jobs], _rngs(g_jobs),
            settings=settings, to_exhaustion=True,
        )
        if n <= _GATHER_MAX_N:
            bat_g = batched_search(
                [space] * g_jobs, tables[:g_jobs], _rngs(g_jobs),
                settings=settings, to_exhaustion=True, layout="gather",
            )
            gather_identical = all(
                bat_g.job_trace(jdx).tried == bat_f.job_trace(jdx).tried
                for jdx in range(g_jobs)
            )
            assert gather_identical, f"gather layout diverged at n={n}"
        # The fused streaming-kernel lane has no n ceiling — that is its
        # entire point — so it is checked at every sweep extent.
        bat_u = batched_search(
            [space] * g_jobs, tables[:g_jobs], _rngs(g_jobs),
            settings=settings, to_exhaustion=True, layout="fused",
        )
        fused_identical = all(
            bat_u.job_trace(jdx).tried == bat_f.job_trace(jdx).tried
            and bat_u.job_trace(jdx).costs == bat_f.job_trace(jdx).costs
            for jdx in range(g_jobs)
        )
        assert fused_identical, f"fused layout diverged at n={n}"

    feature_s, live_mb, largest_mb = _time_packed_step(
        space, table, budget, packed_reps, layout="feature")
    fused_s = _time_packed_step(
        space, table, budget, packed_reps, layout="fused")[0]
    gather_s = (
        _time_packed_step(space, table, budget, packed_reps,
                          layout="gather")[0]
        if n <= _GATHER_MAX_N else None
    )
    dense_s = (
        _time_dense_step(space, table, budget, dense_reps)
        if n <= _DENSE_MAX_N else None
    )
    feature_transient_mb = _step_transient_mb(space, table, budget, "feature")
    fused_transient_mb = _step_transient_mb(space, table, budget, "fused")
    return {
        "n": n,
        "budget": budget,
        "n_jobs": n_jobs,
        "chunk": _CHUNK,
        "feature_step_ms": 1e3 * feature_s,
        "fused_step_ms": 1e3 * fused_s,
        "gather_step_ms": 1e3 * gather_s if gather_s is not None else None,
        "dense_step_ms": 1e3 * dense_s if dense_s is not None else None,
        "step_speedup_vs_dense": dense_s / feature_s if dense_s else None,
        "fused_step_speedup_vs_feature": feature_s / fused_s,
        # XLA's compiled transient accounting: the per-chunk scratch the
        # fused layout's streaming reduction eliminates ((B,n) → (B,tile)).
        "feature_step_transient_mb": feature_transient_mb,
        "fused_step_transient_mb": fused_transient_mb,
        "fused_transient_reduction": (
            feature_transient_mb / fused_transient_mb
            if fused_transient_mb > 0 else None
        ),
        # Geometry memory per job: the feature layout's resident (n,d)
        # encoding vs the (n,n) tensor the gather layout would need.
        "geom_feature_mb": n * d * 4 / 1e6,
        "geom_gather_mb": n * n * 4 / 1e6,
        "live_device_mb": live_mb,
        "largest_live_buffer_mb": largest_mb,
        "sequential_s": t_seq,
        "batched_s": t_bat,
        "speedup": t_seq / t_bat if not step_only else None,
        "total_trials": trials,
        "traces_identical": bool(identical) if identical is not None else None,
        "gather_traces_identical": gather_identical,
        "fused_traces_identical": fused_identical,
        "step_only": step_only,
    }


def bench_scaling(ns: Sequence[int], n_jobs: int, budget: int, check: bool,
                  packed_reps: int = 20, dense_reps: int = 2,
                  step_only_ns: Sequence[int] = ()) -> dict:
    rows = []
    for n in list(ns) + list(step_only_ns):
        r = bench_scaling_point(n, n_jobs, budget, check,
                                packed_reps=packed_reps, dense_reps=dense_reps,
                                step_only=n in step_only_ns)
        rows.append(r)
        gather = (f"{r['gather_step_ms']:8.2f}" if r["gather_step_ms"]
                  else "       –")
        dense = (f"{r['dense_step_ms']:9.2f}" if r["dense_step_ms"]
                 else "        –")
        e2e = (
            f"end-to-end {r['batched_s']:6.2f} s batched vs "
            f"{r['sequential_s']:7.2f} s sequential ({r['speedup']:.2f}x)"
            if not r["step_only"] else "end-to-end skipped (step-only point)"
        )
        print(f"  C. n={r['n']:6d}  B={r['budget']:3d}  "
              f"feature step {r['feature_step_ms']:8.2f} ms/chunk  "
              f"fused {r['fused_step_ms']:8.2f} ms "
              f"({r['fused_step_speedup_vs_feature']:.2f}x, transients "
              f"{r['feature_step_transient_mb']:.1f} -> "
              f"{r['fused_step_transient_mb']:.1f} MB, "
              f"{r['fused_transient_reduction']:.0f}x)  "
              f"gather {gather} ms  dense {dense} ms  "
              f"geom {r['geom_feature_mb']:8.2f} MB (vs "
              f"{r['geom_gather_mb']:9.1f} MB d²)  " + e2e)
    return {"budget": budget, "n_jobs": n_jobs, "sweep": rows}


def bench_session_streaming(
    n_jobs: int, waves: int, check: bool,
    settings: BOSettings = BOSettings(),
) -> dict:
    """Workload D: streaming `TuningSession` — jobs arriving in waves.

    ``n_jobs`` recurring paper jobs (the first ``n_jobs // waves`` catalog
    keys, cycling) arrive in ``waves`` submission waves against ONE
    session with warm-starting on and a session-owned `ProfileCache`.
    Wave 0 is all cold; later waves re-submit the same workload keys, hit
    the probe cache, and are warm-started from their signature class's
    completed trials.  The scenario measures the amortization claim:
    fresh trials until the EI convergence threshold fires, warm vs cold
    (asserted strictly fewer when ``check``), plus cache hit rates and
    end-to-end wall time.
    """
    from benchmarks.common import get_sim
    from repro.fleet import ProfileCache, TuningSession

    per = max(n_jobs // waves, 1)
    wave_keys = [JOB_ORDER[i % len(JOB_ORDER)] for i in range(per)]
    # Build every wave's job objects up front (through the shared simulator
    # memo): the timed region below measures the SESSION — probe/profile,
    # on-device split, lockstep search, warm seeding — not harness setup.
    wave_jobs = [
        cluster_fleet(wave_keys, sims={k: get_sim(k) for k in wave_keys})
        for _ in range(waves)
    ]
    session = TuningSession(
        settings=settings, cache=ProfileCache(), warm_start=True,
        to_exhaustion=False,
    )
    t0 = time.perf_counter()
    submitted = 0
    for jobs in wave_jobs:
        for i, job in enumerate(jobs):
            session.submit(job, seed=1000 + submitted + i)
        submitted += len(jobs)
        # Drain the wave: one batched BO iteration per step for every live
        # search (a real service would interleave submissions here).
        while session.step():
            pass
    elapsed = time.perf_counter() - t0

    outs = session.results()
    warm = [o for o in outs if o.seeded]
    cold = [o for o in outs if not o.seeded]
    mean = lambda xs: float(np.mean(xs)) if xs else None
    cold_iters = mean([len(o.records) for o in cold])
    warm_iters = mean([len(o.records) for o in warm])
    row = {
        "n_jobs": submitted,
        "waves": waves,
        "jobs_per_wave": len(wave_keys),
        "cold_jobs": len(cold),
        "warm_jobs": len(warm),
        "warm_seeded_trials": session.warm_trials,
        "cold_mean_fresh_trials": cold_iters,
        "warm_mean_fresh_trials": warm_iters,
        # None = fully amortized (warm searches needed zero fresh trials).
        "fresh_trial_reduction": (
            cold_iters / warm_iters
            if (warm_iters is not None and warm_iters > 0) else None
        ),
        "cold_mean_best": mean([o.best_cost for o in cold]),
        "warm_mean_best": mean([o.best_cost for o in warm]),
        "profile_cache_hits": session.cache.hits,
        "profile_cache_misses": session.cache.misses,
        "session_s": elapsed,
    }
    if check:
        assert warm and cold, "streaming scenario needs cold AND warm jobs"
        assert warm_iters < cold_iters, (
            f"warm-started searches should converge in fewer fresh trials: "
            f"warm {warm_iters} vs cold {cold_iters}"
        )
    return row


def bench_adversarial(
    n_jobs: int, check: bool, settings: BOSettings,
    *, permanent_jobs: int = 1, steps_before_churn: int = 3,
) -> dict:
    """Workload F: the paper fleet under an adversarial schedule.

    Every job's profiling runs draw Poisson-style transient failures
    (`FaultPlan(transient_rate=0.25, max_injected=3)` — bounded below the
    retry budget, so retried resolution always terminates) and 10% of
    trials are stragglers (latency reported via `TrialRecord.attempts`,
    never fed into costs).  After ``steps_before_churn`` lockstep steps,
    every 10th handle is cancelled and the session loses a device
    (`reshard` 2 → 1).  ``permanent_jobs`` jobs are additionally broken
    outright (every run raises `PermanentRunError`) — they surface as
    first-class "failed" outcomes at submit; the smoke variant passes 0.

    Completion rate is converged / (submitted − cancelled): cancellation
    is the caller's choice, but every job the scheduler was *asked* to
    finish counts — permanently failed ones included.
    """
    from repro.cluster.faults import FaultPlan
    from repro.fleet import TuningSession

    keys = [JOB_ORDER[i % len(JOB_ORDER)] for i in range(n_jobs)]
    plans = {
        k: FaultPlan(seed=i, transient_rate=0.25, max_injected=3,
                     straggler_rate=0.10)
        for i, k in enumerate(dict.fromkeys(keys))
    }
    jobs = cluster_fleet(keys, faults=plans)
    for job in jobs[len(jobs) - permanent_jobs:] if permanent_jobs else []:
        job.profile_run = FaultPlan(permanent=True).wrap_run(
            job.profile_run, job.name,
        )

    shard = 2 if jax.device_count() >= 2 else None
    session = TuningSession(settings=settings, warm_start=False, shard=shard)
    t0 = time.perf_counter()
    handles = [
        session.submit(job, seed=2000 + i) for i, job in enumerate(jobs)
    ]
    for _ in range(steps_before_churn):
        session.step()
    victims = [h for i, h in enumerate(handles) if i % 10 == 9]
    cancelled = sum(h.cancel() for h in victims)
    survivors_moved = session.reshard(shard=None)  # the shard-loss event
    outs = session.drain()
    elapsed = time.perf_counter() - t0

    by = lambda s: [o for o in outs if o.status == s]
    n_converged, n_failed = len(by("converged")), len(by("failed"))
    completion = n_converged / max(n_jobs - cancelled, 1)
    row = {
        "n_jobs": n_jobs,
        "shard": shard,
        "transient_rate": 0.25,
        "straggler_rate": 0.10,
        "cancelled": cancelled,
        "failed": n_failed,
        "converged": n_converged,
        "completion_rate": completion,
        "wasted_trials": sum(len(o.records) for o in by("cancelled")),
        "retry_attempts": sum(o.profile_attempts - 1 for o in outs),
        "retry_backoff_s": sum(o.retry_backoff_s for o in outs),
        "straggler_trials": sum(
            1 for o in outs for r in o.records if r.attempts > 1
        ),
        "reshard_survivors": survivors_moved,
        "adversarial_s": elapsed,
    }
    if check:
        assert len(outs) == n_jobs, "results() must be exactly-once"
        assert completion >= 0.95, (
            f"completion {completion:.3f} under the adversarial schedule"
        )
        assert row["retry_attempts"] > 0, "no transient faults fired"
        assert row["straggler_trials"] > 0, "no stragglers reported"
        assert cancelled == 0 or row["wasted_trials"] > 0
    return row


def _report_adversarial(r: dict) -> None:
    print(f"  F. adversarial fleet ({r['n_jobs']} jobs, shard={r['shard']}, "
          f"transients at {r['transient_rate']}, "
          f"{r['cancelled']} cancelled, {r['failed']} broken)")
    print(f"    completion {100 * r['completion_rate']:.1f}%  "
          f"wasted trials {r['wasted_trials']}  "
          f"retries +{r['retry_attempts']} attempts "
          f"(+{r['retry_backoff_s']:.1f} s backoff)  "
          f"stragglers {r['straggler_trials']}  "
          f"reshard moved {r['reshard_survivors']} rows  "
          f"({r['adversarial_s']:.2f} s)")


# Workload G's heterogeneous admission groups: three space extents →
# three distinct chunk shapes, each with its own dispatch loop under the
# async service (the lockstep session barriers them together).
_G_SPACE_NS = (24, 96, 384)


def bench_open_loop(n_jobs: int, check: bool, *, smoke: bool = False) -> dict:
    """Workload G: Poisson-arrival open-loop fleet, async vs lockstep.

    ``n_jobs`` CherryPick jobs (budget 10) cycle over the three
    `_G_SPACE_NS` spaces and arrive at pre-drawn Poisson times — the SAME
    absolute schedule for both drivers, submitted open-loop (arrivals
    never wait for completions).  Straggler stalls are a deterministic
    per-(group key, group iteration) hash draw shared by both sides:

      * async — `TuningService` with a ``pace`` hook that sleeps the
        straggling group's OWN dispatch thread; the other groups keep
        stepping (stall isolation across worker threads — default device
        placement, since the forced host devices share the same cores);
      * lockstep — a single-threaded barrier loop over `TuningSession`
        internals that admits, then steps every live chunk, sleeping
        inline once per straggling group per barrier — the stall
        semantics of `TuningSession.step()`, where the slowest group
        sets the whole fleet's pace.

    Sojourn is completion minus *scheduled* arrival, so queueing delay
    is charged to the driver; sustained jobs/sec is completions over the
    first-arrival → last-completion window.  When ``check``, the two
    drivers' outcomes must be bit-identical per job (chunk membership
    and scheduling never touch traces) and the async side must clear the
    committed throughput floor (1.3×; 1.1× in smoke, where the fleet is
    too small to amortize thread spin-up).
    """
    from repro.cluster.faults import _hash_unit
    from repro.fleet import FleetJob, TuningService, TuningSession

    budget = 10
    straggler_rate = 0.4
    straggler_delay_s = 0.08
    mean_gap_s = 0.010 if smoke else 0.005
    spaces = [synthetic_space(n) for n in _G_SPACE_NS]
    arrivals = np.cumsum(
        np.random.default_rng(4242).exponential(mean_gap_s, size=n_jobs)
    )

    def make_jobs() -> List:  # fresh objects per driver — submit may annotate
        return [
            FleetJob(
                name=f"g{i}",
                space=spaces[i % len(spaces)][0],
                cost_table=spaces[i % len(spaces)][1],
            )
            for i in range(n_jobs)
        ]

    def session_kwargs() -> dict:
        return dict(
            settings=BOSettings(max_iters=budget), mode="cherrypick",
            to_exhaustion=True, warm_start=False,
        )

    def straggles(key: tuple, iteration: int) -> bool:
        return (
            _hash_unit("workloadG", str(key), str(iteration))
            < straggler_rate
        )

    def completion_clock(session) -> dict:
        done = {}

        def listener(outcome):  # fires under the session lock — keep tiny
            done[outcome.name] = time.perf_counter()

        session._outcome_listeners.append(listener)
        return done

    def submit_at_arrivals(submit, jobs, t0: float) -> None:
        for i, (job, at) in enumerate(zip(jobs, arrivals)):
            lag = (t0 + at) - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            submit(job, seed=3000 + i)

    def stats(done: dict, t0: float) -> dict:
        sojourns = [done[f"g{i}"] - (t0 + arrivals[i]) for i in range(n_jobs)]
        span = max(done.values()) - (t0 + arrivals[0])
        return {
            "jobs_per_sec": n_jobs / span,
            "makespan_s": span,
            "sojourn_p50_s": float(np.percentile(sojourns, 50)),
            "sojourn_p99_s": float(np.percentile(sojourns, 99)),
        }

    # Warm every lockstep program the drivers can hit: admission timing
    # decides chunk ROW extents (2..8 after single-job padding), and a
    # mid-run compile would otherwise be charged as scheduling time.
    for space, table in spaces:
        warm = TuningSession(**session_kwargs())
        for rows in range(2, _CHUNK + 1):
            for i in range(rows):
                warm.submit(
                    FleetJob(name=f"w{rows}-{i}", space=space,
                             cost_table=table),
                    seed=i,
                )
            warm.drain()

    def run_lockstep():
        session = TuningSession(**session_kwargs())
        done = completion_clock(session)
        jobs = make_jobs()
        t0 = time.perf_counter()
        feeder = threading.Thread(
            target=submit_at_arrivals, args=(session.submit, jobs, t0),
            name="g-lockstep-feeder", daemon=True,
        )
        feeder.start()
        iters: dict = {}
        while True:
            with session._lock:
                session._admit()
                chunks = list(session._chunks)
            if not chunks:
                if not feeder.is_alive():
                    with session._lock:
                        if not session._pending and not session._chunks:
                            break
                time.sleep(0.001)
                continue
            paced = set()
            for ch in chunks:
                key = ch.group_key
                if key not in paced:
                    # One straggler draw per group per barrier — identical
                    # injection law to the async pace hook, but the sleep
                    # happens on the ONLY stepping thread: every other
                    # group waits out the stall (the lockstep pathology).
                    paced.add(key)
                    iters[key] = iters.get(key, 0) + 1
                    if straggles(key, iters[key]):
                        time.sleep(straggler_delay_s)
                session._step_chunk(ch)
        feeder.join()
        outs = session.drain()
        return stats(done, t0), outs

    def run_async():
        session = TuningSession(**session_kwargs())
        done = completion_clock(session)

        def pace(key: tuple, iteration: int) -> None:
            if straggles(key, iteration):
                time.sleep(straggler_delay_s)  # stalls this group only

        # devices=None: forced host "devices" share the same CPU cores, and
        # XLA caches executables PER DEVICE — round-robin placement would
        # recompile every (space, rows) program per device and charge it
        # as scheduling time.  Stall isolation is a thread property here.
        svc = TuningService(session, pace=pace, devices=None)
        jobs = make_jobs()
        t0 = time.perf_counter()
        submit_at_arrivals(svc.submit, jobs, t0)
        outs = svc.drain()
        m = svc.metrics()
        svc.shutdown(drain=False)
        return stats(done, t0), outs, m

    lock_stats, lock_outs = run_lockstep()
    async_stats, async_outs, metrics = run_async()

    if check:
        by_lock = {o.name: o.as_dict() for o in lock_outs}
        by_async = {o.name: o.as_dict() for o in async_outs}
        assert by_lock == by_async, (
            "open-loop async outcomes diverged from the lockstep session"
        )

    speedup = async_stats["jobs_per_sec"] / lock_stats["jobs_per_sec"]
    floor = 1.1 if smoke else 1.3
    if check:
        assert speedup >= floor, (
            f"async service sustained only {speedup:.2f}x the lockstep "
            f"jobs/sec under straggler injection (floor {floor}x)"
        )
    return {
        "n_jobs": n_jobs,
        "space_ns": list(_G_SPACE_NS),
        "budget": budget,
        "mean_interarrival_s": mean_gap_s,
        "straggler_rate": straggler_rate,
        "straggler_delay_s": straggler_delay_s,
        "lockstep": lock_stats,
        "async": async_stats,
        "speedup_jobs_per_sec": speedup,
        "speedup_floor": floor,
        "traces_identical": bool(check) if check else None,
        "service_groups": len(metrics["groups"]),
        "service_jobs_per_sec": metrics["jobs_per_sec"],
    }


def _report_open_loop(r: dict) -> None:
    print(f"  G. open-loop service fleet ({r['n_jobs']} Poisson arrivals, "
          f"mean gap {1e3 * r['mean_interarrival_s']:.0f} ms, "
          f"{r['service_groups']} admission groups, stragglers at "
          f"{r['straggler_rate']} x {1e3 * r['straggler_delay_s']:.0f} ms)")
    for tag in ("lockstep", "async"):
        s = r[tag]
        print(f"    {tag:8s}: {s['jobs_per_sec']:6.2f} jobs/s  "
              f"sojourn p50 {1e3 * s['sojourn_p50_s']:7.1f} ms  "
              f"p99 {1e3 * s['sojourn_p99_s']:7.1f} ms  "
              f"(makespan {s['makespan_s']:.2f} s)")
    print(f"    sustained throughput: {r['speedup_jobs_per_sec']:.2f}x "
          f"async vs lockstep (floor {r['speedup_floor']}x, traces "
          f"{'identical' if r['traces_identical'] else 'UNCHECKED'})")


def bench_pricing(check: bool, settings: BOSettings, *, smoke: bool = False,
                  seed: int = 0) -> dict:
    """Workload H: cost-aware tuning over pricing catalogs.

    Three measurements over `repro.cluster.pricing`:

      * **Repricing movement** — for every Table I job × every catalog in
        `default_catalogs(seed)`, does the USD-optimal configuration move
        off the legacy (x86 on-demand) optimum?  Asserted ≥ 3 jobs on at
        least one catalog: if no book can move the optimum, a cost
        objective is a no-op and the whole axis is dead weight.
      * **Objective contrast** — the `spot_volatility_scenarios` fleets
        (priced `cluster_fleet` jobs, per spot epoch) searched twice
        through `TuningSession`, once per objective.  Reports the USD the
        cost objective saves over the runtime objective's pick (summed;
        asserted ≥ 0 with ≥ 1 job where the two objectives choose
        different configurations) and asserts the Pareto-front invariants
        on every cost-run outcome (non-empty, mutually non-dominated,
        deterministic, contains the per-axis argmins).
      * **Family-constrained optima** — the `family_constrained_scenarios`
        Graviton searches evaluated at table level: the USD penalty of
        pinning each job to one instance family vs the whole grid.
    """
    from repro.cluster import (
        JOBS, default_catalogs, family_indices, job_cost_table,
    )
    from repro.cluster.workloads import (
        family_constrained_scenarios, spot_volatility_scenarios,
    )
    from repro.fleet import TuningSession

    t0 = time.perf_counter()

    # -- repricing movement (table-level; cheap enough to always run full)
    legacy_arg = {k: int(np.argmin(job_cost_table(j))) for k, j in JOBS.items()}
    argmin_moved = {}
    for cat in default_catalogs(seed).values():
        argmin_moved[cat.name] = sum(
            int(np.argmin(job_cost_table(j, catalog=cat))) != legacy_arg[k]
            for k, j in JOBS.items()
        )

    # -- objective contrast over the spot-volatility fleets
    scens = spot_volatility_scenarios(seed=seed)
    if smoke:
        first_epoch = scens[0].epoch
        scens = [s for s in scens if s.epoch == first_epoch]
    by_epoch: dict = {}
    for s in scens:
        by_epoch.setdefault(s.epoch, []).append(s)

    job_rows = []
    pareto_max = 0
    for epoch, group in sorted(by_epoch.items()):
        catalog = group[0].catalog
        keys = [s.job_key for s in group]
        jobs = cluster_fleet(keys, catalog=catalog, epoch=epoch)
        outs = {}
        for objective in ("runtime", "cost"):
            session = TuningSession(
                settings=settings, warm_start=False, objective=objective,
            )
            for i, job in enumerate(jobs):
                session.submit(job, seed=7000 + 100 * epoch + i)
            outs[objective] = session.drain()
        for s, o_rt, o_cost in zip(group, outs["runtime"], outs["cost"]):
            rt_pick = min(o_rt.observations, key=lambda r: r.cost)
            cost_pick = min(o_cost.observations, key=lambda r: r.cost)
            front = o_cost.pareto()
            pareto_max = max(pareto_max, len(front))
            if check:
                assert front, f"{s.name}: empty Pareto front"
                assert o_cost.pareto() == front, (
                    f"{s.name}: pareto() is not deterministic"
                )
                for i, a in enumerate(front):
                    for j, b in enumerate(front):
                        if i != j:
                            assert not (
                                b.runtime_h <= a.runtime_h and b.usd <= a.usd
                                and (b.runtime_h < a.runtime_h or b.usd < a.usd)
                            ), f"{s.name}: front member {i} is dominated"
                assert any(r.usd == o_cost.best_usd for r in front)
                assert any(
                    r.runtime_h == o_cost.best_runtime_h for r in front
                )
                # The cost search's own pick IS its cheapest observation.
                assert cost_pick.usd == o_cost.best_usd
            job_rows.append({
                "scenario": s.name,
                "epoch": epoch,
                "runtime_pick": int(rt_pick.index),
                "cost_pick": int(cost_pick.index),
                "usd_at_runtime_pick": float(rt_pick.usd),
                "usd_at_cost_pick": float(cost_pick.usd),
                "usd_saved": float(rt_pick.usd - cost_pick.usd),
                "pareto_size": len(front),
            })

    usd_rt = sum(r["usd_at_runtime_pick"] for r in job_rows)
    usd_cost = sum(r["usd_at_cost_pick"] for r in job_rows)
    contrast = sum(r["runtime_pick"] != r["cost_pick"] for r in job_rows)

    # -- family-constrained Graviton optima (table-level)
    fam_rows = []
    for s in family_constrained_scenarios():
        usd = job_cost_table(JOBS[s.job_key], catalog=s.catalog, epoch=s.epoch)
        idx = family_indices(s.families)
        fam_rows.append({
            "scenario": s.name,
            "families": list(s.families),
            "in_family_usd": float(usd[idx].min()),
            "global_usd": float(usd.min()),
            "family_penalty": float(usd[idx].min() / usd.min()),
        })

    row = {
        "seed": seed,
        "n_scenarios": len(scens) + len(fam_rows),
        "argmin_moved": argmin_moved,
        "jobs": job_rows,
        "usd_runtime_total": usd_rt,
        "usd_cost_total": usd_cost,
        "usd_saved_total": usd_rt - usd_cost,
        "contrast_jobs": int(contrast),
        "pareto_max_size": pareto_max,
        "family": fam_rows,
        "pricing_s": time.perf_counter() - t0,
    }
    if check:
        assert max(argmin_moved.values()) >= 3, (
            f"no catalog moves >= 3 Table I optima: {argmin_moved}"
        )
        assert row["usd_saved_total"] >= 0.0, (
            f"cost objective spent MORE than runtime's pick: {row}"
        )
        assert contrast >= 1, (
            "runtime and cost objectives picked identical configs on every "
            "catalog job — no contrast to measure"
        )
        for f in fam_rows:
            assert f["family_penalty"] >= 1.0 - 1e-12
    return row


def _report_pricing(r: dict) -> None:
    moved = ", ".join(f"{k}:{v}" for k, v in r["argmin_moved"].items())
    print(f"  H. cost-aware pricing ({len(r['jobs'])} priced searches x 2 "
          f"objectives, {len(r['family'])} family scenarios)")
    print(f"    Table I USD-argmin moved per catalog: {moved}")
    print(f"    cost objective saves {r['usd_saved_total']:.2f} USD over the "
          f"runtime picks ({r['usd_runtime_total']:.2f} -> "
          f"{r['usd_cost_total']:.2f}; {r['contrast_jobs']} jobs diverge, "
          f"Pareto fronts <= {r['pareto_max_size']} trials)  "
          f"({r['pricing_s']:.2f} s)")


def bench_paper_replay(jobs, check: bool, settings: BOSettings) -> dict:
    """Workload A: full two-phase Ruya search over the 69-config space."""
    n_jobs = len(jobs)
    warm = jobs[: min(2, n_jobs)]
    tune_fleet(warm, _rngs(len(warm)), settings=settings, to_exhaustion=True,
               engine="sequential")
    tune_fleet(jobs, _rngs(n_jobs), settings=settings, to_exhaustion=True)

    t0 = time.perf_counter()
    seq = tune_fleet(jobs, _rngs(n_jobs), settings=settings,
                     to_exhaustion=True, engine="sequential")
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = tune_fleet(jobs, _rngs(n_jobs), settings=settings,
                     to_exhaustion=True)
    t_bat = time.perf_counter() - t0

    if check:
        for r_s, r_b in zip(seq, bat):
            assert r_s.trace.tried == r_b.trace.tried, "engines diverged"
            assert r_s.trace.stop_iteration == r_b.trace.stop_iteration
            assert r_s.trace.phase_boundary == r_b.trace.phase_boundary
    trials = sum(len(r.trace.tried) for r in bat)
    return {"sequential_s": t_seq, "batched_s": t_bat,
            "speedup": t_seq / t_bat, "total_trials": trials}


def service_fleet_spaces(
    jobs, n_jobs: int
) -> Tuple[List[SearchSpace], List[np.ndarray]]:
    """The priority-only service workload's (spaces, tables): recurring
    flat-memory jobs searched within their memory-derived priority groups
    (~10 configs each) — shared by workload B and the `--shards` sweep."""
    from repro.core.memory_model import MemoryCategory

    flat = [
        job for job in jobs
        if job.profile_result.model.category is MemoryCategory.FLAT
    ]
    if not flat:
        # Small --jobs prefixes of JOB_ORDER may hold no flat job; pull the
        # recurring flat specs from the catalog directly.
        flat = build_fleet(len(JOB_ORDER))
        flat = [
            job for job in flat
            if job.profile_result.model.category is MemoryCategory.FLAT
        ]
    spaces: List[SearchSpace] = []
    tables: List[np.ndarray] = []
    for i in range(n_jobs):
        job = flat[i % len(flat)]
        prio, _ = split_search_space(
            job.space, job.profile_result.model, job.full_input_size,
            per_node_overhead=job.per_node_overhead,
        )
        spaces.append(SearchSpace([job.space.configs[k] for k in prio]))
        tables.append(np.asarray(job.cost_table)[np.asarray(prio, np.int64)])
    return spaces, tables


def bench_priority_service(jobs, check: bool, settings: BOSettings,
                           n_jobs: int) -> dict:
    """Workload B: recurring jobs re-tuned within their priority group only.

    The service scenario: the recurring flat-memory jobs (terasort, join,
    Hadoop pagerank — the ETL-style workloads a cluster re-tunes routinely)
    searched inside their ~10-config priority groups, ``n_jobs`` runs total.
    Unclear jobs have no priority group and linear jobs' groups vary per
    job; the flat fleet is the uniform, dispatch-bound service case.
    """
    spaces, tables = service_fleet_spaces(jobs, n_jobs)

    cost_fns = [lambda i, t=t: float(t[i]) for t in tables]
    # Warm both paths, covering every distinct space shape the sequential
    # engine will compile for.
    seen = set()
    for space, fn in zip(spaces, cost_fns):
        if space.encoded().shape not in seen:
            seen.add(space.encoded().shape)
            cherrypick_search(space, fn, np.random.default_rng(0),
                              settings=settings, to_exhaustion=True)
    batched_search(spaces, tables, _rngs(n_jobs), settings=settings,
                   to_exhaustion=True)

    t0 = time.perf_counter()
    seq = [
        cherrypick_search(space, fn, rng, settings=settings,
                          to_exhaustion=True)
        for space, fn, rng in zip(spaces, cost_fns, _rngs(n_jobs))
    ]
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = batched_search(spaces, tables, _rngs(n_jobs), settings=settings,
                         to_exhaustion=True)
    t_bat = time.perf_counter() - t0

    if check:
        for j, ref in enumerate(seq):
            tr = bat.job_trace(j)
            assert tr.tried == ref.tried, "engines diverged"
            assert tr.stop_iteration == ref.stop_iteration
    trials = sum(len(t.tried) for t in seq)
    return {"sequential_s": t_seq, "batched_s": t_bat,
            "speedup": t_seq / t_bat, "total_trials": trials,
            "n_jobs": n_jobs,
            "mean_space": float(np.mean([len(s) for s in spaces]))}


def bench_sharded(
    spaces: Sequence[SearchSpace], tables: Sequence[np.ndarray],
    check: bool, settings: BOSettings, shards: Sequence[int],
    reps: int = 3, workload: str = "priority_service",
) -> dict:
    """The ``--shards`` axis: the batched engine with the job axis sharded
    across devices vs the single-device lockstep reference, same fleet.

    Best-of-``reps`` wall clock on both sides (this host wobbles ±2×, and
    the quantity of interest — dispatch+execute throughput at a fixed
    array program — is the minimum, not the mean).  Sharded traces are
    asserted bit-identical to the unsharded run when ``check``; shard
    counts above the visible device count are recorded as skipped rather
    than silently run unsharded.
    """
    n_jobs = len(tables)

    def run_once(shard):
        t0 = time.perf_counter()
        bt = batched_search(
            spaces, tables, _rngs(n_jobs), settings=settings,
            to_exhaustion=True, shard=shard,
        )
        return time.perf_counter() - t0, bt

    run_once(None)  # compile warm-up
    t_un = float("inf")
    for _ in range(reps):
        t, ref = run_once(None)
        t_un = min(t_un, t)

    rows = []
    for s in shards:
        if s < 2 or s > jax.device_count():
            rows.append({
                "shards": s, "skipped":
                f"{jax.device_count()} device(s) visible; want ≥ {max(s, 2)}",
            })
            continue
        run_once(s)  # compile warm-up for the sharded programs
        t_s = float("inf")
        for _ in range(reps):
            t, bt = run_once(s)
            t_s = min(t_s, t)
        identical = None  # null = unchecked (--no-check), like the sweep
        if check:
            identical = all(
                bt.job_trace(j).tried == ref.job_trace(j).tried
                and bt.job_trace(j).costs == ref.job_trace(j).costs
                and bt.job_trace(j).stop_iteration
                == ref.job_trace(j).stop_iteration
                for j in range(n_jobs)
            )
            assert identical, f"sharded (S={s}) traces diverged from lockstep"
        rows.append({
            "shards": s,
            "batched_s": t_s,
            "speedup_vs_unsharded": t_un / t_s,
            "traces_identical": identical,
        })
    return {
        "workload": workload,
        "n_jobs": n_jobs,
        "devices_visible": jax.device_count(),
        "reps_best_of": reps,
        "unsharded_s": t_un,
        "shards": rows,
    }


def _report_sharded(r: dict) -> None:
    print(f"  --shards axis ({r['workload']}, {r['n_jobs']} jobs, "
          f"{r['devices_visible']} devices, best of {r['reps_best_of']}): "
          f"unsharded {r['unsharded_s']:.3f} s")
    for row in r["shards"]:
        if "skipped" in row:
            print(f"    S={row['shards']}: skipped ({row['skipped']})")
        else:
            print(f"    S={row['shards']}: {row['batched_s']:.3f} s  "
                  f"({row['speedup_vs_unsharded']:.2f}x vs unsharded, "
                  f"traces {'identical' if row['traces_identical'] else 'UNCHECKED'})")


def _report(tag: str, r: dict) -> None:
    print(f"  {tag}")
    print(f"    sequential engine : {r['sequential_s']:7.2f} s  "
          f"({1e3 * r['sequential_s'] / r['total_trials']:.2f} ms/trial)")
    print(f"    batched engine    : {r['batched_s']:7.2f} s  "
          f"({1e3 * r['batched_s'] / r['total_trials']:.2f} ms/trial)")
    print(f"    speedup           : {r['speedup']:7.2f}x")


def _report_session(r: dict) -> None:
    print(f"  D. streaming session ({r['n_jobs']} jobs in {r['waves']} waves,"
          f" {r['warm_jobs']} warm-started, "
          f"{r['profile_cache_hits']}/{r['profile_cache_hits'] + r['profile_cache_misses']}"
          f" probe-cache hits)")
    red = r["fresh_trial_reduction"]
    print(f"    fresh trials to convergence: cold "
          f"{r['cold_mean_fresh_trials']:.1f} vs warm "
          f"{r['warm_mean_fresh_trials']:.1f} "
          f"({f'{red:.1f}x fewer' if red is not None else 'fully amortized'})")
    print(f"    end-to-end: {r['session_s']:.2f} s "
          f"({r['warm_seeded_trials']} trials seeded from class history)")


def run(n_jobs: int = 64, check: bool = True,
        settings: BOSettings = BOSettings(), *, smoke: bool = False,
        scaling_ns: Sequence[int] = (69, 256, 512, 1024, 8192, 32768),
        scaling_step_only_ns: Sequence[int] = (131072,),
        budget: int = 24, json_path: Optional[str] = None,
        session_only: bool = False, shards: Sequence[int] = (2,)) -> dict:
    # The repo-root BENCH_fleet.json is the committed perf baseline; only
    # the full default protocol (64 jobs, full sweep) may rewrite it —
    # smoke or reduced-job runs would replace it with non-comparable
    # numbers.  Pass json_path explicitly to write elsewhere.
    if json_path is None and not smoke and n_jobs == 64:
        json_path = BENCH_JSON
    packed_reps, dense_reps = 20, 2
    if smoke:
        # Seconds-scale wiring check: tiny fleet, one small sweep point
        # plus the n=32768 feature-buffer point (seconds — nothing of
        # extent n² exists on that path), no cluster workloads (their
        # profiling + jit warm dominates).
        n_jobs = min(n_jobs, 8)
        scaling_ns = (64, 32768)
        scaling_step_only_ns = ()
        budget = 8
        packed_reps, dense_reps = 5, 1

    print(f"\n== Fleet bench: {n_jobs} jobs, traces "
          f"{'verified identical' if check else 'unchecked'}"
          f"{', SMOKE mode' if smoke else ''}"
          f"{', SESSION scenario only' if session_only else ''} ==")

    if session_only:
        d = bench_session_streaming(n_jobs, waves=8, check=check)
        _report_session(d)
        return {"n_jobs": n_jobs, "smoke": False,
                "session_streaming": d}

    donation = check_buffer_donation()
    print("  donation: lockstep state buffers consumed in place "
          f"({', '.join(donation['buffers_checked'])})")

    c = bench_scaling(scaling_ns, n_jobs, budget, check,
                      packed_reps=packed_reps, dense_reps=dense_reps,
                      step_only_ns=scaling_step_only_ns)

    out = {"n_jobs": n_jobs, "traces_identical": bool(check),
           "smoke": bool(smoke), "donation": donation, "scaling": c,
           "peak_rss_mb": _peak_rss_mb()}
    print(f"  peak RSS over the whole run: {out['peak_rss_mb']:.0f} MB")

    if smoke:
        # Sharded-lane wiring check: an 8-job synthetic service-like fleet
        # (10-config spaces, exhaustion) across the requested shard counts,
        # traces verified against the lockstep reference.
        sp_s, tb_s = synthetic_space(10)
        sh = bench_sharded([sp_s] * 8, [tb_s] * 8, check, BOSettings(),
                           shards, reps=2, workload="synthetic_service")
        _report_sharded(sh)
        out["sharding"] = sh
        # Streaming-session wiring check: 16 recurring jobs in 4 waves at a
        # reduced trial budget (small packed capacity → seconds of compile);
        # the warm-vs-cold convergence assertion still runs.
        d = bench_session_streaming(
            16, waves=4, check=check, settings=BOSettings(max_iters=16),
        )
        _report_session(d)
        out["session_streaming"] = d
        # Adversarial-fleet wiring check: 16 disturbed jobs, no broken one
        # (the permanent-failure path is tier-1 chaos-tested; at this fleet
        # size one broken job would drag completion below the ≥95% bar the
        # full protocol is held to).
        adv = bench_adversarial(
            16, check, BOSettings(max_iters=16), permanent_jobs=0,
        )
        _report_adversarial(adv)
        out["adversarial"] = adv
        # Open-loop wiring check: 12 Poisson arrivals over the three
        # admission groups — big enough for every group to live, small
        # enough to stay seconds-scale; the ≥1.1x smoke floor still holds
        # because straggler stalls dominate both drivers' wall clock.
        g = bench_open_loop(12, check, smoke=True)
        _report_open_loop(g)
        out["open_loop"] = g
        # Cost-aware pricing wiring check: one spot epoch (3 priced jobs x
        # 2 objectives) at the smoke trial budget; the table-level
        # repricing-movement and family scenarios always run in full
        # (they are argmin sweeps, not searches).
        h = bench_pricing(check, BOSettings(max_iters=16), smoke=True)
        _report_pricing(h)
        out["pricing"] = h

    if not smoke:
        jobs = build_fleet(n_jobs)
        b = bench_priority_service(jobs, check, settings, n_jobs)
        _report(f"B. priority-only service fleet ({b['n_jobs']} recurring jobs,"
                f" ~{b['mean_space']:.0f}-config spaces, {b['total_trials']} trials)", b)
        # The --shards axis on the same service fleet: the 64-job
        # dispatch-bound workload is exactly where job-axis sharding must
        # pay (target: ≥ 1.5× at 2 shards on the 2-core container).
        sp_b, tb_b = service_fleet_spaces(jobs, n_jobs)
        sh = bench_sharded(sp_b, tb_b, check, settings, shards)
        _report_sharded(sh)
        out["sharding"] = sh
        a = bench_paper_replay(jobs, check, settings)
        _report(f"A. paper replay, two-phase over 69 configs "
                f"({a['total_trials']} trials)", a)
        print("    (A runs to exhaustion, so its packed capacity equals the"
              " space extent\n     — the dense-regime floor; the scaling sweep"
              " C is the budgeted B << n\n     regime the packed engine"
              " targets.)")
        # Workload D: the full streaming scenario — 64 jobs in 8 waves of
        # the recurring paper workloads, natural EI stopping (the packed
        # capacity matches workload A's, so the lockstep compile is shared).
        d = bench_session_streaming(n_jobs, waves=8, check=check)
        _report_session(d)
        # Workload F: the same fleet size under the adversarial schedule,
        # including one permanently broken job.
        adv = bench_adversarial(n_jobs, check, settings)
        _report_adversarial(adv)
        # Workload G: the open-loop Poisson fleet, async service vs
        # lockstep session under straggler injection (≥1.3x floor).
        g = bench_open_loop(n_jobs, check)
        _report_open_loop(g)
        # Workload H: cost-aware tuning over the pricing catalogs — all
        # spot epochs, both objectives, Pareto invariants asserted.
        h = bench_pricing(check, settings)
        _report_pricing(h)
        out.update({"paper_replay": a, "priority_service": b,
                    "session_streaming": d, "adversarial": adv,
                    "open_loop": g, "pricing": h})
        with open(artifact_path("fleet", f"fleet_bench_{n_jobs}.json"), "w") as f:
            json.dump(out, f, indent=1)

    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"  wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=64)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the trace-equivalence assertion")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale wiring check (tiny fleet, two sweep points)")
    ap.add_argument("--session", action="store_true",
                    help="run ONLY the streaming TuningSession scenario "
                         "(jobs arriving in 8 waves, warm-start amortization)")
    ap.add_argument("--shards", type=int, nargs="*", default=[2],
                    help="shard counts for the job-axis sharding sweep on "
                         "the service fleet (default: 2)")
    args = ap.parse_args()
    run(args.jobs, check=not args.no_check, smoke=args.smoke,
        session_only=args.session, shards=tuple(args.shards))
