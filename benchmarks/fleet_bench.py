"""Fleet-scale search benchmark: batched engine vs sequential loop.

Two 64-job fleet workloads, both replayed through both engines:

  A. **Paper replay** — the 16 evaluation jobs × 4 seeds, full two-phase
     Ruya search over the 69-config space, to exhaustion (the Table II
     protocol as a fleet).
  B. **Priority-only service fleet** — 64 runs of the recurring flat-memory
     jobs (terasort, join, Hadoop pagerank) tuned *within their
     memory-derived priority group only* (10 configs each).  This is the
     paper's own observation (the optimum lands in the priority group for
     every categorized job) run the way Blink-style systems run tuning:
     small spaces, cheap trials, as a routine re-tuning service.

Engines:

  * sequential — the per-job engine (`repro.core.bayesopt`), one
    Python-driven jitted BO step per trial: dispatch + host sync per step;
  * batched — `repro.fleet` advances all jobs in device-resident lockstep
    chunks, one jitted call per *fleet* iteration.

Both engines produce identical traces (asserted here and exhaustively in
`tests/test_fleet.py`), so the comparison is pure execution efficiency.
Profiling runs once per distinct job up front and is shared; jit is warmed
before timing.

On a small-core CPU host the full 69-config workload (A) is bound by the
18-point hyperparameter-grid Cholesky sweep.  Both engines run the same
compiled sweep per trial — the sequential engine runs it at batch extent 2
with a duplicated row (the price of bit-identical traces; see `fast_bo`),
so roughly half its measured advantage there is that probe tax and half is
dispatch/loop overhead.  The service workload (B) is dispatch-bound, where
batching pays off in full (≥5×).  On accelerator-backed or many-core
hosts, A moves toward B's regime.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--jobs 64] [--no-check]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List, Sequence

import numpy as np

from benchmarks.common import JOB_ORDER, artifact_path
from repro.core.bayesopt import BOSettings, cherrypick_search
from repro.core.profiler import profile_job
from repro.core.search_space import SearchSpace, split_search_space
from repro.fleet import batched_search, cluster_fleet, tune_fleet


def build_fleet(n_jobs: int):
    keys = [JOB_ORDER[i % len(JOB_ORDER)] for i in range(n_jobs)]
    jobs = cluster_fleet(keys)
    # Profile once per distinct job up front: the bench times the *search*
    # engines, and both must see identical splits.
    profiles = {}
    for job in jobs:
        if job.name not in profiles:
            profiles[job.name] = profile_job(job.profile_run, job.full_input_size)
        job.profile_result = profiles[job.name]
    return jobs


def _rngs(n: int) -> List[np.random.Generator]:
    return [np.random.default_rng(1000 + i) for i in range(n)]


def bench_paper_replay(jobs, check: bool, settings: BOSettings) -> dict:
    """Workload A: full two-phase Ruya search over the 69-config space."""
    n_jobs = len(jobs)
    warm = jobs[: min(2, n_jobs)]
    tune_fleet(warm, _rngs(len(warm)), settings=settings, to_exhaustion=True,
               engine="sequential")
    tune_fleet(jobs, _rngs(n_jobs), settings=settings, to_exhaustion=True)

    t0 = time.perf_counter()
    seq = tune_fleet(jobs, _rngs(n_jobs), settings=settings,
                     to_exhaustion=True, engine="sequential")
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = tune_fleet(jobs, _rngs(n_jobs), settings=settings,
                     to_exhaustion=True)
    t_bat = time.perf_counter() - t0

    if check:
        for r_s, r_b in zip(seq, bat):
            assert r_s.trace.tried == r_b.trace.tried, "engines diverged"
            assert r_s.trace.stop_iteration == r_b.trace.stop_iteration
            assert r_s.trace.phase_boundary == r_b.trace.phase_boundary
    trials = sum(len(r.trace.tried) for r in bat)
    return {"sequential_s": t_seq, "batched_s": t_bat,
            "speedup": t_seq / t_bat, "total_trials": trials}


def bench_priority_service(jobs, check: bool, settings: BOSettings,
                           n_jobs: int) -> dict:
    """Workload B: recurring jobs re-tuned within their priority group only.

    The service scenario: the recurring flat-memory jobs (terasort, join,
    Hadoop pagerank — the ETL-style workloads a cluster re-tunes routinely)
    searched inside their ~10-config priority groups, ``n_jobs`` runs total.
    Unclear jobs have no priority group and linear jobs' groups vary per
    job; the flat fleet is the uniform, dispatch-bound service case.
    """
    from repro.core.memory_model import MemoryCategory

    flat = [
        job for job in jobs
        if job.profile_result.model.category is MemoryCategory.FLAT
    ]
    if not flat:
        # Small --jobs prefixes of JOB_ORDER may hold no flat job; pull the
        # recurring flat specs from the catalog directly.
        flat = build_fleet(len(JOB_ORDER))
        flat = [
            job for job in flat
            if job.profile_result.model.category is MemoryCategory.FLAT
        ]
    spaces: List[SearchSpace] = []
    tables: List[np.ndarray] = []
    for i in range(n_jobs):
        job = flat[i % len(flat)]
        prio, _ = split_search_space(
            job.space, job.profile_result.model, job.full_input_size,
            per_node_overhead=job.per_node_overhead,
        )
        spaces.append(SearchSpace([job.space.configs[k] for k in prio]))
        tables.append(np.asarray(job.cost_table)[np.asarray(prio, np.int64)])

    cost_fns = [lambda i, t=t: float(t[i]) for t in tables]
    # Warm both paths, covering every distinct space shape the sequential
    # engine will compile for.
    seen = set()
    for space, fn in zip(spaces, cost_fns):
        if space.encoded().shape not in seen:
            seen.add(space.encoded().shape)
            cherrypick_search(space, fn, np.random.default_rng(0),
                              settings=settings, to_exhaustion=True)
    batched_search(spaces, tables, _rngs(n_jobs), settings=settings,
                   to_exhaustion=True)

    t0 = time.perf_counter()
    seq = [
        cherrypick_search(space, fn, rng, settings=settings,
                          to_exhaustion=True)
        for space, fn, rng in zip(spaces, cost_fns, _rngs(n_jobs))
    ]
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = batched_search(spaces, tables, _rngs(n_jobs), settings=settings,
                         to_exhaustion=True)
    t_bat = time.perf_counter() - t0

    if check:
        for j, ref in enumerate(seq):
            tr = bat.job_trace(j)
            assert tr.tried == ref.tried, "engines diverged"
            assert tr.stop_iteration == ref.stop_iteration
    trials = sum(len(t.tried) for t in seq)
    return {"sequential_s": t_seq, "batched_s": t_bat,
            "speedup": t_seq / t_bat, "total_trials": trials,
            "n_jobs": n_jobs,
            "mean_space": float(np.mean([len(s) for s in spaces]))}


def _report(tag: str, r: dict) -> None:
    print(f"  {tag}")
    print(f"    sequential engine : {r['sequential_s']:7.2f} s  "
          f"({1e3 * r['sequential_s'] / r['total_trials']:.2f} ms/trial)")
    print(f"    batched engine    : {r['batched_s']:7.2f} s  "
          f"({1e3 * r['batched_s'] / r['total_trials']:.2f} ms/trial)")
    print(f"    speedup           : {r['speedup']:7.2f}x")


def run(n_jobs: int = 64, check: bool = True,
        settings: BOSettings = BOSettings()) -> dict:
    jobs = build_fleet(n_jobs)
    print(f"\n== Fleet bench: {n_jobs} jobs, traces "
          f"{'verified identical' if check else 'unchecked'} ==")

    b = bench_priority_service(jobs, check, settings, n_jobs)
    _report(f"B. priority-only service fleet ({b['n_jobs']} recurring jobs,"
            f" ~{b['mean_space']:.0f}-config spaces, {b['total_trials']} trials)", b)
    a = bench_paper_replay(jobs, check, settings)
    _report(f"A. paper replay, two-phase over 69 configs "
            f"({a['total_trials']} trials)", a)
    print("    (A is bound by the 18-point GP-grid Cholesky sweep; the"
          " sequential\n     engine also pays a 2x extent-2 probe tax — the"
          " price of bit-identical\n     traces.  B is dispatch-bound, where"
          " batching pays off in full.)")

    out = {"n_jobs": n_jobs, "traces_identical": bool(check),
           "paper_replay": a, "priority_service": b}
    with open(artifact_path("fleet", f"fleet_bench_{n_jobs}.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=64)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the trace-equivalence assertion")
    args = ap.parse_args()
    run(args.jobs, check=not args.no_check)
