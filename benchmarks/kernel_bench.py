"""Kernel microbenchmarks: name,us_per_call,derived CSV.

On this CPU container the Pallas kernels execute in interpret mode (Python —
not a performance path), so wall-clock here times the **XLA oracle path**
the models actually run on CPU, and `derived` reports the kernel's
analytic arithmetic intensity (FLOPs/byte) — the quantity that determines
its TPU roofline position.  The interpret-mode kernels are also run once
for a correctness spot-check.
"""

from __future__ import annotations

import csv
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import artifact_path


def time_call(fn, *args, iters: int = 10) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def flash_cases():
    from repro.kernels.flash_attention import ops, ref

    for (b, t, h, kv, d) in [(1, 512, 8, 8, 64), (1, 1024, 8, 2, 128),
                             (4, 512, 16, 4, 64)]:
        ks = jax.random.split(jax.random.key(t + d), 3)
        q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, t, kv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, t, kv, d), jnp.float32)
        fn = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
        us = time_call(fn, q, k, v)
        flops = 4.0 * b * h * t * t * d / 2  # causal half
        bytes_ = (q.size + k.size + v.size) * 4 + q.size * 4
        # interpret-mode spot check
        out_k = ops.flash_attention(q[:, :128], k[:, :128], v[:, :128],
                                    True, None, 128, 128, True)
        out_r = ref.attention_ref(q[:, :128], k[:, :128], v[:, :128],
                                  causal=True)
        err = float(jnp.max(jnp.abs(out_k - out_r)))
        assert err < 1e-4, err
        yield {
            "name": f"flash_attention_b{b}_t{t}_h{h}_kv{kv}_d{d}",
            "us_per_call": round(us, 1),
            "derived": f"AI={flops/bytes_:.1f}flops/B",
        }


def rmsnorm_cases():
    from repro.kernels.rmsnorm import ops, ref

    for (rows, d) in [(4096, 1024), (16384, 4096)]:
        x = jax.random.normal(jax.random.key(0), (rows, d), jnp.float32)
        s = jnp.ones((d,), jnp.float32)
        fn = jax.jit(lambda x, s: ref.rmsnorm_ref(x, s))
        us = time_call(fn, x, s)
        bytes_ = x.size * 4 * 2
        out_k = ops.rmsnorm(x[:256], s, 1e-6, 256, True)
        assert float(jnp.max(jnp.abs(out_k - ref.rmsnorm_ref(x[:256], s)))) < 1e-4
        yield {
            "name": f"rmsnorm_{rows}x{d}",
            "us_per_call": round(us, 1),
            "derived": f"GB_touched={bytes_/1e9:.3f}",
        }


def ssd_cases():
    from repro.kernels.ssd import ops, ref

    for (b, nc, q, h, p, n) in [(1, 8, 256, 8, 64, 64), (2, 16, 256, 4, 64, 128)]:
        ks = jax.random.split(jax.random.key(q * h), 5)
        x = jax.random.normal(ks[0], (b, nc, q, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, q, h)))
        lA = -jax.nn.softplus(jax.random.normal(ks[2], (b, nc, q, h)))
        B_ = jax.random.normal(ks[3], (b, nc, q, h, n))
        C_ = jax.random.normal(ks[4], (b, nc, q, h, n))
        fn = jax.jit(ref.ssd_diag_ref)
        us = time_call(fn, x, dt, lA, B_, C_)
        flops = 2.0 * b * nc * h * (q * q * n + q * q * p)
        small = tuple(a[:1, :1] for a in (x, dt, lA, B_, C_))
        err = float(jnp.max(jnp.abs(
            ops.ssd_diag_chunk(*small, True) - ref.ssd_diag_ref(*small))))
        assert err < 1e-3, err
        yield {
            "name": f"ssd_diag_b{b}_nc{nc}_q{q}_h{h}_p{p}_n{n}",
            "us_per_call": round(us, 1),
            "derived": f"GFLOP={flops/1e9:.2f}",
        }


def run() -> dict:
    rows = list(flash_cases()) + list(rmsnorm_cases()) + list(ssd_cases())
    path = artifact_path("kernels", "kernel_bench.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=["name", "us_per_call", "derived"])
        w.writeheader()
        w.writerows(rows)
    print("\n== Kernel microbench (XLA oracle wall-time on CPU; Pallas "
          "kernels validated in interpret mode) ==")
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    return {"rows": rows, "csv": path}


if __name__ == "__main__":
    run()
