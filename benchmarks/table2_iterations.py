"""Table II: iterations to find configurations with normalized cost
c ≤ 1.2 / ≤ 1.1 / = 1.0 — CherryPick vs Ruya, plus the quotient row.

The paper's headline: mean quotient ≈ 37.9 % / 40.2 % / 49.2 %.
"""

from __future__ import annotations

import csv

import numpy as np

from benchmarks.common import (
    DEFAULT_REPS,
    JOB_ORDER,
    artifact_path,
    mean_iterations_until,
    search_traces,
)

THRESHOLDS = (1.2, 1.1, 1.0)

# Paper Table II mean row, for validation banding.
PAPER_MEAN = {1.2: (8.735, 3.307), 1.1: (16.487, 6.627), 1.0: (23.629, 11.631)}
PAPER_QUOTIENT = {1.2: 0.379, 1.1: 0.402, 1.0: 0.492}


def run(reps: int = DEFAULT_REPS) -> dict:
    rows = []
    for key in JOB_ORDER:
        ruya, cp, prof = search_traces(key, reps=reps)
        row = {"job": key, "category": prof.model.category.value}
        for th in THRESHOLDS:
            row[f"cp_{th}"] = round(mean_iterations_until(cp, th), 3)
            row[f"ruya_{th}"] = round(mean_iterations_until(ruya, th), 3)
            row[f"quot_{th}"] = round(row[f"ruya_{th}"] / row[f"cp_{th}"], 3)
        rows.append(row)
        print(f"  {key:28s} ({row['category']:7s}) "
              + " ".join(f"c≤{th}: {row[f'ruya_{th}']:6.2f}/"
                         f"{row[f'cp_{th}']:6.2f}={row[f'quot_{th}']*100:5.1f}%"
                         for th in THRESHOLDS))

    mean_row = {"job": "MEAN", "category": ""}
    for th in THRESHOLDS:
        cp_m = float(np.mean([r[f"cp_{th}"] for r in rows]))
        ru_m = float(np.mean([r[f"ruya_{th}"] for r in rows]))
        mean_row[f"cp_{th}"] = round(cp_m, 3)
        mean_row[f"ruya_{th}"] = round(ru_m, 3)
        mean_row[f"quot_{th}"] = round(ru_m / cp_m, 3)
    rows.append(mean_row)

    path = artifact_path("paper", "table2.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)

    print(f"\n== Table II mean (reps={reps}) ==")
    for th in THRESHOLDS:
        q = mean_row[f"quot_{th}"]
        print(f"  c≤{th}: Ruya {mean_row[f'ruya_{th}']:6.2f} vs CherryPick "
              f"{mean_row[f'cp_{th}']:6.2f} → quotient {q*100:5.1f}% "
              f"(paper: {PAPER_QUOTIENT[th]*100:.1f}%)")
    return {"rows": rows, "mean": mean_row, "csv": path}


if __name__ == "__main__":
    run()
