"""Table III: memory-profiling time per job (emulated single-machine runs).

Paper: 2–22 minutes per job, mean 565 s, median < 8 min.
"""

from __future__ import annotations

import csv

import numpy as np

from benchmarks.common import JOB_ORDER, artifact_path, job_profile

PAPER_MEAN_S = 565.0


def run() -> dict:
    rows = []
    for key in JOB_ORDER:
        # Shared fleet-job pool: the same ProfileResult the fleet replays
        # (search_traces) and Table I read — profiled once per process.
        prof = job_profile(key)
        rows.append({
            "job": key,
            "time_s": round(prof.total_time_s, 1),
            "calibration_runs": prof.calibration_runs,
            "samples": len(prof.sizes),
        })
    times = [r["time_s"] for r in rows]
    summary = {
        "mean_s": float(np.mean(times)),
        "median_s": float(np.median(times)),
        "min_s": float(np.min(times)),
        "max_s": float(np.max(times)),
    }

    path = artifact_path("paper", "table3.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)

    print("\n== Table III: profiling time ==")
    for r in rows:
        print(f"  {r['job']:28s} {r['time_s']:7.1f}s")
    print(f"  mean {summary['mean_s']:.0f}s (paper {PAPER_MEAN_S:.0f}s), "
          f"median {summary['median_s']:.0f}s, "
          f"range [{summary['min_s']:.0f}, {summary['max_s']:.0f}]s")
    return {"rows": rows, "summary": summary, "csv": path}


if __name__ == "__main__":
    run()
