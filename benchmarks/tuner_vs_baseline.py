"""Beyond-paper: the Ruya tuner on the TPU execution-configuration space.

Compares memory-aware two-phase BO (Ruya) against plain BO (CherryPick) in
*trials to find the best execution configuration* for one (arch × cell) on
the production mesh — each trial being an AOT compile + roofline estimate
(expensive at ~10–20 s each, just like a short profiled run at scale).

The trial costs are computed once (exhaustively) into a cached table; the
searcher comparison then replays against the cache across many seeds, the
same protocol as the paper's Table II.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import artifact_path

# Nominal accelerator price for the dollar-denominated savings line: what
# the trial time the tuner avoids would have billed on the 256-chip mesh.
# A bookkeeping constant (public cloud accelerator-hours are ~$1-2/chip-h),
# not a measurement — the trials-saved quotient is the real result.
USD_PER_CHIP_HOUR = 1.20
TRIAL_CHIPS = 256


def run(arch: str = "granite-8b", cell: str = "train_4k", seeds: int = 25) -> dict:
    """Driver entry: the tuner needs 512 placeholder devices, but the
    benchmark driver's process may already hold a 1-device jax — always run
    the real work in a subprocess with its own XLA_FLAGS."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.tuner_vs_baseline",
         "--arch", arch, "--cell", cell, "--seeds", str(seeds)],
        capture_output=True, text=True, env=env,
    )
    print(proc.stdout, end="")
    if proc.returncode != 0:
        raise RuntimeError(f"tuner subprocess failed:\n{proc.stderr[-2000:]}")
    with open(artifact_path("autotune", f"{arch}__{cell}__compare.json")) as f:
        return json.load(f)


def _run_inprocess(arch: str = "granite-8b", cell: str = "train_4k",
                   seeds: int = 25) -> dict:
    # Import inside: sets XLA device-count flag for the compile trials.
    from repro.launch.autotune import (
        HBM_PER_CHIP,
        TpuTunerEnv,
        predict_peaks,
    )
    from repro.fleet import batched_search

    cache = artifact_path("autotune", f"{arch}__{cell}__trials.json")
    env = TpuTunerEnv(arch, cell, cache_path=cache)
    space, sspace = env.search_space()
    cost_fn = env.trial_cost_fn(space)

    # Fill the trial table exhaustively (cached across runs).
    print(f"\n== Tuner-vs-baseline: {arch} × {cell} "
          f"({len(space)} exec configs) ==")
    missing = [i for i, v in enumerate(space) if v.name not in env.trial_cache]
    if missing:
        print(f"  compiling {len(missing)} uncached trial configs "
              f"(~15 s each) ...")
    costs = np.array([cost_fn(i) for i in range(len(space))])
    best_cost = costs.min()
    print(f"  best config: {space[int(np.argmin(costs))].name} "
          f"(roofline {best_cost:.2f} chip-s/step); worst {costs.max():.2f}")

    # Ruya phase-1/2: memory profiling + prediction (cached too).
    pred_cache = artifact_path("autotune", f"{arch}__{cell}__peaks.json")
    if os.path.exists(pred_cache):
        with open(pred_cache) as f:
            preds = json.load(f)
    else:
        preds, _ = predict_peaks(env, space)
        with open(pred_cache, "w") as f:
            json.dump(preds, f, indent=1)
    prio = [i for i, v in enumerate(space)
            if preds[v.name] <= HBM_PER_CHIP * 1.05]
    rest = sorted(set(range(len(space))) - set(prio))
    print(f"  priority group: {len(prio)}/{len(space)} configs predicted to fit")

    # Both searchers across all seeds run as seed-fleets on the batched
    # engine — trace-identical to sequential ruya_search/cherrypick_search.
    thresh = best_cost * 1.001
    bt_r = batched_search(
        sspace, [costs] * seeds,
        [np.random.default_rng(seed) for seed in range(seeds)],
        priority=[list(prio)] * seeds, remaining=[list(rest)] * seeds,
        to_exhaustion=True,
    )
    bt_c = batched_search(
        sspace, [costs] * seeds,
        [np.random.default_rng(seed) for seed in range(seeds)],
        to_exhaustion=True,
    )
    ruya_iters = [bt_r.job_trace(s).iterations_until(thresh) for s in range(seeds)]
    cp_iters = [bt_c.job_trace(s).iterations_until(thresh) for s in range(seeds)]

    r_m, c_m = float(np.mean(ruya_iters)), float(np.mean(cp_iters))
    quot = r_m / c_m
    print(f"  trials-to-best: Ruya {r_m:.2f} vs plain BO {c_m:.2f} "
          f"→ quotient {quot*100:.1f}%  ({seeds} seeds)")
    chip_s_saved = (c_m - r_m) * 15.0  # ~15 s of 256-chip compile+profile
    usd_saved = chip_s_saved * TRIAL_CHIPS / 3600.0 * USD_PER_CHIP_HOUR
    print(f"  ≈ {chip_s_saved:.0f} wall-s of trial time saved per tuning run "
          f"(× {TRIAL_CHIPS} chips when trials are real profiled runs; "
          f"≈ ${usd_saved:.2f} at ${USD_PER_CHIP_HOUR:.2f}/chip-h)")

    out = {
        "arch": arch, "cell": cell,
        "configs": len(space),
        "priority": len(prio),
        "ruya_trials": r_m,
        "baseline_trials": c_m,
        "quotient": quot,
        "best_config": space[int(np.argmin(costs))].name,
        "best_cost_chip_s": float(best_cost),
        "trial_wall_s_saved": float(chip_s_saved),
        "usd_saved_per_tuning_run": float(usd_saved),
        "usd_per_chip_hour": USD_PER_CHIP_HOUR,
    }
    with open(artifact_path("autotune", f"{arch}__{cell}__compare.json"),
              "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    import argparse

    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
    )
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--cell", default="train_4k")
    ap.add_argument("--seeds", type=int, default=25)
    args = ap.parse_args()
    _run_inprocess(args.arch, args.cell, args.seeds)
