"""Shared benchmark plumbing: artifact paths, cluster-sim evaluation loops."""

from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.cluster import JOBS, ClusterSimulator
from repro.core import BOSettings, profile_job
from repro.fleet import replay_seeds, tune_fleet
from repro.fleet.driver import FleetJob

GiB = 1024**3
ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")

# Paper §IV-C: averaged over 200 repetitions.  The bench default keeps the
# full sweep under a few minutes; set RUYA_BENCH_REPS=200 for paper parity
# (means are stable well below 50 reps — see EXPERIMENTS.md).
DEFAULT_REPS = int(os.environ.get("RUYA_BENCH_REPS", "50"))

JOB_ORDER = [  # Table II row order
    "naivebayes/spark/bigdata",
    "naivebayes/spark/huge",
    "kmeans/spark/bigdata",
    "kmeans/spark/huge",
    "pagerank/spark/bigdata",
    "pagerank/spark/huge",
    "linregr/spark/bigdata",
    "linregr/spark/huge",
    "logregr/spark/bigdata",
    "logregr/spark/huge",
    "join/spark/bigdata",
    "join/spark/huge",
    "pagerank/hadoop/bigdata",
    "pagerank/hadoop/huge",
    "terasort/hadoop/bigdata",
    "terasort/hadoop/huge",
]


def artifact_path(*parts: str) -> str:
    path = os.path.join(ARTIFACTS, *parts)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    return path


def profile_once(sim: ClusterSimulator):
    return profile_job(sim.profile_run_fn(), sim.job.input_gb * GiB)


_TRACE_MEMO: Dict = {}


def search_traces(
    key: str,
    reps: int = DEFAULT_REPS,
    max_iters: Optional[int] = None,
) -> Tuple[List, List, object]:
    """Run Ruya + CherryPick ``reps`` times (to exhaustion) on one job.

    Returns (ruya_traces, cherrypick_traces, profile_result).  The profile
    is computed once and reused — the paper's §IV-D economics.  Memoized so
    Table II / Fig. 4 / Fig. 5 share one sweep.

    The repetitions run as a seed-fleet through the batched engine (one
    jitted call per searcher instead of ``reps`` Python-driven searches);
    traces are identical to the sequential engine's, so every downstream
    number is unchanged.
    """
    memo_key = (key, reps, max_iters)
    if memo_key in _TRACE_MEMO:
        return _TRACE_MEMO[memo_key]
    sim = ClusterSimulator.for_job(key)
    prof = profile_once(sim)
    settings = BOSettings(max_iters=max_iters)
    job = FleetJob(
        name=key,
        space=sim.space,
        cost_table=sim.normalized,
        full_input_size=sim.job.input_gb * GiB,
        profile_result=prof,
        per_node_overhead=0.5 * GiB,
    )
    jobs, rngs = replay_seeds(job, range(reps))
    ruya_traces = [
        r.trace
        for r in tune_fleet(
            jobs, rngs, settings=settings, to_exhaustion=True
        )
    ]
    cp_traces = [
        r.trace
        for r in tune_fleet(
            jobs,
            [np.random.default_rng(s) for s in range(reps)],
            mode="cherrypick",
            settings=settings,
            to_exhaustion=True,
        )
    ]
    _TRACE_MEMO[memo_key] = (ruya_traces, cp_traces, prof)
    return _TRACE_MEMO[memo_key]


def mean_iterations_until(traces, threshold: float) -> float:
    vals = []
    for t in traces:
        it = t.iterations_until(threshold)
        vals.append(it if it is not None else len(t.tried) + 1)
    return float(np.mean(vals))


def best_cost_curve(traces, horizon: int = 69) -> np.ndarray:
    """Mean over traces of min-cost-so-far at each iteration (Fig. 4)."""
    curves = []
    for t in traces:
        costs = np.asarray(t.costs, np.float64)
        best = np.minimum.accumulate(costs)
        if len(best) < horizon:
            best = np.concatenate(
                [best, np.full(horizon - len(best), best[-1])]
            )
        curves.append(best[:horizon])
    return np.mean(curves, axis=0)
