"""Shared benchmark plumbing: artifact paths, the memoized fleet-job pool,
and the fleet-replay evaluation loops.

Every paper table/figure consumes the cluster emulation through ONE pool of
memoized `FleetJob`s (`fleet_job` / `job_profile` / `get_sim`): each of the
16 workloads is instantiated and profiled exactly once per process, no
matter how many suites ask for it, and all search replays run through the
fleet subsystem (`repro.fleet.tune_fleet`) — there is no per-benchmark
sequential profiling/search loop left anywhere under `benchmarks/`.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.cluster import ClusterSimulator
from repro.core import BOSettings, profile_job
from repro.fleet import cluster_fleet, replay_seeds, tune_fleet
from repro.fleet.driver import FleetJob

GiB = 1024**3
ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")

# Paper §IV-C: averaged over 200 repetitions.  The bench default keeps the
# full sweep under a few minutes; set RUYA_BENCH_REPS=200 for paper parity
# (means are stable well below 50 reps — see EXPERIMENTS.md).
DEFAULT_REPS = int(os.environ.get("RUYA_BENCH_REPS", "50"))

JOB_ORDER = [  # Table II row order
    "naivebayes/spark/bigdata",
    "naivebayes/spark/huge",
    "kmeans/spark/bigdata",
    "kmeans/spark/huge",
    "pagerank/spark/bigdata",
    "pagerank/spark/huge",
    "linregr/spark/bigdata",
    "linregr/spark/huge",
    "logregr/spark/bigdata",
    "logregr/spark/huge",
    "join/spark/bigdata",
    "join/spark/huge",
    "pagerank/hadoop/bigdata",
    "pagerank/hadoop/huge",
    "terasort/hadoop/bigdata",
    "terasort/hadoop/huge",
]


def artifact_path(*parts: str) -> str:
    path = os.path.join(ARTIFACTS, *parts)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    return path


_SIM_MEMO: Dict[str, ClusterSimulator] = {}
_JOB_MEMO: Dict[str, FleetJob] = {}


def get_sim(key: str) -> ClusterSimulator:
    """Memoized cluster emulator for one paper workload."""
    if key not in _SIM_MEMO:
        _SIM_MEMO[key] = ClusterSimulator.for_job(key)
    return _SIM_MEMO[key]


def fleet_job(key: str) -> FleetJob:
    """Memoized, profiled `FleetJob` for one paper workload.

    The single entry point every benchmark shares: the job is built through
    the fleet subsystem (`cluster_fleet`, fed the memoized simulator so the
    workload is instantiated once) and its profiling run happens exactly
    once per process — Table I, Table III and the fleet replays all read
    the same `ProfileResult`.
    """
    if key not in _JOB_MEMO:
        job = cluster_fleet([key], sims={key: get_sim(key)})[0]
        job.profile_result = profile_job(job.profile_run, job.full_input_size)
        _JOB_MEMO[key] = job
    return _JOB_MEMO[key]


def job_profile(key: str):
    """The memoized `ProfileResult` for one paper workload."""
    return fleet_job(key).profile_result


_TRACE_MEMO: Dict = {}


def search_traces(
    key: str,
    reps: int = DEFAULT_REPS,
    max_iters: Optional[int] = None,
) -> Tuple[List, List, object]:
    """Run Ruya + CherryPick ``reps`` times (to exhaustion) on one job.

    Returns (ruya_traces, cherrypick_traces, profile_result).  The profile
    comes from the shared `fleet_job` pool — computed once and reused, the
    paper's §IV-D economics.  Memoized so Table II / Fig. 4 / Fig. 5 share
    one sweep.

    The repetitions run as a seed-fleet through the batched engine (one
    jitted call per searcher instead of ``reps`` Python-driven searches);
    traces are identical to the sequential engine's, so every downstream
    number is unchanged.
    """
    memo_key = (key, reps, max_iters)
    if memo_key in _TRACE_MEMO:
        return _TRACE_MEMO[memo_key]
    job = fleet_job(key)
    prof = job.profile_result
    settings = BOSettings(max_iters=max_iters)
    jobs, rngs = replay_seeds(job, range(reps))
    ruya_traces = [
        r.trace
        for r in tune_fleet(
            jobs, rngs, settings=settings, to_exhaustion=True
        )
    ]
    cp_traces = [
        r.trace
        for r in tune_fleet(
            jobs,
            [np.random.default_rng(s) for s in range(reps)],
            mode="cherrypick",
            settings=settings,
            to_exhaustion=True,
        )
    ]
    _TRACE_MEMO[memo_key] = (ruya_traces, cp_traces, prof)
    return _TRACE_MEMO[memo_key]


def mean_iterations_until(traces, threshold: float) -> float:
    vals = []
    for t in traces:
        it = t.iterations_until(threshold)
        vals.append(it if it is not None else len(t.tried) + 1)
    return float(np.mean(vals))


def best_cost_curve(traces, horizon: int = 69) -> np.ndarray:
    """Mean over traces of min-cost-so-far at each iteration (Fig. 4)."""
    curves = []
    for t in traces:
        costs = np.asarray(t.costs, np.float64)
        best = np.minimum.accumulate(costs)
        if len(best) < horizon:
            best = np.concatenate(
                [best, np.full(horizon - len(best), best[-1])]
            )
        curves.append(best[:horizon])
    return np.mean(curves, axis=0)
